//! Reproduce the paper's full evaluation in one command: every table and
//! figure (Table I/II, Fig. 2/3/5/6/7/8) regenerated on the GPU
//! simulator, CSVs written under `results/`.
//!
//! ```bash
//! cargo run --release --example reproduce_paper            # full sweep
//! cargo run --release --example reproduce_paper -- --quick # small sweep
//! ```

use accel_gcn::bench::paper;
use accel_gcn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &argv,
        &["out", "experiment", "seed", "node-cap", "edge-cap", "coldims", "graphs"],
        &["quick"],
    )?;
    paper::run_from_args(&args)
}
