//! Quickstart: the paper's preprocessing + kernel comparison in one
//! self-contained run — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! 1. synthesizes a Collab-like power-law graph,
//! 2. runs degree sorting + block-level partitioning (Algorithms 1–2),
//! 3. executes the partitioned SpMM schedule exactly and checks it
//!    against the dense reference,
//! 4. simulates all four GPU kernels and prints the Fig. 5-style
//!    comparison for one column dimension.

use accel_gcn::graph::datasets::{by_name, materialize, ScalePolicy};
use accel_gcn::graph::degree::DegreeSorted;
use accel_gcn::partition::block_level::BlockPartition;
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::sim::kernels::{CostModel, PreparedGraph};
use accel_gcn::sim::{simulate_kernel, GpuConfig, KernelKind, KernelOptions};
use accel_gcn::spmm::{allclose, spmm_block_level};
use accel_gcn::util::bench::Table;
use accel_gcn::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // 1. a scaled-down Collab (Table I spec, power-law family)
    let spec = by_name("collab").expect("collab is in Table I");
    let policy = ScalePolicy { node_cap: 20_000, edge_cap: 200_000 };
    let csr = materialize(spec, policy, 42);
    println!(
        "graph `collab` (scaled {:.3}): {} nodes, {} edges, max degree {} ({:.1}x avg)",
        policy.factor(spec),
        csr.n_rows,
        csr.nnz(),
        csr.max_degree(),
        csr.max_degree() as f64 / csr.avg_degree()
    );

    // 2. the paper's preprocessing
    let params = PartitionParams::default(); // 12 warps/block, 32 nzs/warp
    let sorted = DegreeSorted::new(&csr);
    let bp = BlockPartition::build(&sorted.csr, params);
    println!(
        "block-level partition: {} blocks, {} warp tasks, {} split rows, metadata ratio {:.1}%",
        bp.n_blocks(),
        bp.n_warp_tasks(),
        bp.n_split_rows,
        bp.footprint().ratio() * 100.0
    );

    // 3. execute the schedule exactly and verify numerics
    let f = 16;
    let mut rng = Pcg::seed_from(7);
    let x: Vec<f32> = (0..csr.n_rows * f).map(|_| rng.f32() - 0.5).collect();
    let got = spmm_block_level(&sorted.csr, &bp, &x, f);
    let want = sorted.csr.spmm_dense(&x, f);
    assert!(allclose(&got, &want, 1e-3, 1e-3), "schedule numerics mismatch");
    println!("block-level schedule == dense reference ✓");

    let layout = BellLayout::build(&sorted.csr, &bp);
    println!(
        "BELL export: {} buckets, padding overhead {:.2}x",
        layout.buckets.len(),
        layout.padding_overhead()
    );

    // 4. simulated kernel comparison (Fig. 5 style)
    let gpu = GpuConfig::rtx3090();
    let cost = CostModel::default();
    let g = PreparedGraph::new(csr, params);
    let mut table = Table::new(&["kernel", "sim time (µs)", "speedup vs cuSPARSE"]);
    let mut times = Vec::new();
    for kind in KernelKind::all() {
        let opts = KernelOptions { combined_warp: kind != KernelKind::GnnAdvisor };
        let r = simulate_kernel(&gpu, &cost, kind, opts, &g, 64);
        times.push((kind.name(), r.micros));
    }
    let cusparse = times.iter().find(|(n, _)| *n == "cusparse").unwrap().1;
    for (name, us) in &times {
        table.row(vec![name.to_string(), format!("{us:.1}"), format!("{:.2}x", cusparse / us)]);
    }
    print!("{}", table.render());
    println!("next: `accel-gcn prepare` + `make artifacts` + examples/train_gcn for the full stack");
    Ok(())
}
