//! Quickstart: the paper's preprocessing + kernel comparison in one
//! self-contained run — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! 1. synthesizes a Collab-like power-law graph,
//! 2. builds its `SpmmPlan` (degree sorting + block-level partitioning,
//!    Algorithms 1–2) through the pipeline layer,
//! 3. executes the partitioned SpMM schedule exactly — sequentially and
//!    sharded across the thread pool — and checks both against the
//!    dense reference,
//! 4. simulates all four GPU kernels and prints the Fig. 5-style
//!    comparison for one column dimension.

use accel_gcn::graph::datasets::{by_name, materialize, ScalePolicy};
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::pipeline::{
    BlockLevel, CsrReference, Executor, ParallelBlockLevel, PlanCache,
};
use accel_gcn::sim::kernels::CostModel;
use accel_gcn::sim::{simulate_kernel, GpuConfig, KernelKind, KernelOptions};
use accel_gcn::spmm::allclose;
use accel_gcn::util::bench::Table;
use accel_gcn::util::rng::Pcg;
use accel_gcn::util::threadpool::default_parallelism;

fn main() -> anyhow::Result<()> {
    // 1. a scaled-down Collab (Table I spec, power-law family)
    let spec = by_name("collab").expect("collab is in Table I");
    let policy = ScalePolicy { node_cap: 20_000, edge_cap: 200_000 };
    let csr = materialize(spec, policy, 42);
    println!(
        "graph `collab` (scaled {:.3}): {} nodes, {} edges, max degree {} ({:.1}x avg)",
        policy.factor(spec),
        csr.n_rows,
        csr.nnz(),
        csr.max_degree(),
        csr.max_degree() as f64 / csr.avg_degree()
    );

    // 2. the paper's preprocessing, via the plan cache (a second request
    // for the same graph would skip this work entirely)
    let params = PartitionParams::default(); // 12 warps/block, 32 nzs/warp
    let plan = PlanCache::global().plan_for(&csr, params);
    println!(
        "block-level partition: {} blocks, {} warp tasks, {} split rows, metadata ratio {:.1}%",
        plan.block.n_blocks(),
        plan.block.n_warp_tasks(),
        plan.block.n_split_rows,
        plan.block.footprint().ratio() * 100.0
    );

    // 3. execute the schedule exactly and verify numerics — sequential
    // and parallel produce the dense reference up to f32 reordering
    let f = 16;
    let mut rng = Pcg::seed_from(7);
    let x: Vec<f32> = (0..csr.n_rows * f).map(|_| rng.f32() - 0.5).collect();
    let want = CsrReference.execute(&plan, &x, f);
    let got = BlockLevel.execute(&plan, &x, f);
    assert!(allclose(&got, &want, 1e-3, 1e-3), "schedule numerics mismatch");
    let threads = default_parallelism();
    let got_par = ParallelBlockLevel::new(threads).execute(&plan, &x, f);
    assert!(allclose(&got_par, &want, 1e-3, 1e-3), "parallel schedule mismatch");
    println!("block-level schedule == dense reference ✓ (sequential and {threads}-thread)");

    let layout = BellLayout::build(&plan.sorted.csr, &plan.block);
    println!(
        "BELL export: {} buckets, padding overhead {:.2}x",
        layout.buckets.len(),
        layout.padding_overhead()
    );

    // 4. simulated kernel comparison (Fig. 5 style) over the same plan
    let gpu = GpuConfig::rtx3090();
    let cost = CostModel::default();
    let mut table = Table::new(&["kernel", "sim time (µs)", "speedup vs cuSPARSE"]);
    let mut times = Vec::new();
    for kind in KernelKind::all() {
        let opts = KernelOptions { combined_warp: kind != KernelKind::GnnAdvisor };
        let r = simulate_kernel(&gpu, &cost, kind, opts, &plan, 64);
        times.push((kind.name(), r.micros));
    }
    let cusparse = times.iter().find(|(n, _)| *n == "cusparse").unwrap().1;
    for (name, us) in &times {
        table.row(vec![name.to_string(), format!("{us:.1}"), format!("{:.2}x", cusparse / us)]);
    }
    print!("{}", table.render());
    println!("next: `accel-gcn prepare` + python -m compile.aot + examples/train_gcn for the full stack");
    Ok(())
}
