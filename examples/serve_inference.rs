//! Serving example: batched SpMM inference requests through the
//! coordinator — router picks the artifact, the column batcher fuses
//! requests (Â·[X₁ X₂] = [Â·X₁ Â·X₂]), the device thread executes, and
//! every response is verified against the exact CPU executor.
//!
//! Requires artifacts: `make artifacts`.
//!
//! ```bash
//! cargo run --release --example serve_inference -- [artifacts/quickstart] [n_requests]
//! ```

use accel_gcn::bench::serve::run_serving;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dir = args.first().map(|s| s.as_str()).unwrap_or("artifacts/quickstart");
    let n: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(64);

    let report = run_serving(dir, n, &[16, 32, 64], 1)?;
    anyhow::ensure!(report.verified);
    println!(
        "\nSERVING OK: {} requests in {} batches, {:.1} req/s",
        report.requests, report.batches, report.requests_per_sec
    );
    Ok(())
}
