//! End-to-end driver (DESIGN.md §5 E2E): trains a 2-layer GCN on a
//! synthetic citation-style graph for a few hundred steps, with the
//! whole train step — Pallas SpMM kernel, forward, backward, SGD — AOT
//! compiled and looped from Rust over PJRT. Logs the loss curve.
//!
//! Requires artifacts: `make artifacts` (or see README quickstart).
//!
//! ```bash
//! cargo run --release --example train_gcn -- [artifacts/quickstart] [steps]
//! ```

use accel_gcn::bench::train::run_training;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dir = args.first().map(|s| s.as_str()).unwrap_or("artifacts/quickstart");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);

    let report = run_training(dir, steps, 20)?;

    // render the loss curve as ASCII for EXPERIMENTS.md
    println!("\nloss curve (each row = {} steps):", (report.losses.len() / 24).max(1));
    let max = report.losses.iter().cloned().fold(f32::MIN, f32::max);
    let stride = (report.losses.len() / 24).max(1);
    for (i, chunk) in report.losses.chunks(stride).enumerate() {
        let avg = chunk.iter().sum::<f32>() / chunk.len() as f32;
        let bar = ((avg / max) * 50.0) as usize;
        println!("step {:>5} {:>8.4} |{}", i * stride, avg, "#".repeat(bar));
    }
    anyhow::ensure!(
        report.losses.last().unwrap() < report.losses.first().unwrap(),
        "training did not reduce the loss"
    );
    println!(
        "\nE2E OK: loss {:.4} -> {:.4}, accuracy {:.1}%, {:.1} steps/s",
        report.losses.first().unwrap(),
        report.losses.last().unwrap(),
        report.final_accuracy * 100.0,
        report.steps_per_sec
    );
    Ok(())
}
