"""BELL layout construction — Python mirror of `rust/src/partition/`.

Implements the paper's preprocessing (degree sorting, Algorithm 1
partition patterns, Algorithm 2 block-level partitioning) and the BELL
bucket export, independently of the Rust implementation. The two are
kept honest by shared invariants (pytest here, proptest there) and by an
integration test that replays Rust-exported layouts.

Build-time only: never imported on the request path.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

ROW_TILE = 8  # must match partition::bucket::ROW_TILE


@dataclasses.dataclass(frozen=True)
class PartitionParams:
    max_block_warps: int = 12
    max_warp_nzs: int = 32

    @property
    def deg_bound(self) -> int:
        return self.max_block_warps * self.max_warp_nzs


@dataclasses.dataclass
class Csr:
    """Minimal CSR container (float32 values)."""

    n_rows: int
    n_cols: int
    row_ptr: np.ndarray  # int64 [n_rows+1]
    col_idx: np.ndarray  # int32 [nnz]
    vals: np.ndarray  # float32 [nnz]

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    def degree(self, r: int) -> int:
        return int(self.row_ptr[r + 1] - self.row_ptr[r])

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    @staticmethod
    def from_dense(a: np.ndarray) -> "Csr":
        n_rows, n_cols = a.shape
        rows, cols = np.nonzero(a)
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        row_ptr = np.zeros(n_rows + 1, dtype=np.int64)
        np.add.at(row_ptr, rows + 1, 1)
        row_ptr = np.cumsum(row_ptr)
        return Csr(
            n_rows,
            n_cols,
            row_ptr,
            cols.astype(np.int32),
            a[rows, cols].astype(np.float32),
        )

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=np.float32)
        for r in range(self.n_rows):
            s, e = self.row_ptr[r], self.row_ptr[r + 1]
            np.add.at(out[r], self.col_idx[s:e], self.vals[s:e])
        return out

    @staticmethod
    def random(rng: np.random.Generator, n: int, avg_deg: float, heavy: bool = False) -> "Csr":
        """Random test graph; `heavy=True` plants a hub row beyond any
        reasonable deg_bound to exercise the split path."""
        degs = rng.poisson(avg_deg, size=n)
        if heavy and n > 1:
            degs[int(rng.integers(0, n))] += int(10 * avg_deg * math.sqrt(n))
        degs = np.minimum(degs, n)
        row_ptr = np.zeros(n + 1, dtype=np.int64)
        row_ptr[1:] = np.cumsum(degs)
        cols = []
        for d in degs:
            cols.append(np.sort(rng.choice(n, size=d, replace=False)).astype(np.int32))
        col_idx = np.concatenate(cols) if cols else np.zeros(0, dtype=np.int32)
        vals = rng.standard_normal(int(row_ptr[-1])).astype(np.float32)
        return Csr(n, n, row_ptr, col_idx, vals)


def degree_sort(csr: Csr) -> tuple[Csr, np.ndarray, np.ndarray]:
    """Stable ascending degree sort (paper §III-C step 1-3).

    Returns (sorted_csr, perm, inv) with perm[i] = original row of sorted
    row i.
    """
    degs = csr.degrees()
    perm = np.argsort(degs, kind="stable").astype(np.int32)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(csr.n_rows, dtype=np.int32)
    # rebuild row_ptr / payload in sorted order
    new_degs = degs[perm]
    row_ptr = np.zeros(csr.n_rows + 1, dtype=np.int64)
    row_ptr[1:] = np.cumsum(new_degs)
    col_idx = np.empty(csr.nnz, dtype=np.int32)
    vals = np.empty(csr.nnz, dtype=np.float32)
    for i, orig in enumerate(perm):
        s, e = csr.row_ptr[orig], csr.row_ptr[orig + 1]
        col_idx[row_ptr[i] : row_ptr[i] + (e - s)] = csr.col_idx[s:e]
        vals[row_ptr[i] : row_ptr[i] + (e - s)] = csr.vals[s:e]
    return Csr(csr.n_rows, csr.n_cols, row_ptr, col_idx, vals), perm, inv


def relabel(csr: Csr, perm: np.ndarray, inv: np.ndarray) -> Csr:
    """Symmetric relabeling P·A·Pᵀ (rows permuted, columns mapped)."""
    assert csr.n_rows == csr.n_cols
    sorted_csr, _, _ = _permute_rows(csr, perm)
    out_cols = inv[sorted_csr.col_idx].astype(np.int32)
    # re-sort each row by the new column ids
    col_idx = out_cols.copy()
    vals = sorted_csr.vals.copy()
    for r in range(csr.n_rows):
        s, e = sorted_csr.row_ptr[r], sorted_csr.row_ptr[r + 1]
        order = np.argsort(col_idx[s:e], kind="stable")
        col_idx[s:e] = col_idx[s:e][order]
        vals[s:e] = vals[s:e][order]
    return Csr(csr.n_rows, csr.n_cols, sorted_csr.row_ptr, col_idx, vals)


def _permute_rows(csr: Csr, perm: np.ndarray) -> tuple[Csr, None, None]:
    degs = csr.degrees()[perm]
    row_ptr = np.zeros(csr.n_rows + 1, dtype=np.int64)
    row_ptr[1:] = np.cumsum(degs)
    col_idx = np.empty(csr.nnz, dtype=np.int32)
    vals = np.empty(csr.nnz, dtype=np.float32)
    for i, orig in enumerate(perm):
        s, e = csr.row_ptr[orig], csr.row_ptr[orig + 1]
        col_idx[row_ptr[i] : row_ptr[i] + (e - s)] = csr.col_idx[s:e]
        vals[row_ptr[i] : row_ptr[i] + (e - s)] = csr.vals[s:e]
    return Csr(csr.n_rows, csr.n_cols, row_ptr, col_idx, vals), None, None


def pattern_table(params: PartitionParams) -> list[tuple[int, int, int]]:
    """Algorithm 1: for deg in 1..=deg_bound returns
    (block_rows, warp_nzs, warps_per_row) at index deg-1."""
    factors = [f for f in range(1, params.max_block_warps + 1) if params.max_block_warps % f == 0]
    table: list[tuple[int, int, int]] = []
    i, deg = 0, 1
    while deg <= params.deg_bound:
        if factors[i] * params.max_warp_nzs >= deg:
            f = factors[i]
            table.append((params.max_block_warps // f, math.ceil(deg / f), f))
            deg += 1
        else:
            i += 1
    return table


@dataclasses.dataclass
class WarpTask:
    sorted_row: int
    nz_start: int
    nz_len: int
    is_split: bool


def block_partition(sorted_csr: Csr, params: PartitionParams) -> list[WarpTask]:
    """Algorithm 2, directly emitting warp tasks (the Rust version emits
    int4 metadata and derives tasks; the task stream is identical)."""
    table = pattern_table(params)
    bound = params.deg_bound
    tasks: list[WarpTask] = []
    n = sorted_csr.n_rows
    r = 0
    while r < n:
        deg = sorted_csr.degree(r)
        if deg == 0:
            r += 1
            continue
        if deg <= bound:
            _, warp_nzs, _ = table[deg - 1]
            warps_per_row = math.ceil(deg / warp_nzs)
            start = int(sorted_csr.row_ptr[r])
            for k in range(warps_per_row):
                s = k * warp_nzs
                tasks.append(WarpTask(r, start + s, min(deg - s, warp_nzs), False))
            r += 1
        else:
            start = int(sorted_csr.row_ptr[r])
            off = 0
            while off < deg:
                chunk = min(deg - off, bound)
                # chunks are further divided into max_warp_nzs warps
                s = 0
                while s < chunk:
                    tasks.append(
                        WarpTask(r, start + off + s, min(chunk - s, params.max_warp_nzs), True)
                    )
                    s += params.max_warp_nzs
                off += chunk
            r += 1
    return tasks


@dataclasses.dataclass
class BellBucket:
    width: int
    rows: int
    padded_rows: int
    cols: np.ndarray  # int32 [padded_rows, width]
    vals: np.ndarray  # float32 [padded_rows, width]
    out_row: np.ndarray  # int32 [padded_rows]


@dataclasses.dataclass
class BellLayout:
    n_rows: int
    n_cols: int
    nnz: int
    buckets: list[BellBucket]

    def padded_nnz(self) -> int:
        return sum(b.padded_rows * b.width for b in self.buckets)

    def spec(self) -> dict:
        return {
            "n_rows": self.n_rows,
            "n_cols": self.n_cols,
            "nnz": self.nnz,
            "row_tile": ROW_TILE,
            "buckets": [
                {"width": b.width, "rows": b.rows, "padded_rows": b.padded_rows}
                for b in self.buckets
            ],
        }


def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def bell_layout(sorted_csr: Csr, params: PartitionParams) -> BellLayout:
    """Group warp tasks into uniform-width buckets (pow2 widths)."""
    tasks = block_partition(sorted_csr, params)
    groups: dict[int, list[WarpTask]] = {}
    for t in tasks:
        groups.setdefault(next_pow2(max(t.nz_len, 1)), []).append(t)
    buckets = []
    for width in sorted(groups):
        ts = groups[width]
        rows = len(ts)
        padded = -(-rows // ROW_TILE) * ROW_TILE
        cols = np.zeros((padded, width), dtype=np.int32)
        vals = np.zeros((padded, width), dtype=np.float32)
        out_row = np.zeros(padded, dtype=np.int32)
        for i, t in enumerate(ts):
            out_row[i] = t.sorted_row
            cols[i, : t.nz_len] = sorted_csr.col_idx[t.nz_start : t.nz_start + t.nz_len]
            vals[i, : t.nz_len] = sorted_csr.vals[t.nz_start : t.nz_start + t.nz_len]
        buckets.append(BellBucket(width, rows, padded, cols, vals, out_row))
    return BellLayout(sorted_csr.n_rows, sorted_csr.n_cols, sorted_csr.nnz, buckets)


def prepare(csr: Csr, params: PartitionParams | None = None) -> tuple[BellLayout, np.ndarray, np.ndarray]:
    """Full preprocessing pipeline on a square adjacency matrix:
    degree-sort + symmetric relabel + block partition + BELL export.
    Returns (layout, perm, inv); the layout's row AND column space are in
    the sorted domain (feed P·X, get P·Y)."""
    params = params or PartitionParams()
    _, perm, inv = degree_sort(csr)
    rel = relabel(csr, perm, inv)
    return bell_layout(rel, params), perm, inv
