"""Layer-2: GCN-family models in JAX, calling the Layer-1 BELL SpMM
kernel for feature aggregation (build-time only; AOT-lowered by aot.py).

The paper's target workload is the GCNConv layer (Fig. 1):
    linear transform    Y = X W
    feature aggregation X' = sigma(A_hat Y)
with the aggregation executed as SpMM over the block-partitioned layout.
GraphSAGE and GIN variants (paper SS II-A) share the same aggregation
kernel with different combine functions.

All graph tensors live in the degree-sorted, symmetrically-relabeled
domain (see layout.prepare): feed P.X, read P.logits.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import spmm_bell


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of a GCN-family node classifier."""

    arch: str = "gcn"  # gcn | sage | gin
    in_dim: int = 64
    hidden_dim: int = 64
    out_dim: int = 8
    n_layers: int = 2
    interpret: bool = True  # Pallas interpret mode (CPU PJRT)

    def layer_dims(self) -> list:
        dims = [self.in_dim] + [self.hidden_dim] * (self.n_layers - 1) + [self.out_dim]
        return list(zip(dims[:-1], dims[1:]))


def init_params(seed: int, cfg: ModelConfig) -> list:
    """Flat parameter list (fixed order for the AOT manifest).

    gcn:  per layer [W, b]
    sage: per layer [W_self, W_neigh, b]
    gin:  per layer [W1, b1, W2, b2] (2-layer MLP), eps fixed to 0
    """
    rng = np.random.default_rng(seed)
    params = []

    def glorot(fan_in, fan_out):
        scale = np.sqrt(6.0 / (fan_in + fan_out))
        return jnp.asarray(
            rng.uniform(-scale, scale, size=(fan_in, fan_out)).astype(np.float32)
        )

    for d_in, d_out in cfg.layer_dims():
        if cfg.arch == "gcn":
            params += [glorot(d_in, d_out), jnp.zeros((d_out,), jnp.float32)]
        elif cfg.arch == "sage":
            params += [
                glorot(d_in, d_out),
                glorot(d_in, d_out),
                jnp.zeros((d_out,), jnp.float32),
            ]
        elif cfg.arch == "gin":
            params += [
                glorot(d_in, d_out),
                jnp.zeros((d_out,), jnp.float32),
                glorot(d_out, d_out),
                jnp.zeros((d_out,), jnp.float32),
            ]
        else:
            raise ValueError(f"unknown arch {cfg.arch}")
    return params


def params_per_layer(arch: str) -> int:
    return {"gcn": 2, "sage": 3, "gin": 4}[arch]


def aggregate(buckets, h, n_rows, *, interpret=True):
    """A_hat . h via the Layer-1 kernel (the paper's SpMM)."""
    return spmm_bell.bell_spmm(buckets, h, n_rows, interpret=interpret)


def forward(params, buckets, x, cfg: ModelConfig):
    """Logits for every node. `buckets` is the BELL triple list."""
    n_rows = x.shape[0]
    ppl = params_per_layer(cfg.arch)
    h = x
    n_layers = cfg.n_layers
    for layer in range(n_layers):
        p = params[layer * ppl : (layer + 1) * ppl]
        if cfg.arch == "gcn":
            w, b = p
            h = aggregate(buckets, h @ w, n_rows, interpret=cfg.interpret) + b
        elif cfg.arch == "sage":
            w_self, w_neigh, b = p
            agg = aggregate(buckets, h, n_rows, interpret=cfg.interpret)
            h = h @ w_self + agg @ w_neigh + b
        elif cfg.arch == "gin":
            w1, b1, w2, b2 = p
            agg = aggregate(buckets, h, n_rows, interpret=cfg.interpret)
            h = jax.nn.relu((h + agg) @ w1 + b1) @ w2 + b2
        if layer + 1 < n_layers:
            h = jax.nn.relu(h)
    return h


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy over all nodes."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def loss_fn(params, buckets, x, labels, cfg: ModelConfig):
    return cross_entropy(forward(params, buckets, x, cfg), labels)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def make_train_step(cfg: ModelConfig, lr: float):
    """SGD train step closure: (params, buckets, x, labels) ->
    (new_params, loss). Lowered once by aot.py; loops in Rust."""

    def step(params, buckets, x, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, buckets, x, labels, cfg)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return new_params, loss

    return step


def make_forward(cfg: ModelConfig):
    def fwd(params, buckets, x):
        return forward(params, buckets, x, cfg)

    return fwd
