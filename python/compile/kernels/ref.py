"""Pure-jnp oracles for the SpMM kernels.

Numerically defines what the Pallas kernel + scatter-add must compute.
Everything here is straight-line jnp/numpy with no Pallas and no custom
layouts — the simplest possible implementation, used only by pytest.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmm_dense_ref(csr, x: np.ndarray) -> np.ndarray:
    """Dense reference: A·X via materialized dense A (float64 accumulate)."""
    return (csr.to_dense().astype(np.float64) @ x.astype(np.float64)).astype(np.float32)


def bucket_partial_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """One bucket's partial sums: gather + weighted reduce.

    cols/vals: [rows, width]; x: [n_cols, f] -> [rows, f].
    """
    gathered = x[cols]  # [rows, width, f]
    return jnp.einsum("rw,rwf->rf", vals, gathered)


def bell_spmm_ref(layout, x) -> jnp.ndarray:
    """Full BELL aggregation: per-bucket partials scatter-added by
    destination row. The output is in the layout's (sorted) row domain."""
    y = jnp.zeros((layout.n_rows, x.shape[1]), dtype=jnp.float32)
    for b in layout.buckets:
        part = bucket_partial_ref(jnp.asarray(b.cols), jnp.asarray(b.vals), jnp.asarray(x))
        y = y.at[jnp.asarray(b.out_row)].add(part)
    return y
