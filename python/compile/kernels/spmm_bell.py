"""Layer-1 Pallas kernel: BELL-bucket SpMM partials.

TPU adaptation of the Accel-GCN kernel (DESIGN.md §Hardware-Adaptation):

* The **combined warp** becomes the lane dimension: the feature axis is
  tiled into `FEAT_TILE`-wide BlockSpec blocks, so within a grid step the
  lanes covering the columns of the dense matrix are contiguous by
  construction — the coalescing property the paper engineers with
  thread-id arithmetic falls out of the layout.
* The **block-level partition** becomes the uniform bucket width: every
  `[ROW_TILE, width]` tile is a dense gather + multiply with no per-row
  branching, the TPU analogue of equal `warp_nzs` within a block.
* **Shared-memory accumulation** becomes the VMEM output block: partial
  sums for a row tile live in VMEM across the inner loop; split-row /
  cross-bucket accumulation (the paper's global atomics) is the
  scatter-add performed by the caller (`model.aggregate`).

The kernel is lowered with `interpret=True`: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
runs anywhere (see /opt/xla-example/README.md). VMEM sizing estimates
for a real TPU are recorded in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row tile must match partition::bucket::ROW_TILE (rust) / layout.ROW_TILE.
ROW_TILE = 8
# Lane tile for the feature (column) dimension — one TPU vreg row of
# 128 lanes, the combined-warp analogue.
FEAT_TILE = 128


def _bucket_kernel(cols_ref, vals_ref, x_ref, o_ref):
    """One grid step: partial sums for a [ROW_TILE, width] task tile over
    a FEAT_TILE-wide slice of X.

    cols_ref: [ROW_TILE, width] int32 — X rows to gather (pad: 0)
    vals_ref: [ROW_TILE, width] f32   — edge weights       (pad: 0.0)
    x_ref:    [n_cols, FT] f32        — dense feature slice
    o_ref:    [ROW_TILE, FT] f32      — partial output tile
    """
    cols = cols_ref[...]
    vals = vals_ref[...]
    x = x_ref[...]
    # gather: [ROW_TILE, width, FT]; zero-width padding contributes 0
    gathered = x[cols]
    o_ref[...] = jax.lax.dot_general(
        vals[:, None, :],
        gathered,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]


def _grad_vals_kernel(cols_ref, g_ref, x_ref, o_ref):
    """Backward kernel wrt edge values:
    dvals[r, w] = Σ_f g[r, f] · X[cols[r, w], f] (per feature tile;
    tiles are summed by the caller's output accumulation)."""
    # zero the accumulator on the first feature tile's visit
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cols = cols_ref[...]
    g = g_ref[...]
    x = x_ref[...]
    gathered = x[cols]  # [ROW_TILE, width, FT]
    o_ref[...] += jnp.einsum("rf,rwf->rw", g, gathered)


def _feat_tile(f: int) -> int:
    """Feature-axis tile: FEAT_TILE when it divides f, else f whole."""
    return FEAT_TILE if f % FEAT_TILE == 0 else f


def _bucket_partial_impl(cols, vals, x, interpret: bool):
    rows, width = cols.shape
    n_cols, f = x.shape
    assert rows % ROW_TILE == 0, f"bucket rows {rows} not a multiple of {ROW_TILE}"
    ft = _feat_tile(f)
    grid = (rows // ROW_TILE, f // ft)
    return pl.pallas_call(
        _bucket_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, width), lambda r, c: (r, 0)),
            pl.BlockSpec((ROW_TILE, width), lambda r, c: (r, 0)),
            pl.BlockSpec((n_cols, ft), lambda r, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, ft), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((rows, f), jnp.float32),
        interpret=interpret,
    )(cols, vals, x)


def _grad_vals_impl(cols, g, x, interpret: bool):
    rows, width = cols.shape
    n_cols, f = x.shape
    ft = _feat_tile(f)
    grid = (f // ft, rows // ROW_TILE)  # feature tiles outermost: the
    # output block revisits accumulate across them (VMEM accumulator)
    return pl.pallas_call(
        _grad_vals_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, width), lambda c, r: (r, 0)),
            pl.BlockSpec((ROW_TILE, ft), lambda c, r: (r, c)),
            pl.BlockSpec((n_cols, ft), lambda c, r: (0, c)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, width), lambda c, r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), jnp.float32),
        interpret=interpret,
    )(cols, g, x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _bucket_partial(cols, vals, x, interpret):
    return _bucket_partial_impl(cols, vals, x, interpret)


def _bucket_partial_fwd(cols, vals, x, interpret):
    return _bucket_partial_impl(cols, vals, x, interpret), (cols, vals, x)


def _bucket_partial_bwd(interpret, res, g):
    cols, vals, x = res
    # dL/dvals via the backward Pallas kernel
    dvals = _grad_vals_impl(cols, g, x, interpret)
    # dL/dX: scatter-add — the transpose of the gather, the same global
    # accumulation pattern as the forward's atomics
    dx = jnp.zeros_like(x).at[cols].add(vals[:, :, None] * g[:, None, :])
    return (None, dvals, dx)


_bucket_partial.defvjp(_bucket_partial_fwd, _bucket_partial_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_partial(cols, vals, x, *, interpret: bool = True):
    """Partial sums for one bucket: [rows, width] tasks × [n_cols, f] X
    → [rows, f]. `rows` must be a multiple of ROW_TILE. Differentiable
    wrt `vals` and `x` (custom VJP over the Pallas kernels)."""
    return _bucket_partial(cols, vals, x, interpret)


def bell_spmm(bucket_arrays, x, n_rows: int, *, interpret: bool = True):
    """Full aggregation `Y = Â·X` over a BELL layout.

    bucket_arrays: sequence of (cols, vals, out_row) triples;
    x: [n_cols, f]; returns [n_rows, f] in the sorted row domain.
    The scatter-add is the paper's global/shared atomic accumulation;
    out_row ids are sorted within a bucket, which XLA's scatter handles
    efficiently.
    """
    f = x.shape[1]
    y = jnp.zeros((n_rows, f), dtype=jnp.float32)
    for cols, vals, out_row in bucket_arrays:
        part = bucket_partial(cols, vals, x, interpret=interpret)
        y = y.at[out_row].add(part)
    return y


def vmem_estimate_bytes(width: int, n_cols: int, f: int) -> dict:
    """Static VMEM footprint estimate per grid step for DESIGN.md §Perf —
    interpret-mode timings are meaningless for TPU, so kernel structure
    is evaluated by footprint: the X slice dominates and motivates
    feature tiling; cols/vals/out tiles are tiny."""
    ft = _feat_tile(f)
    return {
        "cols": ROW_TILE * width * 4,
        "vals": ROW_TILE * width * 4,
        "x_slice": n_cols * ft * 4,
        "out": ROW_TILE * ft * 4,
        "total": (ROW_TILE * width * 8) + (n_cols * ft * 4) + (ROW_TILE * ft * 4),
    }
