"""Layer-1 Pallas kernels + pure-jnp oracles (build-time only)."""
