"""AOT pipeline: lower the L2/L1 computations to HLO **text** for the
Rust PJRT runtime.

Interchange is HLO text, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (the version
the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Inputs: a `bell_spec.json` produced by `accel-gcn prepare` (shapes of
the partitioned graph). Outputs, under --out:

* `spmm_f{N}.hlo.txt`    — aggregation-only SpMM for column dim N
* `{arch}_fwd.hlo.txt`   — full model forward (logits)
* `{arch}_train.hlo.txt` — one SGD train step (params..., loss)
* `params_{i}.npy`       — initial parameters
* `manifest.json`        — flat input/output order, shapes, dtypes

Python runs once at build time; the Rust binary is self-contained
afterwards. Usage:
    python -m compile.aot --spec ../artifacts/quickstart/bell_spec.json \
        --out ../artifacts/quickstart
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import spmm_bell


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d) -> str:
    return {"float32": "f32", "int32": "i32", "int64": "i64"}[np.dtype(d).name]


class SpecShapes:
    """Shapes derived from bell_spec.json."""

    def __init__(self, spec: dict):
        self.n_rows = int(spec["n_rows"])
        self.n_cols = int(spec["n_cols"])
        self.buckets = [
            (int(b["width"]), int(b["padded_rows"])) for b in spec["buckets"]
        ]

    def bucket_arg_specs(self):
        """Flat (cols, vals, rows) ShapeDtypeStructs per bucket, plus
        manifest entries."""
        specs, entries = [], []
        for width, rows in self.buckets:
            specs += [
                jax.ShapeDtypeStruct((rows, width), jnp.int32),
                jax.ShapeDtypeStruct((rows, width), jnp.float32),
                jax.ShapeDtypeStruct((rows,), jnp.int32),
            ]
            entries += [
                {"name": f"bell_w{width}_cols", "shape": [rows, width], "dtype": "i32"},
                {"name": f"bell_w{width}_vals", "shape": [rows, width], "dtype": "f32"},
                {"name": f"bell_w{width}_rows", "shape": [rows], "dtype": "i32"},
            ]
        return specs, entries

    def group_buckets(self, flat):
        """Regroup a flat argument list into (cols, vals, rows) triples."""
        return [tuple(flat[i * 3 : i * 3 + 3]) for i in range(len(self.buckets))]


def lower_spmm(shapes: SpecShapes, coldim: int):
    """Aggregation-only artifact: Y = Â·X for one column dimension."""

    def spmm_flat(*args):
        buckets = shapes.group_buckets(args[:-1])
        x = args[-1]
        return (spmm_bell.bell_spmm(buckets, x, shapes.n_rows, interpret=True),)

    bspecs, bentries = shapes.bucket_arg_specs()
    xspec = jax.ShapeDtypeStruct((shapes.n_cols, coldim), jnp.float32)
    lowered = jax.jit(spmm_flat).lower(*bspecs, xspec)
    inputs = bentries + [{"name": "x", "shape": [shapes.n_cols, coldim], "dtype": "f32"}]
    outputs = [{"name": "y", "shape": [shapes.n_rows, coldim], "dtype": "f32"}]
    return to_hlo_text(lowered), inputs, outputs


def lower_forward(shapes: SpecShapes, cfg: M.ModelConfig, params):
    def fwd_flat(*args):
        n_p = len(params)
        p = list(args[:n_p])
        buckets = shapes.group_buckets(args[n_p:-1])
        x = args[-1]
        return (M.forward(p, buckets, x, cfg),)

    pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    pentries = [
        {"name": f"param_{i}", "shape": list(p.shape), "dtype": _dtype_name(p.dtype)}
        for i, p in enumerate(params)
    ]
    bspecs, bentries = shapes.bucket_arg_specs()
    xspec = jax.ShapeDtypeStruct((shapes.n_rows, cfg.in_dim), jnp.float32)
    lowered = jax.jit(fwd_flat).lower(*pspecs, *bspecs, xspec)
    inputs = pentries + bentries + [
        {"name": "x", "shape": [shapes.n_rows, cfg.in_dim], "dtype": "f32"}
    ]
    outputs = [{"name": "logits", "shape": [shapes.n_rows, cfg.out_dim], "dtype": "f32"}]
    return to_hlo_text(lowered), inputs, outputs


def lower_train_step(shapes: SpecShapes, cfg: M.ModelConfig, params, lr: float):
    step = M.make_train_step(cfg, lr)

    def step_flat(*args):
        n_p = len(params)
        p = list(args[:n_p])
        buckets = shapes.group_buckets(args[n_p:-2])
        x, labels = args[-2], args[-1]
        new_params, loss = step(p, buckets, x, labels)
        return (*new_params, loss)

    pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    pentries = [
        {"name": f"param_{i}", "shape": list(p.shape), "dtype": _dtype_name(p.dtype)}
        for i, p in enumerate(params)
    ]
    bspecs, bentries = shapes.bucket_arg_specs()
    xspec = jax.ShapeDtypeStruct((shapes.n_rows, cfg.in_dim), jnp.float32)
    lspec = jax.ShapeDtypeStruct((shapes.n_rows,), jnp.int32)
    lowered = jax.jit(step_flat).lower(*pspecs, *bspecs, xspec, lspec)
    inputs = pentries + bentries + [
        {"name": "x", "shape": [shapes.n_rows, cfg.in_dim], "dtype": "f32"},
        {"name": "labels", "shape": [shapes.n_rows], "dtype": "i32"},
    ]
    outputs = [
        {"name": f"param_{i}", "shape": list(p.shape), "dtype": _dtype_name(p.dtype)}
        for i, p in enumerate(params)
    ] + [{"name": "loss", "shape": [], "dtype": "f32"}]
    return to_hlo_text(lowered), inputs, outputs


def save_params(params, out: pathlib.Path):
    for i, p in enumerate(params):
        np.save(out / f"param_{i}.npy", np.asarray(p))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", required=True, help="bell_spec.json from `accel-gcn prepare`")
    ap.add_argument("--out", required=True, help="artifact output directory")
    ap.add_argument("--coldims", default="16,32,64,128", help="SpMM column dims")
    ap.add_argument("--arch", default="gcn", choices=["gcn", "sage", "gin"])
    ap.add_argument("--in-dim", type=int, default=64)
    ap.add_argument("--hidden-dim", type=int, default=64)
    ap.add_argument("--out-dim", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-model", action="store_true", help="emit only SpMM artifacts")
    args = ap.parse_args()

    spec = json.loads(pathlib.Path(args.spec).read_text())
    shapes = SpecShapes(spec)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {"n_rows": shapes.n_rows, "n_cols": shapes.n_cols, "artifacts": {}}

    for coldim in [int(c) for c in args.coldims.split(",") if c.strip()]:
        name = f"spmm_f{coldim}"
        text, inputs, outputs = lower_spmm(shapes, coldim)
        (out / f"{name}.hlo.txt").write_text(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

    if not args.skip_model:
        cfg = M.ModelConfig(
            arch=args.arch,
            in_dim=args.in_dim,
            hidden_dim=args.hidden_dim,
            out_dim=args.out_dim,
            n_layers=args.layers,
        )
        params = M.init_params(args.seed, cfg)
        save_params(params, out)
        manifest["model"] = {
            "arch": cfg.arch,
            "in_dim": cfg.in_dim,
            "hidden_dim": cfg.hidden_dim,
            "out_dim": cfg.out_dim,
            "n_layers": cfg.n_layers,
            "lr": args.lr,
            "n_params": len(params),
        }

        text, inputs, outputs = lower_forward(shapes, cfg, params)
        (out / f"{cfg.arch}_fwd.hlo.txt").write_text(text)
        manifest["artifacts"][f"{cfg.arch}_fwd"] = {
            "file": f"{cfg.arch}_fwd.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"wrote {cfg.arch}_fwd.hlo.txt ({len(text)} chars)")

        text, inputs, outputs = lower_train_step(shapes, cfg, params, args.lr)
        (out / f"{cfg.arch}_train.hlo.txt").write_text(text)
        manifest["artifacts"][f"{cfg.arch}_train"] = {
            "file": f"{cfg.arch}_train.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"wrote {cfg.arch}_train.hlo.txt ({len(text)} chars)")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
