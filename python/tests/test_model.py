"""L2 model tests: shapes, training signal, gradients for all three
architectures (GCN / GraphSAGE / GIN)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layout as L, model as M


@pytest.fixture(scope="module")
def small_graph():
    rng = np.random.default_rng(11)
    csr = L.Csr.random(rng, 40, 4.0)
    bell, perm, inv = L.prepare(csr)
    buckets = [
        (jnp.asarray(b.cols), jnp.asarray(b.vals), jnp.asarray(b.out_row))
        for b in bell.buckets
    ]
    x = jnp.asarray(rng.standard_normal((40, 12)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 4, 40))
    return buckets, x, labels


ARCHS = ["gcn", "sage", "gin"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(small_graph, arch):
    buckets, x, _ = small_graph
    cfg = M.ModelConfig(arch=arch, in_dim=12, hidden_dim=16, out_dim=4, n_layers=2)
    logits = M.forward(M.init_params(0, cfg), buckets, x, cfg)
    assert logits.shape == (40, 4)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases(small_graph, arch):
    buckets, x, labels = small_graph
    cfg = M.ModelConfig(arch=arch, in_dim=12, hidden_dim=16, out_dim=4, n_layers=2)
    step = M.make_train_step(cfg, 0.1)
    p = M.init_params(0, cfg)
    p, l0 = step(p, buckets, x, labels)
    for _ in range(25):
        p, l = step(p, buckets, x, labels)
    assert float(l) < float(l0), f"{arch}: {float(l0)} -> {float(l)}"


@pytest.mark.parametrize("n_layers", [1, 2, 3])
def test_depth_variants(small_graph, n_layers):
    buckets, x, _ = small_graph
    cfg = M.ModelConfig(arch="gcn", in_dim=12, hidden_dim=8, out_dim=4, n_layers=n_layers)
    p = M.init_params(0, cfg)
    assert len(p) == 2 * n_layers
    logits = M.forward(p, buckets, x, cfg)
    assert logits.shape == (40, 4)


def test_param_counts():
    for arch, ppl in [("gcn", 2), ("sage", 3), ("gin", 4)]:
        cfg = M.ModelConfig(arch=arch, in_dim=8, hidden_dim=8, out_dim=4, n_layers=2)
        assert len(M.init_params(0, cfg)) == ppl * 2
        assert M.params_per_layer(arch) == ppl


def test_cross_entropy_perfect_prediction():
    logits = jnp.array([[10.0, -10.0], [-10.0, 10.0]])
    labels = jnp.array([0, 1])
    assert float(M.cross_entropy(logits, labels)) < 1e-6
    assert float(M.accuracy(logits, labels)) == 1.0


def test_gradient_finite_difference(small_graph):
    # spot-check dL/dW0[0,0] against central differences through the
    # whole stack (Pallas kernel + custom VJP included)
    buckets, x, labels = small_graph
    cfg = M.ModelConfig(arch="gcn", in_dim=12, hidden_dim=8, out_dim=4, n_layers=2)
    params = M.init_params(0, cfg)

    def loss_of(w00):
        p = [params[0].at[0, 0].set(w00)] + params[1:]
        return M.loss_fn(p, buckets, x, labels, cfg)

    g = jax.grad(loss_of)(params[0][0, 0])
    eps = 1e-2
    fd = (loss_of(params[0][0, 0] + eps) - loss_of(params[0][0, 0] - eps)) / (2 * eps)
    assert abs(float(g) - float(fd)) < 5e-3, f"grad {float(g)} vs fd {float(fd)}"


def test_learns_homophilous_communities():
    # end-to-end learnability: planted communities + correlated features
    rng = np.random.default_rng(42)
    n, k, f = 120, 3, 8
    labels_np = rng.integers(0, k, n)
    dense = np.zeros((n, n), np.float32)
    for _ in range(n * 6):
        a = rng.integers(0, n)
        same = np.flatnonzero(labels_np == labels_np[a])
        b = rng.choice(same) if rng.random() < 0.85 else rng.integers(0, n)
        dense[a, b] = dense[b, a] = 1.0
    # GCN normalization
    dense += np.eye(n, dtype=np.float32)
    d = dense.sum(1)
    dinv = 1.0 / np.sqrt(d)
    a_hat = dense * dinv[:, None] * dinv[None, :]
    csr = L.Csr.from_dense(a_hat)
    bell, perm, inv = L.prepare(csr)
    buckets = [
        (jnp.asarray(b.cols), jnp.asarray(b.vals), jnp.asarray(b.out_row))
        for b in bell.buckets
    ]
    cent = rng.standard_normal((k, f)).astype(np.float32)
    x_np = cent[labels_np] + 0.6 * rng.standard_normal((n, f)).astype(np.float32)
    x = jnp.asarray(x_np[perm])
    labels = jnp.asarray(labels_np[perm].astype(np.int32))

    cfg = M.ModelConfig(arch="gcn", in_dim=f, hidden_dim=16, out_dim=k, n_layers=2)
    p = M.init_params(1, cfg)
    step = M.make_train_step(cfg, 0.3)
    for _ in range(60):
        p, loss = step(p, buckets, x, labels)
    acc = float(M.accuracy(M.forward(p, buckets, x, cfg), labels))
    assert acc > 0.85, f"accuracy {acc}"
