"""AOT pipeline tests: lowering to HLO text, manifest consistency, and
(when artifacts exist) replay of Rust-exported BELL layouts."""

import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, layout as L
from compile.kernels import ref


@pytest.fixture(scope="module")
def shapes():
    rng = np.random.default_rng(2)
    csr = L.Csr.random(rng, 32, 3.0)
    bell, _, _ = L.prepare(csr, L.PartitionParams(2, 2))
    return aot.SpecShapes(bell.spec())


def test_lower_spmm_emits_hlo(shapes):
    text, inputs, outputs = aot.lower_spmm(shapes, 16)
    assert "ENTRY" in text and "HloModule" in text
    # one (cols, vals, rows) triple per bucket + x
    assert len(inputs) == 3 * len(shapes.buckets) + 1
    assert outputs[0]["shape"] == [shapes.n_rows, 16]


def test_lower_forward_and_train(shapes):
    from compile import model as M

    cfg = M.ModelConfig(arch="gcn", in_dim=8, hidden_dim=8, out_dim=3, n_layers=2)
    params = M.init_params(0, cfg)
    fwd_text, fwd_in, fwd_out = aot.lower_forward(shapes, cfg, params)
    assert "ENTRY" in fwd_text
    assert len(fwd_in) == len(params) + 3 * len(shapes.buckets) + 1
    assert fwd_out[0]["shape"] == [shapes.n_rows, 3]

    tr_text, tr_in, tr_out = aot.lower_train_step(shapes, cfg, params, 0.05)
    assert "ENTRY" in tr_text
    # outputs: params + scalar loss
    assert len(tr_out) == len(params) + 1
    assert tr_out[-1]["shape"] == []


def test_dtype_names():
    assert aot._dtype_name(np.float32) == "f32"
    assert aot._dtype_name(np.int32) == "i32"
    assert aot._dtype_name(np.int64) == "i64"


ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "quickstart"


@pytest.mark.skipif(
    not (ARTIFACT_DIR / "bell_spec.json").exists(),
    reason="run `make artifacts` first (rust-exported layout not present)",
)
def test_rust_exported_layout_replays():
    """Cross-language check: the BELL layout exported by `accel-gcn
    prepare` must reproduce A·X for the graph it shipped with."""
    spec = json.loads((ARTIFACT_DIR / "bell_spec.json").read_text())
    # reconstruct the layout from the npy files
    buckets = []
    for b in spec["buckets"]:
        w = b["width"]
        buckets.append(
            L.BellBucket(
                width=w,
                rows=b["rows"],
                padded_rows=b["padded_rows"],
                cols=np.load(ARTIFACT_DIR / f"bell_w{w}_cols.npy"),
                vals=np.load(ARTIFACT_DIR / f"bell_w{w}_vals.npy"),
                out_row=np.load(ARTIFACT_DIR / f"bell_w{w}_rows.npy"),
            )
        )
    layout = L.BellLayout(spec["n_rows"], spec["n_cols"], spec["nnz"], buckets)
    # the graph itself ships as CSR npys (sorted/relabeled domain)
    row_ptr = np.load(ARTIFACT_DIR / "graph_row_ptr.npy")
    col_idx = np.load(ARTIFACT_DIR / "graph_col_idx.npy")
    vals = np.load(ARTIFACT_DIR / "graph_vals.npy")
    csr = L.Csr(spec["n_rows"], spec["n_cols"], row_ptr.astype(np.int64), col_idx.astype(np.int32), vals)

    rng = np.random.default_rng(0)
    x = rng.standard_normal((spec["n_cols"], 16)).astype(np.float32)
    got = np.asarray(ref.bell_spmm_ref(layout, x))
    want = ref.spmm_dense_ref(csr, x)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-4)
