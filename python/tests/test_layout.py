"""Invariant tests for the preprocessing pipeline (layout.py):
degree sorting, Algorithm 1/2, and the BELL export."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layout as L
from compile.kernels import ref

SMALL_PARAMS = L.PartitionParams(max_block_warps=2, max_warp_nzs=2)


def random_csr(seed, n, avg_deg, heavy=False):
    rng = np.random.default_rng(seed)
    return L.Csr.random(rng, n, avg_deg, heavy=heavy)


class TestDegreeSort:
    def test_ascending_and_stable(self):
        csr = random_csr(0, 50, 3.0)
        s, perm, inv = L.degree_sort(csr)
        degs = s.degrees()
        assert (np.diff(degs) >= 0).all()
        assert (inv[perm] == np.arange(50)).all()
        # stability: equal-degree rows keep original order
        for d in np.unique(degs):
            rows = perm[degs == d]
            assert (np.diff(rows) > 0).all()

    def test_permutation_preserves_rows(self):
        csr = random_csr(1, 30, 2.0)
        s, perm, _ = L.degree_sort(csr)
        for i, orig in enumerate(perm):
            a = s.col_idx[s.row_ptr[i] : s.row_ptr[i + 1]]
            b = csr.col_idx[csr.row_ptr[orig] : csr.row_ptr[orig + 1]]
            np.testing.assert_array_equal(a, b)


class TestPatternTable:
    def test_fig3_config(self):
        t = L.pattern_table(SMALL_PARAMS)
        # deg 2 -> (block_rows 2, warp_nzs 2, 1 warp/row)
        assert t[1] == (2, 2, 1)
        # deg 4 = deg_bound -> (1, 2, 2): Fig. 3's BP-2
        assert t[3] == (1, 2, 2)

    @given(
        mbw=st.sampled_from([1, 2, 3, 4, 6, 12]),
        mwn=st.sampled_from([1, 2, 4, 8, 32]),
    )
    @settings(max_examples=20, deadline=None)
    def test_invariants(self, mbw, mwn):
        p = L.PartitionParams(mbw, mwn)
        t = L.pattern_table(p)
        assert len(t) == p.deg_bound
        for deg, (block_rows, warp_nzs, wpr) in enumerate(t, start=1):
            assert wpr * warp_nzs >= deg  # coverage
            assert warp_nzs <= p.max_warp_nzs
            assert block_rows * wpr == p.max_block_warps


class TestBlockPartition:
    @given(
        seed=st.integers(0, 1000),
        n=st.integers(1, 60),
        heavy=st.booleans(),
    )
    @settings(max_examples=25, deadline=None)
    def test_tasks_cover_exactly_once(self, seed, n, heavy):
        csr = random_csr(seed, n, 3.0, heavy=heavy)
        s, _, _ = L.degree_sort(csr)
        tasks = L.block_partition(s, SMALL_PARAMS)
        covered = np.zeros(s.nnz, dtype=int)
        for t in tasks:
            assert t.nz_len >= 1
            assert s.row_ptr[t.sorted_row] <= t.nz_start
            assert t.nz_start + t.nz_len <= s.row_ptr[t.sorted_row + 1]
            covered[t.nz_start : t.nz_start + t.nz_len] += 1
        assert (covered == 1).all()

    def test_split_rows_marked(self):
        # a row with degree far above deg_bound (4)
        csr = random_csr(7, 20, 2.0, heavy=True)
        s, _, _ = L.degree_sort(csr)
        if s.degrees().max() > SMALL_PARAMS.deg_bound:
            tasks = L.block_partition(s, SMALL_PARAMS)
            assert any(t.is_split for t in tasks)


class TestBellLayout:
    @given(seed=st.integers(0, 500), n=st.integers(4, 50))
    @settings(max_examples=20, deadline=None)
    def test_execute_matches_dense(self, seed, n):
        csr = random_csr(seed, n, 3.0)
        bell, perm, inv = L.prepare(csr, SMALL_PARAMS)
        rng = np.random.default_rng(seed + 1)
        f = int(rng.integers(1, 9))
        x = rng.standard_normal((n, f)).astype(np.float32)
        got = np.asarray(ref.bell_spmm_ref(bell, x[perm]))
        want = ref.spmm_dense_ref(csr, x)[perm]
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_widths_pow2_rows_padded(self):
        csr = random_csr(3, 40, 4.0, heavy=True)
        bell, _, _ = L.prepare(csr)
        for b in bell.buckets:
            assert b.width & (b.width - 1) == 0
            assert b.padded_rows % L.ROW_TILE == 0
            assert b.rows <= b.padded_rows < b.rows + L.ROW_TILE
            # padding rows inert
            assert (b.vals[b.rows :] == 0).all()

    def test_spec_roundtrip_fields(self):
        csr = random_csr(4, 25, 2.0)
        bell, _, _ = L.prepare(csr)
        spec = bell.spec()
        assert spec["n_rows"] == 25
        assert spec["row_tile"] == L.ROW_TILE
        assert len(spec["buckets"]) == len(bell.buckets)


class TestRelabel:
    def test_symmetric_relabel_semantics(self):
        # (P·A·Pᵀ)(P·X) == P·(A·X)
        csr = random_csr(5, 30, 3.0)
        s, perm, inv = L.degree_sort(csr)
        rel = L.relabel(csr, perm, inv)
        rng = np.random.default_rng(6)
        x = rng.standard_normal((30, 4)).astype(np.float32)
        got = ref.spmm_dense_ref(rel, x[perm])
        want = ref.spmm_dense_ref(csr, x)[perm]
        np.testing.assert_allclose(got, want, atol=1e-4)
