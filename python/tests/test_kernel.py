"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes (rows, widths, feature dims) including the
FEAT_TILE boundary (f = 128, 256) and ragged dims the paper's combined
warp handles with truncated lanes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layout as L
from compile.kernels import ref, spmm_bell


def random_bucket(seed, rows, width, n_cols):
    """A synthetic BELL bucket (valid: rows multiple of ROW_TILE)."""
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, n_cols, size=(rows, width)).astype(np.int32)
    vals = rng.standard_normal((rows, width)).astype(np.float32)
    # zero a random suffix of each row (padding pattern)
    for r in range(rows):
        k = int(rng.integers(0, width + 1))
        vals[r, k:] = 0.0
    return jnp.asarray(cols), jnp.asarray(vals)


class TestBucketPartial:
    @given(
        seed=st.integers(0, 10_000),
        rows=st.sampled_from([8, 16, 64]),
        width=st.sampled_from([1, 2, 4, 8, 32]),
        n_cols=st.sampled_from([8, 100]),
        f=st.sampled_from([1, 3, 16, 32, 100, 128]),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_oracle(self, seed, rows, width, n_cols, f):
        cols, vals = random_bucket(seed, rows, width, n_cols)
        x = jnp.asarray(
            np.random.default_rng(seed + 1).standard_normal((n_cols, f)).astype(np.float32)
        )
        got = spmm_bell.bucket_partial(cols, vals, x)
        want = ref.bucket_partial_ref(cols, vals, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)

    def test_feat_tile_multiple(self):
        # f = 256 exercises the feature-tile grid dimension (2 tiles)
        cols, vals = random_bucket(0, 16, 4, 50)
        x = jnp.asarray(np.random.default_rng(1).standard_normal((50, 256)).astype(np.float32))
        got = spmm_bell.bucket_partial(cols, vals, x)
        want = ref.bucket_partial_ref(cols, vals, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)

    def test_zero_vals_give_zero(self):
        cols = jnp.zeros((8, 4), jnp.int32)
        vals = jnp.zeros((8, 4), jnp.float32)
        x = jnp.ones((10, 16), jnp.float32)
        out = spmm_bell.bucket_partial(cols, vals, x)
        assert np.asarray(out).sum() == 0.0

    def test_rejects_unpadded_rows(self):
        cols = jnp.zeros((5, 4), jnp.int32)  # 5 not a multiple of 8
        vals = jnp.zeros((5, 4), jnp.float32)
        x = jnp.ones((10, 16), jnp.float32)
        with pytest.raises(AssertionError):
            spmm_bell.bucket_partial(cols, vals, x)


class TestGradients:
    @given(seed=st.integers(0, 1000), f=st.sampled_from([4, 16, 128]))
    @settings(max_examples=10, deadline=None)
    def test_vjp_matches_oracle(self, seed, f):
        cols, vals = random_bucket(seed, 8, 4, 20)
        x = jnp.asarray(np.random.default_rng(seed).standard_normal((20, f)).astype(np.float32))

        def f_pal(v, xx):
            return jnp.sum(jnp.tanh(spmm_bell.bucket_partial(cols, v, xx)))

        def f_ref(v, xx):
            return jnp.sum(jnp.tanh(ref.bucket_partial_ref(cols, v, xx)))

        gv_p, gx_p = jax.grad(f_pal, argnums=(0, 1))(vals, x)
        gv_r, gx_r = jax.grad(f_ref, argnums=(0, 1))(vals, x)
        np.testing.assert_allclose(np.asarray(gv_p), np.asarray(gv_r), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r), atol=1e-4, rtol=1e-4)

    def test_grad_multi_feature_tile(self):
        # backward kernel's accumulator across feature tiles (f=256)
        cols, vals = random_bucket(3, 8, 2, 12)
        x = jnp.asarray(np.random.default_rng(4).standard_normal((12, 256)).astype(np.float32))
        gv = jax.grad(lambda v: jnp.sum(spmm_bell.bucket_partial(cols, v, x)))(vals)
        gv_ref = jax.grad(lambda v: jnp.sum(ref.bucket_partial_ref(cols, v, x)))(vals)
        np.testing.assert_allclose(np.asarray(gv), np.asarray(gv_ref), atol=1e-3, rtol=1e-4)


class TestFullAggregation:
    @given(seed=st.integers(0, 2000), n=st.integers(4, 60), f=st.sampled_from([1, 8, 64]))
    @settings(max_examples=20, deadline=None)
    def test_bell_spmm_vs_dense(self, seed, n, f):
        rng = np.random.default_rng(seed)
        csr = L.Csr.random(rng, n, 3.0, heavy=(seed % 3 == 0))
        bell, perm, inv = L.prepare(csr, L.PartitionParams(2, 2))
        x = rng.standard_normal((n, f)).astype(np.float32)
        buckets = [
            (jnp.asarray(b.cols), jnp.asarray(b.vals), jnp.asarray(b.out_row))
            for b in bell.buckets
        ]
        got = spmm_bell.bell_spmm(buckets, jnp.asarray(x[perm]), bell.n_rows)
        want = ref.spmm_dense_ref(csr, x)[perm]
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-3, rtol=1e-4)

    def test_vmem_estimate(self):
        est = spmm_bell.vmem_estimate_bytes(width=32, n_cols=1000, f=128)
        assert est["x_slice"] == 1000 * 128 * 4
        assert est["total"] > est["x_slice"]
