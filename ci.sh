#!/usr/bin/env bash
# Tier-1 verification: build, test, format. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release

# The test suite runs twice: once with the portable SIMD fallback
# forced (proves the microkernel's arch-independent path end to end)
# and once compiled for the host CPU so the AVX2/NEON intrinsic paths
# are both detected and exercised where the hardware allows.
ACCEL_GCN_SIMD=portable cargo test -q
RUSTFLAGS="-C target-cpu=native" cargo test -q

# Serve-native smoke: the multi-tenant serving path end-to-end on a
# small synthetic load, with every response verified against the exact
# CPU executor (fails the build on any mismatch).
cargo run --release --bin accel-gcn -- serve-native \
    --requests 64 --tenants 2 --nodes 200 --threads 2 --seed 7

# Delta smoke: stream update batches against a generated graph; every
# incrementally patched plan is checked bit-for-bit against a
# from-scratch rebuild and against the dense SpMM reference (the
# command exits nonzero on any divergence).
cargo run --release --bin accel-gcn -- update-demo \
    --nodes 1500 --batches 6 --batch-size 48 --threads 2 --seed 7

# Short delta_update bench in check mode: patch-vs-replan sweep with
# per-batch verification baked in (bench fails if any cell diverges).
cargo run --release --bin accel-gcn -- bench --experiment delta_update --quick \
    --out results-ci-delta

# Adaptive-microkernel smoke: the SIMD × dispatch matrix ({scalar,
# portable-simd, arch-if-available} × {fixed, adaptive}) at tiny scale
# over both skew extremes, every cell checked against the dense
# reference (the bench exits nonzero if any variant diverges), so the
# SIMD lanes, the sparse gather kernel, and the per-bucket dispatch —
# including ragged-tail widths — are exercised on every CI run.
cargo run --release --bin accel-gcn -- bench --experiment microkernel --quick \
    --out results-ci-micro

# Train-native smoke: 50 full-graph steps on the synthetic labeled
# graph with both optimizers. The command verifies the backward SpMM
# against the dense Âᵀ reference before training and exits nonzero
# unless the final loss is ≤ 0.5× the initial loss; the analytic-vs-
# finite-difference gradient check runs in `cargo test` above.
cargo run --release --bin accel-gcn -- train-native --quick --steps 50 \
    --optimizer sgd --threads 2 --seed 7 --require-loss-drop 0.5
cargo run --release --bin accel-gcn -- train-native --quick --steps 50 \
    --optimizer adam --threads 2 --seed 7 --require-loss-drop 0.5

# Observability smoke: run the profiler and a short serve burst with
# tracing on, then schema-validate the emitted metrics snapshots AND
# the Chrome trace-event timelines (required keys present, per-shard
# busy-ns sums positive, histogram quantiles ordered, trace events
# well-formed). The validator is the checked-in `validate-metrics`
# subcommand, so the schema contract is enforced by the same code that
# documents it.
cargo run --release --bin accel-gcn -- profile --quick --threads 2 --seed 7 \
    --json results-ci-obs/profile_metrics.json \
    --trace-out results-ci-obs/profile_trace.json
cargo run --release --bin accel-gcn -- serve-native \
    --requests 48 --tenants 2 --nodes 200 --threads 2 --seed 7 \
    --metrics-out results-ci-obs/serve_metrics.json --metrics-interval-ms 100 \
    --trace-out results-ci-obs/serve_trace.json
cargo run --release --bin accel-gcn -- validate-metrics \
    results-ci-obs/profile_metrics.json results-ci-obs/serve_metrics.json \
    results-ci-obs/profile_trace.json results-ci-obs/serve_trace.json

# Tuning smoke: the closed loop (measure -> fit -> re-cut -> swap) on a
# skewed power-law graph. The profile command itself exits nonzero if a
# tuned plan's output is not bit-for-bit identical to the untuned plan,
# or if the cost-model max/mean shard imbalance increased; the grep
# pins the printed contract so a silent behavior change still fails.
cargo run --release --bin accel-gcn -- profile --quick --threads 2 --seed 7 \
    --tune-every 3 --train-steps 6 \
    | tee results-ci-obs/tune_smoke.txt
grep -q "output bit-identical to untuned: true" results-ci-obs/tune_smoke.txt

# Serve-path tuning smoke: tuner runs between fused rounds, swaps land
# through PlanCache::refresh, responses stay verified against the
# exact executor (serve-native exits nonzero on any mismatch).
cargo run --release --bin accel-gcn -- serve-native \
    --requests 48 --tenants 2 --nodes 200 --threads 2 --seed 7 --tune-every 2

# bench-compare self-check: a report diffed against itself must show
# zero regressions (and the command must exit zero).
cargo run --release --bin accel-gcn -- bench-compare \
    results-ci-delta/BENCH_delta_update.json \
    results-ci-delta/BENCH_delta_update.json --max-regress 5

# Roofline smoke (DESIGN §12): quick STREAM/FMA calibration (cached as
# versioned JSON), then the SpMM roofline on a power-law graph. The
# roofline command itself hard-errors if the analytic traffic model
# and the instrumented counting executor disagree by even one byte,
# and validate-metrics re-checks the written report: achieved GB/s
# must not exceed the calibrated peak, per-bucket nnz must sum to the
# graph's, and the bandwidth- vs compute-bound verdict must match the
# intensity-vs-machine-balance rule.
cargo run --release --bin accel-gcn -- roofline --quick --threads 2 --seed 7 \
    --nodes 1500 --coldims 16,64 \
    --calibration results-ci-obs/calibration.json \
    --json results-ci-obs/roofline.json
cargo run --release --bin accel-gcn -- validate-metrics \
    results-ci-obs/roofline.json results-ci-obs/calibration.json

# Durability smoke (DESIGN §11), part 1: kill-and-recover. A durable
# serve-native run (snapshot + WAL under --data-dir, fsync always)
# takes update batches and is SIGKILLed mid-flight — the binary is
# invoked directly so the kill hits the server, not a cargo wrapper.
# recover-check must then rebuild every tenant from snapshot + WAL
# replay and re-verify SpMM through the full pipeline against the
# dense reference, exiting nonzero on any divergence. Whatever the
# kill interrupts (a WAL append -> torn tail dropped; a snapshot
# write -> tmp+rename discards it) is a documented fallback.
rm -rf results-ci-store
target/release/accel-gcn serve-native \
    --requests 32 --tenants 2 --nodes 200 --threads 2 --seed 7 \
    --rounds 500 --updates 4 --update-size 16 \
    --data-dir results-ci-store/live &
SERVE_PID=$!
sleep 3
kill -9 "$SERVE_PID" 2>/dev/null || true
wait "$SERVE_PID" 2>/dev/null || true
target/release/accel-gcn recover-check \
    --data-dir results-ci-store/live --verify-spmm

# ... and the killed server's state must be *servable*: a restart over
# the same directory recovers the tenants and every response verifies
# against the recovered adjacency.
target/release/accel-gcn serve-native \
    --requests 32 --tenants 2 --nodes 200 --threads 2 --seed 7 \
    --rounds 2 --updates 2 --data-dir results-ci-store/live

# Durability smoke, part 2: fault-injection matrix. Each write-side
# fault degrades gracefully — the serving run completes (shedding with
# typed errors where needed, never panicking) and recovery lands on
# the documented fallback.
#   torn-tail         -> incomplete final WAL record dropped on replay
#   snapshot-truncate -> recovery falls back one snapshot generation
#   disk-full=N       -> appends past the budget shed updates (typed)
for fault in torn-tail snapshot-truncate disk-full=700; do
    rm -rf results-ci-store/fault
    target/release/accel-gcn serve-native \
        --requests 16 --tenants 2 --nodes 120 --threads 2 --seed 7 \
        --rounds 3 --updates 2 --update-size 16 \
        --data-dir results-ci-store/fault --fsync never --snapshot-every 2 \
        --fault "$fault"
    target/release/accel-gcn recover-check \
        --data-dir results-ci-store/fault --verify-spmm
done

# checksum-flip corrupts a WAL record *mid-log* (later records are
# intact, so it is not a droppable tail): recovery must refuse with a
# typed checksum error, and recover-check must exit NONZERO.
rm -rf results-ci-store/fault
target/release/accel-gcn serve-native \
    --requests 16 --tenants 2 --nodes 120 --threads 2 --seed 7 \
    --rounds 3 --updates 2 --update-size 16 \
    --data-dir results-ci-store/fault --fsync never --fault checksum-flip
if target/release/accel-gcn recover-check --data-dir results-ci-store/fault; then
    echo "ERROR: checksum-flip corruption went undetected by recover-check" >&2
    exit 1
fi
echo "recover-check correctly rejected the checksum-flipped WAL"

# Formatting is checked but advisory for now: parts of the seed tree
# predate rustfmt enforcement. Flip to a hard failure once `cargo fmt`
# has been run tree-wide.
if ! cargo fmt --check; then
    echo "warning: rustfmt differences found (advisory, not failing CI yet)" >&2
fi
