//! Integration: the serving engine end-to-end — artifact loading,
//! static-input binding, column batching, concurrent submission, and
//! verification against the exact executor.

use accel_gcn::coordinator::{ColumnBatcher, Engine};
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::runtime::HostTensor;
use accel_gcn::spmm::verify::allclose;
use accel_gcn::util::rng::Pcg;
use std::path::Path;

const ART: &str = "artifacts/quickstart";

fn artifacts_ready() -> bool {
    Path::new(ART).join("manifest.json").exists()
}

#[test]
fn engine_executes_batched_requests() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::start(ART).unwrap();
    let ladder = engine.manifest().spmm_coldims();
    for (_, name) in &ladder {
        engine.load_artifact(name).unwrap();
        engine.bind_bell(name).unwrap();
    }
    let n = engine.manifest().n_cols;
    let layout = BellLayout::load(ART).unwrap();
    let batcher = ColumnBatcher::new(ladder).unwrap();

    let mut rng = Pcg::seed_from(5);
    let widths = [16usize, 16, 32, 64, 16];
    let xs: Vec<HostTensor> = widths
        .iter()
        .map(|&w| HostTensor::f32(&[n, w], (0..n * w).map(|_| rng.f32() - 0.5).collect()))
        .collect();
    let plans = batcher.plan(&widths).unwrap();
    for plan in &plans {
        let member_xs: Vec<&HostTensor> = plan.members.iter().map(|&m| &xs[m]).collect();
        let fused = ColumnBatcher::fuse(plan, &member_xs).unwrap();
        let y = engine.exec_sync(&plan.artifact, vec![fused]).unwrap().pop().unwrap();
        let outs = ColumnBatcher::split(plan, &widths, &y).unwrap();
        for (i, out) in outs.iter().enumerate() {
            let req = plan.members[i];
            let want = layout.execute(xs[req].as_f32().unwrap(), widths[req]);
            assert!(
                allclose(out.as_f32().unwrap(), &want, 1e-3, 1e-3),
                "request {req} mismatch"
            );
        }
    }
    assert!(engine.metrics.requests.get() >= plans.len() as u64);
    assert_eq!(engine.metrics.errors.get(), 0);
}

#[test]
fn engine_reports_errors_not_poisons() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::start(ART).unwrap();
    // executing an unknown artifact errors but the engine stays usable
    assert!(engine.exec_sync("bogus", vec![]).is_err());
    engine.load_artifact("spmm_f16").unwrap();
    engine.bind_bell("spmm_f16").unwrap();
    // wrong dynamic arity errors cleanly
    assert!(engine.exec_sync("spmm_f16", vec![]).is_err());
    // and a correct request still succeeds afterwards
    let n = engine.manifest().n_cols;
    let x = HostTensor::f32(&[n, 16], vec![0.1; n * 16]);
    let out = engine.exec_sync("spmm_f16", vec![x]).unwrap();
    assert_eq!(out[0].shape(), &[engine.manifest().n_rows, 16]);
    assert!(engine.metrics.errors.get() >= 2);
}

#[test]
fn concurrent_clients_share_engine() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = std::sync::Arc::new(Engine::start(ART).unwrap());
    engine.load_artifact("spmm_f16").unwrap();
    engine.bind_bell("spmm_f16").unwrap();
    let n = engine.manifest().n_cols;
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let engine = std::sync::Arc::clone(&engine);
            std::thread::spawn(move || {
                let x = HostTensor::f32(&[n, 16], vec![i as f32 * 0.1; n * 16]);
                engine.exec_sync("spmm_f16", vec![x]).unwrap()
            })
        })
        .collect();
    for h in handles {
        let out = h.join().unwrap();
        assert_eq!(out[0].shape()[1], 16);
    }
}
