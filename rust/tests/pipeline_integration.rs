//! Integration: the full preprocessing pipeline across modules —
//! generator → normalize → degree sort → relabel → partition → BELL →
//! disk → reload, with numerics checked at every boundary. No PJRT or
//! artifacts needed.

use accel_gcn::coordinator::PreparedDataset;
use accel_gcn::graph::datasets::{by_name, materialize, ScalePolicy};
use accel_gcn::graph::generator;
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::pipeline::{
    BlockLevel, CsrReference, Executor, ParallelBlockLevel, PlanCache, WarpLevel,
};
use accel_gcn::spmm::spmm_block_level;
use accel_gcn::spmm::verify::assert_allclose;
use accel_gcn::util::rng::Pcg;

#[test]
fn table1_graph_through_full_pipeline() {
    // a real Table I graph (scaled) through prepare + all executors
    let csr = materialize(by_name("pubmed").unwrap(), ScalePolicy::tiny(), 3);
    let p = PreparedDataset::prepare(&csr, PartitionParams::default());
    let f = 8;
    let n = p.n_rows();
    let mut rng = Pcg::seed_from(17);
    let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();

    let from_layout = p.layout.execute(&x, f);
    let from_executor = spmm_block_level(&p.sorted, &p.partition, &x, f);
    let from_dense = p.sorted.spmm_dense(&x, f);
    assert_allclose(&from_layout, &from_dense, 1e-3, 1e-3, "layout vs dense");
    assert_allclose(&from_executor, &from_dense, 1e-3, 1e-3, "executor vs dense");
}

#[test]
fn plan_cache_and_every_executor_agree_on_a_table1_graph() {
    // one plan from the cache drives all four executors; a second
    // request for the same graph is a cache hit returning the same plan
    let csr = materialize(by_name("collab").unwrap(), ScalePolicy::tiny(), 5);
    let cache = PlanCache::new();
    let plan = cache.plan_for(&csr, PartitionParams::default());
    let again = cache.plan_for(&csr, PartitionParams::default());
    assert!(std::sync::Arc::ptr_eq(&plan, &again), "second request must hit");
    assert_eq!(cache.hits(), 1);

    let f = 8;
    let mut rng = Pcg::seed_from(23);
    let x: Vec<f32> = (0..csr.n_cols * f).map(|_| rng.f32() - 0.5).collect();
    let want = CsrReference.execute(&plan, &x, f);
    let executors: Vec<Box<dyn Executor>> = vec![
        Box::new(BlockLevel),
        Box::new(WarpLevel),
        Box::new(ParallelBlockLevel::new(4)),
    ];
    for exec in &executors {
        let got = exec.execute(&plan, &x, f);
        assert_allclose(&got, &want, 1e-3, 1e-3, exec.name());
    }
}

#[test]
fn prepared_dataset_prepare_hits_global_plan_cache() {
    // the coordinator's preprocessing goes through the global cache:
    // preparing the same adjacency twice reuses the plan
    let mut rng = Pcg::seed_from(41);
    let g = generator::labeled_communities(60, 5.0, 4, 3, 0.8, &mut rng);
    let params = PartitionParams { max_block_warps: 4, max_warp_nzs: 8 };
    let hits_before = PlanCache::global().hits();
    let a = PreparedDataset::prepare(&g.csr, params);
    let b = PreparedDataset::prepare(&g.csr, params);
    assert!(
        PlanCache::global().hits() > hits_before,
        "second prepare must reuse the cached plan"
    );
    assert_eq!(a.sorted, b.sorted);
    assert_eq!(a.perm, b.perm);
}

#[test]
fn prepared_dataset_disk_roundtrip_preserves_numerics() {
    let mut rng = Pcg::seed_from(21);
    let g = generator::labeled_communities(150, 5.0, 8, 4, 0.8, &mut rng);
    let p = PreparedDataset::prepare(&g.csr, PartitionParams { max_block_warps: 4, max_warp_nzs: 8 })
        .with_node_data(8, &g.features, &g.labels);
    let dir = std::env::temp_dir().join("accel_gcn_pipeline_it");
    p.save(&dir).unwrap();

    let layout = BellLayout::load(&dir).unwrap();
    assert_eq!(layout, p.layout);
    let back = PreparedDataset::load(&dir).unwrap();
    let f = 4;
    let x: Vec<f32> = (0..150 * f).map(|_| rng.f32()).collect();
    assert_eq!(back.layout.execute(&x, f), p.layout.execute(&x, f));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn all_18_datasets_partition_cleanly() {
    // every Table I graph materializes and partitions with full nonzero
    // coverage (tiny scale to keep the test fast)
    let policy = ScalePolicy { node_cap: 800, edge_cap: 8_000 };
    for spec in accel_gcn::graph::datasets::TABLE1 {
        let csr = materialize(spec, policy, 11);
        let p = PreparedDataset::prepare(&csr, PartitionParams::default());
        let covered: usize = p.partition.warp_tasks().iter().map(|t| t.nz_len).sum();
        assert_eq!(covered, p.sorted.nnz(), "{}: task coverage", spec.name);
        assert!(p.layout.padding_overhead() < 4.0, "{}: padding", spec.name);
    }
}

#[test]
fn partition_param_grid_consistency() {
    // the pipeline is numerically correct for every partition parameter
    // combination the CLI exposes
    let mut rng = Pcg::seed_from(31);
    let g = generator::labeled_communities(80, 6.0, 4, 3, 0.7, &mut rng);
    let f = 4;
    let x: Vec<f32> = (0..80 * f).map(|_| rng.f32() - 0.5).collect();
    let mut reference: Option<Vec<f32>> = None;
    for mbw in [1usize, 2, 6, 12] {
        for mwn in [1usize, 4, 32] {
            let p = PreparedDataset::prepare(
                &g.csr,
                PartitionParams { max_block_warps: mbw, max_warp_nzs: mwn },
            );
            // compare in the original domain (permutation may differ)
            let sorted_y = p.layout.execute(
                &{
                    let mut px = vec![0f32; 80 * f];
                    for (i, &orig) in p.perm.iter().enumerate() {
                        px[i * f..(i + 1) * f]
                            .copy_from_slice(&x[orig as usize * f..(orig as usize + 1) * f]);
                    }
                    px
                },
                f,
            );
            let mut y = vec![0f32; 80 * f];
            for (i, &orig) in p.perm.iter().enumerate() {
                y[orig as usize * f..(orig as usize + 1) * f]
                    .copy_from_slice(&sorted_y[i * f..(i + 1) * f]);
            }
            match &reference {
                None => reference = Some(y),
                Some(r) => assert_allclose(&y, r, 1e-3, 1e-3, &format!("mbw={mbw} mwn={mwn}")),
            }
        }
    }
}
