//! Failure injection: corrupted artifacts, malformed inputs, and
//! truncated files must produce clean errors — never panics, hangs, or
//! silent garbage numerics.

use accel_gcn::coordinator::{Engine, PreparedDataset};
use accel_gcn::graph::csr::Csr;
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::runtime::{HostTensor, Manifest};
use accel_gcn::util::npy::Npy;
use std::fs;
use std::path::{Path, PathBuf};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("accel_gcn_failures").join(name);
    fs::remove_dir_all(&dir).ok();
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_dataset() -> PreparedDataset {
    let edges: Vec<(u32, u32, f32)> =
        (0..60u32).map(|i| (i % 20, (i * 7 + 3) % 20, 1.0)).collect();
    let adj = Csr::from_edges(20, 20, &edges).unwrap().symmetrize();
    PreparedDataset::prepare(&adj, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 })
}

#[test]
fn corrupted_bell_tensor_is_detected() {
    let dir = tmpdir("bell_corrupt");
    small_dataset().save(&dir).unwrap();
    // find one bucket tensor and truncate it
    let victim = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .find(|e| e.file_name().to_string_lossy().ends_with("_cols.npy"))
        .expect("a bell cols tensor exists");
    let bytes = fs::read(victim.path()).unwrap();
    fs::write(victim.path(), &bytes[..bytes.len() / 2]).unwrap();
    let err = BellLayout::load(&dir).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("truncated") || msg.contains("mismatch") || msg.contains("parse"),
        "unhelpful error: {msg}"
    );
}

#[test]
fn corrupted_spec_json_is_detected() {
    let dir = tmpdir("spec_corrupt");
    small_dataset().save(&dir).unwrap();
    fs::write(dir.join("bell_spec.json"), "{ not json !").unwrap();
    assert!(BellLayout::load(&dir).is_err());
}

#[test]
fn missing_manifest_fields_rejected() {
    let dir = tmpdir("manifest_fields");
    fs::write(dir.join("manifest.json"), r#"{"artifacts": {}}"#).unwrap();
    assert!(Manifest::load(&dir).is_err()); // missing n_rows/n_cols
    fs::write(dir.join("manifest.json"), r#"{"n_rows": 1, "n_cols": 1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err()); // missing artifacts
}

#[test]
fn engine_start_fails_cleanly_without_artifacts() {
    let dir = tmpdir("no_artifacts");
    assert!(Engine::start(dir.to_str().unwrap()).is_err());
}

#[test]
fn engine_survives_corrupt_hlo() {
    // manifest points at an artifact whose HLO file is garbage: loading
    // must error, and the engine must stay alive for later requests
    let dir = tmpdir("bad_hlo");
    fs::write(
        dir.join("manifest.json"),
        r#"{
          "n_rows": 4, "n_cols": 4,
          "artifacts": {
            "broken": {"file": "broken.hlo.txt", "inputs": [], "outputs": []}
          }
        }"#,
    )
    .unwrap();
    fs::write(dir.join("broken.hlo.txt"), "this is not HLO text").unwrap();
    let engine = Engine::start(dir.to_str().unwrap()).unwrap();
    assert!(engine.load_artifact("broken").is_err());
    assert!(engine.load_artifact("broken").is_err()); // still responsive
    assert!(engine.exec_sync("broken", vec![]).is_err());
}

#[test]
fn dataset_load_rejects_tampered_graph() {
    let dir = tmpdir("graph_tamper");
    small_dataset().save(&dir).unwrap();
    let path = dir.join("graph.bin");
    let mut bytes = fs::read(&path).unwrap();
    bytes[0] ^= 0xFF; // break the magic
    fs::write(&path, &bytes).unwrap();
    assert!(PreparedDataset::load(&dir).is_err());
}

#[test]
fn npy_dtype_confusion_rejected() {
    let dir = tmpdir("dtype_confusion");
    small_dataset().save(&dir).unwrap();
    // overwrite a f32 tensor with an i32 one of the same shape
    let vals = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .find(|e| {
            let name = e.file_name().to_string_lossy().to_string();
            name.starts_with("bell_") && name.ends_with("_vals.npy")
        })
        .unwrap();
    let old = Npy::load(vals.path()).unwrap();
    let bogus = Npy::from_i32(&old.shape, &vec![0i32; old.len()]);
    bogus.save(vals.path()).unwrap();
    assert!(BellLayout::load(&dir).is_err());
}

#[test]
fn host_tensor_shape_mismatch_panics_not_corrupts() {
    let r = std::panic::catch_unwind(|| HostTensor::f32(&[2, 3], vec![0.0; 5]));
    assert!(r.is_err(), "shape/data mismatch must be rejected");
}

#[test]
fn artifacts_integration_wrong_width_rejected() {
    let art = Path::new("artifacts/quickstart");
    if !art.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::start("artifacts/quickstart").unwrap();
    engine.load_artifact("spmm_f16").unwrap();
    engine.bind_bell("spmm_f16").unwrap();
    let n = engine.manifest().n_cols;
    // wrong column width for this artifact
    let x = HostTensor::f32(&[n, 32], vec![0.0; n * 32]);
    let err = engine.exec_sync("spmm_f16", vec![x]).unwrap_err();
    assert!(format!("{err:#}").contains("expects"), "{err:#}");
}
