//! End-to-end crash recovery (DESIGN §11): a durable server takes a
//! snapshot plus N WAL-logged update batches, "crashes" without any
//! graceful shutdown, and a fresh process recovers every tenant —
//! plan fingerprint equal to the pre-crash epoch's, SpMM output
//! **bit-identical** to an uncrashed oracle server at the same epoch,
//! and the epoch chain continuing seamlessly under new updates.

use accel_gcn::delta::{DeltaGraph, EdgeUpdate};
use accel_gcn::graph::Csr;
use accel_gcn::runtime::HostTensor;
use accel_gcn::serve::{PersistConfig, ServeConfig, Server};
use accel_gcn::spmm::verify::allclose;
use accel_gcn::store::relabeled_fingerprint;
use accel_gcn::util::rng::Pcg;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("accel_gcn_crash_recovery")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn test_graph(n: usize, seed: u64) -> Csr {
    let mut rng = Pcg::seed_from(seed);
    let degs = accel_gcn::graph::generator::degree_sequence(
        accel_gcn::graph::generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.1 },
        n,
        n * 5,
        &mut rng,
    );
    accel_gcn::graph::generator::from_degree_sequence(n, &degs, &mut rng)
}

/// Deterministic mixed insert/delete batches, all in bounds for `n`.
fn update_batches(n: usize, count: usize, seed: u64) -> Vec<Vec<EdgeUpdate>> {
    let mut rng = Pcg::seed_from(seed);
    (0..count)
        .map(|_| {
            (0..6)
                .map(|_| {
                    let (r, c) = (rng.range(0, n) as u32, rng.range(0, n) as u32);
                    if rng.f64() < 0.3 {
                        EdgeUpdate::Delete { row: r, col: c }
                    } else {
                        EdgeUpdate::Insert { row: r, col: c, val: rng.f32() * 2.0 - 1.0 }
                    }
                })
                .collect()
        })
        .collect()
}

fn durable_config(dir: &PathBuf) -> ServeConfig {
    ServeConfig {
        threads: 2,
        persist: Some(PersistConfig::new(dir)),
        ..ServeConfig::default()
    }
}

#[test]
fn crash_without_shutdown_recovers_bit_identical_to_uncrashed_server() {
    let dir = tmpdir("bit-identical");
    let n = 64;
    let base = test_graph(n, 7);
    let batches = update_batches(n, 4, 11);

    // --- phase 1: durable server applies the batches, then "crashes".
    // `mem::forget` skips Drop entirely: no queue drain, no worker
    // join, no final WAL flush — everything the process would lose to
    // SIGKILL. Durability must come from the WAL-before-apply ordering
    // (fsync Always is PersistConfig's default) alone.
    {
        let server = Server::start(durable_config(&dir)).unwrap();
        let h = server.register_graph("t0", &base).unwrap();
        for b in &batches {
            server.update_graph(h, b.clone()).unwrap();
        }
        assert_eq!(server.graph_epoch(h).unwrap(), batches.len() as u64);
        std::mem::forget(server);
    }

    // --- uncrashed oracle: in-memory server at the same epoch
    let oracle = Server::start(ServeConfig { threads: 2, ..ServeConfig::default() }).unwrap();
    let oh = oracle.register_graph("t0", &base).unwrap();
    for b in &batches {
        oracle.update_graph(oh, b.clone()).unwrap();
    }

    // --- phase 2: restart + recover
    let server2 = Server::start(durable_config(&dir)).unwrap();
    let sums = server2.recover_tenants().unwrap();
    assert_eq!(sums.len(), 1);
    let rec = &sums[0];
    assert_eq!(rec.name, "t0");
    assert_eq!(rec.epoch, batches.len() as u64, "every logged batch replays");
    assert_eq!(rec.replayed_batches, batches.len());
    assert!(rec.fingerprint_verified, "final epoch was sealed before the crash");

    // fingerprint identical to the pre-crash epoch's: the plan-cache
    // key recomputed from a CPU-side application of the same batches
    let mut dg = DeltaGraph::new(base.clone());
    for b in &batches {
        dg.apply(b).unwrap();
    }
    let want_csr = dg.snapshot();
    assert_eq!(rec.fingerprint, relabeled_fingerprint(&want_csr));
    assert_eq!(server2.graph_snapshot(rec.handle).unwrap(), want_csr);
    // recovery pre-warmed the tenant's plan under that fingerprint
    assert!(server2
        .plan_cache()
        .peek(&accel_gcn::pipeline::GraphKey {
            fingerprint: rec.fingerprint,
            params: accel_gcn::partition::patterns::PartitionParams::default(),
        })
        .is_some());

    // --- same SpMM through both servers: bit-identical outputs, and
    // both match the dense reference on the recovered matrix
    let w = 16;
    let mut rng = Pcg::seed_from(23);
    let x = HostTensor::f32(&[n, w], (0..n * w).map(|_| rng.f32() - 0.5).collect());
    let y_rec = server2
        .submit_spmm(rec.handle, x.clone())
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let y_ora = oracle.submit_spmm(oh, x.clone()).unwrap().recv().unwrap().unwrap();
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
    assert_eq!(
        bits(y_rec.y.as_f32().unwrap()),
        bits(y_ora.y.as_f32().unwrap()),
        "recovered server's SpMM must be bit-identical to the uncrashed server's"
    );
    let dense = want_csr.spmm_dense(x.as_f32().unwrap(), w);
    assert!(allclose(y_rec.y.as_f32().unwrap(), &dense, 1e-3, 1e-3));

    // --- the epoch chain continues where the crash left it
    let rep = server2
        .update_graph(rec.handle, vec![EdgeUpdate::Insert { row: 1, col: 2, val: 0.5 }])
        .unwrap();
    assert_eq!(rep.epoch, batches.len() as u64 + 1);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recovery_is_idempotent_across_repeated_crashes() {
    // crash → recover → crash again (no new updates) → recover: the
    // second recovery must see the exact same state
    let dir = tmpdir("idempotent");
    let n = 48;
    let base = test_graph(n, 3);
    let batches = update_batches(n, 3, 5);
    {
        let server = Server::start(durable_config(&dir)).unwrap();
        let h = server.register_graph("g", &base).unwrap();
        for b in &batches {
            server.update_graph(h, b.clone()).unwrap();
        }
        std::mem::forget(server);
    }
    let fp1 = {
        let server = Server::start(durable_config(&dir)).unwrap();
        let sums = server.recover_tenants().unwrap();
        assert_eq!(sums[0].epoch, 3);
        std::mem::forget(server);
        sums[0].fingerprint
    };
    let server = Server::start(durable_config(&dir)).unwrap();
    let sums = server.recover_tenants().unwrap();
    assert_eq!(sums[0].epoch, 3);
    assert_eq!(sums[0].fingerprint, fp1);
    std::fs::remove_dir_all(&dir).ok();
}
