//! Integration: PJRT literal round-trips and artifact execution against
//! the exact CPU executor. Tests that need AOT artifacts skip (with a
//! note) until `make artifacts` has run.

use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::runtime::{HostTensor, Manifest, Runtime};
use accel_gcn::spmm::verify::assert_allclose;
use accel_gcn::util::rng::Pcg;
use std::path::Path;

const ART: &str = "artifacts/quickstart";

fn artifacts_ready() -> bool {
    Path::new(ART).join("manifest.json").exists()
}

#[test]
fn literal_roundtrip_f32_and_i32() {
    let t = HostTensor::f32(&[2, 3], vec![1.0, -2.5, 3.0, 4.0, 0.0, 6.5]);
    let lit = t.to_literal().unwrap();
    let back = HostTensor::from_literal(&lit).unwrap();
    assert_eq!(t, back);

    let t = HostTensor::i32(&[4], vec![i32::MIN, -1, 0, i32::MAX]);
    let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
    assert_eq!(t, back);
}

#[test]
fn spmm_artifact_matches_exact_executor() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(ART).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    rt.load(&manifest, "spmm_f16").unwrap();

    // assemble inputs in manifest order: bell buckets then x
    let layout = BellLayout::load(ART).unwrap();
    let bells = manifest.load_bell_inputs("spmm_f16").unwrap();
    let mut rng = Pcg::seed_from(99);
    let n = manifest.n_cols;
    let x = HostTensor::f32(&[n, 16], (0..n * 16).map(|_| rng.f32() - 0.5).collect());
    let mut inputs: Vec<&HostTensor> = bells.iter().map(|(_, t)| t).collect();
    inputs.push(&x);

    let out = rt.execute("spmm_f16", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[manifest.n_rows, 16]);

    let want = layout.execute(x.as_f32().unwrap(), 16);
    assert_allclose(out[0].as_f32().unwrap(), &want, 1e-3, 1e-3, "PJRT vs exact executor");
}

#[test]
fn artifact_input_validation_rejects_bad_shapes() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(ART).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    rt.load(&manifest, "spmm_f16").unwrap();
    let bogus = HostTensor::f32(&[1, 1], vec![0.0]);
    let inputs: Vec<&HostTensor> = vec![&bogus];
    assert!(rt.execute("spmm_f16", &inputs).is_err());
    assert!(rt.execute("not_an_artifact", &inputs).is_err());
}

#[test]
fn executable_cache_reuses_compilation() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(ART).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    let t0 = rt.load(&manifest, "spmm_f32").unwrap().compile_secs;
    assert!(rt.is_loaded("spmm_f32"));
    // second load must hit the cache (same compile_secs object)
    let t1 = rt.load(&manifest, "spmm_f32").unwrap().compile_secs;
    assert_eq!(t0, t1);
}
