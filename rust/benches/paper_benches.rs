//! `cargo bench` entry point (criterion is unavailable offline; this is
//! a harness=false bench binary over `util::bench`).
//!
//! Regenerates every paper table/figure on the simulator (writing
//! `results/*.csv`) and runs the microbenchmarks that back the paper's
//! complexity claims: O(n) preprocessing scaling and the hot-path
//! executor throughputs. All schedules are built and executed through
//! the `pipeline` layer (`SpmmPlan` + `Executor`).

use accel_gcn::bench::paper;
use accel_gcn::graph::datasets::{by_name, materialize, ScalePolicy};
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::pipeline::{spmm_block_level_parallel, ParallelBlockLevel, SpmmPlan};
use accel_gcn::spmm::{spmm_block_level, spmm_warp_level};
use accel_gcn::util::bench::{fmt_secs, time_fn, Table};
use accel_gcn::util::cli::Args;
use accel_gcn::util::threadpool::default_parallelism;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // cargo bench passes --bench; accept and ignore it
    let argv: Vec<String> = argv.into_iter().filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv, &["out", "seed", "experiment"], &["quick", "skip-paper"])?;
    let seed = args.u64_or("seed", 42)?;
    let out = args.str_or("out", "results");
    std::fs::create_dir_all(Path::new(&out))?;

    if !args.flag("skip-paper") {
        // full paper regeneration (tables + figures + CSVs)
        paper::run_from_args(&args)?;
    }

    // --- microbenchmarks -------------------------------------------------
    println!("\n=== Preprocessing scaling (O(n) claim, §III-C) ===");
    print!("{}", paper::preprocessing_scaling(seed));

    println!("\n=== Hot-path executor microbench (collab-scaled, f=64) ===");
    let policy = if args.flag("quick") {
        ScalePolicy::tiny()
    } else {
        ScalePolicy { node_cap: 30_000, edge_cap: 300_000 }
    };
    let csr = materialize(by_name("collab").unwrap(), policy, seed);
    let plan = Arc::new(SpmmPlan::build(csr, PartitionParams::default()));
    let layout = BellLayout::build(&plan.sorted.csr, &plan.block);
    let f = 64;
    let x = vec![0.5f32; plan.original.n_cols * f];

    // raw schedule executions over the shared plan — inputs are
    // borrowed (zero-copy) everywhere; the parallel row includes its
    // fused unpermute-scatter (a store pattern, not an extra pass), the
    // sequential rows stay in the sorted domain
    let mut table = Table::new(&["executor", "p50", "GFLOP/s"]);
    let flops = 2.0 * plan.nnz() as f64 * f as f64 / 1e9;
    let threads = default_parallelism();
    let parallel = ParallelBlockLevel::new(threads);
    let mut row = |label: String, m: accel_gcn::util::bench::Measurement| {
        table.row(vec![label, fmt_secs(m.p50()), format!("{:.2}", flops / m.p50())]);
    };
    row(
        "block-level (paper)".into(),
        time_fn("block_exec", 1, 0.5, || {
            std::hint::black_box(spmm_block_level(&plan.sorted.csr, &plan.block, &x, f));
        }),
    );
    row(
        format!("block-level parallel ({threads}t)"),
        time_fn("block_exec_parallel", 1, 0.5, || {
            std::hint::black_box(spmm_block_level_parallel(&plan, &x, f, parallel.pool()));
        }),
    );
    row(
        "warp-level (GNNAdvisor)".into(),
        time_fn("warp_exec", 1, 0.5, || {
            std::hint::black_box(spmm_warp_level(&plan.original, &plan.warp, &x, f));
        }),
    );
    row(
        "CSR reference".into(),
        time_fn("csr_dense", 1, 0.5, || {
            std::hint::black_box(plan.sorted.csr.spmm_dense(&x, f));
        }),
    );
    row(
        "BELL layout".into(),
        time_fn("bell_exec", 1, 0.5, || {
            std::hint::black_box(layout.execute(&x, f));
        }),
    );
    print!("{}", table.render());

    println!("\n=== Preprocessing throughput ===");
    let mut table = Table::new(&["stage", "p50", "edges/s (M)"]);
    // plan build owns its matrix, so the timed region includes one
    // O(nnz) CSR copy on top of fingerprint + sort + both partitions —
    // the label discloses it (cf. paper::preprocessing_scaling)
    let m = time_fn("plan_build", 1, 0.5, || {
        std::hint::black_box(
            SpmmPlan::build(plan.original.clone(), plan.params).block.n_blocks(),
        );
    });
    table.row(vec![
        "plan build (incl. CSR copy)".into(),
        fmt_secs(m.p50()),
        format!("{:.1}", plan.nnz() as f64 / m.p50() / 1e6),
    ]);
    let m = time_fn("bell_export", 1, 0.5, || {
        std::hint::black_box(BellLayout::build(&plan.sorted.csr, &plan.block).buckets.len());
    });
    table.row(vec![
        "BELL export".into(),
        fmt_secs(m.p50()),
        format!("{:.1}", plan.nnz() as f64 / m.p50() / 1e6),
    ]);
    print!("{}", table.render());

    Ok(())
}
