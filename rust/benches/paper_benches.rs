//! `cargo bench` entry point (criterion is unavailable offline; this is
//! a harness=false bench binary over `util::bench`).
//!
//! Regenerates every paper table/figure on the simulator (writing
//! `results/*.csv`) and runs the microbenchmarks that back the paper's
//! complexity claims: O(n) preprocessing scaling and the hot-path
//! executor throughputs.

use accel_gcn::bench::paper::{self, SweepConfig};
use accel_gcn::graph::datasets::{by_name, materialize, ScalePolicy};
use accel_gcn::graph::degree::DegreeSorted;
use accel_gcn::partition::block_level::BlockPartition;
use accel_gcn::partition::bucket::BellLayout;
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::partition::warp_level::WarpPartition;
use accel_gcn::spmm::{spmm_block_level, spmm_warp_level};
use accel_gcn::util::bench::{fmt_secs, time_fn, Table};
use accel_gcn::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // cargo bench passes --bench; accept and ignore it
    let argv: Vec<String> = argv.into_iter().filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv, &["out", "seed", "experiment"], &["quick", "skip-paper"])?;
    let seed = args.u64_or("seed", 42)?;
    let out = args.str_or("out", "results");
    std::fs::create_dir_all(Path::new(&out))?;

    if !args.flag("skip-paper") {
        // full paper regeneration (tables + figures + CSVs)
        paper::run_from_args(&args)?;
    }

    // --- microbenchmarks -------------------------------------------------
    println!("\n=== Preprocessing scaling (O(n) claim, §III-C) ===");
    print!("{}", paper::preprocessing_scaling(seed));

    println!("\n=== Hot-path executor microbench (collab-scaled, f=64) ===");
    let policy = if args.flag("quick") { ScalePolicy::tiny() } else { ScalePolicy { node_cap: 30_000, edge_cap: 300_000 } };
    let csr = materialize(by_name("collab").unwrap(), policy, seed);
    let params = PartitionParams::default();
    let ds = DegreeSorted::new(&csr);
    let bp = BlockPartition::build(&ds.csr, params);
    let wp = WarpPartition::build(&csr, params.max_warp_nzs);
    let layout = BellLayout::build(&ds.csr, &bp);
    let f = 64;
    let x = vec![0.5f32; csr.n_rows * f];

    let mut table = Table::new(&["executor", "p50", "GFLOP/s"]);
    let flops = 2.0 * csr.nnz() as f64 * f as f64 / 1e9;
    let m = time_fn("block_exec", 1, 0.5, || {
        std::hint::black_box(spmm_block_level(&ds.csr, &bp, &x, f));
    });
    table.row(vec!["block-level (paper)".into(), fmt_secs(m.p50()), format!("{:.2}", flops / m.p50())]);
    let m = time_fn("warp_exec", 1, 0.5, || {
        std::hint::black_box(spmm_warp_level(&csr, &wp, &x, f));
    });
    table.row(vec!["warp-level (GNNAdvisor)".into(), fmt_secs(m.p50()), format!("{:.2}", flops / m.p50())]);
    let m = time_fn("bell_exec", 1, 0.5, || {
        std::hint::black_box(layout.execute(&x, f));
    });
    table.row(vec!["BELL layout".into(), fmt_secs(m.p50()), format!("{:.2}", flops / m.p50())]);
    let m = time_fn("csr_dense", 1, 0.5, || {
        std::hint::black_box(ds.csr.spmm_dense(&x, f));
    });
    table.row(vec!["CSR reference".into(), fmt_secs(m.p50()), format!("{:.2}", flops / m.p50())]);
    print!("{}", table.render());

    println!("\n=== Partitioning throughput ===");
    let mut table = Table::new(&["stage", "p50", "edges/s (M)"]);
    let m = time_fn("degree_sort", 1, 0.5, || {
        std::hint::black_box(DegreeSorted::new(&csr).perm.len());
    });
    table.row(vec!["degree sort".into(), fmt_secs(m.p50()), format!("{:.1}", csr.nnz() as f64 / m.p50() / 1e6)]);
    let m = time_fn("block_partition", 1, 0.5, || {
        std::hint::black_box(BlockPartition::build(&ds.csr, params).n_blocks());
    });
    table.row(vec!["block partition (Alg. 2)".into(), fmt_secs(m.p50()), format!("{:.1}", csr.nnz() as f64 / m.p50() / 1e6)]);
    let m = time_fn("bell_export", 1, 0.5, || {
        std::hint::black_box(BellLayout::build(&ds.csr, &bp).buckets.len());
    });
    table.row(vec!["BELL export".into(), fmt_secs(m.p50()), format!("{:.1}", csr.nnz() as f64 / m.p50() / 1e6)]);
    print!("{}", table.render());

    Ok(())
}
