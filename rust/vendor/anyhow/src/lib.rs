//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no vendored registry,
//! so this crate re-implements the small surface the workspace actually
//! uses: [`Error`] (a context chain), [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Semantics match upstream where it matters:
//!
//! * `{e}` displays the outermost message; `{e:#}` displays the whole
//!   chain joined with `": "` (upstream's alternate formatting).
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its source chain.
//! * [`Error`] intentionally does **not** implement `std::error::Error`
//!   (same as upstream), which is what keeps the blanket `From` image
//!   coherent.

use std::error::Error as StdError;
use std::fmt;

/// An error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` by default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a single message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a higher-level context message onto the chain.
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // upstream prints the message plus a "Caused by" list; a single
        // joined line carries the same information for test failures
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn StdError + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too large: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too large: 12");
        assert!(format!("{}", f(3).unwrap_err()).contains("condition failed"));
        assert!(f(5).is_err());
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn context_on_option_and_result() {
        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let r: Result<u8> = Err(Error::msg("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
