//! Offline stand-in for the `xla` (PJRT) Rust bindings.
//!
//! The real crate wraps libpjrt + XLA; neither is available in this
//! build environment. This stub keeps the exact API surface
//! `runtime::{tensor, client}` and `coordinator::engine` compile
//! against, with honest runtime behaviour:
//!
//! * [`Literal`] is fully functional — host round-trips (create from
//!   untyped bytes, read back as `f32`/`i32`, shape queries) work, so
//!   everything up to device execution is testable.
//! * [`PjRtClient::cpu`] succeeds (it owns no device), but
//!   [`PjRtClient::compile`] returns an error: artifact execution
//!   requires the real PJRT library. The integration tests that need it
//!   already skip when no AOT artifacts are present.
//! * Client/executable types carry an `Rc` so they are `!Send`, matching
//!   the real bindings — `coordinator::engine`'s single-device-thread
//!   design is enforced by the type system even under the stub.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` (the `xla` path dependency).

use std::error::Error as StdError;
use std::fmt;
use std::rc::Rc;

/// Stub error type (`std::error::Error`, so callers' `anyhow` contexts
/// apply unchanged).
#[derive(Debug)]
pub struct Error(String);

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl StdError for Error {}

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// XLA element types (the subset the workspace stores, plus enough
/// variants that dtype dispatch stays a genuine match).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::U8 => 1,
            ElementType::S32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::F64 => 8,
        }
    }
}

/// Rust scalar ↔ XLA element type binding for [`Literal::to_vec`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(bytes: &[u8]) -> f32 {
        f32::from_le_bytes(bytes.try_into().expect("4 bytes"))
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(bytes: &[u8]) -> i32 {
        i32::from_le_bytes(bytes.try_into().expect("4 bytes"))
    }
}

/// Array shape: element type + dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side literal: shape + little-endian element bytes, or a tuple
/// of literals (what executable results decompose into).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build an array literal from raw bytes (row-major).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.size() != data.len() {
            return err(format!(
                "untyped data is {} bytes, shape {:?} of {:?} needs {}",
                data.len(),
                dims,
                ty,
                n * ty.size()
            ));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
            tuple: None,
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return err("literal is a tuple, not an array");
        }
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return err("literal is a tuple, not an array");
        }
        if self.ty != T::TY {
            return err(format!("literal is {:?}, requested {:?}", self.ty, T::TY));
        }
        let size = self.ty.size();
        Ok(self.data.chunks_exact(size).map(T::from_le_bytes).collect())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => err("literal is an array, not a tuple"),
        }
    }
}

/// Parsed HLO module text. The stub only retains the text; compilation
/// is where the stub stops.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => err(format!("read HLO text {path}: {e}")),
        }
    }
}

/// An XLA computation (opaque in the stub).
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: () }
    }
}

const NO_PJRT: &str =
    "PJRT is unavailable in this offline build (xla is the in-tree stub; see rust/vendor/xla)";

/// PJRT client handle. `!Send` like the real bindings (`Rc`-backed).
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// Create a CPU client. Succeeds — literal plumbing needs no device;
    /// only [`PjRtClient::compile`] requires the real library.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: Rc::new(()) })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(NO_PJRT)
    }
}

/// A compiled executable (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_PJRT)
    }
}

/// A device buffer (never constructed by the stub).
pub struct PjRtBuffer {
    _not_send: Rc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn shape_size_validated() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 7])
            .is_err());
    }

    #[test]
    fn client_exists_but_compile_fails() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        assert!(c.compile(&comp).is_err());
    }
}
