//! [`PlanCache`]: memoized [`SpmmPlan`]s keyed by graph fingerprint.
//!
//! ## Cache-key semantics
//!
//! The key is `(GraphFingerprint, PartitionParams)`. The fingerprint
//! covers the matrix dimensions, nonzero count, and a 64-bit content
//! hash of all three CSR arrays — so a hit means "same matrix, same
//! tunables", and the cached plan's degree sort, permutation, and both
//! partitions are valid verbatim. Requesting the same graph with
//! different `PartitionParams` builds (and caches) a separate plan.
//!
//! Plans are returned as `Arc<SpmmPlan>`: the cache and every consumer
//! share one immutable instance, so a hit costs one fingerprint pass
//! over the CSR (O(nnz)) instead of the full sort + partition chain.
//!
//! The default cache never evicts; it is bounded by the number of
//! distinct (graph, params) pairs a process touches. Long-running
//! processes that cycle through many graphs should either call
//! [`PlanCache::clear`] or use a capacity-bounded cache
//! ([`PlanCache::bounded`]) which evicts the least-recently-used plan
//! once `capacity` plans are resident — the policy the native serve
//! subsystem relies on for multi-tenancy (each cached plan owns two
//! copies of the matrix: original and sorted).
//!
//! Concurrency: `plan_for` is callable from any thread. Plan
//! construction happens outside the map lock, so two threads racing on
//! the same cold key may both build; the first insert wins and both get
//! the same `Arc` afterwards. Eviction only drops the cache's `Arc`:
//! consumers holding a plan keep it alive.

use super::plan::{GraphFingerprint, SpmmPlan};
use crate::graph::csr::Csr;
use crate::partition::patterns::PartitionParams;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    fingerprint: GraphFingerprint,
    params: PartitionParams,
}

#[derive(Debug)]
struct Entry {
    plan: Arc<SpmmPlan>,
    /// Logical timestamp of the last `plan_for` touching this entry.
    last_used: u64,
}

/// Process-wide memoization of SpMM plans.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Entry>>,
    /// `None` = unbounded (the historical default).
    capacity: Option<usize>,
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// A cache holding at most `capacity` plans (≥ 1), evicting the
    /// least-recently-used entry on overflow.
    pub fn bounded(capacity: usize) -> PlanCache {
        PlanCache { capacity: Some(capacity.max(1)), ..PlanCache::default() }
    }

    /// The process-wide cache shared by the binary, the bench harness,
    /// and the serving coordinator.
    pub fn global() -> &'static PlanCache {
        static GLOBAL: OnceLock<PlanCache> = OnceLock::new();
        GLOBAL.get_or_init(PlanCache::new)
    }

    /// Get (or build) the plan for `csr` under `params`.
    pub fn plan_for(&self, csr: &Csr, params: PartitionParams) -> Arc<SpmmPlan> {
        self.plan_for_keyed(GraphFingerprint::of(csr), csr, params)
    }

    /// [`PlanCache::plan_for`] with a caller-supplied fingerprint,
    /// skipping the O(nnz) hash on every lookup. The caller promises
    /// `fingerprint == GraphFingerprint::of(csr)` — the serve registry
    /// computes it once at registration, turning the steady-state hot
    /// path into a plain map probe.
    pub fn plan_for_keyed(
        &self,
        fingerprint: GraphFingerprint,
        csr: &Csr,
        params: PartitionParams,
    ) -> Arc<SpmmPlan> {
        let key = PlanKey { fingerprint, params };
        let now = self.clock.fetch_add(1, Ordering::Relaxed);
        if let Some(entry) = self.plans.lock().unwrap().get_mut(&key) {
            entry.last_used = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&entry.plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // build outside the lock: preprocessing is the expensive part
        let plan = Arc::new(SpmmPlan::build(csr.clone(), params));
        plan.seed_fingerprint(key.fingerprint); // already hashed for the key
        let mut map = self.plans.lock().unwrap();
        let plan =
            Arc::clone(&map.entry(key).or_insert(Entry { plan, last_used: now }).plan);
        if let Some(cap) = self.capacity {
            while map.len() > cap {
                // O(len) scan; bounded caches are small by construction
                let lru = map
                    .iter()
                    .filter(|(k, _)| **k != key) // never evict what we just returned
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                match lru {
                    Some(k) => {
                        map.remove(&k);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break, // capacity 1 and only the fresh key resident
                }
            }
        }
        plan
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits since creation; `clear` does not reset the counters.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Plans evicted by the LRU policy (always 0 for unbounded caches).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Drop every cached plan (outstanding `Arc`s stay alive).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(seed: u64) -> Csr {
        let mut rng = crate::util::rng::Pcg::seed_from(seed);
        let edges: Vec<(u32, u32, f32)> = (0..120)
            .map(|_| (rng.range(0, 40) as u32, rng.range(0, 40) as u32, rng.f32() + 0.1))
            .collect();
        Csr::from_edges(40, 40, &edges).unwrap()
    }

    #[test]
    fn second_request_hits_and_shares() {
        let cache = PlanCache::new();
        let g = graph(1);
        let p1 = cache.plan_for(&g, PartitionParams::default());
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 1, 1));
        let p2 = cache.plan_for(&g, PartitionParams::default());
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the same plan");
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn params_are_part_of_the_key() {
        let cache = PlanCache::new();
        let g = graph(2);
        let a = cache.plan_for(&g, PartitionParams::default());
        let b = cache.plan_for(&g, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
        assert_eq!(b.params.max_block_warps, 2);
    }

    #[test]
    fn different_graphs_miss() {
        let cache = PlanCache::new();
        let a = cache.plan_for(&graph(3), PartitionParams::default());
        let b = cache.plan_for(&graph(4), PartitionParams::default());
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn clear_drops_plans_but_arcs_survive() {
        let cache = PlanCache::new();
        let g = graph(5);
        let p = cache.plan_for(&g, PartitionParams::default());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(p.n_rows(), 40); // outstanding Arc still usable
        let p2 = cache.plan_for(&g, PartitionParams::default());
        assert!(!Arc::ptr_eq(&p, &p2), "rebuilt after clear");
    }

    #[test]
    fn global_is_a_singleton() {
        assert!(std::ptr::eq(PlanCache::global(), PlanCache::global()));
    }

    #[test]
    fn bounded_evicts_least_recently_used() {
        let cache = PlanCache::bounded(2);
        let (g1, g2, g3) = (graph(10), graph(11), graph(12));
        let params = PartitionParams::default();
        cache.plan_for(&g1, params);
        cache.plan_for(&g2, params);
        cache.plan_for(&g1, params); // touch g1: g2 becomes LRU
        let before_g1 = cache.misses();
        cache.plan_for(&g3, params); // overflow: evicts g2
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        cache.plan_for(&g1, params); // still resident
        assert_eq!(cache.misses(), before_g1 + 1, "g1 must hit after g3's insert");
        cache.plan_for(&g2, params); // evicted: rebuilds (and evicts again)
        assert_eq!(cache.misses(), before_g1 + 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn bounded_capacity_one_keeps_latest() {
        let cache = PlanCache::bounded(1);
        let params = PartitionParams::default();
        let a = cache.plan_for(&graph(20), params);
        let b = cache.plan_for(&graph(21), params);
        assert_eq!(cache.len(), 1);
        assert!(!Arc::ptr_eq(&a, &b));
        // the evicted Arc stays usable
        assert_eq!(a.n_rows(), 40);
        // latest entry hits
        let b2 = cache.plan_for(&graph(21), params);
        assert!(Arc::ptr_eq(&b, &b2));
    }

    #[test]
    fn unbounded_never_evicts() {
        let cache = PlanCache::new();
        for seed in 0..10 {
            cache.plan_for(&graph(30 + seed), PartitionParams::default());
        }
        assert_eq!(cache.len(), 10);
        assert_eq!(cache.evictions(), 0);
    }
}
