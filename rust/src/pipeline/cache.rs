//! [`PlanCache`]: memoized [`SpmmPlan`]s keyed by graph fingerprint.
//!
//! ## Cache-key semantics
//!
//! The key is `(GraphFingerprint, PartitionParams)`. The fingerprint
//! covers the matrix dimensions, nonzero count, and a 64-bit content
//! hash of all three CSR arrays — so a hit means "same matrix, same
//! tunables", and the cached plan's degree sort, permutation, and both
//! partitions are valid verbatim. Requesting the same graph with
//! different `PartitionParams` builds (and caches) a separate plan.
//!
//! Plans are returned as `Arc<SpmmPlan>`: the cache and every consumer
//! share one immutable instance, so a hit costs one fingerprint pass
//! over the CSR (O(nnz)) instead of the full sort + partition chain.
//!
//! The default cache never evicts; it is bounded by the number of
//! distinct (graph, params) pairs a process touches. Long-running
//! processes that cycle through many graphs should either call
//! [`PlanCache::clear`] or use a capacity-bounded cache
//! ([`PlanCache::bounded`]) which evicts the least-recently-used plan
//! once `capacity` plans are resident — the policy the native serve
//! subsystem relies on for multi-tenancy (each cached plan owns two
//! copies of the matrix: original and sorted).
//!
//! Concurrency: `plan_for` is callable from any thread. Plan
//! construction happens outside the map lock, so two threads racing on
//! the same cold key may both build; the first insert wins and both get
//! the same `Arc` afterwards. Eviction only drops the cache's `Arc`:
//! consumers holding a plan keep it alive.
//!
//! Dynamic graphs: when a resident graph's topology changes its
//! fingerprint changes with it, so the stale plan would sit in the
//! cache forever (unbounded) or squat an LRU slot (bounded).
//! [`PlanCache::invalidate`] drops exactly one `(graph, params)` entry,
//! and [`PlanCache::refresh`] atomically replaces a stale entry with an
//! incrementally patched plan under its new fingerprint — the delta
//! subsystem's epoch-swap path (see [`crate::delta`]). Both are counted
//! in [`PlanCache::invalidations`] alongside hits/misses/evictions.

use super::plan::{GraphFingerprint, SpmmPlan};
use crate::graph::csr::Csr;
use crate::partition::patterns::PartitionParams;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// The full cache key: one graph identity under one set of partition
/// tunables. Public so the delta subsystem can invalidate/refresh a
/// specific resident plan ([`PlanCache::invalidate`],
/// [`PlanCache::refresh`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GraphKey {
    pub fingerprint: GraphFingerprint,
    pub params: PartitionParams,
}

#[derive(Debug)]
struct Entry {
    plan: Arc<SpmmPlan>,
    /// Logical timestamp of the last `plan_for` touching this entry.
    last_used: u64,
}

/// Process-wide memoization of SpMM plans.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<GraphKey, Entry>>,
    /// `None` = unbounded (the historical default).
    capacity: Option<usize>,
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    invalidations: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// A cache holding at most `capacity` plans (≥ 1), evicting the
    /// least-recently-used entry on overflow.
    pub fn bounded(capacity: usize) -> PlanCache {
        PlanCache { capacity: Some(capacity.max(1)), ..PlanCache::default() }
    }

    /// The process-wide cache shared by the binary, the bench harness,
    /// and the serving coordinator.
    pub fn global() -> &'static PlanCache {
        static GLOBAL: OnceLock<PlanCache> = OnceLock::new();
        GLOBAL.get_or_init(PlanCache::new)
    }

    /// Get (or build) the plan for `csr` under `params`.
    pub fn plan_for(&self, csr: &Csr, params: PartitionParams) -> Arc<SpmmPlan> {
        self.plan_for_keyed(GraphFingerprint::of(csr), csr, params)
    }

    /// [`PlanCache::plan_for`] with a caller-supplied fingerprint,
    /// skipping the O(nnz) hash on every lookup. The caller promises
    /// `fingerprint == GraphFingerprint::of(csr)` — the serve registry
    /// computes it once at registration, turning the steady-state hot
    /// path into a plain map probe.
    pub fn plan_for_keyed(
        &self,
        fingerprint: GraphFingerprint,
        csr: &Csr,
        params: PartitionParams,
    ) -> Arc<SpmmPlan> {
        let key = GraphKey { fingerprint, params };
        let now = self.clock.fetch_add(1, Ordering::Relaxed);
        if let Some(entry) = self.plans.lock().unwrap().get_mut(&key) {
            entry.last_used = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&entry.plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // build outside the lock: preprocessing is the expensive part
        let plan = Arc::new(SpmmPlan::build(csr.clone(), params));
        plan.seed_fingerprint(key.fingerprint); // already hashed for the key
        let mut map = self.plans.lock().unwrap();
        let plan =
            Arc::clone(&map.entry(key).or_insert(Entry { plan, last_used: now }).plan);
        self.enforce_capacity(&mut map, &key);
        plan
    }

    /// Evict least-recently-used entries (never `keep`) until the map
    /// fits the configured capacity.
    fn enforce_capacity(&self, map: &mut HashMap<GraphKey, Entry>, keep: &GraphKey) {
        if let Some(cap) = self.capacity {
            while map.len() > cap {
                // O(len) scan; bounded caches are small by construction
                let lru = map
                    .iter()
                    .filter(|(k, _)| *k != keep) // never evict what we just returned
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                match lru {
                    Some(k) => {
                        map.remove(&k);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break, // capacity 1 and only the fresh key resident
                }
            }
        }
    }

    /// The resident plan for `key`, if any, without building on a miss.
    /// Refreshes the entry's LRU position but touches no hit/miss
    /// counters (this is the delta path's introspection probe, not a
    /// serving lookup).
    pub fn peek(&self, key: &GraphKey) -> Option<Arc<SpmmPlan>> {
        let now = self.clock.fetch_add(1, Ordering::Relaxed);
        let mut map = self.plans.lock().unwrap();
        map.get_mut(key).map(|e| {
            e.last_used = now;
            Arc::clone(&e.plan)
        })
    }

    /// Drop the plan cached under exactly `key`. Returns whether a plan
    /// was resident (and therefore dropped); counted in
    /// [`PlanCache::invalidations`]. Unlike [`PlanCache::clear`], other
    /// tenants' plans are untouched.
    pub fn invalidate(&self, key: &GraphKey) -> bool {
        let dropped = self.plans.lock().unwrap().remove(key).is_some();
        if dropped {
            self.invalidations.fetch_add(1, Ordering::Relaxed);
        }
        dropped
    }

    /// Replace the plan cached under `old` with `plan`, keyed by the
    /// plan's own fingerprint under the same params — the delta
    /// subsystem's patch path: the old graph's entry is invalidated (if
    /// resident) and the patched plan becomes immediately servable
    /// without a build-on-miss. Returns the new key.
    pub fn refresh(&self, old: &GraphKey, plan: Arc<SpmmPlan>) -> GraphKey {
        let key = GraphKey { fingerprint: plan.fingerprint(), params: old.params };
        let now = self.clock.fetch_add(1, Ordering::Relaxed);
        let mut map = self.plans.lock().unwrap();
        if old != &key && map.remove(old).is_some() {
            self.invalidations.fetch_add(1, Ordering::Relaxed);
        }
        map.insert(key, Entry { plan, last_used: now });
        self.enforce_capacity(&mut map, &key);
        key
    }

    /// Snapshot of every resident `(key, plan)` pair, in unspecified
    /// order. The tuning loop enumerates these to re-cut each tenant's
    /// shard boundaries against measured cost; LRU positions and
    /// counters are untouched.
    pub fn entries(&self) -> Vec<(GraphKey, Arc<SpmmPlan>)> {
        self.plans
            .lock()
            .unwrap()
            .iter()
            .map(|(k, e)| (*k, Arc::clone(&e.plan)))
            .collect()
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits since creation; `clear` does not reset the counters.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Plans evicted by the LRU policy (always 0 for unbounded caches).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Plans dropped by [`PlanCache::invalidate`] or displaced by
    /// [`PlanCache::refresh`].
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// Drop every cached plan (outstanding `Arc`s stay alive).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(seed: u64) -> Csr {
        let mut rng = crate::util::rng::Pcg::seed_from(seed);
        let edges: Vec<(u32, u32, f32)> = (0..120)
            .map(|_| (rng.range(0, 40) as u32, rng.range(0, 40) as u32, rng.f32() + 0.1))
            .collect();
        Csr::from_edges(40, 40, &edges).unwrap()
    }

    #[test]
    fn second_request_hits_and_shares() {
        let cache = PlanCache::new();
        let g = graph(1);
        let p1 = cache.plan_for(&g, PartitionParams::default());
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 1, 1));
        let p2 = cache.plan_for(&g, PartitionParams::default());
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the same plan");
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn params_are_part_of_the_key() {
        let cache = PlanCache::new();
        let g = graph(2);
        let a = cache.plan_for(&g, PartitionParams::default());
        let b = cache.plan_for(&g, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
        assert_eq!(b.params.max_block_warps, 2);
    }

    #[test]
    fn different_graphs_miss() {
        let cache = PlanCache::new();
        let a = cache.plan_for(&graph(3), PartitionParams::default());
        let b = cache.plan_for(&graph(4), PartitionParams::default());
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn clear_drops_plans_but_arcs_survive() {
        let cache = PlanCache::new();
        let g = graph(5);
        let p = cache.plan_for(&g, PartitionParams::default());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(p.n_rows(), 40); // outstanding Arc still usable
        let p2 = cache.plan_for(&g, PartitionParams::default());
        assert!(!Arc::ptr_eq(&p, &p2), "rebuilt after clear");
    }

    #[test]
    fn global_is_a_singleton() {
        assert!(std::ptr::eq(PlanCache::global(), PlanCache::global()));
    }

    #[test]
    fn bounded_evicts_least_recently_used() {
        let cache = PlanCache::bounded(2);
        let (g1, g2, g3) = (graph(10), graph(11), graph(12));
        let params = PartitionParams::default();
        cache.plan_for(&g1, params);
        cache.plan_for(&g2, params);
        cache.plan_for(&g1, params); // touch g1: g2 becomes LRU
        let before_g1 = cache.misses();
        cache.plan_for(&g3, params); // overflow: evicts g2
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        cache.plan_for(&g1, params); // still resident
        assert_eq!(cache.misses(), before_g1 + 1, "g1 must hit after g3's insert");
        cache.plan_for(&g2, params); // evicted: rebuilds (and evicts again)
        assert_eq!(cache.misses(), before_g1 + 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn bounded_capacity_one_keeps_latest() {
        let cache = PlanCache::bounded(1);
        let params = PartitionParams::default();
        let a = cache.plan_for(&graph(20), params);
        let b = cache.plan_for(&graph(21), params);
        assert_eq!(cache.len(), 1);
        assert!(!Arc::ptr_eq(&a, &b));
        // the evicted Arc stays usable
        assert_eq!(a.n_rows(), 40);
        // latest entry hits
        let b2 = cache.plan_for(&graph(21), params);
        assert!(Arc::ptr_eq(&b, &b2));
    }

    #[test]
    fn unbounded_never_evicts() {
        let cache = PlanCache::new();
        for seed in 0..10 {
            cache.plan_for(&graph(30 + seed), PartitionParams::default());
        }
        assert_eq!(cache.len(), 10);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn invalidate_drops_one_key_only() {
        let cache = PlanCache::new();
        let (g1, g2) = (graph(40), graph(41));
        let params = PartitionParams::default();
        let p1 = cache.plan_for(&g1, params);
        cache.plan_for(&g2, params);
        let key = GraphKey { fingerprint: p1.fingerprint(), params };
        assert!(cache.invalidate(&key), "resident plan must be dropped");
        assert_eq!(cache.len(), 1, "other tenant untouched");
        assert_eq!(cache.invalidations(), 1);
        assert!(!cache.invalidate(&key), "second invalidate finds nothing");
        assert_eq!(cache.invalidations(), 1, "no-op invalidate not counted");
        // the dropped graph rebuilds on its next request
        let before = cache.misses();
        cache.plan_for(&g1, params);
        assert_eq!(cache.misses(), before + 1);
    }

    #[test]
    fn peek_returns_resident_without_building() {
        let cache = PlanCache::new();
        let g = graph(42);
        let params = PartitionParams::default();
        let key = GraphKey { fingerprint: GraphFingerprint::of(&g), params };
        assert!(cache.peek(&key).is_none(), "peek must not build");
        assert_eq!((cache.len(), cache.misses()), (0, 0));
        let p = cache.plan_for(&g, params);
        let peeked = cache.peek(&key).expect("resident after plan_for");
        assert!(Arc::ptr_eq(&p, &peeked));
        assert_eq!(cache.hits(), 0, "peek leaves the hit counter alone");
    }

    #[test]
    fn refresh_swaps_stale_entry_for_patched_plan() {
        let cache = PlanCache::new();
        let (g_old, g_new) = (graph(50), graph(51));
        let params = PartitionParams::default();
        let old_plan = cache.plan_for(&g_old, params);
        let old_key = GraphKey { fingerprint: old_plan.fingerprint(), params };
        let patched = Arc::new(crate::pipeline::SpmmPlan::build(g_new.clone(), params));
        let new_key = cache.refresh(&old_key, Arc::clone(&patched));
        assert_eq!(new_key.fingerprint, GraphFingerprint::of(&g_new));
        assert_eq!(cache.len(), 1, "old entry displaced, new resident");
        assert_eq!(cache.invalidations(), 1);
        assert!(cache.peek(&old_key).is_none());
        // the refreshed plan serves without a rebuild
        let before = cache.misses();
        let got = cache.plan_for(&g_new, params);
        assert!(Arc::ptr_eq(&got, &patched));
        assert_eq!(cache.misses(), before, "refresh pre-warmed the new key");
    }

    /// The refresh pre-warm satellite: when a patched plan's degree
    /// stats move rows across the dense/sparse crossover, the plan
    /// served from the refreshed cache entry must carry the *re-run*
    /// per-bucket kernel selection — identical to a from-scratch
    /// rebuild's schedule, not the stale pre-patch one.
    #[test]
    fn refresh_carries_patched_kernel_schedule() {
        use crate::delta::graph::{DeltaGraph, EdgeUpdate};
        use crate::spmm::microkernel::SPARSE_DEG_MAX;

        // a graph whose rows all sit in gather territory
        let n = 30usize;
        let edges: Vec<(u32, u32, f32)> =
            (0..n as u32).map(|r| (r, (r + 1) % n as u32, 1.0)).collect();
        let base = Csr::from_edges(n, n, &edges).unwrap();
        let params = PartitionParams::default();
        let cache = PlanCache::new();
        let plan = cache.plan_for(&base, params);
        assert_eq!(plan.kernels.n_dense, 0, "degree-1 rows all select gather");
        let old_key = GraphKey { fingerprint: plan.fingerprint(), params };

        // push row 0 well past the crossover via a delta batch
        let mut dg = DeltaGraph::with_threshold(base, 1e9);
        let batch: Vec<EdgeUpdate> = (2..(SPARSE_DEG_MAX as u32 + 4))
            .map(|c| EdgeUpdate::Insert { row: 0, col: c, val: 0.5 })
            .collect();
        let rep = dg.apply(&batch).unwrap();
        let new_csr = dg.snapshot();
        let (patched, _) = crate::delta::patch_plan(&plan, new_csr.clone(), &rep.changes).unwrap();
        let new_key = cache.refresh(&old_key, Arc::new(patched));

        let served = cache.peek(&new_key).expect("patched plan resident after refresh");
        let rebuilt = SpmmPlan::build(new_csr, params);
        assert_eq!(served.kernels, rebuilt.kernels, "refresh must carry re-run selection");
        assert!(served.kernels.n_dense >= 1, "row 0 crossed to the dense kernel");
        assert!(served.kernels.n_sparse >= 1, "untouched rows stay on gather");
    }

    #[test]
    fn refresh_respects_capacity() {
        let cache = PlanCache::bounded(2);
        let params = PartitionParams::default();
        cache.plan_for(&graph(60), params);
        cache.plan_for(&graph(61), params);
        // refresh under a key that was never resident: plain insert + LRU
        let phantom = GraphKey { fingerprint: GraphFingerprint::of(&graph(62)), params };
        let plan = Arc::new(crate::pipeline::SpmmPlan::build(graph(63), params));
        cache.refresh(&phantom, plan);
        assert_eq!(cache.len(), 2, "capacity still enforced after refresh");
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.invalidations(), 0, "phantom key displaced nothing");
    }
}
