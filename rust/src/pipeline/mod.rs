//! Unified SpMM execution pipeline — the one road from an adjacency
//! matrix to an executed (or simulated) schedule.
//!
//! Before this layer existed, every consumer hand-wired the same chain
//! — degree sort → block-level partition → executor — and re-ran it per
//! request. The pipeline centralizes that chain and makes it cacheable
//! and parallel:
//!
//! * [`plan`] — [`SpmmPlan`]: owns the degree-sorted CSR, the
//!   permutation, and both partitions for one graph; built once, shared
//!   via `Arc`, immutable thereafter (see the module docs for plan
//!   lifetime).
//! * [`cache`] — [`PlanCache`]: memoizes plans by
//!   [`GraphFingerprint`] + [`PartitionParams`](crate::partition::patterns::PartitionParams),
//!   so repeated requests for the same graph skip preprocessing
//!   entirely (see the module docs for cache-key semantics).
//! * [`exec`] — the [`Executor`] trait unifying the CSR reference, the
//!   sequential block-level schedule, and the warp-level baseline under
//!   one original-domain contract.
//! * [`parallel`] — [`ParallelBlockLevel`]: the block-level schedule
//!   sharded across [`crate::util::threadpool::ThreadPool`], executed
//!   through the column-tiled microkernel with zero-copy borrowed
//!   inputs, direct disjoint row writes scattered straight into the
//!   original row order (fused unpermute), and a deterministic
//!   post-join reduction for split rows (see the module docs).
//!
//! Consumers (all four former call sites route through here):
//! * the `accel-gcn` binary (`simulate` builds its plan directly;
//!   `prepare` reaches the cache through the coordinator),
//! * `bench::paper` (the sweep) and `bench::exec_scaling` (the
//!   thread-scaling experiment),
//! * the GPU simulator (`sim::kernels::PreparedGraph` is an alias of
//!   [`SpmmPlan`]),
//! * the serving coordinator (`PreparedDataset::prepare` obtains its
//!   partition from the global cache),
//! * the native serve subsystem (`serve::Server`'s worker executes
//!   every fused batch through a **bounded** [`PlanCache`] and the
//!   parallel executor; see [`crate::serve`]).

pub mod plan;
pub mod cache;
pub mod exec;
pub mod parallel;
pub mod traffic;

pub use cache::{GraphKey, PlanCache};
pub use exec::{AdaptiveBlockLevel, BlockLevel, CsrReference, Executor, WarpLevel};
pub use parallel::{
    shard_ranges_for_plan, spmm_block_level_parallel, spmm_block_level_parallel_into,
    spmm_block_level_parallel_into_with, spmm_block_level_parallel_scalar,
    spmm_block_level_parallel_with, ParallelBlockLevel,
};
pub use plan::{GraphFingerprint, KernelSchedule, SpmmPlan, TunedSharding};
pub use traffic::{block_traffic, BlockTraffic, BucketTraffic, ElemWidths, TrafficModel};
