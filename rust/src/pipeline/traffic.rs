//! Analytic memory-traffic model of the block-level SpMM schedule —
//! the byte-side twin of the FLOP accounting in
//! [`crate::spmm::microkernel`].
//!
//! Accel-GCN's central claim is *memory* efficiency; this module turns
//! that from an assertion into a measurement. A [`TrafficModel`] is
//! attached to every [`SpmmPlan`](super::plan::SpmmPlan) at build time
//! and predicts, exactly, the bytes the parallel executor moves per
//! degree bucket and per [`RowKernel`] variant. The model is derived
//! from the same pure inputs as the kernel schedule — `BlockPartition`
//! metadata plus the per-block [`RowKernel`] choice — so `build()` and
//! the delta path's `from_parts()` produce identical models by
//! construction, and delta-patched plans stay correct.
//!
//! ## The counting convention
//!
//! Bytes are counted at the *instruction* level — every load and store
//! the executor's inner loops issue against the plan's arrays and the
//! X/Y matrices — not at the cache-line level. Per non-empty block:
//!
//! * one 16-byte [`BlockMeta`] read ([`BLOCK_META_BYTES`]);
//! * per nonzero: a 4-byte column index, a 4-byte value, and one
//!   gathered `f`-wide X row (`f · 4` bytes at f32);
//! * destination traffic by kernel shape:
//!   - **dense tiled, non-split** — the tile accumulator lives in
//!     registers, so the destination row is touched once per *row*:
//!     one `f`-wide read-modify-write (`+=` reads then writes `dst`);
//!   - **sparse gather** — each nonzero axpys straight into the
//!     destination row: one `f`-wide RMW per *nonzero*;
//!   - **split chunk** (`deg > deg_bound`, always dense) — one `f`-wide
//!     RMW into the chunk's partial window during execution, then the
//!     post-join reduction reads the window and RMWs the final Y row:
//!     3 `f`-wide reads + 2 `f`-wide writes per chunk in total.
//!
//! Buffer *zeroing* (the `y.fill(0.0)` pass and the partial-arena
//! growth) is deliberately excluded — it is a property of the calling
//! convention (`beta = 0`), not of the schedule, and the instrumented
//! counting executor ([`crate::spmm::verify::spmm_block_level_counting`])
//! applies the identical exclusion so the two agree **byte-for-byte**,
//! split rows included (split chunks carry their actual nonzero count
//! in [`BlockMeta::split_nzs`], so even ragged tail chunks are exact).
//!
//! Empty blocks (`deg == 0` rows) contribute only their metadata read:
//! both kernels early-return before touching the destination.
//!
//! ## Width model
//!
//! Every per-bucket quantity is a *component count* (index loads, value
//! loads, X-row gathers, `f`-wide destination vector ops), so
//! `bytes(f)` is an exact linear function of `f` and of the element
//! widths. [`ElemWidths`] prices the same counts under hypothetical
//! storage types — the report-only i8/f16 "what-if" the tuner and the
//! roofline report print (LW-GCN, PAPERS.md: storage-quantized values
//! and features, f32 index/accumulator/Y traffic).

use crate::partition::block_level::BlockPartition;
use crate::partition::metadata::{BlockMeta, BLOCK_META_BYTES};
use crate::spmm::microkernel::RowKernel;
use std::collections::BTreeMap;

/// Storage width, in bytes, of each traffic component class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElemWidths {
    /// Column index loads (`col_idx`).
    pub idx: usize,
    /// Matrix value loads (`vals`).
    pub val: usize,
    /// Gathered X-row elements.
    pub x: usize,
    /// Destination / partial-arena vector elements (accumulator side).
    pub acc: usize,
}

impl ElemWidths {
    /// The shipped f32 path: everything 4 bytes.
    pub const F32: ElemWidths = ElemWidths { idx: 4, val: 4, x: 4, acc: 4 };
    /// f16-storage what-if: values and features halved, indices and
    /// accumulator/Y traffic still 4 bytes (f32 accumulate).
    pub const F16_STORAGE: ElemWidths = ElemWidths { idx: 4, val: 2, x: 2, acc: 4 };
    /// i8-storage what-if: values and features quartered (per-bucket
    /// affine scales assumed amortized), f32 accumulate.
    pub const I8_STORAGE: ElemWidths = ElemWidths { idx: 4, val: 1, x: 1, acc: 4 };

    pub fn name(self) -> &'static str {
        if self == Self::F32 {
            "f32"
        } else if self == Self::F16_STORAGE {
            "f16-storage"
        } else if self == Self::I8_STORAGE {
            "i8-storage"
        } else {
            "custom"
        }
    }
}

#[inline]
fn bytes_read_of(meta_bytes: u64, nnz: u64, y_vec_reads: u64, f: usize, w: ElemWidths) -> u64 {
    meta_bytes
        + nnz * (w.idx + w.val) as u64
        + nnz * (f * w.x) as u64
        + y_vec_reads * (f * w.acc) as u64
}

#[inline]
fn bytes_written_of(y_vec_writes: u64, f: usize, w: ElemWidths) -> u64 {
    y_vec_writes * (f * w.acc) as u64
}

/// The component counts of one block under one kernel shape — the
/// shared per-block rule both [`TrafficModel::derive`] and the parallel
/// executor's shard sampler apply, so analytic plan totals and measured
/// per-shard bytes can never drift apart.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockTraffic {
    /// Non-split output rows the block finishes.
    pub rows: u64,
    /// Nonzeros the block traverses.
    pub nnz: u64,
    /// `f`-wide destination/partial vector reads.
    pub y_vec_reads: u64,
    /// `f`-wide destination/partial vector writes.
    pub y_vec_writes: u64,
    /// Metadata bytes ([`BLOCK_META_BYTES`] per block).
    pub meta_bytes: u64,
}

impl BlockTraffic {
    pub fn bytes_read_with(&self, f: usize, w: ElemWidths) -> u64 {
        bytes_read_of(self.meta_bytes, self.nnz, self.y_vec_reads, f, w)
    }

    pub fn bytes_written_with(&self, f: usize, w: ElemWidths) -> u64 {
        bytes_written_of(self.y_vec_writes, f, w)
    }

    /// f32 read + written bytes at column width `f`.
    pub fn bytes_total(&self, f: usize) -> u64 {
        self.bytes_read_with(f, ElemWidths::F32) + self.bytes_written_with(f, ElemWidths::F32)
    }
}

/// Component counts of block `m` executed through `kern` — the pure
/// per-block traffic rule (see the module docs for the convention).
/// Split chunks always run dense regardless of `kern`, mirroring the
/// executor's dispatch.
pub fn block_traffic(m: &BlockMeta, kern: RowKernel, deg_bound: usize) -> BlockTraffic {
    let mut t = BlockTraffic { meta_bytes: BLOCK_META_BYTES as u64, ..Default::default() };
    if m.is_split(deg_bound) {
        // chunk RMW into the partial window (1R+1W) + post-join
        // reduction (read window, RMW the final Y row: 2R+1W)
        t.nnz = m.split_nzs() as u64;
        t.y_vec_reads = 3;
        t.y_vec_writes = 2;
    } else {
        let deg = m.deg as u64;
        let rows = m.block_rows() as u64;
        t.rows = rows;
        t.nnz = deg * rows;
        if deg > 0 {
            match kern {
                // register-tile accumulator: one dst RMW per row
                RowKernel::DenseTiled => {
                    t.y_vec_reads = rows;
                    t.y_vec_writes = rows;
                }
                // direct axpy: one dst RMW per nonzero
                RowKernel::SparseGather => {
                    t.y_vec_reads = t.nnz;
                    t.y_vec_writes = t.nnz;
                }
            }
        }
        // deg == 0: both kernels early-return — metadata read only
    }
    t
}

/// Aggregated traffic of every block sharing one
/// `(split, kernel, degree)` bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BucketTraffic {
    /// Row degree of the bucket (for split buckets: the full row degree
    /// whose chunks this bucket holds).
    pub deg: u32,
    /// Whether these are split-row chunks (`deg > deg_bound`).
    pub split: bool,
    /// Kernel shape the bucket's blocks run (split chunks: dense).
    pub kernel: RowKernel,
    /// Blocks aggregated into this bucket.
    pub blocks: u64,
    pub rows: u64,
    pub nnz: u64,
    pub y_vec_reads: u64,
    pub y_vec_writes: u64,
    pub meta_bytes: u64,
}

impl BucketTraffic {
    pub fn bytes_read_with(&self, f: usize, w: ElemWidths) -> u64 {
        bytes_read_of(self.meta_bytes, self.nnz, self.y_vec_reads, f, w)
    }

    pub fn bytes_written_with(&self, f: usize, w: ElemWidths) -> u64 {
        bytes_written_of(self.y_vec_writes, f, w)
    }

    pub fn bytes_total_with(&self, f: usize, w: ElemWidths) -> u64 {
        self.bytes_read_with(f, w) + self.bytes_written_with(f, w)
    }

    /// f32 total at column width `f`.
    pub fn bytes_total(&self, f: usize) -> u64 {
        self.bytes_total_with(f, ElemWidths::F32)
    }

    /// Bytes moved per nonzero at column width `f` (f32).
    pub fn bytes_per_nnz(&self, f: usize) -> f64 {
        if self.nnz == 0 {
            return 0.0;
        }
        self.bytes_total(f) as f64 / self.nnz as f64
    }

    /// FLOPs / byte at column width `f` (f32): `2·nnz·f` over the
    /// bucket's total traffic.
    pub fn arithmetic_intensity(&self, f: usize) -> f64 {
        let b = self.bytes_total(f);
        if b == 0 {
            return 0.0;
        }
        crate::spmm::microkernel::spmm_flops(self.nnz as usize, f) / b as f64
    }
}

/// The plan-level analytic traffic model: one [`BucketTraffic`] per
/// `(split, kernel, degree)` class, derived at plan build (and by the
/// delta patch path) from the partition metadata and the kernel
/// schedule. Immutable, like everything else on the plan.
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficModel {
    /// Buckets sorted by (split, kernel, degree) — non-split gather
    /// first, then non-split dense, then split chunks.
    pub buckets: Vec<BucketTraffic>,
    /// The partition's `deg_bound` (split threshold) the model was
    /// derived under.
    pub deg_bound: usize,
}

impl TrafficModel {
    /// Derive the model from a partition and its kernel schedule — the
    /// same pure inputs [`KernelSchedule::derive`] consumed, so the
    /// build and delta-patch paths agree by construction. Also the hook
    /// the tuner re-runs when it moves the dense/sparse crossover.
    ///
    /// [`KernelSchedule::derive`]: super::plan::KernelSchedule::derive
    pub fn derive(
        block: &BlockPartition,
        kernels: &super::plan::KernelSchedule,
    ) -> TrafficModel {
        debug_assert_eq!(kernels.per_block.len(), block.meta.len());
        let deg_bound = block.params.deg_bound();
        let mut map: BTreeMap<(bool, u8, u32), BucketTraffic> = BTreeMap::new();
        for (b, m) in block.meta.iter().enumerate() {
            let split = m.is_split(deg_bound);
            let kern = if split { RowKernel::DenseTiled } else { kernels.kernel_for(b) };
            let t = block_traffic(m, kern, deg_bound);
            let key = (split, matches!(kern, RowKernel::DenseTiled) as u8, m.deg);
            let e = map.entry(key).or_insert(BucketTraffic {
                deg: m.deg,
                split,
                kernel: kern,
                blocks: 0,
                rows: 0,
                nnz: 0,
                y_vec_reads: 0,
                y_vec_writes: 0,
                meta_bytes: 0,
            });
            e.blocks += 1;
            e.rows += t.rows;
            e.nnz += t.nnz;
            e.y_vec_reads += t.y_vec_reads;
            e.y_vec_writes += t.y_vec_writes;
            e.meta_bytes += t.meta_bytes;
        }
        TrafficModel { buckets: map.into_values().collect(), deg_bound }
    }

    /// Total nonzeros across all buckets (== the plan's nnz).
    pub fn nnz(&self) -> u64 {
        self.buckets.iter().map(|b| b.nnz).sum()
    }

    pub fn bytes_read_with(&self, f: usize, w: ElemWidths) -> u64 {
        self.buckets.iter().map(|b| b.bytes_read_with(f, w)).sum()
    }

    pub fn bytes_written_with(&self, f: usize, w: ElemWidths) -> u64 {
        self.buckets.iter().map(|b| b.bytes_written_with(f, w)).sum()
    }

    pub fn bytes_total_with(&self, f: usize, w: ElemWidths) -> u64 {
        self.bytes_read_with(f, w) + self.bytes_written_with(f, w)
    }

    /// f32 bytes read at column width `f`.
    pub fn bytes_read(&self, f: usize) -> u64 {
        self.bytes_read_with(f, ElemWidths::F32)
    }

    /// f32 bytes written at column width `f`.
    pub fn bytes_written(&self, f: usize) -> u64 {
        self.bytes_written_with(f, ElemWidths::F32)
    }

    /// f32 total bytes (read + written) at column width `f`.
    pub fn bytes_total(&self, f: usize) -> u64 {
        self.bytes_read(f) + self.bytes_written(f)
    }

    /// Bytes moved per nonzero at column width `f` (f32) — the metric
    /// the quantized-path ROADMAP item wants halved.
    pub fn bytes_per_nnz(&self, f: usize) -> f64 {
        let n = self.nnz();
        if n == 0 {
            return 0.0;
        }
        self.bytes_total(f) as f64 / n as f64
    }

    /// Arithmetic intensity at column width `f` (f32): `2·nnz·f` FLOPs
    /// over total bytes. Compared against the calibrated machine
    /// balance (peak GFLOP/s ÷ peak GB/s) for the bandwidth-bound vs
    /// compute-bound verdict.
    pub fn arithmetic_intensity(&self, f: usize) -> f64 {
        let b = self.bytes_total(f);
        if b == 0 {
            return 0.0;
        }
        crate::spmm::microkernel::spmm_flops(self.nnz() as usize, f) / b as f64
    }

    /// Invert the (exactly linear) `bytes_total(f)` to recover the
    /// effective column width behind an observed average bytes/SpMM —
    /// how the tuner prices blocks in ns/byte without threading `f`
    /// through the aggregate. `None` when the plan moves no
    /// `f`-dependent bytes (empty graph).
    pub fn solve_width(&self, bytes_per_spmm: f64) -> Option<f64> {
        let a = self.bytes_total(0) as f64;
        let slope = self.bytes_total(1) as f64 - a;
        if slope <= 0.0 {
            return None;
        }
        Some(((bytes_per_spmm - a) / slope).max(0.0))
    }

    /// Predicted bandwidth win of a storage-quantized path versus f32
    /// at column width `f`: `bytes_f32 / bytes_quantized` (> 1 means
    /// the quantized path moves fewer bytes — a direct throughput
    /// multiplier when bandwidth-bound).
    pub fn quantized_speedup(&self, f: usize, w: ElemWidths) -> f64 {
        let q = self.bytes_total_with(f, w);
        if q == 0 {
            return 1.0;
        }
        self.bytes_total(f) as f64 / q as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::pipeline::plan::SpmmPlan;

    fn plan_of(edges: &[(u32, u32, f32)], n: usize, params: PartitionParams) -> SpmmPlan {
        SpmmPlan::build(Csr::from_edges(n, n, edges).unwrap(), params)
    }

    /// Hand-counted tiny graph: 3 rows of degree 2 (gather territory at
    /// the default crossover) — every component count is checkable on
    /// paper.
    #[test]
    fn hand_counted_gather_bucket() {
        let edges: Vec<(u32, u32, f32)> =
            (0..3u32).flat_map(|r| [(r, 0, 1.0f32), (r, 1, 1.0)]).collect();
        let plan = plan_of(&edges, 3, PartitionParams::default());
        let t = &plan.traffic;
        assert_eq!(t.nnz(), 6);
        let gather: Vec<_> =
            t.buckets.iter().filter(|b| b.kernel == RowKernel::SparseGather).collect();
        assert_eq!(gather.len(), 1, "one degree-2 gather bucket");
        let b = gather[0];
        assert_eq!((b.deg, b.rows, b.nnz), (2, 3, 6));
        assert!(!b.split);
        // gather: one f-wide dst RMW per nonzero
        assert_eq!((b.y_vec_reads, b.y_vec_writes), (6, 6));
        let f = 4;
        // meta + nnz·(4+4) + nnz·f·4 + reads·f·4  /  writes·f·4
        let want_read = b.meta_bytes + 6 * 8 + 6 * (f as u64) * 4 + 6 * (f as u64) * 4;
        assert_eq!(t.bytes_read(f), want_read);
        assert_eq!(t.bytes_written(f), 6 * (f as u64) * 4);
    }

    #[test]
    fn dense_rows_pay_one_rmw_per_row() {
        // one row of degree 8 (dense at crossover 4), never split at
        // the default deg_bound
        let edges: Vec<(u32, u32, f32)> = (0..8u32).map(|c| (0, c % 9, 1.0)).collect();
        let plan = plan_of(&edges, 9, PartitionParams::default());
        let dense: Vec<_> = plan
            .traffic
            .buckets
            .iter()
            .filter(|b| b.kernel == RowKernel::DenseTiled && !b.split)
            .collect();
        let rows: u64 = dense.iter().map(|b| b.rows).sum();
        let reads: u64 = dense.iter().map(|b| b.y_vec_reads).sum();
        let writes: u64 = dense.iter().map(|b| b.y_vec_writes).sum();
        assert_eq!(reads, rows, "dense tiled: one dst read per row");
        assert_eq!(writes, rows, "dense tiled: one dst write per row");
    }

    #[test]
    fn split_chunks_pay_three_reads_two_writes() {
        // one degree-10 row under deg_bound 4 → chunks 4, 4, 2 — the
        // ragged tail chunk must be priced at its ACTUAL size
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let edges: Vec<(u32, u32, f32)> = (0..10u32).map(|c| (0, c, 1.0)).collect();
        let plan = plan_of(&edges, 10, params);
        let split: Vec<_> = plan.traffic.buckets.iter().filter(|b| b.split).collect();
        assert_eq!(split.len(), 1);
        let b = split[0];
        assert_eq!((b.deg, b.blocks, b.nnz), (10, 3, 10), "4+4+2 chunks, exact nnz");
        assert_eq!(b.y_vec_reads, 3 * b.blocks);
        assert_eq!(b.y_vec_writes, 2 * b.blocks);
        assert_eq!(b.rows, 0, "split rows finish in the reduction, not the shard");
    }

    #[test]
    fn empty_rows_cost_metadata_only() {
        let plan = plan_of(&[], 4, PartitionParams::default());
        let t = &plan.traffic;
        assert_eq!(t.nnz(), 0);
        assert_eq!(t.bytes_written(16), 0);
        // whatever deg-0 blocks exist contribute only their meta reads
        assert_eq!(t.bytes_read(16), t.buckets.iter().map(|b| b.meta_bytes).sum::<u64>());
        assert_eq!(t.bytes_read(16), t.bytes_read(1), "no f-dependent traffic");
    }

    /// `bytes_total(f)` is exactly linear in `f` — the property
    /// `solve_width` inverts.
    #[test]
    fn bytes_linear_in_f_and_solve_width_roundtrips() {
        let mut edges = Vec::new();
        for r in 0..40u32 {
            for c in 0..(r % 13) {
                edges.push((r, c, 1.0));
            }
        }
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let plan = plan_of(&edges, 40, params);
        let t = &plan.traffic;
        let a = t.bytes_total(0) as f64;
        let slope = t.bytes_total(1) as f64 - a;
        for f in [3usize, 16, 17, 33] {
            assert_eq!(t.bytes_total(f) as f64, a + slope * f as f64, "linear at f={f}");
            let solved = t.solve_width(t.bytes_total(f) as f64).unwrap();
            assert!((solved - f as f64).abs() < 1e-9, "solve_width({f}) = {solved}");
        }
    }

    #[test]
    fn quantized_widths_shrink_traffic() {
        let edges: Vec<(u32, u32, f32)> =
            (0..30u32).flat_map(|r| (0..6u32).map(move |c| (r, c, 1.0))).collect();
        let plan = plan_of(&edges, 30, PartitionParams::default());
        let t = &plan.traffic;
        let f = 32;
        let f32b = t.bytes_total_with(f, ElemWidths::F32);
        let f16b = t.bytes_total_with(f, ElemWidths::F16_STORAGE);
        let i8b = t.bytes_total_with(f, ElemWidths::I8_STORAGE);
        assert!(f32b > f16b && f16b > i8b);
        assert!(t.quantized_speedup(f, ElemWidths::I8_STORAGE) > 1.0);
        assert_eq!(t.quantized_speedup(f, ElemWidths::F32), 1.0);
        assert_eq!(ElemWidths::F32.name(), "f32");
        assert_eq!(ElemWidths::I8_STORAGE.name(), "i8-storage");
    }

    /// The delta-patch contract: `from_parts` (exercised through a
    /// fresh build of identical parts) derives the identical model.
    #[test]
    fn derive_is_pure_in_partition_and_schedule() {
        let mut edges = Vec::new();
        for r in 0..25u32 {
            for c in 0..(r % 7) {
                edges.push((r, c, 0.5));
            }
        }
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let a = plan_of(&edges, 25, params);
        let b = plan_of(&edges, 25, params);
        assert_eq!(a.traffic, b.traffic);
        assert_eq!(a.traffic, TrafficModel::derive(&a.block, &a.kernels));
        assert_eq!(a.traffic.nnz() as usize, a.nnz());
    }

    #[test]
    fn intensity_grows_with_f_toward_kernel_limit() {
        let edges: Vec<(u32, u32, f32)> =
            (0..50u32).flat_map(|r| (0..8u32).map(move |c| (r, c, 1.0))).collect();
        let plan = plan_of(&edges, 50, PartitionParams::default());
        let t = &plan.traffic;
        let i16 = t.arithmetic_intensity(16);
        let i128 = t.arithmetic_intensity(128);
        assert!(i128 > i16, "per-nonzero overheads amortize with f");
        // SpMM upper bound: 2 flops per gathered x element → at f32,
        // intensity can never reach 0.5 flops/byte
        assert!(i128 < 0.5, "intensity {i128} must stay under the SpMM bound");
    }
}
