//! [`SpmmPlan`]: the product of the paper's preprocessing chain
//! (degree sort → block-level partition, plus the warp-level baseline),
//! built once per graph and reused by every consumer.
//!
//! ## Plan lifetime
//!
//! A plan owns everything derived from one adjacency matrix under one
//! [`PartitionParams`]: the original CSR, the degree-sorted view with
//! its permutation, the block-level partition (the paper's Algorithm 2)
//! and the warp-level baseline partition. Building it is the *only*
//! expensive preprocessing step in the system — O(n + nnz) — so callers
//! hold plans in `Arc` and share them across executors, the GPU
//! simulator, the bench harness, and the serving coordinator. A plan is
//! immutable after construction; repeated executions of any schedule
//! read it concurrently without synchronization.
//!
//! Consumers that need the same graph repeatedly go through
//! [`PlanCache`](super::cache::PlanCache), which keys plans by
//! [`GraphFingerprint`] + params so preprocessing is skipped entirely on
//! a hit.

use crate::graph::csr::Csr;
use crate::graph::degree::DegreeSorted;
use crate::partition::block_level::BlockPartition;
use crate::partition::patterns::PartitionParams;
use crate::partition::warp_level::WarpPartition;
use crate::spmm::microkernel::{RowKernel, SimdLevel};
use super::traffic::TrafficModel;
use std::sync::OnceLock;

/// The sparsity-adaptive kernel schedule: which kernel shape
/// ([`RowKernel`]) each block of the block-level partition runs.
///
/// Derived deterministically from the partition's per-block degree
/// stats by [`KernelSchedule::derive`] — a pure function of
/// `BlockPartition`, so the delta patch path
/// ([`patch_plan`](crate::delta::patch_plan)) reproduces exactly the
/// schedule a from-scratch rebuild would pick (asserted in the delta
/// property tests). Blocks of split rows (`deg > deg_bound`) always run
/// the dense tiled kernel: each chunk carries up to `deg_bound`
/// nonzeros, well past the gather crossover.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelSchedule {
    /// Kernel shape per block, parallel to `BlockPartition::meta`.
    pub per_block: Vec<RowKernel>,
    /// Number of blocks scheduled on the dense tiled kernel.
    pub n_dense: usize,
    /// Number of blocks scheduled on the sparse gather kernel.
    pub n_sparse: usize,
}

impl KernelSchedule {
    /// Select a kernel shape for every block from its degree metadata
    /// ([`crate::spmm::microkernel::select_kernel`] on non-split
    /// blocks, dense for split rows).
    pub fn derive(block: &BlockPartition) -> KernelSchedule {
        // identical to `derive_with` at the static crossover — pinned by
        // the `derive_with_default_crossover_equals_derive` test
        Self::derive_with(block, crate::spmm::microkernel::SPARSE_DEG_MAX)
    }

    /// [`KernelSchedule::derive`] with an explicit dense/sparse degree
    /// crossover instead of the static
    /// [`SPARSE_DEG_MAX`](crate::spmm::microkernel::SPARSE_DEG_MAX) —
    /// the [`PlanTuner`](crate::tune::PlanTuner)'s per-graph revisit of
    /// that threshold. Both kernel shapes accumulate nonzeros in the
    /// same order into a zeroed destination, so moving a block across
    /// the crossover changes performance, never bits.
    pub fn derive_with(block: &BlockPartition, crossover: usize) -> KernelSchedule {
        let deg_bound = block.params.deg_bound();
        let mut per_block = Vec::with_capacity(block.meta.len());
        let mut n_sparse = 0usize;
        for m in &block.meta {
            let k = if m.is_split(deg_bound) || m.deg as usize > crossover {
                RowKernel::DenseTiled
            } else {
                RowKernel::SparseGather
            };
            if k == RowKernel::SparseGather {
                n_sparse += 1;
            }
            per_block.push(k);
        }
        let n_dense = per_block.len() - n_sparse;
        KernelSchedule { per_block, n_dense, n_sparse }
    }

    /// The kernel shape block `b` runs under adaptive dispatch.
    #[inline]
    pub fn kernel_for(&self, b: usize) -> RowKernel {
        self.per_block[b]
    }

    /// Fraction of blocks on the sparse gather kernel (bench reporting).
    pub fn sparse_frac(&self) -> f64 {
        if self.per_block.is_empty() {
            0.0
        } else {
            self.n_sparse as f64 / self.per_block.len() as f64
        }
    }

    /// Human-readable variant tag for metrics footers and bench tables,
    /// e.g. `"avx2+adaptive(dense 12 / sparse 40 blocks)"`.
    pub fn summary(&self, level: SimdLevel) -> String {
        format!(
            "{}+adaptive(dense {} / sparse {} blocks)",
            level.effective().name(),
            self.n_dense,
            self.n_sparse
        )
    }
}

/// Measurement-derived sharding weights attached to a plan by the
/// [`PlanTuner`](crate::tune::PlanTuner).
///
/// When present, the parallel executor cuts `shard_ranges` against
/// `block_cost` (predicted nanoseconds per block, from the fitted
/// per-nonzero kernel costs) instead of the static nonzero prefix —
/// the boundaries move, but every block still runs the same
/// accumulation order into the same rows, and split-row chunks reduce
/// in block order regardless of where the cuts fall, so tuned plans
/// are output-bit-for-bit identical to untuned ones.
#[derive(Clone, Debug)]
pub struct TunedSharding {
    /// Fitted dense-tiled kernel cost, ns per nonzero.
    pub dense_ns_per_nnz: f64,
    /// Fitted sparse-gather kernel cost, ns per nonzero.
    pub sparse_ns_per_nnz: f64,
    /// The dense/sparse degree crossover the tuned [`KernelSchedule`]
    /// was derived with.
    pub crossover: usize,
    /// Predicted cost per block (ns, ≥ 1), parallel to
    /// `BlockPartition::meta` — the weights the executor cuts against.
    pub block_cost: Vec<u64>,
    /// Predicted max/mean shard-cost imbalance of the static
    /// nnz-balanced cuts, at the shard count the tuner evaluated.
    pub predicted_static_imbalance: f64,
    /// Predicted max/mean shard-cost imbalance of the tuned cuts.
    pub predicted_tuned_imbalance: f64,
    /// Shard count the prediction was evaluated at.
    pub n_shards: usize,
}

/// Cheap identity of a CSR matrix: dimensions, nonzero count, and a
/// 64-bit FNV-1a content hash over `row_ptr`/`col_idx`/`vals`.
///
/// Two graphs with the same fingerprint are treated as identical by the
/// [`PlanCache`](super::cache::PlanCache); the structural fields make
/// accidental collisions require a full 64-bit hash collision *between
/// equal-shape graphs*, which we accept (the cache is an optimization —
/// a collision would be astronomically unlikely, not silently frequent).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GraphFingerprint {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    pub content_hash: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv_word(mut h: u64, w: u64) -> u64 {
    // fold the word in 8-bit steps (FNV-1a over little-endian bytes)
    for shift in (0..64).step_by(8) {
        h ^= (w >> shift) & 0xff;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl GraphFingerprint {
    /// Fingerprint a CSR matrix (one linear pass over its arrays).
    pub fn of(csr: &Csr) -> GraphFingerprint {
        let mut h = FNV_OFFSET;
        h = fnv_word(h, csr.n_rows as u64);
        h = fnv_word(h, csr.n_cols as u64);
        for &p in &csr.row_ptr {
            h = fnv_word(h, p as u64);
        }
        for &c in &csr.col_idx {
            h = fnv_word(h, c as u64);
        }
        for &v in &csr.vals {
            h = fnv_word(h, v.to_bits() as u64);
        }
        GraphFingerprint {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            nnz: csr.nnz(),
            content_hash: h,
        }
    }
}

/// A fully-preprocessed SpMM execution plan for one graph.
///
/// Field layout is the contract every schedule consumer programs
/// against (the GPU simulator's `PreparedGraph` is an alias of this
/// type):
///
/// * `original` — the adjacency exactly as given (original row/column
///   ids). The warp-level baseline and the CSR reference run here.
/// * `sorted` — the degree-sorted view: `sorted.csr` has rows permuted
///   ascending by degree (columns unchanged), `sorted.perm`/`sorted.inv`
///   map between domains.
/// * `block` — Algorithm 2's block-level partition of `sorted.csr`.
/// * `warp` — the GNNAdvisor-style fixed-size neighbour groups over
///   `original` (the paper's Fig. 7 comparison target).
#[derive(Clone, Debug)]
pub struct SpmmPlan {
    pub original: Csr,
    pub sorted: DegreeSorted,
    pub block: BlockPartition,
    pub warp: WarpPartition,
    /// Per-block kernel shapes for adaptive dispatch, derived from
    /// `block` at construction (both [`SpmmPlan::build`] and the delta
    /// path's `from_parts` — same pure rule, same schedule).
    pub kernels: KernelSchedule,
    /// Analytic memory-traffic model — bytes read/written per degree
    /// bucket and per kernel variant, derived from `block` + `kernels`
    /// at construction by the same pure rule on both the build and
    /// delta-patch paths (see [`TrafficModel`]).
    pub traffic: TrafficModel,
    pub params: PartitionParams,
    /// Measurement-derived sharding weights, attached by the
    /// [`PlanTuner`](crate::tune::PlanTuner) (`None` on every freshly
    /// built plan). Only partitioning — never math — so outputs stay
    /// bit-for-bit identical with or without it.
    pub tuned: Option<TunedSharding>,
    /// Lazily computed (only cache lookups need it); see
    /// [`SpmmPlan::fingerprint`].
    fingerprint: OnceLock<GraphFingerprint>,
}

impl SpmmPlan {
    /// Run the preprocessing chain: degree sort → block-level partition
    /// → warp-level baseline. The fingerprint is *not* computed here —
    /// it is derived on first [`SpmmPlan::fingerprint`] call, so
    /// direct-build callers never pay the O(nnz) hash.
    ///
    /// The warp-level baseline is built eagerly even though only the
    /// simulator and the fig. 3/7 experiments read it — a deliberate
    /// trade (one extra O(nnz) pass per plan) to keep `warp` a plain
    /// field the trace generators can borrow. Revisit if coordinator
    /// cold-prepare latency ever matters.
    pub fn build(csr: Csr, params: PartitionParams) -> SpmmPlan {
        let sorted = DegreeSorted::new(&csr);
        let block = BlockPartition::build(&sorted.csr, params);
        let warp = WarpPartition::build(&csr, params.max_warp_nzs);
        let kernels = KernelSchedule::derive(&block);
        let traffic = TrafficModel::derive(&block, &kernels);
        SpmmPlan {
            original: csr,
            sorted,
            block,
            warp,
            kernels,
            traffic,
            params,
            tuned: None,
            fingerprint: OnceLock::new(),
        }
    }

    /// The graph's fingerprint, computed on first use and cached.
    pub fn fingerprint(&self) -> GraphFingerprint {
        *self.fingerprint.get_or_init(|| GraphFingerprint::of(&self.original))
    }

    /// Seed the fingerprint cell when the caller already computed it
    /// (the [`PlanCache`](super::cache::PlanCache) hashes the graph for
    /// its key before building). A no-op if already set.
    pub(crate) fn seed_fingerprint(&self, fp: GraphFingerprint) {
        let _ = self.fingerprint.set(fp);
    }

    /// Alias of [`SpmmPlan::build`] kept for the simulator's historical
    /// `PreparedGraph::new` call sites.
    pub fn new(csr: Csr, params: PartitionParams) -> SpmmPlan {
        SpmmPlan::build(csr, params)
    }

    /// Assemble a plan from parts computed incrementally (the delta
    /// subsystem's [`patch_plan`](crate::delta::patch_plan)). The caller
    /// promises the parts are mutually consistent — i.e. exactly what
    /// [`SpmmPlan::build`] would have produced for `original` — which
    /// the delta property tests assert field-for-field.
    pub(crate) fn from_parts(
        original: Csr,
        sorted: DegreeSorted,
        block: BlockPartition,
        warp: WarpPartition,
        params: PartitionParams,
    ) -> SpmmPlan {
        debug_assert_eq!(sorted.csr.n_rows, original.n_rows);
        debug_assert_eq!(block.n_rows, original.n_rows);
        debug_assert_eq!(block.nnz, original.nnz());
        // re-run kernel selection on the patched partition: the patch
        // may have moved rows across the dense/sparse crossover, and the
        // selection rule is pure in the block stats, so this is exactly
        // what a from-scratch rebuild would pick; ditto the traffic
        // model, which is pure in (block, kernels)
        let kernels = KernelSchedule::derive(&block);
        let traffic = TrafficModel::derive(&block, &kernels);
        SpmmPlan {
            original,
            sorted,
            block,
            warp,
            kernels,
            traffic,
            params,
            tuned: None,
            fingerprint: OnceLock::new(),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.original.n_rows
    }

    pub fn nnz(&self) -> usize {
        self.original.nnz()
    }

    /// The symmetrically relabeled matrix `P·A·Pᵀ`: rows *and* columns
    /// in the sorted domain, so GCN layers chain without per-layer
    /// unpermutes (what the serving coordinator executes).
    ///
    /// Row degrees — and therefore `row_ptr` — are identical to
    /// `sorted.csr`'s, so [`SpmmPlan::block`] is a valid partition of
    /// the relabeled matrix too: block metadata only reads `row_ptr`.
    pub fn relabeled(&self) -> Csr {
        let rel = self.original.relabel(&self.sorted.perm, &self.sorted.inv);
        // a release-mode assert: the serving coordinator pairs this
        // matrix with `block` built from `sorted.csr`, so a silent
        // structure mismatch would mean wrong numerics (O(n) check,
        // negligible next to the O(nnz) relabel itself)
        assert_eq!(
            rel.row_ptr, self.sorted.csr.row_ptr,
            "relabel must preserve the sorted row structure"
        );
        rel
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::verify::assert_allclose;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)]; // ≥ 1 nonzero always
        for r in 0..n {
            for _ in 0..rng.range(0, 9) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn build_is_consistent() {
        let csr = random_csr(3, 50);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        assert_eq!(plan.original, csr);
        assert_eq!(plan.n_rows(), 50);
        assert_eq!(plan.nnz(), csr.nnz());
        assert_eq!(plan.block.n_rows, 50);
        assert_eq!(plan.warp.nnz, csr.nnz());
        assert_eq!(plan.fingerprint(), GraphFingerprint::of(&csr));
        assert_eq!(plan.fingerprint(), plan.fingerprint(), "stable across calls");
        assert_eq!(plan.traffic.nnz() as usize, csr.nnz(), "traffic model covers all nonzeros");
        assert_eq!(plan.traffic, TrafficModel::derive(&plan.block, &plan.kernels));
        for r in 1..50 {
            assert!(plan.sorted.csr.degree(r - 1) <= plan.sorted.csr.degree(r));
        }
    }

    #[test]
    fn kernel_schedule_matches_block_degrees() {
        use crate::spmm::microkernel::SPARSE_DEG_MAX;
        let csr = random_csr(7, 80);
        let plan = SpmmPlan::build(csr, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        assert_eq!(plan.kernels.per_block.len(), plan.block.meta.len());
        assert_eq!(plan.kernels.n_dense + plan.kernels.n_sparse, plan.block.meta.len());
        let deg_bound = plan.params.deg_bound();
        for (b, m) in plan.block.meta.iter().enumerate() {
            let k = plan.kernels.kernel_for(b);
            if m.is_split(deg_bound) {
                assert_eq!(k, RowKernel::DenseTiled, "split block {b} must stay dense");
            } else if m.deg as usize <= SPARSE_DEG_MAX {
                assert_eq!(k, RowKernel::SparseGather, "block {b} deg {}", m.deg);
            } else {
                assert_eq!(k, RowKernel::DenseTiled, "block {b} deg {}", m.deg);
            }
        }
        let frac = plan.kernels.sparse_frac();
        assert!((0.0..=1.0).contains(&frac));
        let summary = plan.kernels.summary(SimdLevel::Scalar);
        assert!(summary.starts_with("scalar+adaptive("), "{summary}");
    }

    /// The tuner's generalized crossover must collapse to the static
    /// rule at the default threshold — `derive` (and therefore the
    /// delta patch path) is pinned to `derive_with(_, SPARSE_DEG_MAX)`.
    #[test]
    fn derive_with_default_crossover_equals_derive() {
        use crate::spmm::microkernel::SPARSE_DEG_MAX;
        let csr = random_csr(13, 70);
        let plan = SpmmPlan::build(csr, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        assert_eq!(plan.kernels, KernelSchedule::derive_with(&plan.block, SPARSE_DEG_MAX));
        // widening the crossover can only move blocks dense → sparse
        let wide = KernelSchedule::derive_with(&plan.block, SPARSE_DEG_MAX * 2);
        assert!(wide.n_sparse >= plan.kernels.n_sparse);
        // crossover 0 sends every non-split block with deg ≥ 1 dense;
        // deg-0 blocks (empty rows) stay on the gather (no-op) kernel
        let narrow = KernelSchedule::derive_with(&plan.block, 0);
        let deg_bound = plan.params.deg_bound();
        for (b, m) in plan.block.meta.iter().enumerate() {
            if !m.is_split(deg_bound) && m.deg > 0 {
                assert_eq!(narrow.kernel_for(b), RowKernel::DenseTiled);
            }
        }
    }

    /// The selection-stability satellite: building the same graph twice
    /// yields identical per-block kernel choices (selection is a pure
    /// function of the partition, with no ambient state).
    #[test]
    fn kernel_selection_is_stable() {
        let csr = random_csr(11, 60);
        let params = PartitionParams::default();
        let a = SpmmPlan::build(csr.clone(), params);
        let b = SpmmPlan::build(csr, params);
        assert_eq!(a.kernels, b.kernels);
        assert_eq!(a.kernels, KernelSchedule::derive(&a.block));
    }

    #[test]
    fn fingerprint_detects_value_change() {
        let a = random_csr(4, 30);
        let mut b = a.clone();
        assert_eq!(GraphFingerprint::of(&a), GraphFingerprint::of(&b));
        b.vals[0] += 1.0;
        assert_ne!(GraphFingerprint::of(&a), GraphFingerprint::of(&b));
    }

    #[test]
    fn fingerprint_distinguishes_structure() {
        let a = Csr::from_edges(2, 2, &[(0, 0, 1.0)]).unwrap();
        let b = Csr::from_edges(2, 2, &[(1, 1, 1.0)]).unwrap();
        assert_ne!(GraphFingerprint::of(&a), GraphFingerprint::of(&b));
    }

    #[test]
    fn relabeled_preserves_row_structure_and_semantics() {
        let csr = random_csr(9, 40);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        let rel = plan.relabeled();
        assert_eq!(rel.row_ptr, plan.sorted.csr.row_ptr);
        // (P·A·Pᵀ)·(P·X) == P·(A·X)
        let f = 3;
        let mut rng = Pcg::seed_from(10);
        let x: Vec<f32> = (0..40 * f).map(|_| rng.f32() - 0.5).collect();
        let mut px = vec![0f32; 40 * f];
        for (i, &orig) in plan.sorted.perm.iter().enumerate() {
            px[i * f..(i + 1) * f]
                .copy_from_slice(&x[orig as usize * f..(orig as usize + 1) * f]);
        }
        let got = plan.sorted.unpermute_rows(&rel.spmm_dense(&px, f), f);
        let want = csr.spmm_dense(&x, f);
        assert_allclose(&got, &want, 1e-4, 1e-4, "relabeled semantics");
    }
}
