//! [`SpmmPlan`]: the product of the paper's preprocessing chain
//! (degree sort → block-level partition, plus the warp-level baseline),
//! built once per graph and reused by every consumer.
//!
//! ## Plan lifetime
//!
//! A plan owns everything derived from one adjacency matrix under one
//! [`PartitionParams`]: the original CSR, the degree-sorted view with
//! its permutation, the block-level partition (the paper's Algorithm 2)
//! and the warp-level baseline partition. Building it is the *only*
//! expensive preprocessing step in the system — O(n + nnz) — so callers
//! hold plans in `Arc` and share them across executors, the GPU
//! simulator, the bench harness, and the serving coordinator. A plan is
//! immutable after construction; repeated executions of any schedule
//! read it concurrently without synchronization.
//!
//! Consumers that need the same graph repeatedly go through
//! [`PlanCache`](super::cache::PlanCache), which keys plans by
//! [`GraphFingerprint`] + params so preprocessing is skipped entirely on
//! a hit.

use crate::graph::csr::Csr;
use crate::graph::degree::DegreeSorted;
use crate::partition::block_level::BlockPartition;
use crate::partition::patterns::PartitionParams;
use crate::partition::warp_level::WarpPartition;
use std::sync::OnceLock;

/// Cheap identity of a CSR matrix: dimensions, nonzero count, and a
/// 64-bit FNV-1a content hash over `row_ptr`/`col_idx`/`vals`.
///
/// Two graphs with the same fingerprint are treated as identical by the
/// [`PlanCache`](super::cache::PlanCache); the structural fields make
/// accidental collisions require a full 64-bit hash collision *between
/// equal-shape graphs*, which we accept (the cache is an optimization —
/// a collision would be astronomically unlikely, not silently frequent).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GraphFingerprint {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    pub content_hash: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv_word(mut h: u64, w: u64) -> u64 {
    // fold the word in 8-bit steps (FNV-1a over little-endian bytes)
    for shift in (0..64).step_by(8) {
        h ^= (w >> shift) & 0xff;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl GraphFingerprint {
    /// Fingerprint a CSR matrix (one linear pass over its arrays).
    pub fn of(csr: &Csr) -> GraphFingerprint {
        let mut h = FNV_OFFSET;
        h = fnv_word(h, csr.n_rows as u64);
        h = fnv_word(h, csr.n_cols as u64);
        for &p in &csr.row_ptr {
            h = fnv_word(h, p as u64);
        }
        for &c in &csr.col_idx {
            h = fnv_word(h, c as u64);
        }
        for &v in &csr.vals {
            h = fnv_word(h, v.to_bits() as u64);
        }
        GraphFingerprint {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            nnz: csr.nnz(),
            content_hash: h,
        }
    }
}

/// A fully-preprocessed SpMM execution plan for one graph.
///
/// Field layout is the contract every schedule consumer programs
/// against (the GPU simulator's `PreparedGraph` is an alias of this
/// type):
///
/// * `original` — the adjacency exactly as given (original row/column
///   ids). The warp-level baseline and the CSR reference run here.
/// * `sorted` — the degree-sorted view: `sorted.csr` has rows permuted
///   ascending by degree (columns unchanged), `sorted.perm`/`sorted.inv`
///   map between domains.
/// * `block` — Algorithm 2's block-level partition of `sorted.csr`.
/// * `warp` — the GNNAdvisor-style fixed-size neighbour groups over
///   `original` (the paper's Fig. 7 comparison target).
#[derive(Clone, Debug)]
pub struct SpmmPlan {
    pub original: Csr,
    pub sorted: DegreeSorted,
    pub block: BlockPartition,
    pub warp: WarpPartition,
    pub params: PartitionParams,
    /// Lazily computed (only cache lookups need it); see
    /// [`SpmmPlan::fingerprint`].
    fingerprint: OnceLock<GraphFingerprint>,
}

impl SpmmPlan {
    /// Run the preprocessing chain: degree sort → block-level partition
    /// → warp-level baseline. The fingerprint is *not* computed here —
    /// it is derived on first [`SpmmPlan::fingerprint`] call, so
    /// direct-build callers never pay the O(nnz) hash.
    ///
    /// The warp-level baseline is built eagerly even though only the
    /// simulator and the fig. 3/7 experiments read it — a deliberate
    /// trade (one extra O(nnz) pass per plan) to keep `warp` a plain
    /// field the trace generators can borrow. Revisit if coordinator
    /// cold-prepare latency ever matters.
    pub fn build(csr: Csr, params: PartitionParams) -> SpmmPlan {
        let sorted = DegreeSorted::new(&csr);
        let block = BlockPartition::build(&sorted.csr, params);
        let warp = WarpPartition::build(&csr, params.max_warp_nzs);
        SpmmPlan { original: csr, sorted, block, warp, params, fingerprint: OnceLock::new() }
    }

    /// The graph's fingerprint, computed on first use and cached.
    pub fn fingerprint(&self) -> GraphFingerprint {
        *self.fingerprint.get_or_init(|| GraphFingerprint::of(&self.original))
    }

    /// Seed the fingerprint cell when the caller already computed it
    /// (the [`PlanCache`](super::cache::PlanCache) hashes the graph for
    /// its key before building). A no-op if already set.
    pub(crate) fn seed_fingerprint(&self, fp: GraphFingerprint) {
        let _ = self.fingerprint.set(fp);
    }

    /// Alias of [`SpmmPlan::build`] kept for the simulator's historical
    /// `PreparedGraph::new` call sites.
    pub fn new(csr: Csr, params: PartitionParams) -> SpmmPlan {
        SpmmPlan::build(csr, params)
    }

    /// Assemble a plan from parts computed incrementally (the delta
    /// subsystem's [`patch_plan`](crate::delta::patch_plan)). The caller
    /// promises the parts are mutually consistent — i.e. exactly what
    /// [`SpmmPlan::build`] would have produced for `original` — which
    /// the delta property tests assert field-for-field.
    pub(crate) fn from_parts(
        original: Csr,
        sorted: DegreeSorted,
        block: BlockPartition,
        warp: WarpPartition,
        params: PartitionParams,
    ) -> SpmmPlan {
        debug_assert_eq!(sorted.csr.n_rows, original.n_rows);
        debug_assert_eq!(block.n_rows, original.n_rows);
        debug_assert_eq!(block.nnz, original.nnz());
        SpmmPlan { original, sorted, block, warp, params, fingerprint: OnceLock::new() }
    }

    pub fn n_rows(&self) -> usize {
        self.original.n_rows
    }

    pub fn nnz(&self) -> usize {
        self.original.nnz()
    }

    /// The symmetrically relabeled matrix `P·A·Pᵀ`: rows *and* columns
    /// in the sorted domain, so GCN layers chain without per-layer
    /// unpermutes (what the serving coordinator executes).
    ///
    /// Row degrees — and therefore `row_ptr` — are identical to
    /// `sorted.csr`'s, so [`SpmmPlan::block`] is a valid partition of
    /// the relabeled matrix too: block metadata only reads `row_ptr`.
    pub fn relabeled(&self) -> Csr {
        let rel = self.original.relabel(&self.sorted.perm, &self.sorted.inv);
        // a release-mode assert: the serving coordinator pairs this
        // matrix with `block` built from `sorted.csr`, so a silent
        // structure mismatch would mean wrong numerics (O(n) check,
        // negligible next to the O(nnz) relabel itself)
        assert_eq!(
            rel.row_ptr, self.sorted.csr.row_ptr,
            "relabel must preserve the sorted row structure"
        );
        rel
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::verify::assert_allclose;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)]; // ≥ 1 nonzero always
        for r in 0..n {
            for _ in 0..rng.range(0, 9) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn build_is_consistent() {
        let csr = random_csr(3, 50);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        assert_eq!(plan.original, csr);
        assert_eq!(plan.n_rows(), 50);
        assert_eq!(plan.nnz(), csr.nnz());
        assert_eq!(plan.block.n_rows, 50);
        assert_eq!(plan.warp.nnz, csr.nnz());
        assert_eq!(plan.fingerprint(), GraphFingerprint::of(&csr));
        assert_eq!(plan.fingerprint(), plan.fingerprint(), "stable across calls");
        for r in 1..50 {
            assert!(plan.sorted.csr.degree(r - 1) <= plan.sorted.csr.degree(r));
        }
    }

    #[test]
    fn fingerprint_detects_value_change() {
        let a = random_csr(4, 30);
        let mut b = a.clone();
        assert_eq!(GraphFingerprint::of(&a), GraphFingerprint::of(&b));
        b.vals[0] += 1.0;
        assert_ne!(GraphFingerprint::of(&a), GraphFingerprint::of(&b));
    }

    #[test]
    fn fingerprint_distinguishes_structure() {
        let a = Csr::from_edges(2, 2, &[(0, 0, 1.0)]).unwrap();
        let b = Csr::from_edges(2, 2, &[(1, 1, 1.0)]).unwrap();
        assert_ne!(GraphFingerprint::of(&a), GraphFingerprint::of(&b));
    }

    #[test]
    fn relabeled_preserves_row_structure_and_semantics() {
        let csr = random_csr(9, 40);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        let rel = plan.relabeled();
        assert_eq!(rel.row_ptr, plan.sorted.csr.row_ptr);
        // (P·A·Pᵀ)·(P·X) == P·(A·X)
        let f = 3;
        let mut rng = Pcg::seed_from(10);
        let x: Vec<f32> = (0..40 * f).map(|_| rng.f32() - 0.5).collect();
        let mut px = vec![0f32; 40 * f];
        for (i, &orig) in plan.sorted.perm.iter().enumerate() {
            px[i * f..(i + 1) * f]
                .copy_from_slice(&x[orig as usize * f..(orig as usize + 1) * f]);
        }
        let got = plan.sorted.unpermute_rows(&rel.spmm_dense(&px, f), f);
        let want = csr.spmm_dense(&x, f);
        assert_allclose(&got, &want, 1e-4, 1e-4, "relabeled semantics");
    }
}
