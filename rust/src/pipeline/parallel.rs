//! Parallel block-level SpMM: the paper's schedule sharded across the
//! worker pool ([`crate::util::threadpool::ThreadPool`]).
//!
//! ## Sharding and the split-row reduction strategy
//!
//! Blocks are split into contiguous shards of approximately equal
//! nonzero count (block order == ascending sorted-row order, so a shard
//! is also a contiguous row span). Each shard executes its blocks
//! exactly like the sequential executor, with the paper's three
//! accumulation levels mapped onto threads as follows:
//!
//! 1. **Within a warp task** — the inner `f`-loop over a private
//!    register row (unchanged).
//! 2. **Non-split blocks** — each block accumulates into its private
//!    block-shared buffer and owns a disjoint set of output rows, so
//!    shards produce these rows without any synchronization and the
//!    reduction is a plain disjoint copy ("lock-free" writes).
//! 3. **Split rows** (`deg > deg_bound`) — a long row's chunks may land
//!    in different shards. Each shard accumulates its chunks into a
//!    per-shard partial buffer for that row; after `run_all` joins, the
//!    partials are summed into the output. This mirrors the kernel's
//!    third cache level (global `atomicAdd`) with the atomics replaced
//!    by a deterministic post-join reduction, which keeps the result
//!    bit-stable for a given shard layout.
//!
//! Shard results are combined in shard order, so the floating-point
//! addition order matches the sequential executor's up to the shard
//! boundaries of split rows — within the reordering tolerance the
//! property tests assert.

use super::exec::Executor;
use super::plan::SpmmPlan;
use crate::partition::block_level::BlockPartition;
use crate::partition::metadata::BlockMeta;
use crate::util::threadpool::ThreadPool;
use std::ops::Range;
use std::sync::Arc;

/// One shard's output: disjoint finished rows plus split-row partials.
struct ShardOut {
    /// `(base sorted row, rows×f buffer)` per non-split block.
    dense: Vec<(usize, Vec<f32>)>,
    /// `(sorted row, f partial)` per split row touched by this shard.
    split: Vec<(usize, Vec<f32>)>,
}

/// Slice `bp`'s blocks into at most `n_shards` contiguous ranges of
/// approximately equal nonzero count.
fn shard_ranges(bp: &BlockPartition, n_shards: usize) -> Vec<Range<usize>> {
    let n_blocks = bp.meta.len();
    if n_blocks == 0 {
        return Vec::new();
    }
    let n_shards = n_shards.clamp(1, n_blocks);
    let deg_bound = bp.params.deg_bound();
    let block_nnz = |m: &BlockMeta| -> usize {
        if m.is_split(deg_bound) {
            m.split_nzs()
        } else {
            m.deg as usize * m.block_rows()
        }
    };
    let total: usize = bp.meta.iter().map(block_nnz).sum();
    let target = total.div_ceil(n_shards).max(1);
    let mut ranges = Vec::with_capacity(n_shards);
    let (mut start, mut acc) = (0usize, 0usize);
    for (b, m) in bp.meta.iter().enumerate() {
        acc += block_nnz(m);
        if acc >= target && ranges.len() + 1 < n_shards {
            ranges.push(start..b + 1);
            start = b + 1;
            acc = 0;
        }
    }
    if start < n_blocks {
        ranges.push(start..n_blocks);
    }
    ranges
}

/// Execute one contiguous block range (sequential, no shared state).
fn exec_shard(plan: &SpmmPlan, x: &[f32], f: usize, blocks: Range<usize>) -> ShardOut {
    let sorted = &plan.sorted.csr;
    let bp = &plan.block;
    let deg_bound = bp.params.deg_bound();
    let mut dense: Vec<(usize, Vec<f32>)> = Vec::new();
    let mut split: Vec<(usize, Vec<f32>)> = Vec::new();
    for b in blocks {
        let m = bp.meta[b];
        if m.is_split(deg_bound) {
            let dst = m.row as usize;
            // chunks of one row are contiguous in block order, so the
            // shard keeps at most one open partial per split row
            if split.last().map_or(true, |(r, _)| *r != dst) {
                split.push((dst, vec![0f32; f]));
            }
            let buf = &mut split.last_mut().expect("just pushed").1;
            bp.for_each_block_warp_task(b, |t| {
                for i in t.nz_start..t.nz_start + t.nz_len {
                    let c = sorted.col_idx[i] as usize;
                    let v = sorted.vals[i];
                    let xrow = &x[c * f..(c + 1) * f];
                    for k in 0..f {
                        buf[k] += v * xrow[k];
                    }
                }
            });
        } else {
            // block-shared accumulator, one slot per block row
            let rows = m.block_rows();
            let mut shared = vec![0f32; rows * f];
            bp.for_each_block_warp_task(b, |t| {
                let slot = (t.sorted_row - m.row) as usize;
                let srow = &mut shared[slot * f..(slot + 1) * f];
                for i in t.nz_start..t.nz_start + t.nz_len {
                    let c = sorted.col_idx[i] as usize;
                    let v = sorted.vals[i];
                    let xrow = &x[c * f..(c + 1) * f];
                    for k in 0..f {
                        srow[k] += v * xrow[k];
                    }
                }
            });
            dense.push((m.row as usize, shared));
        }
    }
    ShardOut { dense, split }
}

/// Execute `Y = A_sorted · X` via the block-level schedule, sharded
/// across `pool`. Result rows are in the **sorted** domain, exactly like
/// [`crate::spmm::spmm_block_level`].
///
/// `plan` and `x` are `Arc`s because shard jobs outlive the borrow
/// checker's view of this frame (the pool requires `'static` jobs);
/// `run_all` joins every shard before this function returns.
pub fn spmm_block_level_parallel(
    plan: &Arc<SpmmPlan>,
    x: &Arc<Vec<f32>>,
    f: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    assert_eq!(x.len(), plan.sorted.csr.n_cols * f, "X shape mismatch");
    let jobs: Vec<_> = shard_ranges(&plan.block, pool.size())
        .into_iter()
        .map(|range| {
            let plan = Arc::clone(plan);
            let x = Arc::clone(x);
            move || exec_shard(&plan, &x, f, range)
        })
        .collect();
    let shards = pool.run_all(jobs);

    let mut y = vec![0f32; plan.sorted.csr.n_rows * f];
    for shard in shards {
        for (base, buf) in shard.dense {
            // disjoint rows: plain stores, no accumulation needed
            y[base * f..base * f + buf.len()].copy_from_slice(&buf);
        }
        for (row, partial) in shard.split {
            // the "global atomic" level, reduced deterministically
            let yrow = &mut y[row * f..(row + 1) * f];
            for k in 0..f {
                yrow[k] += partial[k];
            }
        }
    }
    y
}

/// [`Executor`] running the block-level schedule on an owned thread
/// pool. Construct once and reuse: workers persist across `execute`
/// calls.
pub struct ParallelBlockLevel {
    pool: ThreadPool,
}

impl ParallelBlockLevel {
    /// Spawn a pool of `threads` workers (at least 1).
    pub fn new(threads: usize) -> ParallelBlockLevel {
        ParallelBlockLevel { pool: ThreadPool::new(threads) }
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// The underlying pool (for callers that already hold `Arc` inputs
    /// and want the sorted-domain result without the executor's copies).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

impl Executor for ParallelBlockLevel {
    fn name(&self) -> &'static str {
        "block-level-parallel"
    }

    /// Satisfying the slice-based [`Executor`] contract costs one copy
    /// of `x` into an `Arc` per call (the pool needs `'static` jobs).
    /// Hot paths that already hold `Arc` inputs should call
    /// [`spmm_block_level_parallel`] directly — the bench harnesses do.
    fn execute(&self, plan: &Arc<SpmmPlan>, x: &[f32], f: usize) -> Vec<f32> {
        let x = Arc::new(x.to_vec());
        let sorted_y = spmm_block_level_parallel(plan, &x, f, &self.pool);
        plan.sorted.unpermute_rows(&sorted_y, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::pipeline::exec::{BlockLevel, CsrReference};
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn random_plan(rng: &mut Pcg, n: usize, params: PartitionParams) -> Arc<SpmmPlan> {
        let mut edges = Vec::new();
        for r in 0..n {
            let d = if rng.f64() < 0.06 {
                rng.range(0, 3 * n / 2 + 2) // exceeds deg_bound for small params
            } else {
                rng.range(0, 8)
            };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
            }
        }
        Arc::new(SpmmPlan::build(Csr::from_edges(n, n, &edges).unwrap(), params))
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        proptest::check("shard_ranges_cover", 0x54A2, 20, |rng| {
            let n = rng.range(1, 50);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 8]),
            };
            let plan = random_plan(rng, n, params);
            let shards = rng.range(1, 12);
            let ranges = shard_ranges(&plan.block, shards);
            assert!(ranges.len() <= shards.max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "ranges must be contiguous");
                assert!(r.end > r.start, "ranges must be non-empty");
                next = r.end;
            }
            assert_eq!(next, plan.block.meta.len(), "ranges must cover all blocks");
        });
    }

    #[test]
    fn split_row_straddling_shards_reduces_correctly() {
        // one row of degree 60 with deg_bound 4 → 15 split chunks spread
        // over every shard boundary the pool can produce
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let edges: Vec<(u32, u32, f32)> = (0..60).map(|c| (0u32, c, (c % 7) as f32 - 3.0)).collect();
        let csr = Csr::from_edges(1, 60, &edges).unwrap();
        let plan = Arc::new(SpmmPlan::build(csr, params));
        assert!(plan.block.meta.len() > 8, "expected many split chunks");
        let f = 5;
        let x: Vec<f32> = (0..60 * f).map(|i| (i as f32).sin()).collect();
        let want = CsrReference.execute(&plan, &x, f);
        for threads in [1usize, 3, 8] {
            let got = ParallelBlockLevel::new(threads).execute(&plan, &x, f);
            assert_allclose(&got, &want, 1e-4, 1e-4, "split straddle");
        }
    }

    #[test]
    fn prop_parallel_matches_sequential_and_reference() {
        // the satellite property: parallel == sequential == dense
        // reference across random graphs, thread counts, and the
        // paper's column dimensions
        proptest::check("parallel_block_exec", 0x9A54, 8, |rng| {
            let n = rng.range(1, 50);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 4, 32]),
            };
            let plan = random_plan(rng, n, params);
            for &threads in &[1usize, 2, 8] {
                let exec = ParallelBlockLevel::new(threads);
                assert_eq!(exec.threads(), threads);
                for &f in &[16usize, 64, 128] {
                    let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
                    let got = exec.execute(&plan, &x, f);
                    let seq = BlockLevel.execute(&plan, &x, f);
                    let want = CsrReference.execute(&plan, &x, f);
                    assert_allclose(&got, &seq, 1e-4, 1e-4, "parallel vs sequential");
                    assert_allclose(&got, &want, 1e-4, 1e-4, "parallel vs reference");
                }
            }
        });
    }

    #[test]
    fn zero_and_empty_graphs() {
        let params = PartitionParams::default();
        let empty = Arc::new(SpmmPlan::build(Csr::from_edges(0, 0, &[]).unwrap(), params));
        let exec = ParallelBlockLevel::new(2);
        assert!(exec.execute(&empty, &[], 3).is_empty());
        // all-zero rows produce an all-zero result
        let zeros = Arc::new(SpmmPlan::build(Csr::from_edges(4, 4, &[]).unwrap(), params));
        let y = exec.execute(&zeros, &[1.0; 12], 3);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
