//! Parallel block-level SpMM: the paper's schedule sharded across the
//! worker pool ([`crate::util::threadpool::ThreadPool`]), executed
//! through the column-tiled microkernel
//! ([`crate::spmm::microkernel`]).
//!
//! ## The zero-copy tiled hot path
//!
//! [`spmm_block_level_parallel`] is the CPU analog of the paper's
//! combined-warp kernel, with every accumulation level mapped onto
//! threads and registers:
//!
//! 1. **Within a warp task** — the column dimension is swept in
//!    [`TILE`](crate::spmm::microkernel::TILE)-wide register tiles
//!    (tile width ↔ warp span), with a ragged-tail path for
//!    `f % TILE != 0` and zip-fused nonzero iteration, so the inner
//!    loop carries no per-element bounds checks.
//! 2. **Non-split blocks** — each block owns a disjoint set of output
//!    rows, so shards write finished rows **straight into `y`**
//!    (direct-write sharding): no per-block staging buffers, no
//!    post-join copy pass. The write scatters through the plan's
//!    permutation (`y[perm[sorted_row]]`), fusing the former
//!    `unpermute_rows` pass into the store itself.
//! 3. **Split rows** (`deg > deg_bound`) — a long row's chunks may land
//!    in different shards. Each chunk accumulates into its own window of
//!    a reused per-shard arena ([`SplitPartials`]); after the scoped
//!    join, the windows are summed into the output in **global block
//!    order** (shards are contiguous block ranges, so shard-major
//!    window-minor iteration *is* block order). This mirrors the
//!    kernel's third cache level (global `atomicAdd`) with the atomics
//!    replaced by a deterministic post-join reduction — and because the
//!    reduction grouping never depends on where the shard cuts fall,
//!    the result is bit-stable across **any** contiguous shard layout
//!    (the property the tuner's re-cut relies on).
//!
//! Inputs are borrowed (`&[f32]`), jobs run via
//! [`ThreadPool::scoped_run`], and the result comes back already in the
//! **original** row order — no `Arc` input copy, no staging buffers, no
//! separate unpermute pass anywhere on the path.
//!
//! ## Sharding
//!
//! Blocks are split into contiguous shards of approximately equal
//! nonzero count (block order == ascending sorted-row order, so a shard
//! is also a contiguous row span). [`shard_ranges`] places each cut at
//! the block boundary nearest the ideal `i·total/n_shards` prefix —
//! a lookahead that caps every shard near the target, instead of the
//! greedy accumulate-past-target rule that systematically overshot and
//! starved (or dropped) the trailing shards on skewed plans.
//!
//! Plans the [`PlanTuner`](crate::tune::PlanTuner) has annotated carry
//! measured per-block cost weights
//! ([`TunedSharding`](super::plan::TunedSharding)); for those,
//! [`shard_ranges_for_plan`] cuts against predicted nanoseconds instead
//! of raw nonzeros — same nearest-boundary rule, different weights.
//!
//! [`spmm_block_level_parallel_scalar`] preserves the pre-tiling
//! execution path — scalar bounds-checked inner loop, per-block `vec!`
//! staging, `Arc` input copy, post-join copy pass, separate unpermute —
//! as the measured baseline for `bench --experiment microkernel`.

use super::exec::Executor;
use super::plan::SpmmPlan;
use crate::obs::{Registry, ShardSample};
use crate::partition::block_level::BlockPartition;
use crate::partition::metadata::BlockMeta;
use crate::spmm::microkernel;
use crate::spmm::microkernel::{RowKernel, SimdLevel};
use crate::util::threadpool::ThreadPool;
use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

/// Shared output buffer handed to shard jobs as a raw pointer.
///
/// # Safety contract
///
/// Concurrent shards may only write **disjoint** row spans: non-split
/// blocks own disjoint sorted rows (and `perm` is a bijection, so the
/// scattered original rows are disjoint too), and split rows are never
/// written through this pointer — they go through per-shard partials
/// reduced after the join. The pointer is only dereferenced inside
/// `scoped_run`, which joins before the owning `&mut [f32]` is touched
/// again.
struct OutPtr {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

impl OutPtr {
    /// # Safety
    /// `[start, start + n)` must be in bounds and not concurrently
    /// aliased by any other shard (see the type-level contract).
    #[inline]
    unsafe fn slice_mut(&self, start: usize, n: usize) -> &mut [f32] {
        debug_assert!(start + n <= self.len, "OutPtr out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), n)
    }
}

/// Per-shard arena for split-row partial sums: one growable buffer
/// holding one `f`-wide window **per split chunk** the shard executes
/// (chunk `k`'s partial lives at `buf[k*f..(k+1)*f]`), instead of one
/// `vec!` per chunk. A row with several chunks in the shard repeats in
/// `rows` — the reduction sums windows in block order, so the grouping
/// of a row's chunks never depends on where the shard cuts fall.
#[derive(Default)]
struct SplitPartials {
    /// Sorted-domain row id per chunk, in block order (may repeat).
    rows: Vec<u32>,
    /// Concatenated `f`-wide windows, parallel to `rows`.
    buf: Vec<f32>,
}

fn block_nnz(m: &BlockMeta, deg_bound: usize) -> usize {
    if m.is_split(deg_bound) {
        m.split_nzs()
    } else {
        m.deg as usize * m.block_rows()
    }
}

/// Slice `bp`'s blocks into at most `n_shards` contiguous ranges of
/// approximately equal nonzero count.
///
/// Each cut lands on the block boundary whose nonzero prefix is nearest
/// the ideal `s·total/n_shards`, clamped so every shard keeps at least
/// one block. Shard sizes therefore deviate from the target by at most
/// one block's nonzeros — bounded by `deg_bound` — where the old greedy
/// cut-at-`acc ≥ target` rule could stack its overshoot into a wildly
/// over- or under-sized tail shard on skewed plans.
fn shard_ranges(bp: &BlockPartition, n_shards: usize) -> Vec<Range<usize>> {
    let deg_bound = bp.params.deg_bound();
    let weights: Vec<u64> = bp.meta.iter().map(|m| block_nnz(m, deg_bound) as u64).collect();
    cut_by_weights(&weights, n_shards)
}

/// The weighted core of [`shard_ranges`]: slice `weights.len()` blocks
/// into at most `n_shards` contiguous ranges of approximately equal
/// total weight, each cut on the boundary nearest its ideal prefix.
/// Static sharding passes nonzero counts; tuned plans pass predicted
/// per-block cost ([`super::plan::TunedSharding::block_cost`]).
pub(crate) fn cut_by_weights(weights: &[u64], n_shards: usize) -> Vec<Range<usize>> {
    let n_blocks = weights.len();
    if n_blocks == 0 {
        return Vec::new();
    }
    let n_shards = n_shards.clamp(1, n_blocks);
    let mut prefix = Vec::with_capacity(n_blocks + 1);
    prefix.push(0u128);
    for &w in weights {
        prefix.push(prefix[prefix.len() - 1] + w as u128);
    }
    let total = prefix[n_blocks];
    let mut ranges = Vec::with_capacity(n_shards);
    let mut start = 0usize;
    for s in 1..n_shards {
        let lo = start + 1; // shard s-1 keeps ≥ 1 block
        let hi = n_blocks - (n_shards - s); // ≥ 1 block per remaining shard
        let ideal = total * s as u128 / n_shards as u128;
        // first boundary at or past the ideal, then the nearer of it and
        // its predecessor (the lookahead)
        let mut cut = prefix.partition_point(|&p| p < ideal).clamp(lo, hi);
        if cut > lo && prefix[cut] >= ideal && ideal - prefix[cut - 1] < prefix[cut] - ideal {
            cut -= 1;
        }
        ranges.push(start..cut);
        start = cut;
    }
    ranges.push(start..n_blocks);
    ranges
}

/// The shard layout the parallel executor runs `plan` under: tuned
/// cost-weighted cuts when the [`PlanTuner`](crate::tune::PlanTuner)
/// annotated the plan (and its weights still match the partition),
/// static nnz-balanced cuts otherwise. Pure partitioning — every
/// layout produces bit-identical output (split-row reduction is in
/// block order, independent of the cuts).
pub fn shard_ranges_for_plan(plan: &SpmmPlan, n_shards: usize) -> Vec<Range<usize>> {
    if let Some(t) = &plan.tuned {
        if t.block_cost.len() == plan.block.meta.len() {
            return cut_by_weights(&t.block_cost, n_shards);
        }
        debug_assert!(false, "TunedSharding weights out of sync with the partition");
    }
    shard_ranges(&plan.block, n_shards)
}

/// Execute one contiguous block range through the microkernels at the
/// given lane strategy. Non-split rows are finished in place (scattered
/// to original order through `perm`) via the kernel shape the plan's
/// [`KernelSchedule`](super::plan::KernelSchedule) selected for their
/// block (when `adaptive`; always the dense tiled kernel otherwise);
/// split-row chunks carry `deg_bound` nonzeros each and accumulate into
/// one `partials` window per chunk through the dense kernel
/// unconditionally.
fn exec_shard(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    blocks: Range<usize>,
    out: &OutPtr,
    partials: &mut SplitPartials,
    level: SimdLevel,
    adaptive: bool,
) {
    let sorted = &plan.sorted.csr;
    let perm = &plan.sorted.perm;
    let bp = &plan.block;
    let deg_bound = bp.params.deg_bound();
    for b in blocks {
        let m = bp.meta[b];
        let loc = m.loc as usize;
        if m.is_split(deg_bound) {
            // one window per chunk: the post-join reduction then sums
            // chunks in global block order whatever the shard layout,
            // keeping the output bit-identical across re-cuts (merging
            // a shard's chunks here would bake the cut positions into
            // the f32 grouping)
            partials.rows.push(m.row);
            partials.buf.resize(partials.buf.len() + f, 0.0);
            let w = partials.buf.len() - f;
            let nzs = m.split_nzs();
            microkernel::accumulate_row_with(
                level,
                &sorted.col_idx[loc..loc + nzs],
                &sorted.vals[loc..loc + nzs],
                x,
                f,
                &mut partials.buf[w..],
            );
        } else {
            // direct-write: this block owns its rows exclusively, so
            // each finished row scatters straight into y[perm[row]]
            let kern = if adaptive { plan.kernels.kernel_for(b) } else { RowKernel::DenseTiled };
            let deg = m.deg as usize;
            for row_i in 0..m.block_rows() {
                let s = loc + row_i * deg;
                let dst_row = perm[m.row as usize + row_i] as usize;
                // SAFETY: non-split rows are owned by exactly one block,
                // blocks by exactly one shard, and perm is a bijection —
                // no other shard touches this span (see OutPtr).
                let dst = unsafe { out.slice_mut(dst_row * f, f) };
                microkernel::accumulate_row_select(
                    kern,
                    level,
                    &sorted.col_idx[s..s + deg],
                    &sorted.vals[s..s + deg],
                    x,
                    f,
                    dst,
                );
            }
        }
    }
}

/// Execute `Y = A·X` via the block-level schedule, sharded across
/// `pool`, writing into the caller's buffer (which is zeroed first).
/// `x` is `[n_cols × f]` row-major in **original** column order; `y`
/// comes back `[n_rows × f]` in **original** row order — the unpermute
/// is fused into the shards' scattered stores.
///
/// Inputs are borrowed: jobs run via [`ThreadPool::scoped_run`], which
/// joins every shard before returning, so no `Arc` copies are needed.
pub fn spmm_block_level_parallel_into(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    pool: &ThreadPool,
    y: &mut [f32],
) {
    spmm_block_level_parallel_into_with(plan, x, f, pool, y, SimdLevel::best(), true);
}

/// [`spmm_block_level_parallel_into`] with an explicit lane strategy
/// and kernel-dispatch mode — the bench harness's matrix knob. `level`
/// picks the SIMD path ([`SimdLevel::Arch`] degrades to portable when
/// unavailable); `adaptive` toggles the plan's per-block kernel
/// schedule versus forcing the dense tiled kernel everywhere (the PR 4
/// behavior).
pub fn spmm_block_level_parallel_into_with(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    pool: &ThreadPool,
    y: &mut [f32],
    level: SimdLevel,
    adaptive: bool,
) {
    y.fill(0.0);
    exec_into_zeroed(plan, x, f, pool, y, level, adaptive);
}

/// The `_into` body minus the zeroing pass — `y` must already be
/// all-zero (e.g. freshly allocated).
fn exec_into_zeroed(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    pool: &ThreadPool,
    y: &mut [f32],
    level: SimdLevel,
    adaptive: bool,
) {
    assert_eq!(x.len(), plan.sorted.csr.n_cols * f, "X shape mismatch");
    assert_eq!(y.len(), plan.sorted.csr.n_rows * f, "Y shape mismatch");
    let ranges = shard_ranges_for_plan(plan, pool.size());
    if ranges.is_empty() {
        return;
    }
    let mut partials: Vec<SplitPartials> =
        ranges.iter().map(|_| SplitPartials::default()).collect();
    let out = OutPtr { ptr: y.as_mut_ptr(), len: y.len() };
    // One relaxed load decides the whole observability cost: disabled,
    // the job closures below are exactly the pre-instrumentation ones —
    // no clock reads, no sample buffer, no per-shard accounting.
    let obs = Registry::global();
    if obs.enabled() {
        let mut samples = vec![ShardSample::default(); partials.len()];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
            .into_iter()
            .zip(partials.iter_mut())
            .zip(samples.iter_mut())
            .map(|((range, part), slot)| {
                let out = &out;
                Box::new(move || {
                    let start_ns = crate::obs::epoch_now_ns();
                    let t0 = Instant::now();
                    exec_shard(plan, x, f, range.clone(), out, part, level, adaptive);
                    *slot = sample_shard(plan, range, adaptive, f, t0.elapsed());
                    slot.start_ns = start_ns;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped_run(jobs);
        obs.record_spmm_shards(&samples);
    } else {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
            .into_iter()
            .zip(partials.iter_mut())
            .map(|(range, part)| {
                let out = &out;
                Box::new(move || exec_shard(plan, x, f, range, out, part, level, adaptive))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped_run(jobs);
    }
    // the "global atomic" level: split-row partials reduced
    // deterministically in global block order (shards are contiguous
    // block ranges, walked shard-major then window-minor), scattered to
    // original rows — the sum's grouping is invariant to the cuts
    let perm = &plan.sorted.perm;
    for part in &partials {
        for (k, &srow) in part.rows.iter().enumerate() {
            let dst = perm[srow as usize] as usize * f;
            for (d, s) in y[dst..dst + f].iter_mut().zip(&part.buf[k * f..(k + 1) * f]) {
                *d += *s;
            }
        }
    }
}

/// What one shard did, for the per-shard execution timeline: nonzeros
/// and rows from the plan metadata, kernel mix and byte traffic from
/// the same dispatch rule [`exec_shard`] applied (bytes via the shared
/// per-block rule [`block_traffic`], so shard sums always equal the
/// plan's analytic [`TrafficModel`](super::traffic::TrafficModel)
/// totals; a split chunk's post-join reduction traffic is attributed to
/// the shard that ran the chunk), wall time from the shard job itself.
/// Runs inside the shard job, only when the registry is enabled.
fn sample_shard(
    plan: &SpmmPlan,
    blocks: Range<usize>,
    adaptive: bool,
    f: usize,
    busy: std::time::Duration,
) -> ShardSample {
    use super::traffic::{block_traffic, ElemWidths};
    let bp = &plan.block;
    let deg_bound = bp.params.deg_bound();
    let mut s = ShardSample { busy_ns: busy.as_nanos() as u64, ..Default::default() };
    for b in blocks {
        let m = bp.meta[b];
        let nnz = block_nnz(&m, deg_bound) as u64;
        s.nnz += nnz;
        let kern = if m.is_split(deg_bound) || !adaptive {
            RowKernel::DenseTiled
        } else {
            plan.kernels.kernel_for(b)
        };
        if m.is_split(deg_bound) {
            s.dense_blocks += 1; // split chunks always run the dense kernel
            s.dense_nnz += nnz;
        } else {
            s.rows += m.block_rows() as u64;
            match kern {
                RowKernel::DenseTiled => {
                    s.dense_blocks += 1;
                    s.dense_nnz += nnz;
                }
                RowKernel::SparseGather => {
                    s.sparse_blocks += 1;
                    s.sparse_nnz += nnz;
                }
            }
        }
        let t = block_traffic(&m, kern, deg_bound);
        s.bytes_read += t.bytes_read_with(f, ElemWidths::F32);
        s.bytes_written += t.bytes_written_with(f, ElemWidths::F32);
    }
    s
}

/// Allocating wrapper over [`spmm_block_level_parallel_into`]: the
/// zero-copy tiled hot path, result in **original** row order.
pub fn spmm_block_level_parallel(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    spmm_block_level_parallel_with(plan, x, f, pool, SimdLevel::best(), true)
}

/// Allocating wrapper with an explicit lane strategy and dispatch mode
/// (see [`spmm_block_level_parallel_into_with`]).
pub fn spmm_block_level_parallel_with(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    pool: &ThreadPool,
    level: SimdLevel,
    adaptive: bool,
) -> Vec<f32> {
    let mut y = vec![0f32; plan.sorted.csr.n_rows * f];
    exec_into_zeroed(plan, x, f, pool, &mut y, level, adaptive); // fresh allocation: skip the re-zero
    y
}

/// One shard's output on the scalar baseline path: staged buffers that
/// the join copies out (what direct-write sharding deletes).
struct ShardOut {
    /// `(base sorted row, rows×f buffer)` per non-split block.
    dense: Vec<(usize, Vec<f32>)>,
    /// `(sorted row, f partial)` per split row touched by this shard.
    split: Vec<(usize, Vec<f32>)>,
}

/// The scalar baseline's shard body: bounds-checked scalar inner loop
/// over warp tasks, one fresh `vec!` per block.
fn exec_shard_scalar(plan: &SpmmPlan, x: &[f32], f: usize, blocks: Range<usize>) -> ShardOut {
    let sorted = &plan.sorted.csr;
    let bp = &plan.block;
    let deg_bound = bp.params.deg_bound();
    let mut dense: Vec<(usize, Vec<f32>)> = Vec::new();
    let mut split: Vec<(usize, Vec<f32>)> = Vec::new();
    for b in blocks {
        let m = bp.meta[b];
        if m.is_split(deg_bound) {
            let dst = m.row as usize;
            if split.last().map_or(true, |(r, _)| *r != dst) {
                split.push((dst, vec![0f32; f]));
            }
            let buf = &mut split.last_mut().expect("just pushed").1;
            bp.for_each_block_warp_task(b, |t| {
                for i in t.nz_start..t.nz_start + t.nz_len {
                    let c = sorted.col_idx[i] as usize;
                    let v = sorted.vals[i];
                    let xrow = &x[c * f..(c + 1) * f];
                    for k in 0..f {
                        buf[k] += v * xrow[k];
                    }
                }
            });
        } else {
            let rows = m.block_rows();
            let mut shared = vec![0f32; rows * f];
            bp.for_each_block_warp_task(b, |t| {
                let slot = (t.sorted_row - m.row) as usize;
                let srow = &mut shared[slot * f..(slot + 1) * f];
                for i in t.nz_start..t.nz_start + t.nz_len {
                    let c = sorted.col_idx[i] as usize;
                    let v = sorted.vals[i];
                    let xrow = &x[c * f..(c + 1) * f];
                    for k in 0..f {
                        srow[k] += v * xrow[k];
                    }
                }
            });
            dense.push((m.row as usize, shared));
        }
    }
    ShardOut { dense, split }
}

/// The pre-tiling execution path, preserved as the measured baseline
/// for `bench --experiment microkernel`: `x` copied into an `Arc` (the
/// `'static` job bound the scoped path removed), scalar bounds-checked
/// inner loop, per-block `vec!` staging buffers, a post-join copy pass,
/// and a separate full `unpermute_rows`. Result in **original** row
/// order, numerically interchangeable with the tiled path.
pub fn spmm_block_level_parallel_scalar(
    plan: &Arc<SpmmPlan>,
    x: &[f32],
    f: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    assert_eq!(x.len(), plan.sorted.csr.n_cols * f, "X shape mismatch");
    let x: Arc<Vec<f32>> = Arc::new(x.to_vec());
    let jobs: Vec<_> = shard_ranges(&plan.block, pool.size())
        .into_iter()
        .map(|range| {
            let plan = Arc::clone(plan);
            let x = Arc::clone(&x);
            move || exec_shard_scalar(&plan, &x, f, range)
        })
        .collect();
    let shards = pool.run_all(jobs);

    let mut y = vec![0f32; plan.sorted.csr.n_rows * f];
    for shard in shards {
        for (base, buf) in shard.dense {
            y[base * f..base * f + buf.len()].copy_from_slice(&buf);
        }
        for (row, partial) in shard.split {
            let yrow = &mut y[row * f..(row + 1) * f];
            for k in 0..f {
                yrow[k] += partial[k];
            }
        }
    }
    plan.sorted.unpermute_rows(&y, f)
}

/// [`Executor`] running the block-level schedule on an owned thread
/// pool. Construct once and reuse: workers persist across `execute`
/// calls.
pub struct ParallelBlockLevel {
    pool: ThreadPool,
}

impl ParallelBlockLevel {
    /// Spawn a pool of `threads` workers (at least 1).
    pub fn new(threads: usize) -> ParallelBlockLevel {
        ParallelBlockLevel { pool: ThreadPool::new(threads) }
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// The underlying pool (for callers that drive
    /// [`spmm_block_level_parallel_into`] against reused buffers).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

impl Executor for ParallelBlockLevel {
    fn name(&self) -> &'static str {
        "block-level-parallel"
    }

    /// Zero-copy: `x` is borrowed by the scoped shard jobs directly and
    /// the unpermute is fused into the shards' scattered stores.
    fn execute(&self, plan: &SpmmPlan, x: &[f32], f: usize) -> Vec<f32> {
        spmm_block_level_parallel(plan, x, f, &self.pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::pipeline::exec::{BlockLevel, CsrReference};
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn random_plan(rng: &mut Pcg, n: usize, params: PartitionParams) -> Arc<SpmmPlan> {
        let mut edges = Vec::new();
        for r in 0..n {
            let d = if rng.f64() < 0.06 {
                rng.range(0, 3 * n / 2 + 2) // exceeds deg_bound for small params
            } else {
                rng.range(0, 8)
            };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
            }
        }
        Arc::new(SpmmPlan::build(Csr::from_edges(n, n, &edges).unwrap(), params))
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        proptest::check("shard_ranges_cover", 0x54A2, 20, |rng| {
            let n = rng.range(1, 50);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 8]),
            };
            let plan = random_plan(rng, n, params);
            let shards = rng.range(1, 12);
            let ranges = shard_ranges(&plan.block, shards);
            assert!(ranges.len() <= shards.max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "ranges must be contiguous");
                assert!(r.end > r.start, "ranges must be non-empty");
                next = r.end;
            }
            assert_eq!(next, plan.block.meta.len(), "ranges must cover all blocks");
        });
    }

    /// The rebalance satellite: on a skewed power-law plan whose block
    /// granularity is far below the per-shard target, every shard must
    /// land near the target — max/min nonzero ratio ≤ 2 — and the full
    /// shard count must be realized (the old greedy rule could stack
    /// overshoot into a starved or missing tail shard).
    #[test]
    fn shard_ranges_balanced_on_skewed_plan() {
        use crate::graph::generator::{degree_sequence, from_degree_sequence, DegreeModel};
        let mut rng = Pcg::seed_from(0x5BAD);
        let n = 3000;
        let degs = degree_sequence(
            DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.2 },
            n,
            n * 12,
            &mut rng,
        );
        let csr = from_degree_sequence(n, &degs, &mut rng);
        let plan = SpmmPlan::build(csr, PartitionParams::default());
        let deg_bound = plan.block.params.deg_bound();
        for n_shards in [2usize, 4, 6, 8] {
            let ranges = shard_ranges(&plan.block, n_shards);
            assert_eq!(ranges.len(), n_shards, "full shard count must be realized");
            let nnzs: Vec<usize> = ranges
                .iter()
                .map(|r| plan.block.meta[r.clone()].iter().map(|m| block_nnz(m, deg_bound)).sum())
                .collect();
            let max = *nnzs.iter().max().unwrap();
            let min = *nnzs.iter().min().unwrap();
            assert!(
                max <= 2 * min,
                "shards {n_shards}: nnz imbalance {nnzs:?} (max {max} > 2×min {min})"
            );
        }
    }

    #[test]
    fn shard_cut_prefers_nearest_boundary() {
        // blocks sized [4, 31, 31, 31, 31] (deg-ascending): the greedy
        // rule produced [35, 62, 31] — a 2× spread on 3 of 4 requested
        // shards; nearest-boundary cuts give 4 shards within one block
        let params = PartitionParams { max_block_warps: 1, max_warp_nzs: 32 };
        let mut edges: Vec<(u32, u32, f32)> = (0..4).map(|c| (0u32, c, 1.0)).collect();
        for r in 1..5u32 {
            for c in 0..31u32 {
                edges.push((r, c, 1.0));
            }
        }
        let csr = Csr::from_edges(5, 32, &edges).unwrap();
        let plan = SpmmPlan::build(csr, params);
        // one block per row with these params (block_rows = 1)
        assert_eq!(plan.block.meta.len(), 5);
        let ranges = shard_ranges(&plan.block, 4);
        assert_eq!(ranges.len(), 4);
        let deg_bound = params.deg_bound();
        let nnzs: Vec<usize> = ranges
            .iter()
            .map(|r| plan.block.meta[r.clone()].iter().map(|m| block_nnz(m, deg_bound)).sum())
            .collect();
        assert_eq!(nnzs, vec![35, 31, 31, 31]);
    }

    #[test]
    fn split_row_straddling_shards_reduces_correctly() {
        // one row of degree 60 with deg_bound 4 → 15 split chunks spread
        // over every shard boundary the pool can produce
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let edges: Vec<(u32, u32, f32)> = (0..60).map(|c| (0u32, c, (c % 7) as f32 - 3.0)).collect();
        let csr = Csr::from_edges(1, 60, &edges).unwrap();
        let plan = Arc::new(SpmmPlan::build(csr, params));
        assert!(plan.block.meta.len() > 8, "expected many split chunks");
        let f = 5;
        let x: Vec<f32> = (0..60 * f).map(|i| (i as f32).sin()).collect();
        let want = CsrReference.execute(&plan, &x, f);
        for threads in [1usize, 3, 8] {
            let got = ParallelBlockLevel::new(threads).execute(&plan, &x, f);
            assert_allclose(&got, &want, 1e-4, 1e-4, "split straddle");
        }
    }

    /// The tuning bit-identity guarantee: a [`TunedSharding`] annotation
    /// moves shard cuts but must never move a bit of output — the
    /// split-row reduction runs in global block order regardless of the
    /// layout, and non-split rows are written whole by exactly one
    /// shard. Exercised with deliberately pathological weights (the
    /// inverse-ish of nnz) so the tuned cuts genuinely differ.
    #[test]
    fn tuned_sharding_is_bit_identical_to_static() {
        use super::super::plan::TunedSharding;
        let mut rng = Pcg::seed_from(0x7E57);
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let plan = random_plan(&mut rng, 48, params);
        assert!(plan.block.meta.len() > 8, "need enough blocks to re-cut");
        // anti-correlated weights: heavy blocks get cost 1, light get 97
        let deg_bound = params.deg_bound();
        let block_cost: Vec<u64> = plan
            .block
            .meta
            .iter()
            .map(|m| 1 + 97 / (block_nnz(m, deg_bound) as u64 + 1))
            .collect();
        let mut tuned_plan = (*plan).clone();
        tuned_plan.tuned = Some(TunedSharding {
            dense_ns_per_nnz: 1.0,
            sparse_ns_per_nnz: 1.0,
            crossover: crate::spmm::microkernel::SPARSE_DEG_MAX,
            block_cost,
            predicted_static_imbalance: 1.0,
            predicted_tuned_imbalance: 1.0,
            n_shards: 3,
        });
        let tuned_plan = Arc::new(tuned_plan);
        let f = 9;
        let x: Vec<f32> = (0..48 * f).map(|_| rng.f32() - 0.5).collect();
        let mut layouts_differed = false;
        for threads in [1usize, 3, 8] {
            let static_ranges = shard_ranges_for_plan(&plan, threads);
            let tuned_ranges = shard_ranges_for_plan(&tuned_plan, threads);
            layouts_differed |= static_ranges != tuned_ranges;
            let exec = ParallelBlockLevel::new(threads);
            let want = exec.execute(&plan, &x, f);
            let got = exec.execute(&tuned_plan, &x, f);
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "lane {j} at {threads} threads");
            }
        }
        assert!(layouts_differed, "weights were supposed to move at least one cut");
    }

    #[test]
    fn prop_parallel_matches_sequential_and_reference() {
        // the core property: parallel == sequential == dense reference
        // across random graphs, thread counts, and the paper's column
        // dimensions
        proptest::check("parallel_block_exec", 0x9A54, 8, |rng| {
            let n = rng.range(1, 50);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 4, 32]),
            };
            let plan = random_plan(rng, n, params);
            for &threads in &[1usize, 2, 8] {
                let exec = ParallelBlockLevel::new(threads);
                assert_eq!(exec.threads(), threads);
                for &f in &[16usize, 64, 128] {
                    let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
                    let got = exec.execute(&plan, &x, f);
                    let seq = BlockLevel.execute(&plan, &x, f);
                    let want = CsrReference.execute(&plan, &x, f);
                    assert_allclose(&got, &seq, 1e-4, 1e-4, "parallel vs sequential");
                    assert_allclose(&got, &want, 1e-4, 1e-4, "parallel vs reference");
                }
            }
        });
    }

    /// The ragged-tail satellite: column widths that exercise the
    /// microkernel's sub-tile (`f < TILE`), tail (`f % TILE != 0`) and
    /// multi-tile paths inside the full sharded executor, on graphs
    /// with empty rows, against the dense reference, across threads.
    #[test]
    fn prop_microkernel_ragged_tails() {
        proptest::check("parallel_ragged_tails", 0x7A17, 10, |rng| {
            let n = rng.range(1, 40);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 32]),
            };
            // heavy zero-row mix so empty rows and degree runs both occur
            let mut edges = Vec::new();
            for r in 0..n {
                let d = match rng.range(0, 4) {
                    0 => 0, // empty row
                    1 => rng.range(1, 4),
                    2 => rng.range(1, 12),
                    _ => rng.range(0, 2 * n + 2), // may split
                };
                for _ in 0..d {
                    edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
                }
            }
            let plan =
                Arc::new(SpmmPlan::build(Csr::from_edges(n, n, &edges).unwrap(), params));
            for &threads in &[1usize, 2, 8] {
                let exec = ParallelBlockLevel::new(threads);
                for &f in &[1usize, 3, 17, 33] {
                    let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
                    let got = exec.execute(&plan, &x, f);
                    let want = CsrReference.execute(&plan, &x, f);
                    assert_allclose(&got, &want, 1e-4, 1e-4, "ragged tail vs reference");
                }
            }
        });
    }

    /// The SIMD-equivalence satellite at executor scope: every
    /// (lane strategy × dispatch mode) combination agrees with the
    /// dense reference across thread counts {1, 2, 8}, the required
    /// column widths, empty rows, and split rows. Scalar and portable
    /// are additionally held bit-for-bit identical (same per-lane op
    /// order, same shard layout); arch is allclose within the
    /// documented FMA tolerance.
    #[test]
    fn prop_simd_levels_and_dispatch_match_reference() {
        use crate::spmm::microkernel::ARCH_REL_TOL;
        proptest::check("parallel_simd_matrix", 0x51D5, 6, |rng| {
            let n = rng.range(1, 40);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 32]),
            };
            // sparse-heavy degree mix so both kernel shapes are selected
            let mut edges = Vec::new();
            for r in 0..n {
                let d = match rng.range(0, 5) {
                    0 => 0, // empty row
                    1 | 2 => rng.range(1, 5), // gather territory
                    3 => rng.range(5, 20),
                    _ => rng.range(0, 2 * n + 2), // may split
                };
                for _ in 0..d {
                    edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
                }
            }
            let plan =
                Arc::new(SpmmPlan::build(Csr::from_edges(n, n, &edges).unwrap(), params));
            for &threads in &[1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                for &f in &[1usize, 3, 8, 16, 17, 33] {
                    let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
                    let want = CsrReference.execute(&plan, &x, f);
                    let mut scalar_adaptive = None;
                    for level in [SimdLevel::Scalar, SimdLevel::Portable, SimdLevel::Arch] {
                        for adaptive in [false, true] {
                            let got = spmm_block_level_parallel_with(
                                &plan, &x, f, &pool, level, adaptive,
                            );
                            assert_allclose(
                                &got,
                                &want,
                                1e-4,
                                1e-4,
                                &format!("{} adaptive={adaptive}", level.name()),
                            );
                            match (level, adaptive) {
                                (SimdLevel::Scalar, true) => scalar_adaptive = Some(got),
                                (SimdLevel::Portable, true) => {
                                    // bit-for-bit vs scalar on the same shard layout
                                    let sa = scalar_adaptive.as_ref().expect("scalar ran first");
                                    for (j, (a, b)) in got.iter().zip(sa).enumerate() {
                                        assert_eq!(
                                            a.to_bits(),
                                            b.to_bits(),
                                            "lane {j}: portable vs scalar bitwise"
                                        );
                                    }
                                }
                                (SimdLevel::Arch, true) => {
                                    let sa = scalar_adaptive.as_ref().expect("scalar ran first");
                                    for (a, b) in got.iter().zip(sa) {
                                        assert!(
                                            (a - b).abs() <= ARCH_REL_TOL * (1.0 + b.abs()),
                                            "arch {a} vs scalar {b} beyond ARCH_REL_TOL"
                                        );
                                    }
                                }
                                _ => {}
                            }
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn zero_and_empty_graphs() {
        let params = PartitionParams::default();
        let empty = Arc::new(SpmmPlan::build(Csr::from_edges(0, 0, &[]).unwrap(), params));
        let exec = ParallelBlockLevel::new(2);
        assert!(exec.execute(&empty, &[], 3).is_empty());
        assert!(exec.execute(&empty, &[], 17).is_empty());
        // all-zero rows produce an all-zero result, at ragged widths too
        let zeros = Arc::new(SpmmPlan::build(Csr::from_edges(4, 4, &[]).unwrap(), params));
        for f in [3usize, 17] {
            let y = exec.execute(&zeros, &vec![1.0; 4 * f], f);
            assert_eq!(y.len(), 4 * f);
            assert!(y.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn into_variant_reuses_buffers() {
        let mut rng = Pcg::seed_from(0x1A7E);
        let plan = random_plan(&mut rng, 30, PartitionParams { max_block_warps: 2, max_warp_nzs: 4 });
        let pool = ThreadPool::new(3);
        let f = 7;
        let mut y = vec![f32::NAN; 30 * f]; // stale garbage must be cleared
        for trial in 0..2 {
            let x: Vec<f32> = (0..30 * f).map(|_| rng.f32() - 0.5).collect();
            spmm_block_level_parallel_into(&plan, &x, f, &pool, &mut y);
            let want = CsrReference.execute(&plan, &x, f);
            assert_allclose(&y, &want, 1e-4, 1e-4, &format!("into trial {trial}"));
        }
    }

    #[test]
    fn scalar_baseline_matches_tiled_path() {
        // the bench baseline must be numerically interchangeable with
        // the hot path it is compared against
        let mut rng = Pcg::seed_from(0xBA5E);
        let plan = random_plan(&mut rng, 45, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        let pool = ThreadPool::new(4);
        for &f in &[5usize, 16, 33] {
            let x: Vec<f32> = (0..45 * f).map(|_| rng.f32() - 0.5).collect();
            let scalar = spmm_block_level_parallel_scalar(&plan, &x, f, &pool);
            let tiled = spmm_block_level_parallel(&plan, &x, f, &pool);
            let want = CsrReference.execute(&plan, &x, f);
            assert_allclose(&scalar, &want, 1e-4, 1e-4, "scalar vs reference");
            assert_allclose(&tiled, &scalar, 1e-4, 1e-4, "tiled vs scalar");
        }
    }
}
