//! The [`Executor`] trait: one contract over every way this codebase
//! can run `Y = A·X`.
//!
//! All executors consume the same [`SpmmPlan`] and agree on one
//! convention: `x` is `[n_cols × f]` row-major in the **original**
//! column order, and the returned `Y` is `[n_rows × f]` in the
//! **original** row order. Executors that internally run the
//! degree-sorted schedule undo the permutation before returning — the
//! sequential one with an explicit `unpermute_rows` pass, the parallel
//! one by scattering stores through the permutation (fused) — so any
//! two executors' outputs are directly comparable, up to f32 addition
//! reordering, which is exactly what the property tests assert.
//!
//! Implementations:
//! * [`CsrReference`] — the dense-traversal numeric ground truth.
//! * [`BlockLevel`] — the paper's schedule, sequential
//!   ([`crate::spmm::spmm_block_level`]).
//! * [`WarpLevel`] — the GNNAdvisor-style baseline
//!   ([`crate::spmm::spmm_warp_level`]).
//! * [`ParallelBlockLevel`](super::parallel::ParallelBlockLevel) — the
//!   block-level schedule sharded across the thread pool (see
//!   [`super::parallel`]).

use super::plan::SpmmPlan;
use crate::spmm::{spmm_block_level, spmm_block_level_adaptive, spmm_warp_level, SimdLevel};

/// A strategy for executing one SpMM request against a prebuilt plan.
///
/// The contract is **zero-copy**: both `plan` and `x` are plain
/// borrows, so implementations must not require owned or `Arc`-wrapped
/// inputs. Parallel executors achieve this with scoped pool jobs
/// ([`crate::util::threadpool::ThreadPool::scoped_run`]) that join
/// before `execute` returns. Callers holding `Arc<SpmmPlan>` /
/// `Arc<Vec<f32>>` pass `&plan` / `&x` and deref coercion does the
/// rest.
pub trait Executor {
    /// Stable identifier (used in bench output and test reports).
    fn name(&self) -> &'static str;

    /// Compute `Y = A·X`. `x` is `[plan.original.n_cols × f]` row-major;
    /// the result is `[plan.original.n_rows × f]`, original row order.
    fn execute(&self, plan: &SpmmPlan, x: &[f32], f: usize) -> Vec<f32>;
}

/// Dense CSR traversal over the original matrix — the reference.
pub struct CsrReference;

impl Executor for CsrReference {
    fn name(&self) -> &'static str {
        "csr-reference"
    }

    fn execute(&self, plan: &SpmmPlan, x: &[f32], f: usize) -> Vec<f32> {
        plan.original.spmm_dense(x, f)
    }
}

/// The paper's block-level schedule, executed sequentially block by
/// block (three accumulation levels, see [`crate::spmm::block_exec`]).
pub struct BlockLevel;

impl Executor for BlockLevel {
    fn name(&self) -> &'static str {
        "block-level"
    }

    fn execute(&self, plan: &SpmmPlan, x: &[f32], f: usize) -> Vec<f32> {
        let sorted_y = spmm_block_level(&plan.sorted.csr, &plan.block, x, f);
        plan.sorted.unpermute_rows(&sorted_y, f)
    }
}

/// The block-level schedule with the plan's sparsity-adaptive kernel
/// dispatch, sequential, at an explicit SIMD level
/// ([`crate::spmm::spmm_block_level_adaptive`]). The sequential
/// counterpart of running
/// [`ParallelBlockLevel`](super::parallel::ParallelBlockLevel) in
/// adaptive mode — used by tests and the bench harness to isolate
/// kernel-shape effects from sharding.
pub struct AdaptiveBlockLevel(pub SimdLevel);

impl Executor for AdaptiveBlockLevel {
    fn name(&self) -> &'static str {
        "block-level-adaptive"
    }

    fn execute(&self, plan: &SpmmPlan, x: &[f32], f: usize) -> Vec<f32> {
        let sorted_y = spmm_block_level_adaptive(plan, x, f, self.0);
        plan.sorted.unpermute_rows(&sorted_y, f)
    }
}

/// The warp-level (GNNAdvisor-style) baseline schedule.
pub struct WarpLevel;

impl Executor for WarpLevel {
    fn name(&self) -> &'static str {
        "warp-level"
    }

    fn execute(&self, plan: &SpmmPlan, x: &[f32], f: usize) -> Vec<f32> {
        spmm_warp_level(&plan.original, &plan.warp, x, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;
    use std::sync::Arc;

    fn random_plan(rng: &mut Pcg, n: usize) -> Arc<SpmmPlan> {
        let mut edges = Vec::new();
        for r in 0..n {
            let d = if rng.f64() < 0.06 { rng.range(0, n + 2) } else { rng.range(0, 8) };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
            }
        }
        let csr = Csr::from_edges(n, n, &edges).unwrap();
        let params = PartitionParams {
            max_block_warps: *rng.choose(&[1usize, 2, 4, 12]),
            max_warp_nzs: *rng.choose(&[1usize, 2, 4, 32]),
        };
        Arc::new(SpmmPlan::build(csr, params))
    }

    #[test]
    fn names_are_distinct() {
        let adaptive = AdaptiveBlockLevel(SimdLevel::Scalar);
        let execs: [&dyn Executor; 4] = [&CsrReference, &BlockLevel, &WarpLevel, &adaptive];
        let mut names: Vec<&str> = execs.iter().map(|e| e.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 4);
    }

    #[test]
    fn prop_all_executors_agree_in_original_domain() {
        proptest::check("executors_agree", 0xE8EC, 20, |rng| {
            let n = rng.range(1, 60);
            let plan = random_plan(rng, n);
            let f = rng.range(1, 8);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = CsrReference.execute(&plan, &x, f);
            let adaptive = AdaptiveBlockLevel(SimdLevel::best());
            for exec in [&BlockLevel as &dyn Executor, &WarpLevel, &adaptive] {
                let got = exec.execute(&plan, &x, f);
                assert_allclose(&got, &want, 1e-4, 1e-4, exec.name());
            }
        });
    }
}
