//! Env/flag-driven fault injection for the durability layer.
//!
//! `ACCEL_GCN_FAULT` is a comma-separated list of faults, each of which
//! must degrade gracefully (DESIGN §11 fault matrix) — a typed
//! [`StoreError`](super::StoreError) or a documented fallback, never a
//! panic:
//!
//! | flag                | injection point                              | expected degradation |
//! |---------------------|----------------------------------------------|----------------------|
//! | `torn-tail`         | WAL writer close truncates the final record  | tail dropped + warning on replay |
//! | `checksum-flip`     | first WAL batch record's CRC gets a bit flip | typed `ChecksumMismatch`/`Corrupt` on replay (mid-log) or dropped tail (if last) |
//! | `snapshot-truncate` | every snapshot generation **after the first** is cut in half | recovery falls back to the previous generation |
//! | `disk-full=BYTES`   | WAL appends fail once BYTES have been written| update shed with typed `DiskFull`, server keeps serving |
//!
//! The plan is shared (`Arc`) across every tenant of a
//! [`Store`](super::Store) so budget-style faults (`disk-full`) apply
//! globally, like a real device would.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Active fault switches. The default ([`FaultPlan::none`]) injects
/// nothing and is what production paths run with.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Truncate the final WAL record mid-payload when the writer
    /// closes — simulates a crash during the last append.
    pub torn_tail: bool,
    /// Flip one bit in the CRC of the first batch record written.
    checksum_flip: AtomicBool,
    /// Truncate each snapshot generation after the first to half its
    /// size right after the atomic rename.
    pub snapshot_truncate: bool,
    /// Total WAL bytes allowed before appends report `DiskFull`
    /// (`None` = unlimited).
    pub disk_full_after: Option<u64>,
    /// WAL bytes appended so far under this plan (all tenants).
    appended: AtomicU64,
}

impl FaultPlan {
    /// No faults (production).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse `ACCEL_GCN_FAULT`. Unknown flags are reported on stderr
    /// and ignored — a typo must not silently disable the whole matrix
    /// nor crash the server.
    pub fn from_env() -> FaultPlan {
        match std::env::var("ACCEL_GCN_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec),
            _ => FaultPlan::none(),
        }
    }

    /// Parse a comma-separated fault spec (see module docs).
    pub fn parse(spec: &str) -> FaultPlan {
        let mut plan = FaultPlan::none();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part {
                "torn-tail" => plan.torn_tail = true,
                "checksum-flip" => plan.checksum_flip = AtomicBool::new(true),
                "snapshot-truncate" => plan.snapshot_truncate = true,
                _ => match part.strip_prefix("disk-full=").and_then(|v| v.parse::<u64>().ok()) {
                    Some(bytes) => plan.disk_full_after = Some(bytes),
                    None => eprintln!("[store] ignoring unknown fault flag '{part}'"),
                },
            }
        }
        plan
    }

    /// True if any fault is armed (logged at store open).
    pub fn any(&self) -> bool {
        self.torn_tail
            || self.snapshot_truncate
            || self.disk_full_after.is_some()
            || self.checksum_flip.load(Ordering::Relaxed)
    }

    /// Consume the one-shot checksum-flip trigger (first batch record
    /// only, so the corruption lands mid-log once more records follow).
    pub(crate) fn take_checksum_flip(&self) -> bool {
        self.checksum_flip.swap(false, Ordering::Relaxed)
    }

    /// Account `bytes` of WAL append; `true` means the simulated device
    /// is full and the append must fail *before* writing.
    pub(crate) fn wal_append_would_fill(&self, bytes: u64) -> bool {
        match self.disk_full_after {
            None => false,
            Some(limit) => {
                let before = self.appended.fetch_add(bytes, Ordering::Relaxed);
                before + bytes > limit
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse("torn-tail, checksum-flip,snapshot-truncate,disk-full=4096");
        assert!(p.torn_tail);
        assert!(p.snapshot_truncate);
        assert_eq!(p.disk_full_after, Some(4096));
        assert!(p.any());
        assert!(p.take_checksum_flip(), "armed once");
        assert!(!p.take_checksum_flip(), "consumed");
    }

    #[test]
    fn unknown_flags_are_ignored() {
        let p = FaultPlan::parse("warp-core-breach,disk-full=oops");
        assert!(!p.any());
    }

    #[test]
    fn disk_full_budget_trips_once_exceeded() {
        let p = FaultPlan::parse("disk-full=100");
        assert!(!p.wal_append_would_fill(60));
        assert!(!p.wal_append_would_fill(40), "exactly at the limit still fits");
        assert!(p.wal_append_would_fill(1));
        let none = FaultPlan::none();
        assert!(!none.wal_append_would_fill(u64::MAX / 2));
    }
}
