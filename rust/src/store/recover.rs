//! Crash recovery: rebuild one tenant from its newest readable
//! snapshot plus the WAL tail, replayed through the **same**
//! [`DeltaGraph::apply`] path the live server uses, then assert the
//! recovered plan identity against the last commit seal.
//!
//! The epoch chain is checked strictly: after skipping batches at or
//! before the snapshot epoch, the remaining batches must advance the
//! epoch by exactly one each — a gap means the WAL and snapshot
//! disagree about history and recovery refuses with a typed error
//! rather than serving a silently wrong graph.

use super::wal::replay_wal;
use super::{StoreError, TenantStore};
use crate::delta::DeltaGraph;
use crate::graph::csr::Csr;
use crate::graph::degree::DegreeSorted;
use crate::pipeline::GraphFingerprint;

/// One tenant rebuilt from disk.
#[derive(Debug)]
pub struct RecoveredTenant {
    /// Registry name (from the snapshot header, not the directory).
    pub name: String,
    /// Original-domain effective adjacency at `epoch`.
    pub csr: Csr,
    /// Epoch after replaying the WAL tail.
    pub epoch: u64,
    /// Fingerprint of the relabeled matrix at `epoch` — the recovered
    /// plan identity ([`relabeled_fingerprint`]).
    pub fingerprint: GraphFingerprint,
    /// True when a commit seal for `epoch` existed and matched; false
    /// when the crash landed between a batch append and its seal (the
    /// batch is still applied — it was durably logged — but there is
    /// nothing to verify against).
    pub fingerprint_verified: bool,
    /// Epoch of the snapshot replay started from.
    pub snapshot_epoch: u64,
    /// Snapshot generation used.
    pub snapshot_gen: u64,
    /// True when the newest generation was unreadable and recovery
    /// fell back.
    pub snapshot_fell_back: bool,
    /// WAL batches replayed on top of the snapshot.
    pub replayed_batches: usize,
    /// True when a torn/damaged final WAL record was dropped.
    pub torn_tail_dropped: bool,
}

/// The plan identity a CSR would get when registered for serving: the
/// fingerprint of its degree-relabeled form (`P·A·Pᵀ`), which is what
/// [`PlanCache`](crate::pipeline::PlanCache) keys on. The incremental
/// path is proven equal to this fresh sort
/// (`registry::tests::update_bumps_epoch_and_matches_fresh_registration`),
/// which is exactly why recovered fingerprints are comparable to live
/// ones.
pub fn relabeled_fingerprint(csr: &Csr) -> GraphFingerprint {
    let sorted = DegreeSorted::new(csr);
    GraphFingerprint::of(&csr.relabel(&sorted.perm, &sorted.inv))
}

/// Rebuild one tenant: newest readable snapshot + strict WAL replay +
/// fingerprint assertion. Every failure is a typed [`StoreError`];
/// degraded-but-sound outcomes (fallback generation, dropped torn
/// tail, unverified final epoch) are flagged on the result instead.
pub fn recover_tenant(ts: &TenantStore) -> Result<RecoveredTenant, StoreError> {
    let (snap, snapshot_gen, snapshot_fell_back) = ts.load_snapshot()?;
    let wal_path = ts.wal_path();
    let replay = replay_wal(&wal_path)?;
    let mut dg = DeltaGraph::new(snap.csr);
    let mut epoch = snap.epoch;
    let mut replayed = 0usize;
    for (batch_epoch, updates) in replay.batches() {
        if batch_epoch <= snap.epoch {
            continue; // already folded into the snapshot
        }
        if batch_epoch != epoch + 1 {
            return Err(StoreError::EpochGap {
                path: wal_path.clone(),
                want: epoch + 1,
                got: batch_epoch,
            });
        }
        dg.apply(updates).map_err(|e| StoreError::Corrupt {
            path: wal_path.clone(),
            offset: 0,
            detail: format!("logged batch for epoch {batch_epoch} fails to apply: {e}"),
        })?;
        epoch = batch_epoch;
        replayed += 1;
    }
    let csr = dg.snapshot();
    let fingerprint = relabeled_fingerprint(&csr);
    let expected = if epoch == snap.epoch {
        Some(snap.fingerprint)
    } else {
        replay.commit_fingerprint(epoch)
    };
    let fingerprint_verified = match expected {
        Some(want) => {
            if want != fingerprint {
                return Err(StoreError::FingerprintMismatch {
                    tenant: snap.name,
                    epoch,
                    detail: format!(
                        "sealed {:#018x}, replay produced {:#018x}",
                        want.content_hash, fingerprint.content_hash
                    ),
                });
            }
            true
        }
        None => {
            eprintln!(
                "[store] warning: tenant '{}' epoch {epoch} has no commit seal \
                 (crash between append and apply); replayed state is unverified",
                snap.name
            );
            false
        }
    };
    Ok(RecoveredTenant {
        name: snap.name,
        csr,
        epoch,
        fingerprint,
        fingerprint_verified,
        snapshot_epoch: snap.epoch,
        snapshot_gen,
        snapshot_fell_back,
        replayed_batches: replayed,
        torn_tail_dropped: replay.torn_tail_dropped,
    })
}

#[cfg(test)]
mod tests {
    use super::super::wal::{WalRecord, WalWriter};
    use super::super::{test_dir, FaultPlan, FsyncPolicy, Snapshot, Store, StoreError};
    use super::*;
    use crate::delta::EdgeUpdate;
    use crate::util::rng::Pcg;
    use std::sync::Arc;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(1, 6) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    fn random_updates(rng: &mut Pcg, n: usize, k: usize) -> Vec<EdgeUpdate> {
        (0..k)
            .map(|_| EdgeUpdate::Insert {
                row: rng.range(0, n) as u32,
                col: rng.range(0, n) as u32,
                val: rng.f32() + 0.1,
            })
            .collect()
    }

    /// Write snapshot at epoch 0 + N sealed WAL batches; recovery must
    /// land on the exact fingerprint an uncrashed in-memory replay
    /// produces.
    #[test]
    fn snapshot_plus_wal_recovers_to_sealed_fingerprint() {
        let d = test_dir("recover-e2e");
        let store = Store::open(&d, FsyncPolicy::Never).unwrap();
        let ts = store.tenant("g").unwrap();
        let base = random_csr(1, 40);
        ts.write_snapshot(&Snapshot {
            name: "g".into(),
            epoch: 0,
            fingerprint: relabeled_fingerprint(&base),
            csr: base.clone(),
        })
        .unwrap();
        let mut rng = Pcg::seed_from(2);
        let mut oracle = DeltaGraph::new(base);
        let mut w =
            WalWriter::open(ts.wal_path(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                .unwrap();
        for e in 1..=5u64 {
            let batch = random_updates(&mut rng, 40, 8);
            w.append(&WalRecord::Batch { epoch: e, updates: batch.clone() }).unwrap();
            oracle.apply(&batch).unwrap();
            let fp = relabeled_fingerprint(&oracle.snapshot());
            w.append(&WalRecord::Commit { epoch: e, fingerprint: fp }).unwrap();
        }
        drop(w);
        let rec = recover_tenant(&ts).unwrap();
        assert_eq!(rec.name, "g");
        assert_eq!(rec.epoch, 5);
        assert_eq!(rec.replayed_batches, 5);
        assert!(rec.fingerprint_verified);
        assert!(!rec.snapshot_fell_back && !rec.torn_tail_dropped);
        assert_eq!(rec.csr, oracle.snapshot(), "recovered CSR == uncrashed CSR");
        assert_eq!(rec.fingerprint, relabeled_fingerprint(&oracle.snapshot()));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn unsealed_final_batch_is_applied_but_unverified() {
        let d = test_dir("recover-unsealed");
        let store = Store::open(&d, FsyncPolicy::Never).unwrap();
        let ts = store.tenant("g").unwrap();
        let base = random_csr(3, 25);
        ts.write_snapshot(&Snapshot {
            name: "g".into(),
            epoch: 0,
            fingerprint: relabeled_fingerprint(&base),
            csr: base.clone(),
        })
        .unwrap();
        let mut rng = Pcg::seed_from(4);
        let batch = random_updates(&mut rng, 25, 5);
        let mut w =
            WalWriter::open(ts.wal_path(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                .unwrap();
        // crash before the commit seal could be appended
        w.append(&WalRecord::Batch { epoch: 1, updates: batch.clone() }).unwrap();
        drop(w);
        let rec = recover_tenant(&ts).unwrap();
        assert_eq!(rec.epoch, 1);
        assert!(!rec.fingerprint_verified, "no seal to verify against");
        let mut oracle = DeltaGraph::new(base);
        oracle.apply(&batch).unwrap();
        assert_eq!(rec.csr, oracle.snapshot(), "the logged batch still applies");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn epoch_gap_and_bad_seal_are_typed_errors() {
        let d = test_dir("recover-gap");
        let store = Store::open(&d, FsyncPolicy::Never).unwrap();
        let ts = store.tenant("g").unwrap();
        let base = random_csr(5, 20);
        ts.write_snapshot(&Snapshot {
            name: "g".into(),
            epoch: 0,
            fingerprint: relabeled_fingerprint(&base),
            csr: base.clone(),
        })
        .unwrap();
        let mut rng = Pcg::seed_from(6);
        {
            let mut w =
                WalWriter::open(ts.wal_path(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                    .unwrap();
            // epoch 2 with no epoch 1 before it
            w.append(&WalRecord::Batch { epoch: 2, updates: random_updates(&mut rng, 20, 3) })
                .unwrap();
        }
        match recover_tenant(&ts) {
            Err(StoreError::EpochGap { want: 1, got: 2, .. }) => {}
            other => panic!("expected EpochGap, got {other:?}"),
        }
        // now a contiguous batch whose seal lies about the fingerprint
        std::fs::remove_file(ts.wal_path()).unwrap();
        {
            let mut w =
                WalWriter::open(ts.wal_path(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                    .unwrap();
            w.append(&WalRecord::Batch { epoch: 1, updates: random_updates(&mut rng, 20, 3) })
                .unwrap();
            let lie = GraphFingerprint { n_rows: 20, n_cols: 20, nnz: 1, content_hash: 0xBAD };
            w.append(&WalRecord::Commit { epoch: 1, fingerprint: lie }).unwrap();
        }
        match recover_tenant(&ts) {
            Err(StoreError::FingerprintMismatch { epoch: 1, .. }) => {}
            other => panic!("expected FingerprintMismatch, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn fallback_generation_replays_the_longer_wal_tail() {
        // gen1 at epoch 0, gen2 at epoch 2 (injected-truncated), WAL
        // holding epochs 1..=3: recovery must fall back to gen1 and
        // still reach epoch 3 because compaction kept the tail
        let d = test_dir("recover-fallback");
        let store = Store::open_with_faults(
            &d,
            FsyncPolicy::Never,
            FaultPlan::parse("snapshot-truncate"),
        )
        .unwrap();
        let ts = store.tenant("g").unwrap();
        let base = random_csr(7, 30);
        ts.write_snapshot(&Snapshot {
            name: "g".into(),
            epoch: 0,
            fingerprint: relabeled_fingerprint(&base),
            csr: base.clone(),
        })
        .unwrap();
        let mut rng = Pcg::seed_from(8);
        let mut oracle = DeltaGraph::new(base);
        let mut w =
            WalWriter::open(ts.wal_path(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                .unwrap();
        for e in 1..=3u64 {
            let batch = random_updates(&mut rng, 30, 6);
            w.append(&WalRecord::Batch { epoch: e, updates: batch.clone() }).unwrap();
            oracle.apply(&batch).unwrap();
            let fp = relabeled_fingerprint(&oracle.snapshot());
            w.append(&WalRecord::Commit { epoch: e, fingerprint: fp }).unwrap();
            if e == 2 {
                // periodic snapshot — injected fault truncates it (gen 2)
                let info = ts
                    .write_snapshot(&Snapshot {
                        name: "g".into(),
                        epoch: 2,
                        fingerprint: fp,
                        csr: oracle.snapshot(),
                    })
                    .unwrap();
                // compaction cutoff = oldest retained gen's epoch (0)
                w.compact(info.retained_oldest_epoch).unwrap();
            }
        }
        drop(w);
        let rec = recover_tenant(&ts).unwrap();
        assert!(rec.snapshot_fell_back, "gen2 is damaged");
        assert_eq!(rec.snapshot_gen, 1);
        assert_eq!(rec.epoch, 3);
        assert_eq!(rec.replayed_batches, 3, "full tail replays from gen1");
        assert!(rec.fingerprint_verified);
        assert_eq!(rec.csr, oracle.snapshot());
        let _ = std::fs::remove_dir_all(&d);
    }
}
