//! Byte-level codec shared by the snapshot and WAL formats: little-
//! endian primitives over growable buffers, a checked read cursor, the
//! IEEE CRC-32 both file formats checksum with, and the
//! [`EdgeUpdate`] wire encoding.
//!
//! Everything is explicit-width little-endian — the formats are
//! byte-identical across architectures.

use crate::delta::EdgeUpdate;
use crate::pipeline::GraphFingerprint;
use std::sync::OnceLock;

// ---------------------------------------------------------------------
// writers

pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_bytes(buf: &mut Vec<u8>, v: &[u8]) {
    put_u32(buf, v.len() as u32);
    buf.extend_from_slice(v);
}

// ---------------------------------------------------------------------
// checked reader

/// Bounds-checked little-endian reader; every `take_*` returns `None`
/// on underflow so callers turn truncation into their own typed error
/// with the right file/offset context.
pub struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(data: &'a [u8]) -> Cursor<'a> {
        Cursor { data, pos: 0 }
    }

    /// Bytes consumed so far.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left.
    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    pub fn take_u8(&mut self) -> Option<u8> {
        let b = *self.data.get(self.pos)?;
        self.pos += 1;
        Some(b)
    }

    pub fn take_u32(&mut self) -> Option<u32> {
        let raw = self.data.get(self.pos..self.pos + 4)?;
        self.pos += 4;
        Some(u32::from_le_bytes(raw.try_into().unwrap()))
    }

    pub fn take_u64(&mut self) -> Option<u64> {
        let raw = self.data.get(self.pos..self.pos + 8)?;
        self.pos += 8;
        Some(u64::from_le_bytes(raw.try_into().unwrap()))
    }

    pub fn take_f32(&mut self) -> Option<f32> {
        let raw = self.data.get(self.pos..self.pos + 4)?;
        self.pos += 4;
        Some(f32::from_le_bytes(raw.try_into().unwrap()))
    }

    pub fn take_bytes(&mut self) -> Option<&'a [u8]> {
        let len = self.take_u32()? as usize;
        let raw = self.data.get(self.pos..self.pos + len)?;
        self.pos += len;
        Some(raw)
    }
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, init/xorout 0xFFFFFFFF)

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// IEEE CRC-32 of `data` (the `cksum`/zlib polynomial, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// domain encodings

/// Wire tags for [`EdgeUpdate`] (one byte each).
const TAG_INSERT: u8 = 0;
const TAG_DELETE: u8 = 1;

/// Append one edge update: `tag u8, row u32, col u32[, val f32]`.
pub fn put_update(buf: &mut Vec<u8>, u: &EdgeUpdate) {
    match *u {
        EdgeUpdate::Insert { row, col, val } => {
            put_u8(buf, TAG_INSERT);
            put_u32(buf, row);
            put_u32(buf, col);
            put_f32(buf, val);
        }
        EdgeUpdate::Delete { row, col } => {
            put_u8(buf, TAG_DELETE);
            put_u32(buf, row);
            put_u32(buf, col);
        }
    }
}

/// Decode one edge update; `None` on truncation or an unknown tag.
pub fn take_update(cur: &mut Cursor<'_>) -> Option<EdgeUpdate> {
    match cur.take_u8()? {
        TAG_INSERT => Some(EdgeUpdate::Insert {
            row: cur.take_u32()?,
            col: cur.take_u32()?,
            val: cur.take_f32()?,
        }),
        TAG_DELETE => Some(EdgeUpdate::Delete { row: cur.take_u32()?, col: cur.take_u32()? }),
        _ => None,
    }
}

/// Append a fingerprint as four u64 words (dims, nnz, content hash).
pub fn put_fingerprint(buf: &mut Vec<u8>, fp: &GraphFingerprint) {
    put_u64(buf, fp.n_rows as u64);
    put_u64(buf, fp.n_cols as u64);
    put_u64(buf, fp.nnz as u64);
    put_u64(buf, fp.content_hash);
}

pub fn take_fingerprint(cur: &mut Cursor<'_>) -> Option<GraphFingerprint> {
    Some(GraphFingerprint {
        n_rows: cur.take_u64()? as usize,
        n_cols: cur.take_u64()? as usize,
        nnz: cur.take_u64()? as usize,
        content_hash: cur.take_u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_answer() {
        // the standard check value for CRC-32/ISO-HDLC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn primitives_roundtrip() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 0xAB);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 7);
        put_f32(&mut buf, -1.5e-3);
        put_bytes(&mut buf, b"tenant");
        let mut cur = Cursor::new(&buf);
        assert_eq!(cur.take_u8(), Some(0xAB));
        assert_eq!(cur.take_u32(), Some(0xDEAD_BEEF));
        assert_eq!(cur.take_u64(), Some(u64::MAX - 7));
        assert_eq!(cur.take_f32(), Some(-1.5e-3));
        assert_eq!(cur.take_bytes(), Some(&b"tenant"[..]));
        assert_eq!(cur.remaining(), 0);
        assert_eq!(cur.take_u8(), None, "underflow is None, not a panic");
    }

    #[test]
    fn updates_roundtrip_including_nan_bits() {
        let ups = vec![
            EdgeUpdate::Insert { row: 0, col: u32::MAX, val: f32::NAN },
            EdgeUpdate::Delete { row: 7, col: 7 },
            EdgeUpdate::Insert { row: 42, col: 1, val: -0.0 },
        ];
        let mut buf = Vec::new();
        for u in &ups {
            put_update(&mut buf, u);
        }
        let mut cur = Cursor::new(&buf);
        for u in &ups {
            let got = take_update(&mut cur).unwrap();
            // compare by bits: the codec must preserve NaN payloads and
            // signed zero exactly
            match (u, &got) {
                (
                    EdgeUpdate::Insert { row, col, val },
                    EdgeUpdate::Insert { row: r2, col: c2, val: v2 },
                ) => {
                    assert_eq!((row, col), (r2, c2));
                    assert_eq!(val.to_bits(), v2.to_bits());
                }
                (a, b) => assert_eq!(format!("{a:?}"), format!("{b:?}")),
            }
        }
        assert_eq!(cur.remaining(), 0);
        // unknown tag decodes to None
        let bad = [9u8, 0, 0, 0, 0, 0, 0, 0, 0];
        assert!(take_update(&mut Cursor::new(&bad)).is_none());
    }

    #[test]
    fn truncated_bytes_field_is_none() {
        let mut buf = Vec::new();
        put_bytes(&mut buf, b"abcdef");
        for cut in 0..buf.len() {
            let mut cur = Cursor::new(&buf[..cut]);
            assert!(cur.take_bytes().is_none(), "cut at {cut}");
        }
    }
}
