//! The delta WAL: an append-only log of `UpdateGraph` batches (written
//! **before** the in-memory apply) and post-apply **commit** seals.
//!
//! ## File format
//!
//! ```text
//! header : "AGWL" u32-version
//! record : u32 len | u32 crc32(payload) | payload (len bytes)
//! payload: kind u8
//!   kind 1 (batch) : u64 epoch | u32 count | count × EdgeUpdate
//!   kind 2 (commit): u64 epoch | GraphFingerprint (4 × u64)
//! ```
//!
//! A **batch** record at epoch `e` means "the updates that take the
//! tenant from epoch `e-1` to `e` are durable"; it is appended before
//! [`GraphRegistry::update`](crate::serve::GraphRegistry::update) runs,
//! so logged == applied-or-about-to-apply and nothing applies that was
//! not logged. The **commit** record seals the apply with the
//! relabeled-matrix fingerprint the plan cache keys on — recovery
//! replays batches and asserts its recomputed fingerprint against the
//! last seal.
//!
//! ## Torn tails vs corruption
//!
//! Appends are a single `write_all`; a crash can only tear a *prefix*
//! of the final record. Replay therefore drops an incomplete or
//! CRC-failed **final** record with a warning (the batch never
//! committed in memory either — see the append-before-apply ordering),
//! but a CRC failure anywhere earlier means real corruption and is a
//! typed [`StoreError`].

use super::codec::{self, Cursor};
use super::faults::FaultPlan;
use super::{FsyncPolicy, StoreError};
use crate::delta::EdgeUpdate;
use crate::pipeline::GraphFingerprint;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const WAL_MAGIC: &[u8; 4] = b"AGWL";
const WAL_VERSION: u32 = 1;
const HEADER_LEN: u64 = 8;
const RECORD_HEADER_LEN: u64 = 8;
/// Upper bound on one record's payload; anything larger on disk is
/// corruption, not a real record.
const MAX_RECORD: u32 = 1 << 26;

const KIND_BATCH: u8 = 1;
const KIND_COMMIT: u8 = 2;

/// One decoded WAL record.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// The updates taking the tenant to `epoch` (from `epoch - 1`).
    Batch { epoch: u64, updates: Vec<EdgeUpdate> },
    /// Post-apply seal: the relabeled-matrix fingerprint at `epoch`.
    Commit { epoch: u64, fingerprint: GraphFingerprint },
}

impl WalRecord {
    pub fn epoch(&self) -> u64 {
        match self {
            WalRecord::Batch { epoch, .. } | WalRecord::Commit { epoch, .. } => *epoch,
        }
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            WalRecord::Batch { epoch, updates } => {
                codec::put_u8(&mut p, KIND_BATCH);
                codec::put_u64(&mut p, *epoch);
                codec::put_u32(&mut p, updates.len() as u32);
                for u in updates {
                    codec::put_update(&mut p, u);
                }
            }
            WalRecord::Commit { epoch, fingerprint } => {
                codec::put_u8(&mut p, KIND_COMMIT);
                codec::put_u64(&mut p, *epoch);
                codec::put_fingerprint(&mut p, fingerprint);
            }
        }
        p
    }

    fn decode_payload(payload: &[u8]) -> Option<WalRecord> {
        let mut cur = Cursor::new(payload);
        let rec = match cur.take_u8()? {
            KIND_BATCH => {
                let epoch = cur.take_u64()?;
                let count = cur.take_u32()? as usize;
                let mut updates = Vec::with_capacity(count.min(1 << 20));
                for _ in 0..count {
                    updates.push(codec::take_update(&mut cur)?);
                }
                WalRecord::Batch { epoch, updates }
            }
            KIND_COMMIT => WalRecord::Commit {
                epoch: cur.take_u64()?,
                fingerprint: codec::take_fingerprint(&mut cur)?,
            },
            _ => return None,
        };
        (cur.remaining() == 0).then_some(rec)
    }

    /// Frame the record for disk: `len | crc | payload`.
    fn encode_frame(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut frame = Vec::with_capacity(payload.len() + RECORD_HEADER_LEN as usize);
        codec::put_u32(&mut frame, payload.len() as u32);
        codec::put_u32(&mut frame, codec::crc32(&payload));
        frame.extend_from_slice(&payload);
        frame
    }
}

/// What a full WAL scan produced.
#[derive(Debug, Default)]
pub struct WalReplay {
    /// Every intact record, in append order.
    pub records: Vec<WalRecord>,
    /// True when an incomplete / CRC-failed final record was dropped.
    pub torn_tail_dropped: bool,
    /// Bytes of intact log scanned (excludes a dropped tail).
    pub bytes: u64,
}

impl WalReplay {
    /// The batch records in order.
    pub fn batches(&self) -> impl Iterator<Item = (u64, &[EdgeUpdate])> {
        self.records.iter().filter_map(|r| match r {
            WalRecord::Batch { epoch, updates } => Some((*epoch, updates.as_slice())),
            WalRecord::Commit { .. } => None,
        })
    }

    /// The sealed fingerprint at `epoch`, if a commit record survived.
    pub fn commit_fingerprint(&self, epoch: u64) -> Option<GraphFingerprint> {
        self.records.iter().rev().find_map(|r| match r {
            WalRecord::Commit { epoch: e, fingerprint } if *e == epoch => Some(*fingerprint),
            _ => None,
        })
    }

    /// Highest batch epoch in the log (0 when no batches survived).
    pub fn last_batch_epoch(&self) -> u64 {
        self.batches().map(|(e, _)| e).max().unwrap_or(0)
    }
}

/// Scan a WAL file. A missing file is an empty (valid) log. See the
/// module docs for the torn-tail-vs-corruption contract.
pub fn replay_wal(path: &Path) -> Result<WalReplay, StoreError> {
    let data = match std::fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(WalReplay::default()),
        Err(e) => return Err(StoreError::from_io("read", path, e)),
    };
    let mut out = WalReplay::default();
    if data.is_empty() {
        return Ok(out);
    }
    if data.len() < HEADER_LEN as usize {
        // the file was created but the header write itself tore
        warn_torn(path, 0);
        out.torn_tail_dropped = true;
        return Ok(out);
    }
    if &data[..4] != WAL_MAGIC {
        return Err(StoreError::BadMagic { path: path.to_path_buf() });
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version != WAL_VERSION {
        return Err(StoreError::UnsupportedVersion { path: path.to_path_buf(), version });
    }
    let mut pos = HEADER_LEN as usize;
    while pos < data.len() {
        let remaining = data.len() - pos;
        if remaining < RECORD_HEADER_LEN as usize {
            warn_torn(path, pos);
            out.torn_tail_dropped = true;
            break;
        }
        let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
        let stored_crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
        if len > MAX_RECORD {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                offset: pos as u64,
                detail: format!("record length {len} exceeds the {MAX_RECORD}-byte bound"),
            });
        }
        let body_start = pos + RECORD_HEADER_LEN as usize;
        let body_end = body_start + len as usize;
        if body_end > data.len() {
            // the final append tore mid-payload
            warn_torn(path, pos);
            out.torn_tail_dropped = true;
            break;
        }
        let payload = &data[body_start..body_end];
        let computed = codec::crc32(payload);
        let at_eof = body_end == data.len();
        if computed != stored_crc {
            if at_eof {
                // a damaged *final* record is indistinguishable from a
                // torn append — drop it like one
                warn_torn(path, pos);
                out.torn_tail_dropped = true;
                break;
            }
            return Err(StoreError::ChecksumMismatch {
                path: path.to_path_buf(),
                want: stored_crc,
                got: computed,
            });
        }
        match WalRecord::decode_payload(payload) {
            Some(rec) => out.records.push(rec),
            None => {
                return Err(StoreError::Corrupt {
                    path: path.to_path_buf(),
                    offset: pos as u64,
                    detail: "record payload fails structural decode despite a valid CRC".into(),
                })
            }
        }
        pos = body_end;
        out.bytes = pos as u64;
    }
    Ok(out)
}

fn warn_torn(path: &Path, offset: usize) {
    eprintln!(
        "[store] warning: dropping torn/damaged final WAL record in {} at byte {offset}",
        path.display()
    );
}

/// Append handle over one tenant's WAL. The worker thread is the only
/// appender; recovery uses [`replay_wal`] read-only.
#[derive(Debug)]
pub struct WalWriter {
    file: File,
    path: PathBuf,
    fsync: FsyncPolicy,
    faults: Arc<FaultPlan>,
    /// Current file length.
    end: u64,
    /// Offset of the most recently appended record (== `end` when no
    /// append has happened through this handle).
    last_record_start: u64,
}

impl WalWriter {
    /// Open (creating + writing the header if new) for appending. An
    /// existing file gets its header validated — a WAL we cannot parse
    /// must fail loudly here, not corrupt silently on the next append.
    pub fn open(
        path: PathBuf,
        fsync: FsyncPolicy,
        faults: Arc<FaultPlan>,
    ) -> Result<WalWriter, StoreError> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .read(true)
            .open(&path)
            .map_err(|e| StoreError::from_io("open", &path, e))?;
        let mut end =
            file.metadata().map_err(|e| StoreError::from_io("stat", &path, e))?.len();
        if end == 0 {
            let mut header = Vec::with_capacity(HEADER_LEN as usize);
            header.extend_from_slice(WAL_MAGIC);
            codec::put_u32(&mut header, WAL_VERSION);
            (&file).write_all(&header).map_err(|e| StoreError::from_io("write", &path, e))?;
            end = HEADER_LEN;
        } else {
            let mut head = [0u8; HEADER_LEN as usize];
            let mut reader =
                File::open(&path).map_err(|e| StoreError::from_io("open", &path, e))?;
            reader.read_exact(&mut head).map_err(|e| StoreError::from_io("read", &path, e))?;
            if &head[..4] != WAL_MAGIC {
                return Err(StoreError::BadMagic { path });
            }
            let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
            if version != WAL_VERSION {
                return Err(StoreError::UnsupportedVersion { path, version });
            }
        }
        Ok(WalWriter { file, path, fsync, faults, end, last_record_start: end })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record; returns the frame size in bytes. On any
    /// error — including injected disk-full — nothing is considered
    /// durable and the caller must not apply the logged batch.
    pub fn append(&mut self, rec: &WalRecord) -> Result<u64, StoreError> {
        let mut frame = rec.encode_frame();
        if self.faults.wal_append_would_fill(frame.len() as u64) {
            return Err(StoreError::DiskFull { path: self.path.clone() });
        }
        if matches!(rec, WalRecord::Batch { .. }) && self.faults.take_checksum_flip() {
            frame[4] ^= 0x01; // one bit of the stored CRC
        }
        (&self.file)
            .write_all(&frame)
            .map_err(|e| StoreError::from_io("append", &self.path, e))?;
        if self.fsync == FsyncPolicy::Always {
            self.file.sync_data().map_err(|e| StoreError::from_io("fsync", &self.path, e))?;
        }
        self.last_record_start = self.end;
        self.end += frame.len() as u64;
        Ok(frame.len() as u64)
    }

    /// Force everything appended so far to disk regardless of policy
    /// (shutdown path).
    pub fn flush(&mut self) -> Result<(), StoreError> {
        self.file.sync_data().map_err(|e| StoreError::from_io("fsync", &self.path, e))
    }

    /// Drop every record with `epoch <= keep_after_epoch` by atomically
    /// rewriting the file (tmp + rename) and re-opening the append
    /// handle. Called after a snapshot: the retained tail must still
    /// cover replay from the *previous* retained generation, so the
    /// cutoff is that generation's epoch, not the new one's.
    pub fn compact(&mut self, keep_after_epoch: u64) -> Result<(), StoreError> {
        let replay = replay_wal(&self.path)?;
        let tmp = self.path.with_extension("tmp");
        {
            let mut f =
                File::create(&tmp).map_err(|e| StoreError::from_io("create", &tmp, e))?;
            let mut buf = Vec::new();
            buf.extend_from_slice(WAL_MAGIC);
            codec::put_u32(&mut buf, WAL_VERSION);
            for rec in replay.records.iter().filter(|r| r.epoch() > keep_after_epoch) {
                buf.extend_from_slice(&rec.encode_frame());
            }
            f.write_all(&buf).map_err(|e| StoreError::from_io("write", &tmp, e))?;
            f.sync_data().map_err(|e| StoreError::from_io("fsync", &tmp, e))?;
        }
        std::fs::rename(&tmp, &self.path).map_err(|e| StoreError::from_io("rename", &tmp, e))?;
        let reopened = OpenOptions::new()
            .append(true)
            .read(true)
            .open(&self.path)
            .map_err(|e| StoreError::from_io("open", &self.path, e))?;
        self.end = reopened
            .metadata()
            .map_err(|e| StoreError::from_io("stat", &self.path, e))?
            .len();
        self.last_record_start = self.end;
        self.file = reopened;
        Ok(())
    }
}

impl Drop for WalWriter {
    fn drop(&mut self) {
        // injected crash-during-final-append: leave a torn prefix of
        // the last record on disk
        if self.faults.torn_tail && self.last_record_start < self.end {
            let body = (self.end - self.last_record_start).saturating_sub(RECORD_HEADER_LEN);
            let cut = if body > 1 {
                self.last_record_start + RECORD_HEADER_LEN + body / 2
            } else {
                self.last_record_start + RECORD_HEADER_LEN / 2
            };
            let _ = self.file.set_len(cut);
            let _ = self.file.sync_data();
        } else if self.fsync == FsyncPolicy::Never {
            // best-effort flush on graceful close
            let _ = self.file.sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_dir;
    use super::*;
    use crate::util::rng::Pcg;

    fn tmp_wal(tag: &str) -> PathBuf {
        let d = test_dir(tag);
        std::fs::create_dir_all(&d).unwrap();
        d.join("wal.bin")
    }

    fn random_batch_rec(rng: &mut Pcg, epoch: u64) -> WalRecord {
        let n = rng.range(0, 12);
        let updates = (0..n)
            .map(|_| {
                if rng.f64() < 0.3 {
                    EdgeUpdate::Delete { row: rng.range(0, 500) as u32, col: rng.range(0, 500) as u32 }
                } else {
                    EdgeUpdate::Insert {
                        row: rng.range(0, 500) as u32,
                        col: rng.range(0, 500) as u32,
                        val: rng.f32() - 0.5,
                    }
                }
            })
            .collect();
        WalRecord::Batch { epoch, updates }
    }

    fn write_all(path: &Path, records: &[WalRecord]) {
        let mut w =
            WalWriter::open(path.to_path_buf(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                .unwrap();
        for r in records {
            w.append(r).unwrap();
        }
    }

    /// Satellite: proptest encode/decode of random `UpdateGraph`
    /// batches — every batch written is read back exactly, in order,
    /// interleaved with commit seals.
    #[test]
    fn wal_roundtrip_random_batches() {
        crate::util::proptest::check("wal_roundtrip", 0x9A17, 30, |rng| {
            let path = tmp_wal("roundtrip");
            let n_rec = rng.range(1, 9);
            let mut records = Vec::new();
            for e in 1..=n_rec {
                records.push(random_batch_rec(rng, e as u64));
                if rng.f64() < 0.5 {
                    let fp = GraphFingerprint {
                        n_rows: rng.range(1, 100),
                        n_cols: rng.range(1, 100),
                        nnz: rng.range(0, 1000),
                        content_hash: rng.next_u64(),
                    };
                    records.push(WalRecord::Commit { epoch: e as u64, fingerprint: fp });
                }
            }
            write_all(&path, &records);
            let replay = replay_wal(&path).unwrap();
            assert!(!replay.torn_tail_dropped);
            assert_eq!(replay.records, records);
            let _ = std::fs::remove_dir_all(path.parent().unwrap());
        });
    }

    /// Satellite: deterministic truncation at **every byte offset** of
    /// the final record recovers exactly the earlier records.
    #[test]
    fn truncation_at_every_offset_of_final_record() {
        let path = tmp_wal("torn");
        let mut rng = Pcg::seed_from(42);
        let keep = vec![random_batch_rec(&mut rng, 1), random_batch_rec(&mut rng, 2)];
        let mut all = keep.clone();
        all.push(random_batch_rec(&mut rng, 3));
        write_all(&path, &all);
        let full = std::fs::read(&path).unwrap();
        let last_frame = all.last().unwrap().encode_frame();
        let last_start = full.len() - last_frame.len();
        for cut in last_start + 1..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let replay = replay_wal(&path).unwrap_or_else(|e| panic!("cut at {cut}: {e}"));
            assert_eq!(replay.records, keep, "cut at {cut}");
            assert!(replay.torn_tail_dropped, "cut at {cut} must flag the dropped tail");
        }
        // untouched file: everything back
        std::fs::write(&path, &full).unwrap();
        let replay = replay_wal(&path).unwrap();
        assert_eq!(replay.records, all);
        assert!(!replay.torn_tail_dropped);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn midlog_corruption_is_a_typed_error() {
        let path = tmp_wal("midlog");
        let mut rng = Pcg::seed_from(7);
        let recs: Vec<WalRecord> = (1..=3).map(|e| random_batch_rec(&mut rng, e)).collect();
        write_all(&path, &recs);
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one payload bit of the FIRST record (well before EOF)
        bytes[HEADER_LEN as usize + RECORD_HEADER_LEN as usize + 1] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        match replay_wal(&path) {
            Err(StoreError::ChecksumMismatch { .. }) => {}
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn damaged_final_record_drops_like_a_torn_tail() {
        let path = tmp_wal("tail-crc");
        let mut rng = Pcg::seed_from(8);
        let recs: Vec<WalRecord> = (1..=2).map(|e| random_batch_rec(&mut rng, e)).collect();
        write_all(&path, &recs);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let replay = replay_wal(&path).unwrap();
        assert_eq!(replay.records, recs[..1]);
        assert!(replay.torn_tail_dropped);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn missing_and_empty_logs_are_valid_and_bad_magic_is_not() {
        let path = tmp_wal("edge");
        assert!(replay_wal(&path).unwrap().records.is_empty(), "missing file = empty log");
        std::fs::write(&path, b"").unwrap();
        assert!(replay_wal(&path).unwrap().records.is_empty());
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(matches!(replay_wal(&path), Err(StoreError::BadMagic { .. })));
        std::fs::write(&path, b"AGWL\x63\x00\x00\x00").unwrap();
        assert!(matches!(replay_wal(&path), Err(StoreError::UnsupportedVersion { .. })));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn compact_drops_only_old_epochs_and_keeps_appending() {
        let path = tmp_wal("compact");
        let mut rng = Pcg::seed_from(9);
        let mut w =
            WalWriter::open(path.clone(), FsyncPolicy::Never, Arc::new(FaultPlan::none()))
                .unwrap();
        for e in 1..=4u64 {
            w.append(&random_batch_rec(&mut rng, e)).unwrap();
            let fp = GraphFingerprint { n_rows: 1, n_cols: 1, nnz: 0, content_hash: e };
            w.append(&WalRecord::Commit { epoch: e, fingerprint: fp }).unwrap();
        }
        w.compact(2).unwrap();
        let tail = random_batch_rec(&mut rng, 5);
        w.append(&tail).unwrap();
        drop(w);
        let replay = replay_wal(&path).unwrap();
        let epochs: Vec<u64> = replay.records.iter().map(WalRecord::epoch).collect();
        assert_eq!(epochs, vec![3, 3, 4, 4, 5]);
        assert_eq!(replay.records.last().unwrap(), &tail, "post-compact appends land intact");
        assert!(replay.commit_fingerprint(2).is_none());
        assert_eq!(replay.commit_fingerprint(4).unwrap().content_hash, 4);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn disk_full_fault_sheds_appends_with_typed_error() {
        let path = tmp_wal("disk-full");
        let faults = Arc::new(FaultPlan::parse("disk-full=96"));
        let mut w = WalWriter::open(path.clone(), FsyncPolicy::Never, faults).unwrap();
        let mut rng = Pcg::seed_from(11);
        let mut wrote = 0usize;
        let mut shed = 0usize;
        for e in 1..=12u64 {
            match w.append(&random_batch_rec(&mut rng, e)) {
                Ok(_) => wrote += 1,
                Err(StoreError::DiskFull { .. }) => shed += 1,
                Err(other) => panic!("expected DiskFull, got {other}"),
            }
        }
        assert!(wrote > 0 && shed > 0, "budget must admit some and shed some");
        drop(w);
        // everything that reported success is replayable
        assert_eq!(replay_wal(&path).unwrap().records.len(), wrote);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn torn_tail_fault_leaves_a_recoverable_log() {
        let path = tmp_wal("torn-fault");
        let faults = Arc::new(FaultPlan::parse("torn-tail"));
        let mut rng = Pcg::seed_from(13);
        let recs: Vec<WalRecord> = (1..=3).map(|e| random_batch_rec(&mut rng, e)).collect();
        {
            let mut w = WalWriter::open(path.clone(), FsyncPolicy::Never, faults).unwrap();
            for r in &recs {
                w.append(r).unwrap();
            }
        } // drop tears the final record
        let replay = replay_wal(&path).unwrap();
        assert!(replay.torn_tail_dropped, "injected tear must be visible");
        assert_eq!(replay.records, recs[..2], "only the final record is lost");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
