//! Generational, checksummed tenant snapshots: the original-domain CSR
//! plus the metadata recovery needs to rebuild the tenant and verify
//! its plan identity.
//!
//! ## File format (`snap-<gen>-e<epoch>.bin`)
//!
//! ```text
//! header : "AGSN" u32-version | u32 crc32(payload) | u64 payload_len
//! payload: name (u32-len bytes) | u64 epoch
//!        | GraphFingerprint (4 × u64, of the *relabeled* matrix)
//!        | u64 n_rows | u64 n_cols | u64 nnz
//!        | row_ptr (n_rows+1 × u64) | col_idx (nnz × u32) | vals (nnz × f32)
//! ```
//!
//! Writes are atomic (tmp + rename); generation numbers only grow. The
//! newest two generations are retained so a snapshot that turns out
//! corrupt at recovery **falls back to the previous generation** — the
//! WAL compaction cutoff ([`WalWriter::compact`](super::WalWriter))
//! guarantees the log still reaches back to it.

use super::codec::{self, Cursor};
use super::{StoreError, TenantStore};
use crate::graph::csr::Csr;
use crate::pipeline::GraphFingerprint;
use std::io::Write;
use std::path::{Path, PathBuf};

const SNAP_MAGIC: &[u8; 4] = b"AGSN";
const SNAP_VERSION: u32 = 1;
/// magic + version + crc + payload_len
const SNAP_HEADER_LEN: usize = 20;

/// One durable tenant state: everything needed to re-register the
/// tenant at `epoch` and verify the rebuilt plan identity.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Registry tenant name (authoritative — directory names are
    /// sanitized).
    pub name: String,
    /// Epoch this CSR corresponds to.
    pub epoch: u64,
    /// Fingerprint of the **relabeled** matrix at `epoch` — the plan
    /// cache key, asserted on recovery.
    pub fingerprint: GraphFingerprint,
    /// Original-domain effective adjacency at `epoch`.
    pub csr: Csr,
}

impl Snapshot {
    fn encode(&self) -> Vec<u8> {
        let csr = &self.csr;
        let mut p = Vec::with_capacity(64 + csr.row_ptr.len() * 8 + csr.nnz() * 8);
        codec::put_bytes(&mut p, self.name.as_bytes());
        codec::put_u64(&mut p, self.epoch);
        codec::put_fingerprint(&mut p, &self.fingerprint);
        codec::put_u64(&mut p, csr.n_rows as u64);
        codec::put_u64(&mut p, csr.n_cols as u64);
        codec::put_u64(&mut p, csr.nnz() as u64);
        for &r in &csr.row_ptr {
            codec::put_u64(&mut p, r as u64);
        }
        for &c in &csr.col_idx {
            codec::put_u32(&mut p, c);
        }
        for &v in &csr.vals {
            codec::put_f32(&mut p, v);
        }
        p
    }

    fn decode(path: &Path, payload: &[u8]) -> Result<Snapshot, StoreError> {
        let corrupt = |cur: &Cursor<'_>, what: &str| StoreError::Corrupt {
            path: path.to_path_buf(),
            offset: (SNAP_HEADER_LEN + cur.pos()) as u64,
            detail: format!("snapshot payload truncated in {what}"),
        };
        let mut cur = Cursor::new(payload);
        let name = match cur.take_bytes() {
            Some(b) => String::from_utf8_lossy(b).into_owned(),
            None => return Err(corrupt(&cur, "name")),
        };
        let epoch = cur.take_u64().ok_or_else(|| corrupt(&cur, "epoch"))?;
        let fingerprint =
            codec::take_fingerprint(&mut cur).ok_or_else(|| corrupt(&cur, "fingerprint"))?;
        let n_rows = cur.take_u64().ok_or_else(|| corrupt(&cur, "dims"))? as usize;
        let n_cols = cur.take_u64().ok_or_else(|| corrupt(&cur, "dims"))? as usize;
        let nnz = cur.take_u64().ok_or_else(|| corrupt(&cur, "dims"))? as usize;
        // sanity before allocating: the arrays must fit the remaining
        // bytes exactly
        let want = (n_rows + 1) * 8 + nnz * 4 + nnz * 4;
        if cur.remaining() != want {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                offset: (SNAP_HEADER_LEN + cur.pos()) as u64,
                detail: format!(
                    "array bytes mismatch: {} remaining, dims demand {want}",
                    cur.remaining()
                ),
            });
        }
        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        for _ in 0..=n_rows {
            row_ptr.push(cur.take_u64().ok_or_else(|| corrupt(&cur, "row_ptr"))? as usize);
        }
        let mut col_idx = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            col_idx.push(cur.take_u32().ok_or_else(|| corrupt(&cur, "col_idx"))?);
        }
        let mut vals = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            vals.push(cur.take_f32().ok_or_else(|| corrupt(&cur, "vals"))?);
        }
        let csr = Csr::from_raw(n_rows, n_cols, row_ptr, col_idx, vals).map_err(|e| {
            StoreError::Corrupt {
                path: path.to_path_buf(),
                offset: SNAP_HEADER_LEN as u64,
                detail: format!("CSR fails structural validation: {e}"),
            }
        })?;
        Ok(Snapshot { name, epoch, fingerprint, csr })
    }
}

/// What [`TenantStore::write_snapshot`] did — the generation it wrote
/// and the WAL-compaction cutoff implied by pruning.
#[derive(Clone, Copy, Debug)]
pub struct SnapshotWriteInfo {
    /// Generation number just written.
    pub gen: u64,
    /// Epoch of the **oldest retained** generation after pruning: the
    /// WAL may drop records at or before this epoch and fallback
    /// recovery still has full replay coverage.
    pub retained_oldest_epoch: u64,
}

impl TenantStore {
    /// Snapshot generations on disk, ascending by generation:
    /// `(gen, epoch, path)`.
    pub fn generations(&self) -> Result<Vec<(u64, u64, PathBuf)>, StoreError> {
        let mut out = Vec::new();
        let rd = match std::fs::read_dir(self.dir()) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(StoreError::from_io("read_dir", self.dir(), e)),
        };
        for ent in rd {
            let ent = ent.map_err(|e| StoreError::from_io("read_dir", self.dir(), e))?;
            let fname = ent.file_name();
            let Some(name) = fname.to_str() else { continue };
            if let Some((gen, epoch)) = parse_snapshot_name(name) {
                out.push((gen, epoch, ent.path()));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Write `snap` as the next generation (atomic: tmp + rename), then
    /// prune to the newest two generations. Injected
    /// `snapshot-truncate` damages every generation after the first —
    /// recovery must survive it by falling back.
    pub fn write_snapshot(&self, snap: &Snapshot) -> Result<SnapshotWriteInfo, StoreError> {
        self.ensure_dir()?;
        let gens = self.generations()?;
        let gen = gens.last().map_or(1, |&(g, _, _)| g + 1);
        let payload = snap.encode();
        let mut bytes = Vec::with_capacity(SNAP_HEADER_LEN + payload.len());
        bytes.extend_from_slice(SNAP_MAGIC);
        codec::put_u32(&mut bytes, SNAP_VERSION);
        codec::put_u32(&mut bytes, codec::crc32(&payload));
        codec::put_u64(&mut bytes, payload.len() as u64);
        bytes.extend_from_slice(&payload);
        let tmp = self.dir().join(".snap.tmp");
        let path = self.dir().join(format!("snap-{gen:06}-e{}.bin", snap.epoch));
        {
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| StoreError::from_io("create", &tmp, e))?;
            f.write_all(&bytes).map_err(|e| StoreError::from_io("write", &tmp, e))?;
            if self.fsync() == super::FsyncPolicy::Always {
                f.sync_data().map_err(|e| StoreError::from_io("fsync", &tmp, e))?;
            }
        }
        std::fs::rename(&tmp, &path).map_err(|e| StoreError::from_io("rename", &tmp, e))?;
        self.sync_dir();
        if self.faults().snapshot_truncate && gen > 1 {
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&path)
                .map_err(|e| StoreError::from_io("open", &path, e))?;
            let _ = f.set_len((bytes.len() / 2) as u64);
            eprintln!("[store] fault: truncated snapshot {}", path.display());
        }
        // prune: keep this generation plus its predecessor
        let mut retained_oldest_epoch = snap.epoch;
        for &(g, e, ref p) in gens.iter() {
            if g + 1 < gen {
                let _ = std::fs::remove_file(p);
            } else {
                retained_oldest_epoch = retained_oldest_epoch.min(e);
            }
        }
        Ok(SnapshotWriteInfo { gen, retained_oldest_epoch })
    }

    /// Load the newest readable snapshot: `(snapshot, gen, fell_back)`.
    /// A generation that fails validation (bad magic, short file, CRC
    /// mismatch, structural damage) is skipped with a warning — the
    /// documented fallback — and only when **no** generation is
    /// readable does this become a typed error.
    pub fn load_snapshot(&self) -> Result<(Snapshot, u64, bool), StoreError> {
        let gens = self.generations()?;
        let mut fell_back = false;
        for &(gen, _, ref path) in gens.iter().rev() {
            match read_snapshot_file(path) {
                Ok(snap) => return Ok((snap, gen, fell_back)),
                Err(e) => {
                    eprintln!(
                        "[store] warning: snapshot {} unreadable ({e}); falling back a generation",
                        path.display()
                    );
                    fell_back = true;
                }
            }
        }
        Err(StoreError::NoSnapshot { dir: self.dir().to_path_buf() })
    }
}

fn parse_snapshot_name(name: &str) -> Option<(u64, u64)> {
    let rest = name.strip_prefix("snap-")?.strip_suffix(".bin")?;
    let (gen, epoch) = rest.split_once("-e")?;
    Some((gen.parse().ok()?, epoch.parse().ok()?))
}

/// Decode one snapshot file, checking magic, version, framing and CRC.
pub fn read_snapshot_file(path: &Path) -> Result<Snapshot, StoreError> {
    let data = std::fs::read(path).map_err(|e| StoreError::from_io("read", path, e))?;
    if data.len() < SNAP_HEADER_LEN {
        return Err(StoreError::Corrupt {
            path: path.to_path_buf(),
            offset: 0,
            detail: format!("{} bytes is shorter than the header", data.len()),
        });
    }
    if &data[..4] != SNAP_MAGIC {
        return Err(StoreError::BadMagic { path: path.to_path_buf() });
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version != SNAP_VERSION {
        return Err(StoreError::UnsupportedVersion { path: path.to_path_buf(), version });
    }
    let stored_crc = u32::from_le_bytes(data[8..12].try_into().unwrap());
    let payload_len = u64::from_le_bytes(data[12..20].try_into().unwrap()) as usize;
    let payload = match data.get(SNAP_HEADER_LEN..SNAP_HEADER_LEN + payload_len) {
        Some(p) if data.len() == SNAP_HEADER_LEN + payload_len => p,
        _ => {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                offset: SNAP_HEADER_LEN as u64,
                detail: format!(
                    "payload length {payload_len} disagrees with file size {}",
                    data.len()
                ),
            })
        }
    };
    let computed = codec::crc32(payload);
    if computed != stored_crc {
        return Err(StoreError::ChecksumMismatch {
            path: path.to_path_buf(),
            want: stored_crc,
            got: computed,
        });
    }
    Snapshot::decode(path, payload)
}

#[cfg(test)]
mod tests {
    use super::super::{test_dir, FaultPlan, FsyncPolicy, Store, StoreError};
    use super::*;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 6) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    fn snap(seed: u64, epoch: u64) -> Snapshot {
        let csr = random_csr(seed, 30);
        let fingerprint = GraphFingerprint::of(&csr);
        Snapshot { name: format!("tenant/{seed}"), epoch, fingerprint, csr }
    }

    fn tenant(tag: &str) -> (std::path::PathBuf, TenantStore) {
        let d = test_dir(tag);
        let store = Store::open(&d, FsyncPolicy::Never).unwrap();
        let ts = store.tenant("t0").unwrap();
        (d, ts)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (d, ts) = tenant("snap-rt");
        let s = snap(1, 3);
        let info = ts.write_snapshot(&s).unwrap();
        assert_eq!(info.gen, 1);
        assert_eq!(info.retained_oldest_epoch, 3);
        let (back, gen, fell_back) = ts.load_snapshot().unwrap();
        assert_eq!(gen, 1);
        assert!(!fell_back);
        assert_eq!(back, s, "snapshot roundtrips bit-exactly (name kept despite sanitizing)");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn generations_grow_and_prune_to_two() {
        let (d, ts) = tenant("snap-gen");
        for e in 0..4u64 {
            let info = ts.write_snapshot(&snap(10 + e, e)).unwrap();
            assert_eq!(info.gen, e + 1);
        }
        let gens = ts.generations().unwrap();
        assert_eq!(gens.len(), 2, "pruned to the newest two");
        assert_eq!((gens[0].0, gens[0].1), (3, 2));
        assert_eq!((gens[1].0, gens[1].1), (4, 3));
        // compaction cutoff is the *older* retained generation's epoch
        let info = ts.write_snapshot(&snap(99, 4)).unwrap();
        assert_eq!(info.retained_oldest_epoch, 3);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_newest_falls_back_one_generation() {
        let (d, ts) = tenant("snap-fallback");
        let older = snap(20, 1);
        ts.write_snapshot(&older).unwrap();
        ts.write_snapshot(&snap(21, 2)).unwrap();
        let gens = ts.generations().unwrap();
        // flip a payload bit in the newest generation
        let newest = &gens.last().unwrap().2;
        let mut bytes = std::fs::read(newest).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0x10;
        std::fs::write(newest, &bytes).unwrap();
        let (back, gen, fell_back) = ts.load_snapshot().unwrap();
        assert!(fell_back, "checksum flip must trigger the fallback");
        assert_eq!(gen, 1);
        assert_eq!(back, older);
        // truncation of the newest behaves the same way
        std::fs::write(newest, &bytes[..n / 2]).unwrap();
        let (back2, _, fb2) = ts.load_snapshot().unwrap();
        assert!(fb2);
        assert_eq!(back2, older);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn no_readable_generation_is_typed() {
        let (d, ts) = tenant("snap-none");
        assert!(matches!(ts.load_snapshot(), Err(StoreError::NoSnapshot { .. })));
        ts.write_snapshot(&snap(30, 0)).unwrap();
        let gens = ts.generations().unwrap();
        std::fs::write(&gens[0].2, b"garbage").unwrap();
        assert!(matches!(ts.load_snapshot(), Err(StoreError::NoSnapshot { .. })));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn snapshot_truncate_fault_spares_the_first_generation() {
        let d = test_dir("snap-fault");
        let store =
            Store::open_with_faults(&d, FsyncPolicy::Never, FaultPlan::parse("snapshot-truncate"))
                .unwrap();
        let ts = store.tenant("t0").unwrap();
        let first = snap(40, 0);
        ts.write_snapshot(&first).unwrap();
        ts.write_snapshot(&snap(41, 2)).unwrap();
        let (back, gen, fell_back) = ts.load_snapshot().unwrap();
        assert!(fell_back, "gen 2 was injected-truncated");
        assert_eq!(gen, 1);
        assert_eq!(back, first);
        let _ = std::fs::remove_dir_all(&d);
    }
}
