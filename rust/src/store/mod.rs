//! Durability layer: per-tenant graph **snapshots** plus a **delta
//! WAL**, so a restarted server rebuilds every tenant — and its plans —
//! from disk (DESIGN §11).
//!
//! ## Directory layout
//!
//! ```text
//! <data-dir>/
//!   <tenant-dir>/                  # sanitized tenant name
//!     snap-<gen>-e<epoch>.bin      # generational checksummed snapshots
//!     wal.bin                      # delta WAL (batch + commit records)
//! ```
//!
//! * [`snapshot`] — versioned binary CSR + metadata, CRC-checksummed,
//!   written atomically (tmp + rename). The newest **generation** is
//!   authoritative; the previous one is retained so a corrupt snapshot
//!   falls back one generation (the WAL keeps enough tail to replay
//!   from it).
//! * [`wal`] — length-prefixed records with a per-record CRC. A
//!   **batch** record logs an `UpdateGraph` batch *before* it is
//!   applied; a **commit** record seals the post-apply epoch with the
//!   relabeled-matrix fingerprint the plan cache keys on. A torn final
//!   record (crash mid-append) is dropped with a warning on replay;
//!   corruption anywhere earlier is a typed error.
//! * [`recover`] — snapshot load + WAL tail replay through the same
//!   [`DeltaGraph::apply`](crate::delta::DeltaGraph::apply) path the
//!   live server uses, with the recovered fingerprint asserted against
//!   the last commit record.
//! * [`faults`] — env-driven fault injection (torn tail, truncated
//!   snapshot, checksum flip, disk full) used by tests and the CI
//!   fault matrix; every fault must degrade to a typed error or a
//!   documented fallback, never a panic.
//!
//! The layer is deliberately serve-agnostic: it knows CSRs, epochs and
//! fingerprints, not handles or queues. The serve-side glue lives in
//! [`serve::persist`](crate::serve::persist).

pub mod codec;
pub mod faults;
pub mod recover;
pub mod snapshot;
pub mod wal;

pub use faults::FaultPlan;
pub use recover::{recover_tenant, relabeled_fingerprint, RecoveredTenant};
pub use snapshot::{read_snapshot_file, Snapshot, SnapshotWriteInfo};
pub use wal::{replay_wal, WalRecord, WalReplay, WalWriter};

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// When the store calls `fsync` on durable writes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every WAL append and snapshot write — survives
    /// power loss, the default for `--data-dir` serving.
    Always,
    /// Leave flushing to the OS page cache — survives process crashes
    /// (SIGKILL) but not power loss; fastest.
    Never,
}

impl FsyncPolicy {
    /// Parse a `--fsync` flag value.
    pub fn parse(s: &str) -> Result<FsyncPolicy, StoreError> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            other => Err(StoreError::Config(format!(
                "unknown fsync policy '{other}' (expected always|never)"
            ))),
        }
    }
}

/// Typed durability errors. Every failure mode of the store surfaces
/// here so callers can distinguish "disk full — shed the update" from
/// "bytes are corrupt — fall back / refuse to serve".
#[derive(Debug)]
pub enum StoreError {
    /// An OS-level I/O failure that is not disk-full.
    Io { op: &'static str, path: PathBuf, detail: String },
    /// The device ran out of space mid-append; the record was not
    /// committed and the in-memory state must not advance.
    DiskFull { path: PathBuf },
    /// Bytes on disk fail structural validation (bad length, bad tag,
    /// truncation that is not a torn tail).
    Corrupt { path: PathBuf, offset: u64, detail: String },
    /// A record or snapshot CRC does not match its payload.
    ChecksumMismatch { path: PathBuf, want: u32, got: u32 },
    /// The file does not start with the expected magic.
    BadMagic { path: PathBuf },
    /// The format version is newer than this build understands.
    UnsupportedVersion { path: PathBuf, version: u32 },
    /// No readable snapshot generation exists for the tenant.
    NoSnapshot { dir: PathBuf },
    /// WAL batches do not chain epoch-contiguously from the snapshot.
    EpochGap { path: PathBuf, want: u64, got: u64 },
    /// The recovered relabeled-matrix fingerprint diverges from the
    /// last committed one — replay did not reproduce the pre-crash
    /// state.
    FingerprintMismatch { tenant: String, epoch: u64, detail: String },
    /// Registering a tenant whose directory already holds state (must
    /// recover instead of overwriting).
    TenantExists { dir: PathBuf },
    /// Invalid configuration (flag values, empty names).
    Config(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io { op, path, detail } => {
                write!(f, "store io error during {op} on {}: {detail}", path.display())
            }
            StoreError::DiskFull { path } => {
                write!(f, "disk full appending to {}", path.display())
            }
            StoreError::Corrupt { path, offset, detail } => {
                write!(f, "corrupt store file {} at byte {offset}: {detail}", path.display())
            }
            StoreError::ChecksumMismatch { path, want, got } => write!(
                f,
                "checksum mismatch in {} (stored {want:#010x}, computed {got:#010x})",
                path.display()
            ),
            StoreError::BadMagic { path } => {
                write!(f, "bad magic in {}", path.display())
            }
            StoreError::UnsupportedVersion { path, version } => {
                write!(f, "unsupported format version {version} in {}", path.display())
            }
            StoreError::NoSnapshot { dir } => {
                write!(f, "no readable snapshot generation under {}", dir.display())
            }
            StoreError::EpochGap { path, want, got } => write!(
                f,
                "wal {} is not epoch-contiguous: expected batch epoch {want}, found {got}",
                path.display()
            ),
            StoreError::FingerprintMismatch { tenant, epoch, detail } => write!(
                f,
                "recovered fingerprint for tenant '{tenant}' diverges at epoch {epoch}: {detail}"
            ),
            StoreError::TenantExists { dir } => write!(
                f,
                "tenant state already exists under {} (recover it instead of re-registering)",
                dir.display()
            ),
            StoreError::Config(msg) => write!(f, "store config error: {msg}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl StoreError {
    /// Map an OS error to the store's typed space: `ENOSPC` (and the
    /// short-write shape it produces) becomes [`StoreError::DiskFull`],
    /// everything else [`StoreError::Io`].
    pub fn from_io(op: &'static str, path: &Path, e: std::io::Error) -> StoreError {
        // ENOSPC by raw errno (`ErrorKind::StorageFull` is newer than
        // the minimum toolchain); a zero-length write is the same
        // condition surfaced through `write_all`
        if e.raw_os_error() == Some(28) || e.kind() == std::io::ErrorKind::WriteZero {
            return StoreError::DiskFull { path: path.to_path_buf() };
        }
        StoreError::Io { op, path: path.to_path_buf(), detail: e.to_string() }
    }
}

/// Root handle over a `--data-dir`: opens per-tenant stores and lists
/// what is on disk. Cheap to clone paths from; owns the shared
/// [`FaultPlan`] so injected faults hit every tenant consistently.
#[derive(Debug)]
pub struct Store {
    root: PathBuf,
    fsync: FsyncPolicy,
    faults: Arc<FaultPlan>,
}

impl Store {
    /// Open (creating if needed) the data directory. Fault injection is
    /// read from `ACCEL_GCN_FAULT` (see [`FaultPlan::from_env`]).
    pub fn open(root: impl AsRef<Path>, fsync: FsyncPolicy) -> Result<Store, StoreError> {
        Store::open_with_faults(root, fsync, FaultPlan::from_env())
    }

    /// Open with an explicit fault plan (tests).
    pub fn open_with_faults(
        root: impl AsRef<Path>,
        fsync: FsyncPolicy,
        faults: FaultPlan,
    ) -> Result<Store, StoreError> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root).map_err(|e| StoreError::from_io("create_dir", &root, e))?;
        Ok(Store { root, fsync, faults: Arc::new(faults) })
    }

    /// Open an existing data directory; errors if it is absent
    /// (`recover-check` must not silently invent an empty store).
    pub fn open_existing(root: impl AsRef<Path>, fsync: FsyncPolicy) -> Result<Store, StoreError> {
        let root = root.as_ref().to_path_buf();
        if !root.is_dir() {
            return Err(StoreError::Io {
                op: "open",
                path: root,
                detail: "data directory does not exist".into(),
            });
        }
        Ok(Store { root, fsync, faults: Arc::new(FaultPlan::from_env()) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn fsync(&self) -> FsyncPolicy {
        self.fsync
    }

    pub fn faults(&self) -> &Arc<FaultPlan> {
        &self.faults
    }

    /// The tenant's on-disk store (directory created lazily on first
    /// write). `name` is the registry name; the directory is its
    /// sanitized form.
    pub fn tenant(&self, name: &str) -> Result<TenantStore, StoreError> {
        if name.is_empty() {
            return Err(StoreError::Config("tenant name must be non-empty".into()));
        }
        Ok(TenantStore {
            dir: self.root.join(sanitize(name)),
            fsync: self.fsync,
            faults: Arc::clone(&self.faults),
        })
    }

    /// Sorted tenant directory names currently on disk (sanitized; the
    /// authoritative registry name lives inside each snapshot).
    pub fn tenant_dirs(&self) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        let rd = std::fs::read_dir(&self.root)
            .map_err(|e| StoreError::from_io("read_dir", &self.root, e))?;
        for ent in rd {
            let ent = ent.map_err(|e| StoreError::from_io("read_dir", &self.root, e))?;
            if ent.path().is_dir() {
                if let Some(n) = ent.file_name().to_str() {
                    out.push(n.to_string());
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// A tenant store addressed by its on-disk directory name (what
    /// [`Store::tenant_dirs`] returns) — used by recovery, which does
    /// not know registry names yet.
    pub fn tenant_by_dir(&self, dir_name: &str) -> TenantStore {
        TenantStore {
            dir: self.root.join(dir_name),
            fsync: self.fsync,
            faults: Arc::clone(&self.faults),
        }
    }
}

/// Map a tenant name to a filesystem-safe directory name. Collisions
/// between names differing only in exotic characters are accepted (the
/// snapshot header records the real name).
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect()
}

/// One tenant's durable state: its snapshot generations plus its WAL.
#[derive(Debug, Clone)]
pub struct TenantStore {
    dir: PathBuf,
    fsync: FsyncPolicy,
    faults: Arc<FaultPlan>,
}

impl TenantStore {
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn fsync(&self) -> FsyncPolicy {
        self.fsync
    }

    pub fn faults(&self) -> &Arc<FaultPlan> {
        &self.faults
    }

    /// Path of the tenant's WAL file.
    pub fn wal_path(&self) -> PathBuf {
        self.dir.join("wal.bin")
    }

    /// True once any durable state exists for this tenant.
    pub fn exists(&self) -> bool {
        self.dir.is_dir()
            && (self.wal_path().is_file() || !self.generations().unwrap_or_default().is_empty())
    }

    pub(crate) fn ensure_dir(&self) -> Result<(), StoreError> {
        std::fs::create_dir_all(&self.dir)
            .map_err(|e| StoreError::from_io("create_dir", &self.dir, e))
    }

    /// Fsync the tenant directory itself (makes renames durable); a
    /// failure here is ignored — not all filesystems support it.
    pub(crate) fn sync_dir(&self) {
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
    }
}

#[cfg(test)]
pub(crate) fn test_dir(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "accel-gcn-store-{}-{tag}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsync_policy_parses() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        let e = FsyncPolicy::parse("sometimes").unwrap_err();
        assert!(e.to_string().contains("fsync policy"), "{e}");
    }

    #[test]
    fn sanitize_keeps_safe_chars() {
        assert_eq!(sanitize("tenant-0"), "tenant-0");
        assert_eq!(sanitize("a/b c"), "a_b_c");
        assert_eq!(sanitize("g.1_x"), "g.1_x");
    }

    #[test]
    fn open_existing_requires_directory() {
        let d = test_dir("open-existing");
        assert!(Store::open_existing(&d, FsyncPolicy::Never).is_err());
        let s = Store::open(&d, FsyncPolicy::Never).unwrap();
        assert!(s.tenant_dirs().unwrap().is_empty());
        assert!(Store::open_existing(&d, FsyncPolicy::Never).is_ok());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn disk_full_maps_from_io_kind() {
        let e = std::io::Error::new(std::io::ErrorKind::WriteZero, "short write");
        match StoreError::from_io("append", Path::new("/x"), e) {
            StoreError::DiskFull { .. } => {}
            other => panic!("expected DiskFull, got {other}"),
        }
    }
}
