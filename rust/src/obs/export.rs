//! Snapshot schema helpers: run metadata, the versioned-schema
//! constant, and the CI validator for emitted metrics JSON.
//!
//! All of it is zero-dependency: the ISO-8601 timestamp is computed
//! from `SystemTime` with the days-from-civil inverse (no chrono), and
//! the git commit is read best-effort from `.git/HEAD` (no subprocess)
//! so bench reports stay anchored even where `git` is not on PATH.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Version tag of [`Registry::snapshot`](super::Registry::snapshot)
/// documents. Bump on any breaking schema change.
pub const SCHEMA_VERSION: &str = "accel-gcn-metrics/v1";

/// Version tag of [`Registry::export_trace`](super::Registry::export_trace)
/// documents (`--trace-out`). The payload itself is standard Chrome
/// trace-event JSON; this tag only marks our envelope.
pub const TRACE_SCHEMA_VERSION: &str = "accel-gcn-trace/v1";

/// Version tag of `accel-gcn roofline --json` reports.
pub const ROOFLINE_SCHEMA_VERSION: &str = "accel-gcn-roofline/v1";

/// Version tag of the cached STREAM/FMA calibration document
/// ([`super::calibrate`]).
pub const CALIBRATION_SCHEMA_VERSION: &str = "accel-gcn-calibration/v1";

/// Run metadata embedded in every `BENCH_*.json` and metrics snapshot:
/// `{git_commit, timestamp_utc, threads, simd, schema}`.
pub fn run_metadata() -> Json {
    let mut m = Json::obj();
    match git_commit(Path::new(".")) {
        Some(c) => m.set("git_commit", c),
        None => m.set("git_commit", Json::Null),
    };
    m.set("timestamp_utc", iso8601_utc_now());
    m.set(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    m.set("simd", crate::spmm::SimdLevel::best().name());
    m.set("schema", SCHEMA_VERSION);
    m
}

/// Current UTC time as `YYYY-MM-DDTHH:MM:SSZ`.
pub fn iso8601_utc_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, mo, d) = civil_from_days((secs / 86_400) as i64);
    let rem = secs % 86_400;
    format!(
        "{y:04}-{mo:02}-{d:02}T{:02}:{:02}:{:02}Z",
        rem / 3600,
        (rem % 3600) / 60,
        rem % 60
    )
}

/// Gregorian date from days since 1970-01-01 (Hinnant's civil-from-days).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// The current commit hash, read from `repo_root/.git` without spawning
/// `git`: `HEAD` directly for a detached head, the named ref file (or
/// `packed-refs`) otherwise. `None` when not in a checkout.
pub fn git_commit(repo_root: &Path) -> Option<String> {
    let git = repo_root.join(".git");
    let head = std::fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    let Some(refname) = head.strip_prefix("ref: ") else {
        return is_hex(head).then(|| head.to_string());
    };
    if let Ok(c) = std::fs::read_to_string(git.join(refname)) {
        let c = c.trim();
        if is_hex(c) {
            return Some(c.to_string());
        }
    }
    let packed = std::fs::read_to_string(git.join("packed-refs")).ok()?;
    packed.lines().find_map(|line| {
        let (hash, name) = line.split_once(' ')?;
        (name == refname && is_hex(hash)).then(|| hash.to_string())
    })
}

fn is_hex(s: &str) -> bool {
    s.len() >= 7 && s.chars().all(|c| c.is_ascii_hexdigit())
}

/// The CI validator for emitted metrics snapshots (`accel-gcn
/// validate-metrics FILE...`): required keys present, shard busy-ns
/// totals positive, and every histogram's quantiles ordered
/// (`p99 ≥ p50`) — in the core document and, when present, the merged
/// `serve` section.
pub fn validate_snapshot(doc: &Json) -> Result<()> {
    let schema = doc.req_str("schema").context("snapshot is missing `schema`")?;
    if schema != SCHEMA_VERSION {
        bail!("schema `{schema}` is not the supported `{SCHEMA_VERSION}`");
    }
    for key in ["counters", "gauges", "histograms", "spans", "shards"] {
        if doc.get(key).is_none() {
            bail!("snapshot is missing required key `{key}`");
        }
    }
    validate_histogram_map(doc.get("histograms").unwrap(), "histograms")?;
    let shards = doc.get("shards").unwrap();
    let per_shard = shards.req_arr("per_shard").context("shards.per_shard")?;
    if per_shard.is_empty() {
        bail!("shards.per_shard is empty — no SpMM was observed");
    }
    let mut busy_total = 0.0;
    for (i, s) in per_shard.iter().enumerate() {
        busy_total += s.req_f64("busy_ns").with_context(|| format!("per_shard[{i}]"))?;
        s.req_f64("nnz").with_context(|| format!("per_shard[{i}]"))?;
        s.req_f64("rows").with_context(|| format!("per_shard[{i}]"))?;
    }
    if !(busy_total > 0.0) {
        bail!("per-shard busy-ns sums to {busy_total} — shard timing was not recorded");
    }
    if let Some(serve) = doc.get("serve") {
        if let Some(lat) = serve.get("latencies") {
            validate_histogram_map(lat, "serve.latencies")?;
        }
    }
    Ok(())
}

/// The CI validator for exported trace timelines (`--trace-out`
/// files): the document must be the Chrome trace-event object form —
/// a `traceEvents` array whose entries carry the keys the viewers
/// require (`name`/`cat`/`ph`/`pid`/`tid`, a numeric `ts`, and a
/// numeric `dur` for complete events). `validate-metrics` routes any
/// document containing `traceEvents` here instead of
/// [`validate_snapshot`].
pub fn validate_trace(doc: &Json) -> Result<()> {
    if let Some(schema) = doc.get("schema").and_then(Json::as_str) {
        if schema != TRACE_SCHEMA_VERSION {
            bail!("trace schema `{schema}` is not the supported `{TRACE_SCHEMA_VERSION}`");
        }
    }
    let events = doc.req_arr("traceEvents").context("trace is missing `traceEvents`")?;
    if events.is_empty() {
        bail!("traceEvents is empty — nothing was recorded");
    }
    let mut complete = 0usize;
    for (i, e) in events.iter().enumerate() {
        let ctx = || format!("traceEvents[{i}]");
        e.req_str("name").with_context(ctx)?;
        e.req_str("cat").with_context(ctx)?;
        e.req_f64("pid").with_context(ctx)?;
        e.req_f64("tid").with_context(ctx)?;
        let ph = e.req_str("ph").with_context(ctx)?;
        match ph {
            "X" => {
                complete += 1;
                let ts = e.req_f64("ts").with_context(ctx)?;
                let dur = e.req_f64("dur").with_context(ctx)?;
                if ts < 0.0 || dur < 0.0 {
                    bail!("traceEvents[{i}]: negative ts/dur ({ts}, {dur})");
                }
            }
            "i" => {
                e.req_f64("ts").with_context(ctx)?;
            }
            "M" => {} // metadata (process/thread names) — no timestamp required
            other => bail!("traceEvents[{i}]: unsupported phase `{other}`"),
        }
    }
    if complete == 0 {
        bail!("trace has no complete ('X') events — no durations were recorded");
    }
    Ok(())
}

/// The CI validator for cached calibration documents
/// (`accel-gcn-calibration/v1`): peaks positive, points present, and
/// no STREAM point above the peak the document claims (the peak is
/// defined as their max).
pub fn validate_calibration(doc: &Json) -> Result<()> {
    let schema = doc.req_str("schema").context("calibration is missing `schema`")?;
    if schema != CALIBRATION_SCHEMA_VERSION {
        bail!("schema `{schema}` is not the supported `{CALIBRATION_SCHEMA_VERSION}`");
    }
    let peak_gbps = doc.req_f64("peak_gbps")?;
    let peak_gflops = doc.req_f64("peak_gflops")?;
    if !(peak_gbps > 0.0) || !(peak_gflops > 0.0) {
        bail!("calibration peaks must be positive (gbps {peak_gbps}, gflops {peak_gflops})");
    }
    let balance = doc.req_f64("machine_balance")?;
    if !(balance > 0.0) {
        bail!("machine_balance {balance} must be positive");
    }
    if doc.req_usize("best_threads")? == 0 {
        bail!("best_threads must be ≥ 1");
    }
    doc.req_str("simd").context("calibration is missing `simd`")?;
    let points = doc.req_arr("points").context("calibration.points")?;
    if points.is_empty() {
        bail!("calibration has no measurement points");
    }
    for (i, p) in points.iter().enumerate() {
        let ctx = || format!("points[{i}]");
        let kernel = p.req_str("kernel").with_context(ctx)?;
        p.req_usize("threads").with_context(ctx)?;
        p.req_f64("mb").with_context(ctx)?;
        let gbps = p.req_f64("gbps").with_context(ctx)?;
        let gflops = p.req_f64("gflops").with_context(ctx)?;
        if gbps < 0.0 || gflops < 0.0 {
            bail!("points[{i}]: negative measurement");
        }
        if kernel != "fma" && gbps > peak_gbps * (1.0 + 1e-9) {
            bail!("points[{i}]: {kernel} at {gbps} GB/s exceeds the claimed peak {peak_gbps}");
        }
    }
    Ok(())
}

/// The CI validator for `accel-gcn roofline --json` reports
/// (`accel-gcn-roofline/v1`). Beyond shape, it enforces the two
/// invariants the roofline smoke gates on: **achieved GB/s never
/// exceeds the calibrated peak**, and on every graph where the
/// instrumented counting executor ran, its byte count **equals** the
/// analytic model's.
pub fn validate_roofline(doc: &Json) -> Result<()> {
    let schema = doc.req_str("schema").context("roofline is missing `schema`")?;
    if schema != ROOFLINE_SCHEMA_VERSION {
        bail!("schema `{schema}` is not the supported `{ROOFLINE_SCHEMA_VERSION}`");
    }
    let cal = doc.get("calibration").context("roofline is missing `calibration`")?;
    let peak_gbps = cal.req_f64("peak_gbps").context("calibration.peak_gbps")?;
    if !(peak_gbps > 0.0) {
        bail!("calibration.peak_gbps {peak_gbps} must be positive");
    }
    let balance = cal.req_f64("machine_balance").context("calibration.machine_balance")?;
    let graphs = doc.req_arr("graphs").context("roofline.graphs")?;
    if graphs.is_empty() {
        bail!("roofline has no graphs");
    }
    for (gi, g) in graphs.iter().enumerate() {
        let ctx = || format!("graphs[{gi}]");
        g.req_str("graph").with_context(ctx)?;
        let nnz = g.req_f64("nnz").with_context(ctx)?;
        g.req_usize("f").with_context(ctx)?;
        let analytic = g.req_f64("analytic_bytes").with_context(ctx)?;
        if let Some(instr) = g.get("instrumented_bytes").and_then(Json::as_f64) {
            if instr != analytic {
                bail!(
                    "graphs[{gi}]: instrumented bytes {instr} != analytic {analytic} — \
                     the traffic model drifted from the executor"
                );
            }
        }
        let achieved = g.req_f64("achieved_gbps").with_context(ctx)?;
        if achieved > peak_gbps * (1.0 + 1e-9) {
            bail!(
                "graphs[{gi}]: achieved {achieved} GB/s exceeds the calibrated peak \
                 {peak_gbps} GB/s — calibration or byte accounting is wrong"
            );
        }
        let pct = g.req_f64("pct_peak").with_context(ctx)?;
        if !(0.0..=100.0 + 1e-9).contains(&pct) {
            bail!("graphs[{gi}]: pct_peak {pct} out of range");
        }
        let intensity = g.req_f64("arithmetic_intensity").with_context(ctx)?;
        let verdict = g.req_str("verdict").with_context(ctx)?;
        match verdict {
            "bandwidth-bound" | "compute-bound" => {}
            other => bail!("graphs[{gi}]: unknown verdict `{other}`"),
        }
        // the verdict must be consistent with the intensity-vs-balance rule
        let expect = if intensity < balance { "bandwidth-bound" } else { "compute-bound" };
        if verdict != expect {
            bail!("graphs[{gi}]: verdict `{verdict}` contradicts intensity {intensity} vs balance {balance}");
        }
        let buckets = g.req_arr("buckets").with_context(ctx)?;
        if buckets.is_empty() {
            bail!("graphs[{gi}] has no traffic buckets");
        }
        let mut bucket_nnz = 0.0;
        for (bi, b) in buckets.iter().enumerate() {
            let bctx = || format!("graphs[{gi}].buckets[{bi}]");
            b.req_f64("deg").with_context(bctx)?;
            let kernel = b.req_str("kernel").with_context(bctx)?;
            // RowKernel::name() spellings
            if kernel != "dense-tiled" && kernel != "sparse-gather" {
                bail!("graphs[{gi}].buckets[{bi}]: unknown kernel `{kernel}`");
            }
            b.req_f64("blocks").with_context(bctx)?;
            bucket_nnz += b.req_f64("nnz").with_context(bctx)?;
            b.req_f64("bytes_total").with_context(bctx)?;
            b.req_f64("bytes_per_nnz").with_context(bctx)?;
        }
        if bucket_nnz != nnz {
            bail!("graphs[{gi}]: bucket nnz {bucket_nnz} != graph nnz {nnz}");
        }
    }
    Ok(())
}

fn validate_histogram_map(map: &Json, what: &str) -> Result<()> {
    let Json::Obj(entries) = map else {
        bail!("`{what}` must be an object");
    };
    for (name, h) in entries {
        let p50 = h.req_f64("p50").with_context(|| format!("{what}.{name}"))?;
        let p99 = h.req_f64("p99").with_context(|| format!("{what}.{name}"))?;
        let max = h.req_f64("max").with_context(|| format!("{what}.{name}"))?;
        h.req_f64("mean").with_context(|| format!("{what}.{name}"))?;
        h.req_usize("count").with_context(|| format!("{what}.{name}"))?;
        if p99 < p50 {
            bail!("{what}.{name}: p99 {p99} < p50 {p50}");
        }
        if max < p99 {
            bail!("{what}.{name}: max {max} < p99 {p99}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // leap year start
        assert_eq!(civil_from_days(19_782), (2024, 2, 29)); // leap day
        assert_eq!(civil_from_days(20_673), (2026, 8, 8));
    }

    #[test]
    fn timestamp_shape() {
        let t = iso8601_utc_now();
        assert_eq!(t.len(), 20, "{t}");
        assert!(t.ends_with('Z') && t.as_bytes()[10] == b'T', "{t}");
    }

    #[test]
    fn run_metadata_has_required_fields() {
        let m = run_metadata();
        assert_eq!(m.req_str("schema").unwrap(), SCHEMA_VERSION);
        assert!(m.req_usize("threads").unwrap() >= 1);
        assert!(!m.req_str("simd").unwrap().is_empty());
        assert!(m.get("git_commit").is_some());
        assert!(m.req_str("timestamp_utc").unwrap().ends_with('Z'));
    }

    #[test]
    fn validator_rejects_broken_snapshots() {
        // missing everything
        assert!(validate_snapshot(&Json::obj()).is_err());
        // minimal valid document
        let text = format!(
            r#"{{
              "schema": "{SCHEMA_VERSION}",
              "counters": {{}}, "gauges": {{}},
              "histograms": {{"t": {{"count": 2, "mean": 1.0, "p50": 1.0, "p95": 2.0, "p99": 2.0, "max": 2.0}}}},
              "spans": [],
              "shards": {{"per_shard": [{{"shard": 0, "busy_ns": 123.0, "nnz": 10, "rows": 4}}], "events": []}}
            }}"#
        );
        let doc = Json::parse(&text).unwrap();
        validate_snapshot(&doc).expect("minimal snapshot validates");
        // zero busy time must fail
        let broken = Json::parse(&text.replace("123.0", "0.0")).unwrap();
        assert!(validate_snapshot(&broken).unwrap_err().to_string().contains("busy-ns"));
        // inverted quantiles must fail
        let inverted = Json::parse(&text.replace(r#""p50": 1.0"#, r#""p50": 3.0"#)).unwrap();
        assert!(validate_snapshot(&inverted).is_err());
    }

    #[test]
    fn trace_validator_accepts_chrome_shape_and_rejects_broken() {
        let good = r#"{
          "traceEvents": [
            {"name": "process_name", "cat": "__metadata", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "timeline"}},
            {"name": "serve_round/fuse", "cat": "span", "ph": "X", "pid": 1, "tid": 3,
             "ts": 12.5, "dur": 80.0, "args": {"traces": [1, 2]}},
            {"name": "plan_tune", "cat": "tune", "ph": "i", "pid": 1, "tid": 3, "ts": 100.0, "s": "p"}
          ]
        }"#;
        validate_trace(&Json::parse(good).unwrap()).expect("well-formed trace validates");
        // empty event list
        assert!(validate_trace(&Json::parse(r#"{"traceEvents": []}"#).unwrap()).is_err());
        // not a trace document at all
        assert!(validate_trace(&Json::obj()).is_err());
        // complete event missing `dur`
        let no_dur = good.replace(r#""dur": 80.0, "#, "");
        assert!(validate_trace(&Json::parse(&no_dur).unwrap()).is_err());
        // unsupported phase letter
        let bad_ph = good.replace(r#""ph": "i""#, r#""ph": "Q""#);
        assert!(validate_trace(&Json::parse(&bad_ph).unwrap()).is_err());
        // instants alone are not a usable timeline
        let only_instant = r#"{"traceEvents": [
            {"name": "m", "cat": "t", "ph": "i", "pid": 1, "tid": 1, "ts": 1.0}
        ]}"#;
        assert!(validate_trace(&Json::parse(only_instant).unwrap()).is_err());
        // wrong envelope schema tag
        let wrong_schema = r#"{"schema": "bogus/v9", "traceEvents": [
            {"name": "a", "cat": "s", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 1.0}
        ]}"#;
        assert!(validate_trace(&Json::parse(wrong_schema).unwrap()).is_err());
    }

    #[test]
    fn calibration_validator_enforces_peak_consistency() {
        let good = format!(
            r#"{{
              "schema": "{CALIBRATION_SCHEMA_VERSION}",
              "quick": true, "simd": "scalar",
              "peak_gbps": 20.0, "peak_gflops": 40.0, "machine_balance": 2.0,
              "best_threads": 4,
              "points": [
                {{"kernel": "copy", "threads": 1, "mb": 8.0, "gbps": 15.0, "gflops": 0.0}},
                {{"kernel": "triad", "threads": 4, "mb": 8.0, "gbps": 20.0, "gflops": 0.0}},
                {{"kernel": "fma", "threads": 4, "mb": 0.0, "gbps": 0.0, "gflops": 40.0}}
              ]
            }}"#
        );
        validate_calibration(&Json::parse(&good).unwrap()).expect("well-formed calibration");
        // a STREAM point above the claimed peak is inconsistent
        let over = good.replace(r#""gbps": 15.0"#, r#""gbps": 25.0"#);
        assert!(validate_calibration(&Json::parse(&over).unwrap())
            .unwrap_err()
            .to_string()
            .contains("exceeds"));
        // zero peak is not a calibration
        let zero = good.replace(r#""peak_gbps": 20.0"#, r#""peak_gbps": 0.0"#);
        assert!(validate_calibration(&Json::parse(&zero).unwrap()).is_err());
        assert!(validate_calibration(&Json::obj()).is_err());
    }

    fn roofline_fixture() -> String {
        format!(
            r#"{{
              "schema": "{ROOFLINE_SCHEMA_VERSION}",
              "calibration": {{"peak_gbps": 20.0, "peak_gflops": 40.0,
                               "machine_balance": 2.0, "threads": 4, "simd": "scalar"}},
              "graphs": [
                {{"graph": "powerlaw-1k", "n": 1000, "nnz": 8000, "f": 32, "threads": 4,
                  "analytic_bytes": 3300000.0, "instrumented_bytes": 3300000.0,
                  "bytes_per_nnz": 412.5, "arithmetic_intensity": 0.155,
                  "achieved_gbps": 9.5, "achieved_gflops": 1.5, "pct_peak": 47.5,
                  "verdict": "bandwidth-bound",
                  "buckets": [
                    {{"deg": 3, "split": false, "kernel": "sparse-gather", "blocks": 100,
                      "rows": 500, "nnz": 1500, "bytes_total": 800000.0,
                      "bytes_per_nnz": 533.3, "intensity": 0.12}},
                    {{"deg": 13, "split": false, "kernel": "dense-tiled", "blocks": 300,
                      "rows": 500, "nnz": 6500, "bytes_total": 2500000.0,
                      "bytes_per_nnz": 384.6, "intensity": 0.17}}
                  ]}}
              ]
            }}"#
        )
    }

    #[test]
    fn roofline_validator_enforces_smoke_invariants() {
        validate_roofline(&Json::parse(&roofline_fixture()).unwrap())
            .expect("well-formed roofline");
        // achieved above peak must fail — the CI smoke's core invariant
        let over = roofline_fixture().replace(r#""achieved_gbps": 9.5"#, r#""achieved_gbps": 21.0"#);
        assert!(validate_roofline(&Json::parse(&over).unwrap())
            .unwrap_err()
            .to_string()
            .contains("exceeds the calibrated peak"));
        // instrumented bytes diverging from the analytic model must fail
        let drift =
            roofline_fixture().replace(r#""instrumented_bytes": 3300000.0"#, r#""instrumented_bytes": 3300001.0"#);
        assert!(validate_roofline(&Json::parse(&drift).unwrap())
            .unwrap_err()
            .to_string()
            .contains("drifted"));
        // bucket nnz must tile the graph nnz
        let holes = roofline_fixture().replace(r#""nnz": 1500"#, r#""nnz": 1000"#);
        assert!(validate_roofline(&Json::parse(&holes).unwrap()).is_err());
        // verdict must match the intensity-vs-balance rule
        let lie = roofline_fixture().replace("bandwidth-bound", "compute-bound");
        assert!(validate_roofline(&Json::parse(&lie).unwrap()).is_err());
        assert!(validate_roofline(&Json::obj()).is_err());
    }
}
