//! Snapshot schema helpers: run metadata, the versioned-schema
//! constant, and the CI validator for emitted metrics JSON.
//!
//! All of it is zero-dependency: the ISO-8601 timestamp is computed
//! from `SystemTime` with the days-from-civil inverse (no chrono), and
//! the git commit is read best-effort from `.git/HEAD` (no subprocess)
//! so bench reports stay anchored even where `git` is not on PATH.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Version tag of [`Registry::snapshot`](super::Registry::snapshot)
/// documents. Bump on any breaking schema change.
pub const SCHEMA_VERSION: &str = "accel-gcn-metrics/v1";

/// Version tag of [`Registry::export_trace`](super::Registry::export_trace)
/// documents (`--trace-out`). The payload itself is standard Chrome
/// trace-event JSON; this tag only marks our envelope.
pub const TRACE_SCHEMA_VERSION: &str = "accel-gcn-trace/v1";

/// Run metadata embedded in every `BENCH_*.json` and metrics snapshot:
/// `{git_commit, timestamp_utc, threads, simd, schema}`.
pub fn run_metadata() -> Json {
    let mut m = Json::obj();
    match git_commit(Path::new(".")) {
        Some(c) => m.set("git_commit", c),
        None => m.set("git_commit", Json::Null),
    };
    m.set("timestamp_utc", iso8601_utc_now());
    m.set(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    m.set("simd", crate::spmm::SimdLevel::best().name());
    m.set("schema", SCHEMA_VERSION);
    m
}

/// Current UTC time as `YYYY-MM-DDTHH:MM:SSZ`.
pub fn iso8601_utc_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, mo, d) = civil_from_days((secs / 86_400) as i64);
    let rem = secs % 86_400;
    format!(
        "{y:04}-{mo:02}-{d:02}T{:02}:{:02}:{:02}Z",
        rem / 3600,
        (rem % 3600) / 60,
        rem % 60
    )
}

/// Gregorian date from days since 1970-01-01 (Hinnant's civil-from-days).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// The current commit hash, read from `repo_root/.git` without spawning
/// `git`: `HEAD` directly for a detached head, the named ref file (or
/// `packed-refs`) otherwise. `None` when not in a checkout.
pub fn git_commit(repo_root: &Path) -> Option<String> {
    let git = repo_root.join(".git");
    let head = std::fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    let Some(refname) = head.strip_prefix("ref: ") else {
        return is_hex(head).then(|| head.to_string());
    };
    if let Ok(c) = std::fs::read_to_string(git.join(refname)) {
        let c = c.trim();
        if is_hex(c) {
            return Some(c.to_string());
        }
    }
    let packed = std::fs::read_to_string(git.join("packed-refs")).ok()?;
    packed.lines().find_map(|line| {
        let (hash, name) = line.split_once(' ')?;
        (name == refname && is_hex(hash)).then(|| hash.to_string())
    })
}

fn is_hex(s: &str) -> bool {
    s.len() >= 7 && s.chars().all(|c| c.is_ascii_hexdigit())
}

/// The CI validator for emitted metrics snapshots (`accel-gcn
/// validate-metrics FILE...`): required keys present, shard busy-ns
/// totals positive, and every histogram's quantiles ordered
/// (`p99 ≥ p50`) — in the core document and, when present, the merged
/// `serve` section.
pub fn validate_snapshot(doc: &Json) -> Result<()> {
    let schema = doc.req_str("schema").context("snapshot is missing `schema`")?;
    if schema != SCHEMA_VERSION {
        bail!("schema `{schema}` is not the supported `{SCHEMA_VERSION}`");
    }
    for key in ["counters", "gauges", "histograms", "spans", "shards"] {
        if doc.get(key).is_none() {
            bail!("snapshot is missing required key `{key}`");
        }
    }
    validate_histogram_map(doc.get("histograms").unwrap(), "histograms")?;
    let shards = doc.get("shards").unwrap();
    let per_shard = shards.req_arr("per_shard").context("shards.per_shard")?;
    if per_shard.is_empty() {
        bail!("shards.per_shard is empty — no SpMM was observed");
    }
    let mut busy_total = 0.0;
    for (i, s) in per_shard.iter().enumerate() {
        busy_total += s.req_f64("busy_ns").with_context(|| format!("per_shard[{i}]"))?;
        s.req_f64("nnz").with_context(|| format!("per_shard[{i}]"))?;
        s.req_f64("rows").with_context(|| format!("per_shard[{i}]"))?;
    }
    if !(busy_total > 0.0) {
        bail!("per-shard busy-ns sums to {busy_total} — shard timing was not recorded");
    }
    if let Some(serve) = doc.get("serve") {
        if let Some(lat) = serve.get("latencies") {
            validate_histogram_map(lat, "serve.latencies")?;
        }
    }
    Ok(())
}

/// The CI validator for exported trace timelines (`--trace-out`
/// files): the document must be the Chrome trace-event object form —
/// a `traceEvents` array whose entries carry the keys the viewers
/// require (`name`/`cat`/`ph`/`pid`/`tid`, a numeric `ts`, and a
/// numeric `dur` for complete events). `validate-metrics` routes any
/// document containing `traceEvents` here instead of
/// [`validate_snapshot`].
pub fn validate_trace(doc: &Json) -> Result<()> {
    if let Some(schema) = doc.get("schema").and_then(Json::as_str) {
        if schema != TRACE_SCHEMA_VERSION {
            bail!("trace schema `{schema}` is not the supported `{TRACE_SCHEMA_VERSION}`");
        }
    }
    let events = doc.req_arr("traceEvents").context("trace is missing `traceEvents`")?;
    if events.is_empty() {
        bail!("traceEvents is empty — nothing was recorded");
    }
    let mut complete = 0usize;
    for (i, e) in events.iter().enumerate() {
        let ctx = || format!("traceEvents[{i}]");
        e.req_str("name").with_context(ctx)?;
        e.req_str("cat").with_context(ctx)?;
        e.req_f64("pid").with_context(ctx)?;
        e.req_f64("tid").with_context(ctx)?;
        let ph = e.req_str("ph").with_context(ctx)?;
        match ph {
            "X" => {
                complete += 1;
                let ts = e.req_f64("ts").with_context(ctx)?;
                let dur = e.req_f64("dur").with_context(ctx)?;
                if ts < 0.0 || dur < 0.0 {
                    bail!("traceEvents[{i}]: negative ts/dur ({ts}, {dur})");
                }
            }
            "i" => {
                e.req_f64("ts").with_context(ctx)?;
            }
            "M" => {} // metadata (process/thread names) — no timestamp required
            other => bail!("traceEvents[{i}]: unsupported phase `{other}`"),
        }
    }
    if complete == 0 {
        bail!("trace has no complete ('X') events — no durations were recorded");
    }
    Ok(())
}

fn validate_histogram_map(map: &Json, what: &str) -> Result<()> {
    let Json::Obj(entries) = map else {
        bail!("`{what}` must be an object");
    };
    for (name, h) in entries {
        let p50 = h.req_f64("p50").with_context(|| format!("{what}.{name}"))?;
        let p99 = h.req_f64("p99").with_context(|| format!("{what}.{name}"))?;
        let max = h.req_f64("max").with_context(|| format!("{what}.{name}"))?;
        h.req_f64("mean").with_context(|| format!("{what}.{name}"))?;
        h.req_usize("count").with_context(|| format!("{what}.{name}"))?;
        if p99 < p50 {
            bail!("{what}.{name}: p99 {p99} < p50 {p50}");
        }
        if max < p99 {
            bail!("{what}.{name}: max {max} < p99 {p99}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // leap year start
        assert_eq!(civil_from_days(19_782), (2024, 2, 29)); // leap day
        assert_eq!(civil_from_days(20_673), (2026, 8, 8));
    }

    #[test]
    fn timestamp_shape() {
        let t = iso8601_utc_now();
        assert_eq!(t.len(), 20, "{t}");
        assert!(t.ends_with('Z') && t.as_bytes()[10] == b'T', "{t}");
    }

    #[test]
    fn run_metadata_has_required_fields() {
        let m = run_metadata();
        assert_eq!(m.req_str("schema").unwrap(), SCHEMA_VERSION);
        assert!(m.req_usize("threads").unwrap() >= 1);
        assert!(!m.req_str("simd").unwrap().is_empty());
        assert!(m.get("git_commit").is_some());
        assert!(m.req_str("timestamp_utc").unwrap().ends_with('Z'));
    }

    #[test]
    fn validator_rejects_broken_snapshots() {
        // missing everything
        assert!(validate_snapshot(&Json::obj()).is_err());
        // minimal valid document
        let text = format!(
            r#"{{
              "schema": "{SCHEMA_VERSION}",
              "counters": {{}}, "gauges": {{}},
              "histograms": {{"t": {{"count": 2, "mean": 1.0, "p50": 1.0, "p95": 2.0, "p99": 2.0, "max": 2.0}}}},
              "spans": [],
              "shards": {{"per_shard": [{{"shard": 0, "busy_ns": 123.0, "nnz": 10, "rows": 4}}], "events": []}}
            }}"#
        );
        let doc = Json::parse(&text).unwrap();
        validate_snapshot(&doc).expect("minimal snapshot validates");
        // zero busy time must fail
        let broken = Json::parse(&text.replace("123.0", "0.0")).unwrap();
        assert!(validate_snapshot(&broken).unwrap_err().to_string().contains("busy-ns"));
        // inverted quantiles must fail
        let inverted = Json::parse(&text.replace(r#""p50": 1.0"#, r#""p50": 3.0"#)).unwrap();
        assert!(validate_snapshot(&inverted).is_err());
    }

    #[test]
    fn trace_validator_accepts_chrome_shape_and_rejects_broken() {
        let good = r#"{
          "traceEvents": [
            {"name": "process_name", "cat": "__metadata", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "timeline"}},
            {"name": "serve_round/fuse", "cat": "span", "ph": "X", "pid": 1, "tid": 3,
             "ts": 12.5, "dur": 80.0, "args": {"traces": [1, 2]}},
            {"name": "plan_tune", "cat": "tune", "ph": "i", "pid": 1, "tid": 3, "ts": 100.0, "s": "p"}
          ]
        }"#;
        validate_trace(&Json::parse(good).unwrap()).expect("well-formed trace validates");
        // empty event list
        assert!(validate_trace(&Json::parse(r#"{"traceEvents": []}"#).unwrap()).is_err());
        // not a trace document at all
        assert!(validate_trace(&Json::obj()).is_err());
        // complete event missing `dur`
        let no_dur = good.replace(r#""dur": 80.0, "#, "");
        assert!(validate_trace(&Json::parse(&no_dur).unwrap()).is_err());
        // unsupported phase letter
        let bad_ph = good.replace(r#""ph": "i""#, r#""ph": "Q""#);
        assert!(validate_trace(&Json::parse(&bad_ph).unwrap()).is_err());
        // instants alone are not a usable timeline
        let only_instant = r#"{"traceEvents": [
            {"name": "m", "cat": "t", "ph": "i", "pid": 1, "tid": 1, "ts": 1.0}
        ]}"#;
        assert!(validate_trace(&Json::parse(only_instant).unwrap()).is_err());
        // wrong envelope schema tag
        let wrong_schema = r#"{"schema": "bogus/v9", "traceEvents": [
            {"name": "a", "cat": "s", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 1.0}
        ]}"#;
        assert!(validate_trace(&Json::parse(wrong_schema).unwrap()).is_err());
    }
}
