//! One-shot STREAM-style peak-bandwidth (and FMA peak-compute)
//! calibration — the machine-specific roof every roofline number is
//! reported against.
//!
//! ## Methodology
//!
//! The classic STREAM kernels — **copy** (`b[i] = a[i]`, 8 B/elem),
//! **scale** (`b[i] = s·a[i]`, 8 B/elem) and **triad**
//! (`a[i] = b[i] + s·c[i]`, 12 B/elem) — are swept across thread-pool
//! sizes *and* working-set sizes, from cache-resident to DRAM-sized
//! buffers, with each configuration timed over several repetitions and
//! the best (minimum) time kept. `peak_gbps` is the **max over the
//! whole sweep**: the SpMM hot loop often runs partially cache-resident,
//! so a DRAM-only roof would let "achieved > peak" happen legitimately;
//! taking the cache-side max keeps the CI invariant *achieved ≤ peak*
//! meaningful. A register-resident FMA chain sweep provides
//! `peak_gflops`, and `machine_balance = peak_gflops / peak_gbps`
//! (FLOPs/byte) is the compute/bandwidth verdict threshold.
//!
//! ## Caching
//!
//! Calibration is expensive relative to everything else observability
//! does, so the result is persisted as a versioned JSON document
//! ([`CALIBRATION_SCHEMA_VERSION`]) and [`load_or_run`] reuses a valid
//! cached file. A process-global copy ([`set_global`]/[`global`]) lets
//! `bench::report::write_report` stamp calibration meta into every
//! bench JSON without re-measuring.

use super::export::{
    run_metadata, validate_calibration, CALIBRATION_SCHEMA_VERSION,
};
use crate::spmm::microkernel::SimdLevel;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};
use std::hint::black_box;
use std::path::Path;
use std::sync::OnceLock;
use std::time::Instant;

/// One measured configuration of the sweep. `gbps` is 0 for the `fma`
/// kernel; `gflops` is 0 for the STREAM kernels.
#[derive(Clone, Debug, PartialEq)]
pub struct CalPoint {
    /// `copy` | `scale` | `triad` | `fma`.
    pub kernel: String,
    pub threads: usize,
    /// Total working-set size in MiB (0 for `fma`: register-resident).
    pub mb: f64,
    pub gbps: f64,
    pub gflops: f64,
}

/// The calibrated machine roofs; see the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// Best STREAM bandwidth anywhere in the sweep, GB/s.
    pub peak_gbps: f64,
    /// Best FMA throughput anywhere in the sweep, GFLOP/s.
    pub peak_gflops: f64,
    /// Thread count that achieved `peak_gbps`.
    pub best_threads: usize,
    /// SIMD level the process ran at during calibration.
    pub simd: String,
    /// Whether this was a `--quick` (reduced-sweep) calibration.
    pub quick: bool,
    pub points: Vec<CalPoint>,
}

impl Calibration {
    /// `peak_gflops / peak_gbps`, FLOPs per byte — kernels below this
    /// arithmetic intensity are bandwidth-bound on this machine.
    pub fn machine_balance(&self) -> f64 {
        if self.peak_gbps <= 0.0 {
            return 0.0;
        }
        self.peak_gflops / self.peak_gbps
    }

    /// `gbps` as a percentage of the calibrated peak, clamped to
    /// [0, 100] so float jitter can never push a report out of range.
    pub fn pct_of_peak(&self, gbps: f64) -> f64 {
        if self.peak_gbps <= 0.0 {
            return 0.0;
        }
        (100.0 * gbps / self.peak_gbps).clamp(0.0, 100.0)
    }

    /// The bandwidth-bound vs compute-bound verdict for a kernel of the
    /// given arithmetic intensity (FLOPs/byte).
    pub fn verdict(&self, intensity: f64) -> &'static str {
        if intensity < self.machine_balance() {
            "bandwidth-bound"
        } else {
            "compute-bound"
        }
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("schema", CALIBRATION_SCHEMA_VERSION);
        doc.set("meta", run_metadata());
        doc.set("quick", self.quick);
        doc.set("simd", self.simd.as_str());
        doc.set("peak_gbps", self.peak_gbps);
        doc.set("peak_gflops", self.peak_gflops);
        doc.set("machine_balance", self.machine_balance());
        doc.set("best_threads", self.best_threads);
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("kernel", p.kernel.as_str());
                o.set("threads", p.threads);
                o.set("mb", p.mb);
                o.set("gbps", p.gbps);
                o.set("gflops", p.gflops);
                o
            })
            .collect();
        doc.set("points", points);
        doc
    }

    /// Parse a calibration document (validated first, so a stale or
    /// corrupt cache file is rejected rather than half-read).
    pub fn from_json(doc: &Json) -> Result<Calibration> {
        validate_calibration(doc)?;
        let points = doc
            .req_arr("points")?
            .iter()
            .map(|p| {
                Ok(CalPoint {
                    kernel: p.req_str("kernel")?.to_string(),
                    threads: p.req_usize("threads")?,
                    mb: p.req_f64("mb")?,
                    gbps: p.req_f64("gbps")?,
                    gflops: p.req_f64("gflops")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Calibration {
            peak_gbps: doc.req_f64("peak_gbps")?,
            peak_gflops: doc.req_f64("peak_gflops")?,
            best_threads: doc.req_usize("best_threads")?,
            simd: doc.req_str("simd")?.to_string(),
            quick: doc.get("quick").and_then(Json::as_bool).unwrap_or(false),
            points,
        })
    }

    /// One-line summary for footers and report meta.
    pub fn summary(&self) -> String {
        format!(
            "peak {:.1} GB/s ({} threads, {}), {:.1} GFLOP/s, balance {:.2} flops/B{}",
            self.peak_gbps,
            self.best_threads,
            self.simd,
            self.peak_gflops,
            self.machine_balance(),
            if self.quick { " [quick]" } else { "" }
        )
    }
}

/// Time `passes` executions of `run` and return the best per-pass
/// seconds (min over `reps` timed repetitions).
fn best_secs(reps: usize, passes: usize, mut run: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        for _ in 0..passes.max(1) {
            run();
        }
        best = best.min(t0.elapsed().as_secs_f64() / passes.max(1) as f64);
    }
    best.max(1e-12)
}

fn stream_pass(pool: &ThreadPool, chunk: usize, kernel: &str, a: &mut [f32], b: &mut [f32], c: &[f32]) {
    let s = 1.000_1f32;
    match kernel {
        "copy" => {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = b
                .chunks_mut(chunk)
                .zip(a.chunks(chunk))
                .map(|(bc, ac)| {
                    Box::new(move || {
                        bc.copy_from_slice(ac);
                        black_box(&bc[0]);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped_run(jobs);
        }
        "scale" => {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = b
                .chunks_mut(chunk)
                .zip(a.chunks(chunk))
                .map(|(bc, ac)| {
                    Box::new(move || {
                        for (x, y) in bc.iter_mut().zip(ac) {
                            *x = s * *y;
                        }
                        black_box(&bc[0]);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped_run(jobs);
        }
        "triad" => {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = a
                .chunks_mut(chunk)
                .zip(b.chunks(chunk))
                .zip(c.chunks(chunk))
                .map(|((ac, bc), cc)| {
                    Box::new(move || {
                        for ((x, y), z) in ac.iter_mut().zip(bc).zip(cc) {
                            *x = *y + s * *z;
                        }
                        black_box(&ac[0]);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped_run(jobs);
        }
        other => unreachable!("unknown STREAM kernel {other}"),
    }
}

/// Bytes moved per element by each STREAM kernel (read + write, f32).
fn stream_bytes_per_elem(kernel: &str) -> u64 {
    match kernel {
        "copy" | "scale" => 8, // 1 read + 1 write
        "triad" => 12,         // 2 reads + 1 write
        other => unreachable!("unknown STREAM kernel {other}"),
    }
}

/// Register-resident FMA chains: `chains` independent accumulators per
/// thread, `iters` steps each → `2 · iters · chains` FLOPs per thread.
fn fma_pass(pool: &ThreadPool, threads: usize, iters: usize) {
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..threads)
        .map(|t| {
            Box::new(move || {
                const CHAINS: usize = 16;
                let mut acc = [0.0f32; CHAINS];
                for (k, a) in acc.iter_mut().enumerate() {
                    *a = 1.0 + (t * CHAINS + k) as f32 * 1e-6;
                }
                let m = black_box(1.000_000_1f32);
                let add = black_box(1e-9f32);
                for _ in 0..iters {
                    for a in acc.iter_mut() {
                        *a = *a * m + add;
                    }
                }
                black_box(acc[0]);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.scoped_run(jobs);
}

const FMA_CHAINS: usize = 16;

/// Run the full calibration sweep with explicit knobs (the `calibrate`
/// wrapper picks them from `quick`): thread counts × working-set sizes
/// × {copy, scale, triad}, plus the FMA compute roof per thread count.
pub fn calibrate_with(
    thread_counts: &[usize],
    sizes_kb: &[usize],
    reps: usize,
    passes: usize,
    quick: bool,
) -> Calibration {
    let mut points = Vec::new();
    let mut peak_gbps = 0.0f64;
    let mut peak_gflops = 0.0f64;
    let mut best_threads = thread_counts.first().copied().unwrap_or(1).max(1);
    for &threads in thread_counts {
        let threads = threads.max(1);
        let pool = ThreadPool::new(threads);
        for &kb in sizes_kb {
            let elems = (kb * 1024 / 4).max(threads);
            let chunk = elems.div_ceil(threads);
            let mut a = vec![1.0f32; elems];
            let mut b = vec![0.0f32; elems];
            let c = vec![2.0f32; elems];
            for kernel in ["copy", "scale", "triad"] {
                let secs = best_secs(reps, passes, || {
                    stream_pass(&pool, chunk, kernel, &mut a, &mut b, &c)
                });
                let bytes = stream_bytes_per_elem(kernel) * elems as u64;
                let gbps = bytes as f64 / secs / 1e9;
                if gbps > peak_gbps {
                    peak_gbps = gbps;
                    best_threads = threads;
                }
                points.push(CalPoint {
                    kernel: kernel.to_string(),
                    threads,
                    mb: elems as f64 * 4.0 / (1024.0 * 1024.0),
                    gbps,
                    gflops: 0.0,
                });
            }
        }
        // compute roof: enough iterations to dwarf pool dispatch cost
        let iters = if quick { 2_000_000 } else { 8_000_000 };
        let secs = best_secs(reps, 1, || fma_pass(&pool, threads, iters));
        let flops = 2.0 * iters as f64 * FMA_CHAINS as f64 * threads as f64;
        let gflops = flops / secs / 1e9;
        peak_gflops = peak_gflops.max(gflops);
        points.push(CalPoint {
            kernel: "fma".to_string(),
            threads,
            mb: 0.0,
            gbps: 0.0,
            gflops,
        });
    }
    Calibration {
        peak_gbps,
        peak_gflops,
        best_threads,
        simd: SimdLevel::best().effective().name().to_string(),
        quick,
        points,
    }
}

/// The standard sweep: thread counts {1, 2, 4, …, max_threads},
/// working sets from L1-resident (64 KiB) to DRAM-sized. `quick`
/// halves the sweep for CI smokes.
pub fn calibrate(quick: bool, max_threads: usize) -> Calibration {
    let max_threads = max_threads.max(1);
    let mut threads = vec![1usize];
    let mut t = 2;
    while t < max_threads {
        threads.push(t);
        t *= 2;
    }
    if max_threads > 1 {
        threads.push(max_threads);
    }
    if quick {
        // endpoints only: serial + full pool
        threads = vec![1, max_threads];
        threads.dedup();
    }
    // the 64 KiB point is L1-resident on purpose: a tiny graph's SpMM
    // can run entirely out of L1, and the peak must bound that too or
    // the CI invariant "achieved ≤ peak" fails legitimately
    let sizes_kb: &[usize] =
        if quick { &[64, 512, 8 * 1024] } else { &[64, 512, 4 * 1024, 32 * 1024] };
    let (reps, passes) = if quick { (2, 2) } else { (3, 4) };
    calibrate_with(&threads, sizes_kb, reps, passes, quick)
}

/// Load a cached calibration from `path` if present and valid,
/// otherwise run the sweep and cache it there. `force` re-runs even
/// when a valid cache exists (`roofline --recalibrate`).
pub fn load_or_run(path: &Path, quick: bool, max_threads: usize, force: bool) -> Result<(Calibration, bool)> {
    if !force {
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(doc) = Json::parse(&text) {
                if let Ok(cal) = Calibration::from_json(&doc) {
                    return Ok((cal, true));
                }
            }
            // unreadable / stale cache: fall through and re-measure
        }
    }
    let cal = calibrate(quick, max_threads);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating calibration dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, cal.to_json().to_pretty())
        .with_context(|| format!("writing calibration cache {}", path.display()))?;
    Ok((cal, false))
}

static GLOBAL_CAL: OnceLock<Calibration> = OnceLock::new();

/// Publish a calibration process-wide so report writers
/// ([`crate::bench::report::write_report`]) can stamp its meta without
/// re-measuring. First write wins; later calls are no-ops.
pub fn set_global(cal: &Calibration) {
    let _ = GLOBAL_CAL.set(cal.clone());
}

/// The process-wide calibration, if one was loaded or run this process.
pub fn global() -> Option<&'static Calibration> {
    GLOBAL_CAL.get()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The smallest possible sweep still yields positive, consistent
    /// roofs and a document that validates + round-trips.
    #[test]
    fn tiny_sweep_roundtrips() {
        let cal = calibrate_with(&[1], &[64], 1, 1, true);
        assert!(cal.peak_gbps > 0.0, "copy/scale/triad must measure something");
        assert!(cal.peak_gflops > 0.0);
        assert!(cal.machine_balance() > 0.0);
        assert_eq!(cal.points.len(), 4, "3 STREAM kernels + 1 FMA point");
        assert!(cal.points.iter().filter(|p| p.kernel != "fma").all(|p| p.gbps <= cal.peak_gbps));
        let doc = cal.to_json();
        validate_calibration(&doc).expect("emitted calibration validates");
        let back = Calibration::from_json(&Json::parse(&doc.to_pretty()).unwrap()).unwrap();
        assert_eq!(back.points.len(), cal.points.len());
        assert_eq!(back.best_threads, cal.best_threads);
        assert!((back.peak_gbps - cal.peak_gbps).abs() < 1e-9);
        assert!(cal.summary().contains("GB/s"));
    }

    #[test]
    fn pct_and_verdict_helpers() {
        let cal = Calibration {
            peak_gbps: 10.0,
            peak_gflops: 40.0,
            best_threads: 2,
            simd: "scalar".to_string(),
            quick: true,
            points: vec![],
        };
        assert_eq!(cal.machine_balance(), 4.0);
        assert_eq!(cal.pct_of_peak(5.0), 50.0);
        assert_eq!(cal.pct_of_peak(1e9), 100.0, "clamped");
        assert_eq!(cal.verdict(0.5), "bandwidth-bound");
        assert_eq!(cal.verdict(17.0), "compute-bound");
    }

    #[test]
    fn cache_file_roundtrip_and_force() {
        let dir = std::env::temp_dir().join(format!("accel-gcn-cal-test-{}", std::process::id()));
        let path = dir.join("calibration.json");
        let _ = std::fs::remove_file(&path);
        let (first, was_cached) = load_or_run(&path, true, 1, false).unwrap();
        assert!(!was_cached, "first run measures");
        let (second, was_cached) = load_or_run(&path, true, 1, false).unwrap();
        assert!(was_cached, "second run loads the cache");
        assert!((first.peak_gbps - second.peak_gbps).abs() < 1e-9);
        // corrupt cache falls back to a fresh run
        std::fs::write(&path, "{not json").unwrap();
        let (_third, was_cached) = load_or_run(&path, true, 1, false).unwrap();
        assert!(!was_cached, "corrupt cache is re-measured");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn global_publish_is_idempotent() {
        let cal = calibrate_with(&[1], &[16], 1, 1, true);
        set_global(&cal);
        set_global(&cal);
        let g = global().expect("global set");
        assert!(g.peak_gbps > 0.0);
    }
}
