//! Monotonic-clock span timers with thread-local nesting.
//!
//! [`Registry::span`](super::Registry::span) returns a guard; dropping
//! it records `{count, total_ns, max_ns}` under the span's slash-joined
//! path (`"serve_round/execute"`), built from a thread-local stack of
//! the names currently open **on this thread** — so nesting reconstructs
//! from the aggregated paths alone, with no per-event allocation kept
//! around. When the registry is disabled, `span()` is a single relaxed
//! atomic load and returns an inert guard: no clock read, no allocation,
//! no thread-local touch.
//!
//! Recording spans additionally capture a wall-clock begin against the
//! process trace epoch and, on drop, push one [`TraceEvent`] into the
//! registry's trace ring — so the same guard feeds both the aggregated
//! span table and the exported Chrome timeline. [`Span::annotate`]
//! attaches structured payload (e.g. the trace ids fused into a serve
//! batch) to that timeline event.

use super::trace::{epoch_now_ns, trace_tid, TraceEvent};
use super::Registry;
use crate::util::json::Json;
use std::cell::RefCell;
use std::time::Instant;

thread_local! {
    /// Names of the spans currently open on this thread, outermost
    /// first.
    static SPAN_STACK: RefCell<Vec<String>> = const { RefCell::new(Vec::new()) };
}

/// Aggregated statistics of one span path.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanStat {
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
}

impl SpanStat {
    pub fn merge_ns(&mut self, ns: u64) {
        self.count += 1;
        self.total_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }
}

/// RAII span guard; see the module docs. `#[must_use]`: binding it to
/// `_` drops immediately and times nothing.
#[must_use = "a span measures until dropped — bind it to a named `_guard`"]
pub struct Span<'a> {
    /// `None` when the registry was disabled at entry.
    inner: Option<SpanInner<'a>>,
}

struct SpanInner<'a> {
    reg: &'a Registry,
    start: Instant,
    /// Wall-clock begin against the process trace epoch.
    begin_ns: u64,
    /// Payload attached via [`Span::annotate`], forwarded to the
    /// timeline event.
    args: Option<Json>,
}

impl<'a> Span<'a> {
    pub(super) fn enter(reg: &'a Registry, name: &str) -> Span<'a> {
        if !reg.enabled() {
            return Span { inner: None };
        }
        SPAN_STACK.with(|s| s.borrow_mut().push(name.to_string()));
        let begin_ns = epoch_now_ns();
        Span { inner: Some(SpanInner { reg, start: Instant::now(), begin_ns, args: None }) }
    }

    /// Whether this guard is actually recording.
    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }

    /// Attach one `key: value` pair to the trace-event this span emits
    /// on drop. No-op when the span is inert; repeated keys overwrite.
    pub fn annotate(&mut self, key: &str, value: impl Into<Json>) {
        if let Some(inner) = &mut self.inner {
            inner.args.get_or_insert_with(Json::obj).set(key, value);
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            let ns = inner.start.elapsed().as_nanos() as u64;
            let path = SPAN_STACK.with(|s| {
                let mut stack = s.borrow_mut();
                let path = stack.join("/");
                stack.pop();
                path
            });
            inner.reg.record_span_ns(&path, ns);
            inner.reg.push_trace_event(TraceEvent {
                name: path,
                cat: "span".to_string(),
                ph: 'X',
                begin_ns: inner.begin_ns,
                dur_ns: ns,
                tid: trace_tid(),
                args: inner.args,
            });
        }
    }
}

/// Indented tree rendering of span paths (the `profile` subcommand's
/// "flamegraph-style" view). Paths sort lexicographically, so a parent
/// immediately precedes its children; depth is the slash count.
pub fn render_span_tree(stats: &[(String, SpanStat)]) -> String {
    if stats.is_empty() {
        return "  (no spans recorded)\n".to_string();
    }
    let mut out = String::new();
    for (path, st) in stats {
        let depth = path.matches('/').count();
        let name = path.rsplit('/').next().unwrap_or(path);
        let mean_us = st.total_ns as f64 / st.count.max(1) as f64 / 1e3;
        out.push_str(&format!(
            "  {:indent$}{name:<24} count {:>7}  total {:>10.3} ms  mean {:>9.1} µs  max {:>9.1} µs\n",
            "",
            st.count,
            st.total_ns as f64 / 1e6,
            mean_us,
            st.max_ns as f64 / 1e3,
            indent = depth * 2,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nesting_reconstructs_paths() {
        let reg = Registry::new();
        {
            let _outer = reg.span("step");
            {
                let _inner = reg.span("fwd");
                let _leaf = reg.span("spmm");
            }
            let _inner2 = reg.span("bwd");
        }
        let _again = reg.span("step");
        drop(_again);
        let stats = reg.span_stats();
        let paths: Vec<&str> = stats.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(paths, vec!["step", "step/bwd", "step/fwd", "step/fwd/spmm"]);
        let step = stats.iter().find(|(p, _)| p == "step").unwrap();
        assert_eq!(step.1.count, 2, "two top-level step spans");
        assert!(step.1.total_ns >= step.1.max_ns);
        assert!(render_span_tree(&stats).contains("spmm"));
    }

    #[test]
    fn spans_emit_trace_events_with_annotations() {
        let reg = Registry::new();
        {
            let mut s = reg.span("fuse");
            s.annotate("traces", vec![7u64, 8, 9]);
            s.annotate("width", 64u64);
        }
        let evs = reg.trace_events(usize::MAX);
        assert_eq!(evs.len(), 1, "one timeline event per recording span");
        assert_eq!(evs[0].name, "fuse");
        assert_eq!(evs[0].ph, 'X');
        let args = evs[0].args.as_ref().expect("annotations attached");
        assert_eq!(args.req_arr("traces").unwrap().len(), 3);
        assert_eq!(args.req_usize("width").unwrap(), 64);
    }

    #[test]
    fn disabled_spans_record_nothing_and_keep_stack_clean() {
        let reg = Registry::new();
        reg.set_enabled(false);
        {
            let outer = reg.span("ghost");
            assert!(!outer.is_recording());
            // flip on mid-flight: the already-open disabled span must
            // not pop a name it never pushed
            reg.set_enabled(true);
            let _inner = reg.span("real");
        }
        let stats = reg.span_stats();
        let paths: Vec<&str> = stats.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(paths, vec!["real"], "only the enabled span recorded");
    }
}
