//! Fixed log-scale bucket histogram: lock-free recording, bounded
//! memory, quantiles with a documented error bound.
//!
//! ## Bucket layout and error bound
//!
//! Buckets grow geometrically by `r = 2^(1/BUCKETS_PER_OCTAVE)` from
//! [`LO`] (1 ns) across [`OCTAVES`] doublings (~4.9 h at the top), with
//! one underflow bucket below `LO` and one overflow bucket above the
//! range. Quantiles report the containing bucket's **upper edge**
//! (clamped to the exact recorded max), so a reported quantile `q̂`
//! satisfies `q ≤ q̂ ≤ r·q` — a one-sided relative error of at most
//! `r − 1 = 2^(1/32) − 1 ≈ 2.2%`, well inside the ≤ 5% bound the
//! serving metrics document. `count`, `sum`, `mean`, and `max` are
//! exact over every recorded sample (no sampling, unlike the reservoir
//! this replaced).
//!
//! Recording is a handful of relaxed atomic ops (bucket increment plus
//! CAS loops for the f64 sum/max), so concurrent recorders never block;
//! memory is a fixed `(OCTAVES·BUCKETS_PER_OCTAVE + 2)` slots of
//! `AtomicU64` per histogram, regardless of how long a server runs.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per doubling of the value range (growth ratio
/// `2^(1/32) ≈ 1.0219`).
pub const BUCKETS_PER_OCTAVE: usize = 32;
/// Lowest bucketed value: 1 ns (as seconds). Everything at or below
/// lands in the underflow bucket.
pub const LO: f64 = 1e-9;
/// Doublings covered above [`LO`]: `1e-9 · 2^44 ≈ 1.76e4` seconds.
pub const OCTAVES: usize = 44;
const N_LOG: usize = OCTAVES * BUCKETS_PER_OCTAVE;

/// Max one-sided relative quantile error: `2^(1/32) − 1`.
pub const QUANTILE_REL_ERROR: f64 = 0.0219;

/// Summary of a recorded distribution. `count`/`mean`/`max` are exact;
/// the quantiles carry the bucket error bound above.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSnapshot {
    pub count: usize,
    pub sum: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Lock-free log-bucket histogram of non-negative f64 samples
/// (seconds on the latency paths; any unit works).
#[derive(Debug)]
pub struct Histogram {
    /// `[underflow, N_LOG log buckets, overflow]`.
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    /// f64 bits, CAS-accumulated.
    sum_bits: AtomicU64,
    /// f64 bits; non-negative f64 bit patterns order like integers, so
    /// `fetch_max` on the bits is `fetch_max` on the values.
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: (0..N_LOG + 2).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    fn bucket_of(v: f64) -> usize {
        if !(v > LO) {
            return 0; // underflow (and NaN, defensively)
        }
        let i = ((v / LO).log2() * BUCKETS_PER_OCTAVE as f64).floor();
        if i >= N_LOG as f64 {
            N_LOG + 1 // overflow
        } else {
            i as usize + 1
        }
    }

    /// Upper edge of log bucket `idx` (1-based, per the layout).
    fn upper_edge(idx: usize) -> f64 {
        LO * (idx as f64 / BUCKETS_PER_OCTAVE as f64).exp2()
    }

    /// Record one sample. Negative values clamp to 0 (latencies and
    /// rates are non-negative by construction; the clamp keeps the
    /// bit-ordering trick for `max` sound).
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.max_bits.fetch_max(v.to_bits(), Ordering::Relaxed);
    }

    pub fn count(&self) -> usize {
        self.count.load(Ordering::Relaxed) as usize
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Quantile `q ∈ [0, 1]` by nearest-rank over the buckets: the
    /// containing bucket's upper edge, clamped to the exact max (the
    /// overflow bucket reports the max itself).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let max = self.max();
        let mut cum = 0u64;
        for (idx, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                let edge = if idx == 0 {
                    LO
                } else if idx == N_LOG + 1 {
                    max
                } else {
                    Self::upper_edge(idx)
                };
                return edge.min(max);
            }
        }
        max // racing recorders moved `count` past the buckets; max is safe
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let count = self.count();
        if count == 0 {
            return HistSnapshot::default();
        }
        HistSnapshot {
            count,
            sum: self.sum(),
            mean: self.sum() / count as f64,
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn empty_histogram_snapshot_is_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn quantiles_within_documented_bound() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // 1ms .. 1s
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.max, 1.0);
        assert!((s.mean - 0.5005).abs() < 1e-9, "mean is exact: {}", s.mean);
        // one-sided: true ≤ reported ≤ true · (1 + bound)
        for (got, want) in [(s.p50, 0.5), (s.p95, 0.95), (s.p99, 0.99)] {
            assert!(
                got >= want - 1e-12 && got <= want * (1.0 + QUANTILE_REL_ERROR) + 1e-12,
                "quantile {got} outside [{want}, {}]",
                want * (1.0 + QUANTILE_REL_ERROR)
            );
        }
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max, "ordered");
    }

    #[test]
    fn underflow_overflow_and_garbage_samples() {
        let h = Histogram::new();
        h.record(0.0); // underflow
        h.record(-3.0); // clamped
        h.record(1e30); // overflow bucket
        h.record(f64::NAN); // clamped
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.max, 1e30);
        assert!(s.p50 <= LO + 1e-18, "half the mass is at ~0");
        assert_eq!(s.p99, 1e30, "overflow quantile reports the exact max");
    }

    /// Exact-bucket-edge satellite: a value exactly on a bucket
    /// boundary (`LO · 2^(k/32)`) must respect the documented one-sided
    /// bound `v ≤ q̂ ≤ v·(1 + ε)` — the edge cases where `log2`
    /// rounding could misplace the sample by one bucket.
    #[test]
    fn quantile_at_exact_bucket_edges() {
        for k in [1usize, BUCKETS_PER_OCTAVE, BUCKETS_PER_OCTAVE * 10, N_LOG - 1] {
            let v = LO * (k as f64 / BUCKETS_PER_OCTAVE as f64).exp2();
            let h = Histogram::new();
            h.record(v);
            let got = h.quantile(0.5);
            assert!(
                got >= v - 1e-24 && got <= v * (1.0 + QUANTILE_REL_ERROR) + 1e-24,
                "edge k={k}: value {v} reported {got}"
            );
            assert_eq!(h.max(), v, "max is exact at edges");
        }
        // the LO edge itself is the underflow boundary: `v > LO` is
        // false, so it lands underflow and reports exactly LO
        let h = Histogram::new();
        h.record(LO);
        assert_eq!(h.quantile(0.5), LO);
    }

    /// Single-sample satellite: every quantile of a one-sample
    /// distribution is that sample (within the bucket bound), and the
    /// snapshot's exact fields are exactly it.
    #[test]
    fn single_sample_quantiles() {
        let v = 3.7e-4;
        let h = Histogram::new();
        h.record(v);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.max, v);
        assert_eq!(s.sum, v);
        assert_eq!(s.mean, v);
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            let got = h.quantile(q);
            assert!(
                got >= v - 1e-18 && got <= v * (1.0 + QUANTILE_REL_ERROR),
                "q={q}: reported {got} for single sample {v}"
            );
        }
        // quantiles clamp to the exact max, so p=1.0 is exact
        assert_eq!(h.quantile(1.0), h.quantile(1.0).min(v));
    }

    #[test]
    fn concurrent_recording_conserves_totals() {
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.record((t * 1000 + i) as f64 * 1e-6);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 8000, "every sample counted exactly once");
        let want_sum: f64 = (0..8000).map(|i| i as f64 * 1e-6).sum();
        assert!((s.sum - want_sum).abs() < 1e-9, "sum conserved: {} vs {want_sum}", s.sum);
        assert_eq!(s.max, 7999.0 * 1e-6);
    }
}
