//! Structured event ring buffer: the per-shard execution timeline.
//!
//! Every observed SpMM pushes one [`ShardEvent`] per shard; the ring
//! keeps the most recent [`EventRing::capacity`] events (constant
//! memory for a server that runs forever) while monotonically
//! increasing sequence numbers keep the timeline stitchable even after
//! wraparound.

use std::collections::VecDeque;
use std::sync::Mutex;

/// One shard's measured execution within one SpMM dispatch.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardEvent {
    /// Global event sequence number (monotonic, gap-free).
    pub seq: u64,
    /// Which SpMM dispatch this shard belonged to.
    pub spmm: u64,
    /// Shard index within the dispatch.
    pub shard: u32,
    /// Non-split output rows the shard finished.
    pub rows: u64,
    /// Nonzeros the shard traversed.
    pub nnz: u64,
    /// Wall-clock begin of the shard's job, nanoseconds since the
    /// process trace epoch ([`super::epoch_now_ns`]); 0 when the
    /// producer predates wall-clock capture.
    pub start_ns: u64,
    /// Wall time the shard's job ran, nanoseconds.
    pub busy_ns: u64,
    /// Blocks executed through the dense tiled kernel (split-row
    /// chunks included: they always run dense).
    pub dense_blocks: u64,
    /// Blocks executed through the sparse gather kernel.
    pub sparse_blocks: u64,
    /// Nonzeros traversed by the dense tiled kernel.
    pub dense_nnz: u64,
    /// Nonzeros traversed by the sparse gather kernel.
    pub sparse_nnz: u64,
}

/// Bounded ring of [`ShardEvent`]s.
#[derive(Debug)]
pub struct EventRing {
    capacity: usize,
    inner: Mutex<RingInner>,
}

#[derive(Debug, Default)]
struct RingInner {
    next_seq: u64,
    buf: VecDeque<ShardEvent>,
}

impl EventRing {
    pub fn new(capacity: usize) -> EventRing {
        EventRing { capacity: capacity.max(1), inner: Mutex::new(RingInner::default()) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Append `ev` (its `seq` is assigned here), evicting the oldest
    /// event when full. Returns the assigned sequence number.
    pub fn push(&self, mut ev: ShardEvent) -> u64 {
        let mut g = self.inner.lock().unwrap();
        ev.seq = g.next_seq;
        g.next_seq += 1;
        if g.buf.len() == self.capacity {
            g.buf.pop_front();
        }
        g.buf.push_back(ev);
        ev.seq
    }

    /// Events recorded so far (including evicted ones).
    pub fn total_recorded(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// The retained timeline, oldest first, at most `limit` newest
    /// events (`usize::MAX` for all retained).
    pub fn tail(&self, limit: usize) -> Vec<ShardEvent> {
        let g = self.inner.lock().unwrap();
        let skip = g.buf.len().saturating_sub(limit);
        g.buf.iter().skip(skip).copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_memory_and_keeps_sequence() {
        let ring = EventRing::new(4);
        for i in 0..10u64 {
            let seq = ring.push(ShardEvent { spmm: i, ..Default::default() });
            assert_eq!(seq, i, "sequence numbers are assigned in order");
        }
        assert_eq!(ring.total_recorded(), 10);
        let tail = ring.tail(usize::MAX);
        assert_eq!(tail.len(), 4, "only capacity events retained");
        assert_eq!(tail.first().unwrap().seq, 6, "oldest retained after eviction");
        assert_eq!(tail.last().unwrap().seq, 9);
        let last2 = ring.tail(2);
        assert_eq!(last2.len(), 2);
        assert_eq!(last2[0].seq, 8);
    }

    /// Wraparound under concurrent writers: sequence numbers stay
    /// gap-free, the retained window is exactly `capacity`, and the
    /// tail is the true newest suffix (sorted, contiguous, ending at
    /// `total - 1`).
    #[test]
    fn concurrent_writers_wrap_without_gaps() {
        use std::sync::Arc;
        let cap = 64;
        let ring = Arc::new(EventRing::new(cap));
        let writers = 8;
        let per_writer = 200u64; // 1600 events through a 64-slot ring
        let handles: Vec<_> = (0..writers)
            .map(|w| {
                let ring = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..per_writer {
                        ring.push(ShardEvent {
                            spmm: w as u64 * per_writer + i,
                            shard: w as u32,
                            ..Default::default()
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = writers as u64 * per_writer;
        assert_eq!(ring.total_recorded(), total, "every push counted once");
        let tail = ring.tail(usize::MAX);
        assert_eq!(tail.len(), cap, "exactly capacity events retained");
        for (k, pair) in tail.windows(2).enumerate() {
            assert_eq!(
                pair[1].seq,
                pair[0].seq + 1,
                "retained window is seq-contiguous at offset {k}"
            );
        }
        assert_eq!(tail.last().unwrap().seq, total - 1, "newest event is the last push");
        assert_eq!(tail.first().unwrap().seq, total - cap as u64);
    }
}
