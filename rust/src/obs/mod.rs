//! Unified tracing & profiling: the always-compiled observability core.
//!
//! One [`Registry`] holds every telemetry primitive the stack emits
//! into:
//!
//! * typed [`Counter`]s / [`Gauge`]s — lock-free, get-or-create by
//!   name;
//! * [`Histogram`]s — fixed log-scale buckets (exact count/mean/max,
//!   quantiles within a documented ≤ 2.2% bound, constant memory; see
//!   [`hist`]);
//! * [`Span`] timers — monotonic-clock guards whose thread-local
//!   nesting aggregates under slash-joined paths (see [`span`]);
//! * the per-shard execution timeline — one [`ShardEvent`] per shard
//!   per observed SpMM in a bounded [`EventRing`], plus running
//!   per-shard aggregates and a max/mean busy-ratio histogram
//!   (`spmm.shard_imbalance`), the input signal for the AWB-GCN-style
//!   [`crate::tune::PlanTuner`];
//! * the wall-clock trace timeline — every recording [`Span`] also
//!   lands a [`TraceEvent`] (begin + duration against one process
//!   epoch) in a bounded [`TraceRing`]; [`Registry::export_trace`]
//!   renders spans, per-shard SpMM lanes, and tuning decisions as
//!   Chrome trace-event JSON (`chrome://tracing` / Perfetto).
//!
//! ## Cost discipline
//!
//! Every hot-path hook checks [`Registry::enabled`] first — a single
//! relaxed atomic load. Disabled, nothing allocates, no clock is read,
//! and no lock is taken; the parallel executor's whole observability
//! footprint is that one load per SpMM dispatch. The process-global
//! [`Registry::global`] starts **disabled** (opt in via
//! [`Registry::set_enabled`] or `ACCEL_GCN_OBS=1`); locally constructed
//! registries start enabled, since constructing one is already the
//! opt-in.
//!
//! ## Export
//!
//! [`Registry::snapshot`] renders everything into one versioned JSON
//! document ([`SCHEMA_VERSION`]) — written by `accel-gcn serve-native
//! --metrics-out` and `accel-gcn profile --json`, validated in CI by
//! `accel-gcn validate-metrics` ([`validate_snapshot`]), and embedded
//! (as [`run_metadata`]) in every `BENCH_*.json`.

pub mod calibrate;
pub mod export;
pub mod hist;
pub mod ring;
pub mod span;
pub mod trace;

pub use calibrate::{Calibration, CalPoint};
pub use export::{
    git_commit, iso8601_utc_now, run_metadata, validate_calibration, validate_roofline,
    validate_snapshot, validate_trace, CALIBRATION_SCHEMA_VERSION, ROOFLINE_SCHEMA_VERSION,
    SCHEMA_VERSION, TRACE_SCHEMA_VERSION,
};
pub use hist::{HistSnapshot, Histogram, QUANTILE_REL_ERROR};
pub use ring::{EventRing, ShardEvent};
pub use span::{render_span_tree, Span, SpanStat};
pub use trace::{epoch_now_ns, trace_tid, TraceEvent, TraceRing};

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (e.g. queue depth): settable, signed so transient
/// dips below zero under racing inc/dec never wrap.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    /// Ratchet the gauge up to `v` (no-op if already higher) — for
    /// high-water levels like "highest tenant epoch" where plain `set`
    /// would regress under interleaved writers.
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// One shard's contribution to one SpMM dispatch, as measured by the
/// parallel executor (the pre-`seq` form of [`ShardEvent`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardSample {
    /// Non-split output rows finished by the shard.
    pub rows: u64,
    /// Nonzeros traversed.
    pub nnz: u64,
    /// Wall-clock begin of the shard job, ns since the process trace
    /// epoch ([`epoch_now_ns`]); 0 when the producer did not stamp it.
    pub start_ns: u64,
    /// Wall time of the shard job, nanoseconds.
    pub busy_ns: u64,
    /// Blocks run through the dense tiled kernel (split chunks
    /// included).
    pub dense_blocks: u64,
    /// Blocks run through the sparse gather kernel.
    pub sparse_blocks: u64,
    /// Nonzeros traversed by the dense tiled kernel.
    pub dense_nnz: u64,
    /// Nonzeros traversed by the sparse gather kernel.
    pub sparse_nnz: u64,
    /// Bytes read by the shard under the analytic traffic-model
    /// convention ([`crate::pipeline::traffic`]) — computed from the
    /// plan metadata by the same per-block rule the model uses, so
    /// shard sums always equal the plan totals.
    pub bytes_read: u64,
    /// Bytes written by the shard (same convention).
    pub bytes_written: u64,
}

/// Running totals for one shard index across every observed SpMM.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardAgg {
    pub spmms: u64,
    pub rows: u64,
    pub nnz: u64,
    pub busy_ns: u64,
    pub dense_blocks: u64,
    pub sparse_blocks: u64,
    pub dense_nnz: u64,
    pub sparse_nnz: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl ShardAgg {
    /// Achieved bandwidth of this shard: traffic-model bytes over busy
    /// time, GB/s (0 before any observation).
    pub fn achieved_gbps(&self) -> f64 {
        if self.busy_ns == 0 {
            return 0.0;
        }
        (self.bytes_read + self.bytes_written) as f64 / self.busy_ns as f64
    }
}

/// Events the snapshot embeds from the ring (the full ring stays
/// readable via [`Registry::shard_events`]).
const SNAPSHOT_EVENT_TAIL: usize = 128;
/// Ring capacity of the global registry and [`Registry::new`].
const DEFAULT_RING_CAPACITY: usize = 4096;
/// Trace-event ring capacity (spans are coarser than shard events, but
/// serve rounds emit several each, so keep a deep window).
const DEFAULT_TRACE_CAPACITY: usize = 16384;

/// The telemetry sink; see the module docs. Constructible for tests and
/// embedded use, with one process-global instance behind
/// [`Registry::global`].
#[derive(Debug)]
pub struct Registry {
    enabled: AtomicBool,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    spans: Mutex<BTreeMap<String, SpanStat>>,
    shards: Mutex<Vec<ShardAgg>>,
    ring: EventRing,
    traces: TraceRing,
    spmm_seq: AtomicU64,
    trace_ids: AtomicU64,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    /// A fresh registry, **enabled** (constructing one is the opt-in).
    pub fn new() -> Registry {
        Registry {
            enabled: AtomicBool::new(true),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(BTreeMap::new()),
            shards: Mutex::new(Vec::new()),
            ring: EventRing::new(DEFAULT_RING_CAPACITY),
            traces: TraceRing::new(DEFAULT_TRACE_CAPACITY),
            spmm_seq: AtomicU64::new(0),
            trace_ids: AtomicU64::new(0),
        }
    }

    /// The process-global registry the pipeline, serve worker, and
    /// trainer emit into. Starts **disabled** unless `ACCEL_GCN_OBS=1`
    /// — the disabled path is one relaxed load, so always-compiled
    /// instrumentation stays free in production.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let reg = Registry::new();
            let on = std::env::var("ACCEL_GCN_OBS").map(|v| v == "1").unwrap_or(false);
            reg.enabled.store(on, Ordering::Relaxed);
            reg
        })
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Get-or-create a named counter. Counters record even while spans
    /// are disabled — they are cheap and callers hold the `Arc`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Open a span named `name`; the returned guard records
    /// `{count, total, max}` under the slash-joined path of every span
    /// open on this thread when it drops. Disabled: one atomic load,
    /// inert guard.
    pub fn span(&self, name: &str) -> Span<'_> {
        Span::enter(self, name)
    }

    /// Record a duration under an explicit span path — for durations
    /// measured across threads (queue wait) or already measured by
    /// other code (the trainer's phase breakdown), where a guard
    /// cannot wrap the region.
    pub fn record_span_ns(&self, path: &str, ns: u64) {
        if !self.enabled() {
            return;
        }
        self.spans.lock().unwrap().entry(path.to_string()).or_default().merge_ns(ns);
    }

    /// [`Registry::record_span_ns`] plus a timeline entry: for
    /// cross-thread durations whose wall-clock begin is known (e.g.
    /// queue wait measured from enqueue on another thread).
    pub fn record_span_interval(&self, path: &str, begin_ns: u64, dur_ns: u64, args: Option<Json>) {
        if !self.enabled() {
            return;
        }
        self.spans.lock().unwrap().entry(path.to_string()).or_default().merge_ns(dur_ns);
        self.traces.push(TraceEvent {
            name: path.to_string(),
            cat: "span".to_string(),
            ph: 'X',
            begin_ns,
            dur_ns,
            tid: trace::trace_tid(),
            args,
        });
    }

    /// Append one event to the trace timeline (gated on
    /// [`Registry::enabled`], like every event path).
    pub fn push_trace_event(&self, ev: TraceEvent) {
        if !self.enabled() {
            return;
        }
        self.traces.push(ev);
    }

    /// An instant marker (tuning decisions, epoch swaps) with payload.
    pub fn record_instant(&self, name: &str, cat: &str, args: Json) {
        if !self.enabled() {
            return;
        }
        self.traces.push(TraceEvent::instant(name, cat).with_args(args));
    }

    /// The newest `limit` timeline events, oldest first.
    pub fn trace_events(&self, limit: usize) -> Vec<TraceEvent> {
        self.traces.tail(limit)
    }

    /// A fresh, process-unique request trace id (never 0 — 0 means
    /// "untraced"). Allocated by `Server::submit` and threaded through
    /// fuse/execute/split span annotations.
    pub fn next_trace_id(&self) -> u64 {
        self.trace_ids.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// All span paths with their aggregates, lexicographic (parents
    /// immediately before children).
    pub fn span_stats(&self) -> Vec<(String, SpanStat)> {
        self.spans.lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// One observed SpMM dispatch: per-shard samples from the parallel
    /// executor. Feeds the event ring, the per-shard aggregates, and
    /// the `spmm.shard_imbalance` histogram (max/mean busy ratio —
    /// 1.0 is perfect balance).
    pub fn record_spmm_shards(&self, samples: &[ShardSample]) {
        if samples.is_empty() || !self.enabled() {
            return;
        }
        let spmm = self.spmm_seq.fetch_add(1, Ordering::Relaxed);
        {
            let mut agg = self.shards.lock().unwrap();
            if agg.len() < samples.len() {
                agg.resize(samples.len(), ShardAgg::default());
            }
            for (i, s) in samples.iter().enumerate() {
                let a = &mut agg[i];
                a.spmms += 1;
                a.rows += s.rows;
                a.nnz += s.nnz;
                a.busy_ns += s.busy_ns;
                a.dense_blocks += s.dense_blocks;
                a.sparse_blocks += s.sparse_blocks;
                a.dense_nnz += s.dense_nnz;
                a.sparse_nnz += s.sparse_nnz;
                a.bytes_read += s.bytes_read;
                a.bytes_written += s.bytes_written;
            }
        }
        let busy = self.histogram("spmm.shard_busy");
        for (i, s) in samples.iter().enumerate() {
            self.ring.push(ShardEvent {
                seq: 0, // assigned by the ring
                spmm,
                shard: i as u32,
                rows: s.rows,
                nnz: s.nnz,
                start_ns: s.start_ns,
                busy_ns: s.busy_ns,
                dense_blocks: s.dense_blocks,
                sparse_blocks: s.sparse_blocks,
                dense_nnz: s.dense_nnz,
                sparse_nnz: s.sparse_nnz,
            });
            busy.record(s.busy_ns as f64 * 1e-9);
        }
        let max = samples.iter().map(|s| s.busy_ns).max().unwrap_or(0) as f64;
        let mean =
            samples.iter().map(|s| s.busy_ns).sum::<u64>() as f64 / samples.len() as f64;
        if mean > 0.0 {
            self.histogram("spmm.shard_imbalance").record(max / mean);
        }
        self.counter("spmm.executions").inc();
        self.counter("spmm.shards").add(samples.len() as u64);
    }

    /// Per-shard running totals (index == shard index).
    pub fn shard_aggregates(&self) -> Vec<ShardAgg> {
        self.shards.lock().unwrap().clone()
    }

    /// Clear the per-shard running totals (the event ring and
    /// histograms are untouched). The tuner calls this after a plan
    /// swap so the next warmup window measures only the new sharding;
    /// the tuning smoke calls it between its untuned/tuned windows.
    pub fn reset_shards(&self) {
        self.shards.lock().unwrap().clear();
    }

    /// The newest `limit` timeline events, oldest first.
    pub fn shard_events(&self, limit: usize) -> Vec<ShardEvent> {
        self.ring.tail(limit)
    }

    /// Everything, as one versioned JSON document (see
    /// [`SCHEMA_VERSION`] and DESIGN.md §9 for the schema table).
    pub fn snapshot(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("schema", SCHEMA_VERSION);
        doc.set("meta", run_metadata());

        let mut counters = Json::obj();
        for (name, c) in self.counters.lock().unwrap().iter() {
            counters.set(name, c.get());
        }
        doc.set("counters", counters);

        let mut gauges = Json::obj();
        for (name, g) in self.gauges.lock().unwrap().iter() {
            gauges.set(name, g.get());
        }
        doc.set("gauges", gauges);

        let mut hists = Json::obj();
        for (name, h) in self.histograms.lock().unwrap().iter() {
            hists.set(name, hist_snapshot_json(&h.snapshot()));
        }
        doc.set("histograms", hists);

        let spans: Vec<Json> = self
            .span_stats()
            .into_iter()
            .map(|(path, st)| {
                let mut o = Json::obj();
                o.set("path", path);
                o.set("count", st.count);
                o.set("total_ns", st.total_ns);
                o.set("max_ns", st.max_ns);
                o
            })
            .collect();
        doc.set("spans", spans);

        let mut shards = Json::obj();
        let per_shard: Vec<Json> = self
            .shard_aggregates()
            .iter()
            .enumerate()
            .map(|(i, a)| {
                let mut o = Json::obj();
                o.set("shard", i);
                o.set("spmms", a.spmms);
                o.set("rows", a.rows);
                o.set("nnz", a.nnz);
                o.set("busy_ns", a.busy_ns);
                o.set("dense_blocks", a.dense_blocks);
                o.set("sparse_blocks", a.sparse_blocks);
                o.set("dense_nnz", a.dense_nnz);
                o.set("sparse_nnz", a.sparse_nnz);
                o.set("bytes_read", a.bytes_read);
                o.set("bytes_written", a.bytes_written);
                o.set("achieved_gbps", a.achieved_gbps());
                o
            })
            .collect();
        shards.set("per_shard", per_shard);
        let events: Vec<Json> = self
            .shard_events(SNAPSHOT_EVENT_TAIL)
            .iter()
            .map(|e| {
                let mut o = Json::obj();
                o.set("seq", e.seq);
                o.set("spmm", e.spmm);
                o.set("shard", e.shard);
                o.set("rows", e.rows);
                o.set("nnz", e.nnz);
                o.set("start_ns", e.start_ns);
                o.set("busy_ns", e.busy_ns);
                o.set("dense_blocks", e.dense_blocks);
                o.set("sparse_blocks", e.sparse_blocks);
                o.set("dense_nnz", e.dense_nnz);
                o.set("sparse_nnz", e.sparse_nnz);
                o
            })
            .collect();
        shards.set("events", events);
        shards.set("events_recorded", self.ring.total_recorded());
        doc.set("shards", shards);
        doc
    }

    /// Everything on the timeline as one Chrome trace-event JSON
    /// document (the `{"traceEvents": [...]}` object form —
    /// `chrome://tracing` and Perfetto both load it). Lanes: pid 1 is
    /// the span/tuning timeline (tid = dense per-thread lane id), pid 2
    /// is the per-shard SpMM timeline (tid = shard index), synthesized
    /// from retained [`ShardEvent`]s whose producers stamped
    /// `start_ns`. Validated by [`validate_trace`] / the
    /// `validate-metrics` subcommand.
    pub fn export_trace(&self) -> Json {
        fn base(name: &str, cat: &str, ph: &str, pid: usize, tid: u64) -> Json {
            let mut o = Json::obj();
            o.set("name", name).set("cat", cat).set("ph", ph);
            o.set("pid", pid).set("tid", tid);
            o
        }
        let mut events: Vec<Json> = Vec::new();
        for (pid, pname) in [(1usize, "timeline"), (2usize, "spmm shards")] {
            let mut meta = base("process_name", "__metadata", "M", pid, 0);
            meta.set("ts", 0.0);
            let mut args = Json::obj();
            args.set("name", pname);
            meta.set("args", args);
            events.push(meta);
        }
        for ev in self.traces.tail(usize::MAX) {
            let mut o = base(&ev.name, &ev.cat, &ev.ph.to_string(), 1, ev.tid);
            o.set("ts", ev.begin_ns as f64 / 1e3);
            if ev.ph == 'X' {
                o.set("dur", ev.dur_ns as f64 / 1e3);
            } else {
                o.set("s", "p"); // process-scoped instant
            }
            if let Some(args) = &ev.args {
                o.set("args", args.clone());
            }
            events.push(o);
        }
        for e in self.shard_events(usize::MAX) {
            if e.start_ns == 0 {
                continue; // producer predates wall-clock capture
            }
            let mut o = base(&format!("spmm#{}", e.spmm), "shard", "X", 2, e.shard as u64);
            o.set("ts", e.start_ns as f64 / 1e3);
            o.set("dur", e.busy_ns as f64 / 1e3);
            let mut args = Json::obj();
            args.set("seq", e.seq)
                .set("rows", e.rows)
                .set("nnz", e.nnz)
                .set("dense_blocks", e.dense_blocks)
                .set("sparse_blocks", e.sparse_blocks)
                .set("dense_nnz", e.dense_nnz)
                .set("sparse_nnz", e.sparse_nnz);
            o.set("args", args);
            events.push(o);
        }
        let mut doc = Json::obj();
        doc.set("schema", TRACE_SCHEMA_VERSION);
        doc.set("meta", run_metadata());
        doc.set("displayTimeUnit", "ms");
        doc.set("traceEvents", events);
        doc
    }

    /// The `profile` subcommand's per-shard utilization table: rows,
    /// nnz, busy time, kernel mix, and each shard's busy share of the
    /// busiest shard.
    pub fn render_shard_table(&self) -> String {
        let agg = self.shard_aggregates();
        if agg.is_empty() {
            return "  (no SpMM observed)\n".to_string();
        }
        let max_busy = agg.iter().map(|a| a.busy_ns).max().unwrap_or(0).max(1);
        let mut table = crate::util::bench::Table::new(&[
            "shard", "spmms", "rows", "nnz", "busy ms", "util %", "GB/s", "dense blk",
            "sparse blk",
        ]);
        for (i, a) in agg.iter().enumerate() {
            table.row(vec![
                i.to_string(),
                a.spmms.to_string(),
                a.rows.to_string(),
                a.nnz.to_string(),
                format!("{:.3}", a.busy_ns as f64 / 1e6),
                format!("{:.1}", 100.0 * a.busy_ns as f64 / max_busy as f64),
                format!("{:.2}", a.achieved_gbps()),
                a.dense_blocks.to_string(),
                a.sparse_blocks.to_string(),
            ]);
        }
        table.render()
    }

    /// Max/mean busy ratio over the per-shard running totals (1.0 =
    /// perfectly balanced; the per-dispatch ratio distribution lives in
    /// the `spmm.shard_imbalance` histogram).
    pub fn imbalance_ratio(&self) -> f64 {
        let agg = self.shard_aggregates();
        if agg.is_empty() {
            return 0.0;
        }
        let max = agg.iter().map(|a| a.busy_ns).max().unwrap_or(0) as f64;
        let mean = agg.iter().map(|a| a.busy_ns).sum::<u64>() as f64 / agg.len() as f64;
        if mean > 0.0 {
            max / mean
        } else {
            0.0
        }
    }
}

/// A histogram snapshot as the schema's summary object.
pub fn hist_snapshot_json(s: &HistSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("count", s.count);
    o.set("sum", s.sum);
    o.set("mean", s.mean);
    o.set("p50", s.p50);
    o.set("p95", s.p95);
    o.set("p99", s.p99);
    o.set("max", s.max);
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = Arc::new(Counter::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn gauge_levels() {
        let g = Gauge::new();
        g.set(5);
        g.inc();
        g.dec();
        g.dec();
        assert_eq!(g.get(), 4);
        g.set(0);
        g.dec();
        assert_eq!(g.get(), -1, "signed: no wraparound under racing dec");
        g.set_max(5);
        g.set_max(3);
        assert_eq!(g.get(), 5, "set_max never regresses");
    }

    /// The snapshot-consistency satellite: concurrent counter and
    /// histogram updates from 8 threads land in one snapshot with
    /// totals conserved.
    #[test]
    fn concurrent_updates_yield_consistent_snapshot() {
        let reg = Arc::new(Registry::new());
        let per_thread = 500u64;
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let reg = Arc::clone(&reg);
                std::thread::spawn(move || {
                    let c = reg.counter("work.items");
                    let h = reg.histogram("work.latency");
                    for i in 0..per_thread {
                        c.inc();
                        h.record((t as f64 + 1.0) * 1e-6 * (i as f64 + 1.0));
                        reg.record_span_ns("work", 100 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let doc = reg.snapshot();
        assert_eq!(
            doc.get("counters").unwrap().req_f64("work.items").unwrap() as u64,
            8 * per_thread,
            "counter total conserved"
        );
        let lat = doc.get("histograms").unwrap().get("work.latency").unwrap();
        assert_eq!(lat.req_usize("count").unwrap() as u64, 8 * per_thread);
        assert!(lat.req_f64("p99").unwrap() >= lat.req_f64("p50").unwrap());
        let spans = doc.req_arr("spans").unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].req_f64("count").unwrap() as u64, 8 * per_thread);
    }

    /// The JSON-round-trip satellite: a populated snapshot passes the
    /// schema-shape assertion after a parse round-trip.
    #[test]
    fn snapshot_roundtrips_through_schema_validation() {
        let reg = Registry::new();
        reg.counter("spmm.executions"); // exists even before traffic
        reg.record_spmm_shards(&[
            ShardSample {
                rows: 10,
                nnz: 100,
                busy_ns: 5_000,
                dense_blocks: 3,
                sparse_blocks: 1,
                dense_nnz: 80,
                sparse_nnz: 20,
                ..Default::default()
            },
            ShardSample {
                rows: 12,
                nnz: 90,
                busy_ns: 7_500,
                dense_blocks: 2,
                sparse_blocks: 2,
                dense_nnz: 60,
                sparse_nnz: 30,
                ..Default::default()
            },
        ]);
        reg.record_spmm_shards(&[
            ShardSample {
                rows: 10,
                nnz: 100,
                busy_ns: 6_000,
                dense_blocks: 3,
                sparse_blocks: 1,
                dense_nnz: 80,
                sparse_nnz: 20,
                ..Default::default()
            },
            ShardSample {
                rows: 12,
                nnz: 90,
                busy_ns: 6_100,
                dense_blocks: 2,
                sparse_blocks: 2,
                dense_nnz: 60,
                sparse_nnz: 30,
                ..Default::default()
            },
        ]);
        {
            let _s = reg.span("profile");
        }
        let text = reg.snapshot().to_pretty();
        let back = Json::parse(&text).expect("snapshot is parseable JSON");
        validate_snapshot(&back).expect("snapshot validates against the schema shape");
        // spot-check the shard aggregation arithmetic survived export
        let shards = back.get("shards").unwrap();
        let per = shards.req_arr("per_shard").unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].req_f64("busy_ns").unwrap(), 11_000.0);
        assert_eq!(per[1].req_f64("nnz").unwrap(), 180.0);
        assert_eq!(per[0].req_f64("dense_nnz").unwrap(), 160.0);
        assert_eq!(per[1].req_f64("sparse_nnz").unwrap(), 60.0);
        assert_eq!(shards.req_arr("events").unwrap().len(), 4);
        // imbalance: per-dispatch max/mean ratios were recorded
        let imb = back.get("histograms").unwrap().get("spmm.shard_imbalance").unwrap();
        assert_eq!(imb.req_usize("count").unwrap(), 2);
        assert!(imb.req_f64("max").unwrap() >= 1.0);
        assert!(reg.imbalance_ratio() >= 1.0);
        assert!(reg.render_shard_table().contains("busy ms"));
    }

    /// Trace-export round-trip (obs-edges satellite): spans, a
    /// cross-thread interval, shard lanes, and a tuning instant all
    /// land in one document that re-parses and passes
    /// [`validate_trace`] — the same check `validate-metrics` runs on
    /// `--trace-out` files.
    #[test]
    fn trace_export_roundtrips_through_validation() {
        let reg = Registry::new();
        {
            let mut fuse = reg.span("round/fuse");
            fuse.annotate("traces", vec![1u64, 2, 3]);
        }
        let t0 = epoch_now_ns();
        reg.record_span_interval("round/queue_wait", t0, 1_500, None);
        reg.record_spmm_shards(&[
            ShardSample { nnz: 50, start_ns: epoch_now_ns(), busy_ns: 900, ..Default::default() },
            ShardSample { nnz: 60, start_ns: epoch_now_ns(), busy_ns: 1_100, ..Default::default() },
        ]);
        let mut tune = Json::obj();
        tune.set("old_imbalance", 1.8).set("new_imbalance", 1.1).set("boundaries_moved", 3usize);
        reg.record_instant("plan_tune", "tune", tune);

        let text = reg.export_trace().to_pretty();
        let back = Json::parse(&text).expect("trace is parseable JSON");
        validate_trace(&back).expect("trace validates against the Chrome trace-event shape");
        let events = back.req_arr("traceEvents").unwrap();
        // 2 metadata + fuse span + interval + 2 shard lanes + 1 instant
        assert_eq!(events.len(), 7);
        let fuse = events
            .iter()
            .find(|e| e.req_str("name").map(|n| n == "round/fuse").unwrap_or(false))
            .expect("span event present");
        assert_eq!(fuse.get("args").unwrap().req_arr("traces").unwrap().len(), 3);
        let shard_lanes = events
            .iter()
            .filter(|e| e.req_str("cat").map(|c| c == "shard").unwrap_or(false))
            .count();
        assert_eq!(shard_lanes, 2, "one lane event per stamped shard");
        assert!(
            events.iter().any(|e| e.req_str("cat").map(|c| c == "tune").unwrap_or(false)),
            "tuning instant exported"
        );
    }

    #[test]
    fn reset_shards_clears_aggregates_only() {
        let reg = Registry::new();
        reg.record_spmm_shards(&[ShardSample { nnz: 10, busy_ns: 100, ..Default::default() }]);
        assert_eq!(reg.shard_aggregates().len(), 1);
        let events_before = reg.ring.total_recorded();
        reg.reset_shards();
        assert!(reg.shard_aggregates().is_empty(), "aggregates cleared");
        assert_eq!(reg.ring.total_recorded(), events_before, "timeline untouched");
        // next window accumulates from zero
        reg.record_spmm_shards(&[ShardSample { nnz: 7, busy_ns: 50, ..Default::default() }]);
        assert_eq!(reg.shard_aggregates()[0].nnz, 7);
    }

    /// Byte traffic aggregates per shard and lands in the snapshot with
    /// the derived GB/s (bytes/ns ≡ GB/s, so 2000 B over 1000 ns = 2).
    #[test]
    fn shard_bytes_aggregate_and_export() {
        let reg = Registry::new();
        let s = ShardSample {
            nnz: 10,
            busy_ns: 500,
            bytes_read: 800,
            bytes_written: 200,
            ..Default::default()
        };
        reg.record_spmm_shards(&[s]);
        reg.record_spmm_shards(&[s]);
        let a = reg.shard_aggregates()[0];
        assert_eq!((a.bytes_read, a.bytes_written, a.busy_ns), (1600, 400, 1000));
        assert!((a.achieved_gbps() - 2.0).abs() < 1e-12);
        assert_eq!(ShardAgg::default().achieved_gbps(), 0.0, "guarded before observation");
        let doc = reg.snapshot();
        let per = doc.get("shards").unwrap().req_arr("per_shard").unwrap();
        assert_eq!(per[0].req_f64("bytes_read").unwrap(), 1600.0);
        assert_eq!(per[0].req_f64("bytes_written").unwrap(), 400.0);
        assert!((per[0].req_f64("achieved_gbps").unwrap() - 2.0).abs() < 1e-12);
        assert!(reg.render_shard_table().contains("GB/s"));
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let reg = Registry::new();
        let a = reg.next_trace_id();
        let b = reg.next_trace_id();
        assert_ne!(a, 0, "0 is reserved for untraced");
        assert!(b > a, "monotone allocation");
    }

    #[test]
    fn disabled_registry_drops_events_not_counters() {
        let reg = Registry::new();
        reg.set_enabled(false);
        reg.record_spmm_shards(&[ShardSample { busy_ns: 1, ..Default::default() }]);
        reg.record_span_ns("x", 5);
        reg.push_trace_event(TraceEvent::instant("x", "span"));
        assert!(reg.shard_aggregates().is_empty());
        assert!(reg.span_stats().is_empty());
        assert!(reg.trace_events(usize::MAX).is_empty());
        // counters handed out by Arc still count — the flag gates the
        // event/span paths the hot loops guard on
        let c = reg.counter("still.works");
        c.inc();
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn global_registry_exists_and_defaults_off() {
        // other tests may enable it; just exercise the accessor and the
        // get-or-create identity property
        let g = Registry::global();
        let a = g.counter("test.global.identity");
        let b = g.counter("test.global.identity");
        a.add(2);
        assert!(b.get() >= 2, "same underlying counter");
    }
}
