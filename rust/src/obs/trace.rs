//! Wall-clock trace timeline: the process trace epoch, per-thread lane
//! ids, and a bounded ring of [`TraceEvent`]s that
//! [`Registry::export_trace`](super::Registry::export_trace) renders as
//! Chrome trace-event JSON (loadable in `chrome://tracing` and
//! Perfetto).
//!
//! The aggregated span table ([`super::span`]) answers "where does time
//! go on average"; the trace ring answers "what happened *when*" —
//! every span drop, every cross-thread interval, and every tuning
//! decision lands here with a wall-clock begin relative to one shared
//! process epoch, so lanes from different threads line up on a common
//! axis. Per-shard SpMM lanes are not duplicated into this ring: the
//! exporter synthesizes them from the [`ShardEvent`](super::ShardEvent)
//! ring's `start_ns`/`busy_ns` at export time.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Nanoseconds since the process trace epoch. The epoch is pinned on
/// first call (process-wide, monotonic), so every timestamp in one
/// exported trace shares a single origin.
pub fn epoch_now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Small dense lane id for the calling thread. `std::thread::ThreadId`
/// is opaque; trace viewers want small stable integers per lane.
pub fn trace_tid() -> u64 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// One timeline entry. `ph` follows the Chrome trace-event phase
/// alphabet — only the subset the exporter emits: `'X'` (complete
/// event, `dur_ns` meaningful) and `'i'` (instant event).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event name (span path, tuning decision, ...).
    pub name: String,
    /// Category: `"span"`, `"serve"`, `"tune"`, ... — filterable in
    /// the viewer.
    pub cat: String,
    /// Chrome phase: `'X'` or `'i'`.
    pub ph: char,
    /// Wall-clock begin, ns since [`epoch_now_ns`]'s epoch.
    pub begin_ns: u64,
    /// Duration in ns (0 for instants).
    pub dur_ns: u64,
    /// Lane (thread) id, from [`trace_tid`].
    pub tid: u64,
    /// Optional structured payload (trace ids, tuning deltas, ...).
    pub args: Option<Json>,
}

impl TraceEvent {
    /// A complete ('X') event.
    pub fn complete(name: &str, cat: &str, begin_ns: u64, dur_ns: u64) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: 'X',
            begin_ns,
            dur_ns,
            tid: trace_tid(),
            args: None,
        }
    }

    /// An instant ('i') event stamped now.
    pub fn instant(name: &str, cat: &str) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: 'i',
            begin_ns: epoch_now_ns(),
            dur_ns: 0,
            tid: trace_tid(),
            args: None,
        }
    }

    pub fn with_args(mut self, args: Json) -> TraceEvent {
        self.args = Some(args);
        self
    }
}

/// Bounded ring of [`TraceEvent`]s: constant memory for a process that
/// runs forever, newest-window semantics like
/// [`EventRing`](super::EventRing).
#[derive(Debug)]
pub struct TraceRing {
    capacity: usize,
    inner: Mutex<TraceInner>,
}

#[derive(Debug, Default)]
struct TraceInner {
    total: u64,
    buf: VecDeque<TraceEvent>,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing { capacity: capacity.max(1), inner: Mutex::new(TraceInner::default()) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn push(&self, ev: TraceEvent) {
        let mut g = self.inner.lock().unwrap();
        g.total += 1;
        if g.buf.len() == self.capacity {
            g.buf.pop_front();
        }
        g.buf.push_back(ev);
    }

    /// Events recorded so far (including evicted ones).
    pub fn total_recorded(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// The retained timeline, oldest first, at most `limit` newest.
    pub fn tail(&self, limit: usize) -> Vec<TraceEvent> {
        let g = self.inner.lock().unwrap();
        let skip = g.buf.len().saturating_sub(limit);
        g.buf.iter().skip(skip).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_monotone_and_shared() {
        let a = epoch_now_ns();
        let b = epoch_now_ns();
        assert!(b >= a, "one shared monotone epoch");
    }

    #[test]
    fn tids_are_stable_per_thread_and_distinct_across() {
        let here = trace_tid();
        assert_eq!(here, trace_tid(), "stable within a thread");
        let other = std::thread::spawn(trace_tid).join().unwrap();
        assert_ne!(here, other, "distinct lanes across threads");
    }

    #[test]
    fn ring_bounds_and_orders() {
        let ring = TraceRing::new(3);
        for i in 0..5u64 {
            ring.push(TraceEvent::complete(&format!("e{i}"), "span", i * 10, 1));
        }
        assert_eq!(ring.total_recorded(), 5);
        let tail = ring.tail(usize::MAX);
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[0].name, "e2", "oldest retained after eviction");
        assert_eq!(tail[2].name, "e4");
        assert_eq!(ring.tail(1)[0].name, "e4");
    }
}
