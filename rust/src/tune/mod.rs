//! Closed-loop plan tuning: measured cost in, re-cut shards out.
//!
//! The static pipeline balances shards by **nonzero count** — the same
//! proxy Accel-GCN's block-level partition uses at preprocessing time.
//! That proxy is wrong exactly when the kernel mix is skewed: a
//! gather-kernel nonzero and a dense-tile nonzero do not cost the same,
//! so an nnz-balanced cut can leave one shard holding the expensive
//! mix. This module closes the loop with the [`obs`](crate::obs)
//! timeline:
//!
//! 1. **Measure** — the parallel executor records per-shard
//!    `{busy_ns, dense_nnz, sparse_nnz}` aggregates into the global
//!    [`Registry`](crate::obs::Registry) whenever observability is on.
//! 2. **Fit** — [`CostModel::fit`] solves the 2×2 least-squares system
//!    `busy ≈ c_d·dense_nnz + c_s·sparse_nnz` over the shard samples
//!    (with single-kernel and uniform fallbacks when the system is
//!    degenerate), clamped to a sane band around the uniform cost.
//! 3. **Decide** — [`PlanTuner::analyze`] prices every block under the
//!    fitted model, revisits the dense/sparse crossover among
//!    [`CROSSOVER_CANDIDATES`], and re-cuts the shard boundaries
//!    against predicted cost
//!    ([`cut_by_weights`](crate::pipeline::parallel::cut_by_weights)).
//!    The re-cut is applied only when it is predicted to improve the
//!    max/mean shard-cost imbalance by at least
//!    [`TuneConfig::min_improvement`].
//! 4. **Swap** — [`PlanTuner::maybe_tune`] clones the plan, attaches
//!    the [`TunedSharding`] annotation (and the re-derived
//!    [`KernelSchedule`] when the crossover moved), and the caller
//!    swaps it through [`PlanCache::refresh`](crate::pipeline::PlanCache::refresh)
//!    (serve) or a direct `Arc` replacement (train). Every analysis
//!    emits a `plan_tune` instant event into the trace timeline.
//!
//! ## What tuning may and may not change
//!
//! Tuning only ever moves **partitioning** decisions whose output is
//! bit-for-bit identical by construction: shard cuts (the split-row
//! reduction runs in global block order, independent of the cuts) and
//! the per-block kernel choice (both microkernels accumulate a row's
//! nonzeros in the same order at every SIMD level). The partition
//! parameters themselves (`deg_bound` via `PartitionParams`) are
//! **advisory only**: changing them would re-chunk the graph, change
//! the plan's cache key, and break bit-identity — the tuner reports on
//! them but never applies them.

use crate::obs::{Registry, ShardAgg};
use crate::partition::metadata::BlockMeta;
use crate::pipeline::parallel::cut_by_weights;
use crate::pipeline::plan::{KernelSchedule, SpmmPlan, TunedSharding};
use crate::pipeline::traffic::{block_traffic, ElemWidths, TrafficModel};
use crate::spmm::microkernel::{RowKernel, SPARSE_DEG_MAX};
use crate::util::json::Json;

/// Dense/sparse crossover degrees the tuner prices (the static default
/// [`SPARSE_DEG_MAX`] is always among them, so "no change" is always a
/// candidate).
pub const CROSSOVER_CANDIDATES: [usize; 3] = [2, 4, 8];

/// Fitted per-kernel costs are clamped to
/// `[uniform / COST_CLAMP, uniform × COST_CLAMP]` around the uniform
/// ns-per-nnz — least squares over a handful of noisy shards can
/// produce wild coefficients, and a 10× band is already far beyond any
/// plausible dense/gather cost ratio.
pub const COST_CLAMP: f64 = 10.0;

/// Per-nanosecond-per-nonzero cost of each kernel shape, fitted from
/// the measured per-shard timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    pub dense_ns_per_nnz: f64,
    pub sparse_ns_per_nnz: f64,
}

impl CostModel {
    /// Least-squares fit of `busy ≈ c_d·dense_nnz + c_s·sparse_nnz`
    /// over the per-shard aggregates (normal equations of the 2×2
    /// system). Degenerate systems fall back gracefully:
    /// * only one kernel observed → that kernel gets the exact ratio,
    ///   the unobserved one the uniform cost;
    /// * collinear samples (every shard has the same mix) → both get
    ///   the uniform cost.
    ///
    /// Returns `None` when there is no signal at all (no nonzeros or no
    /// busy time recorded).
    pub fn fit(aggs: &[ShardAgg]) -> Option<CostModel> {
        let (mut s11, mut s12, mut s22, mut b1, mut b2) = (0f64, 0f64, 0f64, 0f64, 0f64);
        let (mut sum_x1, mut sum_x2, mut sum_y) = (0f64, 0f64, 0f64);
        for a in aggs {
            let x1 = a.dense_nnz as f64;
            let x2 = a.sparse_nnz as f64;
            let y = a.busy_ns as f64;
            s11 += x1 * x1;
            s12 += x1 * x2;
            s22 += x2 * x2;
            b1 += x1 * y;
            b2 += x2 * y;
            sum_x1 += x1;
            sum_x2 += x2;
            sum_y += y;
        }
        let sum_x = sum_x1 + sum_x2;
        if sum_x <= 0.0 || sum_y <= 0.0 {
            return None;
        }
        let uniform = sum_y / sum_x;
        let det = s11 * s22 - s12 * s12;
        // relative determinant test: collinear shard mixes make the
        // normal equations numerically rank-1
        let well_posed = s11 > 0.0 && s22 > 0.0 && det > 1e-9 * s11 * s22;
        let (cd, cs) = if well_posed {
            let cd = (b1 * s22 - b2 * s12) / det;
            let cs = (b2 * s11 - b1 * s12) / det;
            if cd > 0.0 && cs > 0.0 {
                (cd, cs)
            } else {
                (uniform, uniform) // sign flip: noise won, trust the mean
            }
        } else if sum_x1 > 0.0 && sum_x2 == 0.0 {
            (sum_y / sum_x1, uniform)
        } else if sum_x2 > 0.0 && sum_x1 == 0.0 {
            (uniform, sum_y / sum_x2)
        } else {
            (uniform, uniform)
        };
        let clamp = |c: f64| c.clamp(uniform / COST_CLAMP, uniform * COST_CLAMP);
        Some(CostModel { dense_ns_per_nnz: clamp(cd), sparse_ns_per_nnz: clamp(cs) })
    }

    /// Predicted cost of one block under this model.
    fn block_cost(&self, nnz: u64, dense: bool) -> f64 {
        nnz as f64 * if dense { self.dense_ns_per_nnz } else { self.sparse_ns_per_nnz }
    }
}

/// Knobs of the tuning decision (not of the measurement).
#[derive(Clone, Copy, Debug)]
pub struct TuneConfig {
    /// Minimum SpMM executions the warmup window must have aggregated
    /// before the fit is trusted.
    pub warmup_spmms: u64,
    /// Minimum relative improvement of the predicted max/mean shard
    /// imbalance (or of the predicted total cost, for a crossover
    /// move) required to apply — hysteresis against swap churn.
    pub min_improvement: f64,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig { warmup_spmms: 4, min_improvement: 0.02 }
    }
}

/// One tuning decision, applied or declined — serialized into the
/// trace timeline as a `plan_tune` instant event.
#[derive(Clone, Debug)]
pub struct TuneReport {
    pub applied: bool,
    pub reason: String,
    pub dense_ns_per_nnz: f64,
    pub sparse_ns_per_nnz: f64,
    pub old_crossover: usize,
    pub new_crossover: usize,
    /// Max/mean predicted shard cost under the static nnz-balanced cut.
    pub predicted_static_imbalance: f64,
    /// Max/mean predicted shard cost under the cost-balanced cut.
    pub predicted_tuned_imbalance: f64,
    /// Shard boundaries that moved between the two layouts.
    pub boundaries_moved: usize,
    pub n_shards: usize,
    /// SpMM executions aggregated in the warmup window.
    pub spmms_observed: u64,
    /// Measured bandwidth of the window: traffic-model bytes over busy
    /// time, GB/s (0 when the window carried no byte accounting).
    pub achieved_gbps: f64,
    /// Measured bytes moved per nonzero over the window (0 without
    /// byte accounting).
    pub bytes_per_nnz: f64,
    /// The fitted bandwidth cost: ns per traffic-model byte (0 without
    /// byte accounting) — the floor under every block's predicted cost.
    pub ns_per_byte: f64,
    /// Report-only storage-quantization what-if (LW-GCN): predicted
    /// bytes/nnz and bandwidth win at i8/f16 storage widths. Empty
    /// without byte accounting; never applied.
    pub whatif: String,
}

impl TuneReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("applied", self.applied)
            .set("reason", self.reason.as_str())
            .set("dense_ns_per_nnz", self.dense_ns_per_nnz)
            .set("sparse_ns_per_nnz", self.sparse_ns_per_nnz)
            .set("old_crossover", self.old_crossover)
            .set("new_crossover", self.new_crossover)
            .set("predicted_static_imbalance", self.predicted_static_imbalance)
            .set("predicted_tuned_imbalance", self.predicted_tuned_imbalance)
            .set("boundaries_moved", self.boundaries_moved)
            .set("n_shards", self.n_shards)
            .set("spmms_observed", self.spmms_observed)
            .set("achieved_gbps", self.achieved_gbps)
            .set("bytes_per_nnz", self.bytes_per_nnz)
            .set("ns_per_byte", self.ns_per_byte)
            .set("whatif", self.whatif.as_str())
            .set(
                "advisory",
                "partition params (deg_bound) held fixed: re-chunking would \
                 change the cache key and break bit-identity",
            );
        j
    }
}

/// The utilization-driven tuner; see the module docs for the loop.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanTuner {
    pub cfg: TuneConfig,
}

impl PlanTuner {
    pub fn new(cfg: TuneConfig) -> PlanTuner {
        PlanTuner { cfg }
    }

    /// Price `plan` under the measured aggregates and decide whether a
    /// re-cut is worth applying. Returns `None` while the warmup window
    /// is unmet (or there is nothing to measure); otherwise the report
    /// plus `Some(annotation)` when the tuned layout clears the
    /// improvement bar.
    pub fn analyze(
        &self,
        aggs: &[ShardAgg],
        plan: &SpmmPlan,
        n_shards: usize,
    ) -> Option<(TuneReport, Option<TunedSharding>)> {
        if n_shards == 0 || plan.block.meta.is_empty() {
            return None;
        }
        let spmms = aggs.iter().map(|a| a.spmms).max().unwrap_or(0);
        if spmms < self.cfg.warmup_spmms {
            return None;
        }
        let model = CostModel::fit(aggs)?;
        let deg_bound = plan.block.params.deg_bound();
        let old_crossover =
            plan.tuned.as_ref().map(|t| t.crossover).unwrap_or(SPARSE_DEG_MAX);

        // bandwidth term: when the window carried traffic-model bytes
        // (PR 10), fit ns/byte over the whole window and use it as a
        // floor under the per-kernel nnz cost — a block can never be
        // predicted cheaper than the bytes it moves at memory speed.
        // Windows without byte accounting (bytes == 0) degrade to the
        // pure nnz model.
        let total_bytes: u64 =
            aggs.iter().map(|a| a.bytes_read + a.bytes_written).sum();
        let total_busy: u64 = aggs.iter().map(|a| a.busy_ns).sum();
        let total_nnz: u64 = aggs.iter().map(|a| a.nnz).sum();
        let ns_per_byte = (total_bytes > 0 && total_busy > 0)
            .then(|| total_busy as f64 / total_bytes as f64);
        // recover the effective feature width from the bytes one SpMM
        // moved — the traffic model is exactly linear in f
        let eff_f = ns_per_byte.and_then(|_| {
            plan.traffic.solve_width(total_bytes as f64 / spmms.max(1) as f64)
        });

        let nnz_of = |m: &BlockMeta| -> u64 {
            if m.is_split(deg_bound) {
                m.split_nzs() as u64
            } else {
                m.deg as u64 * m.block_rows() as u64
            }
        };
        let price = |m: &BlockMeta, crossover: usize| -> f64 {
            let dense = m.is_split(deg_bound) || m.deg as usize > crossover;
            let kern_cost = model.block_cost(nnz_of(m), dense);
            if let (Some(nspb), Some(f)) = (ns_per_byte, eff_f) {
                let kern =
                    if dense { RowKernel::DenseTiled } else { RowKernel::SparseGather };
                let bt = block_traffic(m, kern, deg_bound);
                // bytes at the (fractional) effective width, via the
                // model's linearity in f
                let base = bt.bytes_total(0) as f64;
                let slope = bt.bytes_total(1) as f64 - base;
                kern_cost.max(nspb * (base + slope * f))
            } else {
                kern_cost
            }
        };
        let total_under = |crossover: usize| -> f64 {
            plan.block.meta.iter().map(|m| price(m, crossover)).sum()
        };

        // revisit the crossover: strict improvement over the current
        // one, ties keep it (no churn)
        let mut new_crossover = old_crossover;
        let mut best_total = total_under(old_crossover);
        for c in CROSSOVER_CANDIDATES {
            let t = total_under(c);
            if t < best_total * (1.0 - 1e-9) {
                best_total = t;
                new_crossover = c;
            }
        }

        let block_cost: Vec<u64> = plan
            .block
            .meta
            .iter()
            .map(|m| price(m, new_crossover).round().max(1.0) as u64)
            .collect();
        let nnz_weights: Vec<u64> = plan.block.meta.iter().map(nnz_of).collect();

        let imbalance = |ranges: &[std::ops::Range<usize>]| -> f64 {
            let sums: Vec<u128> = ranges
                .iter()
                .map(|r| block_cost[r.clone()].iter().map(|&c| c as u128).sum())
                .collect();
            let total: u128 = sums.iter().sum();
            if total == 0 || sums.is_empty() {
                return 1.0;
            }
            let mean = total as f64 / sums.len() as f64;
            *sums.iter().max().unwrap() as f64 / mean
        };
        let static_ranges = cut_by_weights(&nnz_weights, n_shards);
        let tuned_ranges = cut_by_weights(&block_cost, n_shards);
        let static_imb = imbalance(&static_ranges);
        let tuned_imb = imbalance(&tuned_ranges);
        let boundaries_moved = static_ranges
            .iter()
            .zip(&tuned_ranges)
            .filter(|(a, b)| a.start != b.start)
            .count()
            + static_ranges.len().abs_diff(tuned_ranges.len());

        let sharding_wins = tuned_imb <= static_imb * (1.0 - self.cfg.min_improvement);
        let crossover_wins = new_crossover != old_crossover
            && best_total <= total_under(old_crossover) * (1.0 - self.cfg.min_improvement);
        let applied = sharding_wins || crossover_wins;
        let reason = if sharding_wins && crossover_wins {
            "re-cut shards and moved crossover".to_string()
        } else if sharding_wins {
            "re-cut shards against measured cost".to_string()
        } else if crossover_wins {
            "moved dense/sparse crossover".to_string()
        } else {
            format!(
                "declined: predicted imbalance {tuned_imb:.3} vs static {static_imb:.3} \
                 below the {:.0}% bar",
                self.cfg.min_improvement * 100.0
            )
        };
        // report-only quantized-storage what-if: what the same plan
        // would move per nonzero at f16/i8 storage widths (LW-GCN
        // style); advisory text, never applied to the plan
        let whatif = match eff_f {
            Some(f) if plan.traffic.nnz() > 0 => {
                let fw = (f.round().max(1.0)) as usize;
                let f16x = plan.traffic.quantized_speedup(fw, ElemWidths::F16_STORAGE);
                let i8x = plan.traffic.quantized_speedup(fw, ElemWidths::I8_STORAGE);
                format!(
                    "storage what-if at f={fw}: f32 {:.1} B/nnz; f16-storage \
                     {:.1} B/nnz ({f16x:.2}x less traffic); i8-storage {:.1} \
                     B/nnz ({i8x:.2}x less traffic)",
                    plan.traffic.bytes_per_nnz(fw),
                    plan.traffic.bytes_total_with(fw, ElemWidths::F16_STORAGE) as f64
                        / plan.traffic.nnz() as f64,
                    plan.traffic.bytes_total_with(fw, ElemWidths::I8_STORAGE) as f64
                        / plan.traffic.nnz() as f64,
                )
            }
            _ => String::new(),
        };
        let report = TuneReport {
            applied,
            reason,
            dense_ns_per_nnz: model.dense_ns_per_nnz,
            sparse_ns_per_nnz: model.sparse_ns_per_nnz,
            old_crossover,
            new_crossover,
            predicted_static_imbalance: static_imb,
            predicted_tuned_imbalance: tuned_imb,
            boundaries_moved,
            n_shards,
            spmms_observed: spmms,
            achieved_gbps: if total_busy > 0 {
                total_bytes as f64 / total_busy as f64
            } else {
                0.0
            },
            bytes_per_nnz: if total_nnz > 0 {
                total_bytes as f64 / total_nnz as f64
            } else {
                0.0
            },
            ns_per_byte: ns_per_byte.unwrap_or(0.0),
            whatif,
        };
        let annotation = applied.then(|| TunedSharding {
            dense_ns_per_nnz: model.dense_ns_per_nnz,
            sparse_ns_per_nnz: model.sparse_ns_per_nnz,
            crossover: new_crossover,
            block_cost,
            predicted_static_imbalance: static_imb,
            predicted_tuned_imbalance: tuned_imb,
            n_shards,
        });
        Some((report, annotation))
    }

    /// The full loop step: read `reg`'s shard aggregates, [`Self::analyze`],
    /// emit the `plan_tune` trace event, and return the re-tuned plan
    /// when the decision was to apply. The returned plan is a clone of
    /// `plan` differing only in its sharding annotation and (possibly)
    /// kernel schedule — same graph, same fingerprint, bit-identical
    /// output — ready for `PlanCache::refresh` or a direct `Arc` swap.
    pub fn maybe_tune(
        &self,
        reg: &Registry,
        plan: &SpmmPlan,
        n_shards: usize,
    ) -> Option<SpmmPlan> {
        let aggs = reg.shard_aggregates();
        let (report, annotation) = self.analyze(&aggs, plan, n_shards)?;
        reg.record_instant("plan_tune", "tune", report.to_json());
        let t = annotation?;
        let mut tuned = plan.clone();
        if t.crossover != plan.tuned.as_ref().map(|p| p.crossover).unwrap_or(SPARSE_DEG_MAX)
        {
            tuned.kernels = KernelSchedule::derive_with(&tuned.block, t.crossover);
            // the traffic model is pure in (block, kernels): a moved
            // crossover changes per-bucket y traffic, so re-derive
            tuned.traffic = TrafficModel::derive(&tuned.block, &tuned.kernels);
        }
        tuned.tuned = Some(t);
        Some(tuned)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::pipeline::parallel::shard_ranges_for_plan;
    use crate::pipeline::ParallelBlockLevel;
    use crate::pipeline::Executor;
    use crate::obs::ShardSample;
    use crate::spmm::microkernel::RowKernel;
    use crate::util::rng::Pcg;
    use std::sync::Arc;

    fn agg(dense_nnz: u64, sparse_nnz: u64, busy_ns: u64) -> ShardAgg {
        ShardAgg {
            spmms: 8,
            nnz: dense_nnz + sparse_nnz,
            busy_ns,
            dense_nnz,
            sparse_nnz,
            ..Default::default()
        }
    }

    #[test]
    fn cost_fit_recovers_synthetic_costs() {
        // busy = 3·dense + 1·sparse, non-collinear mixes → exact fit
        let aggs = [
            agg(100, 0, 300),
            agg(0, 100, 100),
            agg(50, 50, 200),
            agg(80, 20, 260),
        ];
        let m = CostModel::fit(&aggs).unwrap();
        assert!((m.dense_ns_per_nnz - 3.0).abs() < 1e-6, "dense {}", m.dense_ns_per_nnz);
        assert!((m.sparse_ns_per_nnz - 1.0).abs() < 1e-6, "sparse {}", m.sparse_ns_per_nnz);
    }

    #[test]
    fn cost_fit_falls_back_on_degenerate_systems() {
        // single kernel observed: exact ratio for it, uniform for the other
        let m = CostModel::fit(&[agg(100, 0, 500), agg(200, 0, 1000)]).unwrap();
        assert!((m.dense_ns_per_nnz - 5.0).abs() < 1e-9);
        assert!((m.sparse_ns_per_nnz - 5.0).abs() < 1e-9, "uniform fallback");
        // collinear mixes (every shard 2:1): rank-1 system → uniform
        let m = CostModel::fit(&[agg(100, 50, 450), agg(200, 100, 900)]).unwrap();
        let uniform = 1350.0 / 450.0;
        assert!((m.dense_ns_per_nnz - uniform).abs() < 1e-9);
        assert!((m.sparse_ns_per_nnz - uniform).abs() < 1e-9);
        // no signal at all
        assert!(CostModel::fit(&[ShardAgg::default()]).is_none());
        // clamp: a 100× ratio is capped at COST_CLAMP× the uniform
        let m = CostModel::fit(&[agg(100, 0, 100), agg(0, 100, 10000), agg(50, 50, 5050)])
            .unwrap();
        let uniform = 15150.0 / 300.0;
        assert!(m.sparse_ns_per_nnz <= uniform * COST_CLAMP + 1e-9);
        assert!(m.dense_ns_per_nnz >= uniform / COST_CLAMP - 1e-9);
    }

    /// A graph engineered so nnz-balanced cuts are badly cost-skewed:
    /// 8 degree-2 rows (gather kernel) and 8 degree-30 rows (dense
    /// kernel), one block per row.
    fn mixed_plan() -> Arc<SpmmPlan> {
        let params = PartitionParams { max_block_warps: 1, max_warp_nzs: 32 };
        let mut edges = Vec::new();
        for r in 0..8u32 {
            edges.push((r, 2 * r, 1.0));
            edges.push((r, 2 * r + 1, 1.0));
        }
        for r in 8..16u32 {
            for c in 0..30u32 {
                edges.push((r, c, 0.5));
            }
        }
        let csr = Csr::from_edges(16, 32, &edges).unwrap();
        Arc::new(SpmmPlan::build(csr, params))
    }

    /// Synthesize the warmup window the executor would have recorded:
    /// per-shard dense/sparse nnz from the plan's own dispatch, busy
    /// time from a ground-truth cost model where gather nonzeros are
    /// 50× dense ones.
    fn record_synthetic_window(reg: &Registry, plan: &SpmmPlan, n_shards: usize, reps: u64) {
        let deg_bound = plan.block.params.deg_bound();
        let ranges = shard_ranges_for_plan(plan, n_shards);
        let samples: Vec<ShardSample> = ranges
            .iter()
            .map(|r| {
                let (mut dense, mut sparse) = (0u64, 0u64);
                for b in r.clone() {
                    let m = plan.block.meta[b];
                    let nnz = if m.is_split(deg_bound) {
                        m.split_nzs()
                    } else {
                        m.deg as usize * m.block_rows()
                    } as u64;
                    if m.is_split(deg_bound)
                        || plan.kernels.kernel_for(b) == RowKernel::DenseTiled
                    {
                        dense += nnz;
                    } else {
                        sparse += nnz;
                    }
                }
                ShardSample {
                    nnz: dense + sparse,
                    busy_ns: dense + 50 * sparse,
                    dense_nnz: dense,
                    sparse_nnz: sparse,
                    ..Default::default()
                }
            })
            .collect();
        for _ in 0..reps {
            reg.record_spmm_shards(&samples);
        }
    }

    #[test]
    fn warmup_gate_holds_back_the_fit() {
        let reg = Registry::new();
        let plan = mixed_plan();
        record_synthetic_window(&reg, &plan, 4, 2); // default warmup is 4
        let tuner = PlanTuner::default();
        assert!(tuner.maybe_tune(&reg, &plan, 4).is_none());
        assert!(reg.trace_events(usize::MAX).is_empty(), "no event before warmup");
    }

    #[test]
    fn maybe_tune_rebalances_and_stays_bit_identical() {
        let reg = Registry::new();
        let plan = mixed_plan();
        assert_eq!(plan.block.meta.len(), 16, "one block per row");
        record_synthetic_window(&reg, &plan, 4, 6);
        let tuner = PlanTuner::default();
        let tuned = tuner.maybe_tune(&reg, &plan, 4).expect("skewed cost must apply");
        let t = tuned.tuned.as_ref().expect("annotation attached");
        assert_eq!(t.block_cost.len(), plan.block.meta.len());
        assert!(
            t.predicted_tuned_imbalance
                <= t.predicted_static_imbalance * (1.0 - TuneConfig::default().min_improvement),
            "tuned {} vs static {}",
            t.predicted_tuned_imbalance,
            t.predicted_static_imbalance
        );
        // the decision is on the record
        let evs = reg.trace_events(usize::MAX);
        let tune_ev = evs.iter().find(|e| e.name == "plan_tune").expect("tune event");
        assert_eq!(tune_ev.cat, "tune");
        let args = tune_ev.args.as_ref().unwrap();
        assert_eq!(args.get("applied").and_then(|v| v.as_bool()), Some(true));
        // the layouts genuinely differ, the math does not: bit-for-bit
        let tuned = Arc::new(tuned);
        assert_ne!(
            shard_ranges_for_plan(&plan, 4),
            shard_ranges_for_plan(&tuned, 4),
            "cuts must move"
        );
        let mut rng = Pcg::seed_from(0x7E11);
        let f = 7;
        let x: Vec<f32> = (0..32 * f).map(|_| rng.f32() - 0.5).collect();
        for threads in [1usize, 3, 4] {
            let exec = ParallelBlockLevel::new(threads);
            let want = exec.execute(&plan, &x, f);
            let got = exec.execute(&tuned, &x, f);
            assert_eq!(want.len(), got.len());
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "lane {j} at {threads} threads");
            }
        }
    }

    #[test]
    fn byte_accounting_feeds_the_bandwidth_term() {
        // same skewed window as above, but with the traffic-model bytes
        // the parallel executor now records: the report must carry the
        // measured GB/s, recover the feature width, and print the
        // quantized-storage what-if
        let plan = mixed_plan();
        let deg_bound = plan.block.params.deg_bound();
        let f = 16usize;
        let reg = Registry::new();
        let ranges = shard_ranges_for_plan(&plan, 4);
        let samples: Vec<ShardSample> = ranges
            .iter()
            .map(|r| {
                let (mut dense, mut sparse) = (0u64, 0u64);
                let (mut br, mut bw) = (0u64, 0u64);
                for b in r.clone() {
                    let m = plan.block.meta[b];
                    let split = m.is_split(deg_bound);
                    let nnz = if split {
                        m.split_nzs()
                    } else {
                        m.deg as usize * m.block_rows()
                    } as u64;
                    let dispatch_dense =
                        split || plan.kernels.kernel_for(b) == RowKernel::DenseTiled;
                    let kern = if dispatch_dense {
                        RowKernel::DenseTiled
                    } else {
                        RowKernel::SparseGather
                    };
                    if dispatch_dense {
                        dense += nnz;
                    } else {
                        sparse += nnz;
                    }
                    let t = block_traffic(&m, kern, deg_bound);
                    br += t.bytes_read_with(f, ElemWidths::F32);
                    bw += t.bytes_written_with(f, ElemWidths::F32);
                }
                ShardSample {
                    nnz: dense + sparse,
                    busy_ns: dense + 50 * sparse,
                    dense_nnz: dense,
                    sparse_nnz: sparse,
                    bytes_read: br,
                    bytes_written: bw,
                    ..Default::default()
                }
            })
            .collect();
        for _ in 0..6 {
            reg.record_spmm_shards(&samples);
        }
        let tuner = PlanTuner::default();
        let aggs = reg.shard_aggregates();
        let (report, _) = tuner.analyze(&aggs, &plan, 4).expect("past warmup");

        let total_bytes = plan.traffic.bytes_total(f);
        let total_busy: u64 = samples.iter().map(|s| s.busy_ns).sum();
        assert!(
            (report.achieved_gbps - total_bytes as f64 / total_busy as f64).abs() < 1e-9,
            "gbps {}",
            report.achieved_gbps
        );
        assert!(
            (report.ns_per_byte * report.achieved_gbps - 1.0).abs() < 1e-9,
            "ns/byte is the reciprocal of GB/s (bytes/ns)"
        );
        assert!(
            (report.bytes_per_nnz - plan.traffic.bytes_per_nnz(f)).abs() < 1e-9,
            "measured bytes/nnz {} vs model {}",
            report.bytes_per_nnz,
            plan.traffic.bytes_per_nnz(f)
        );
        // the model is linear in f, so the window's bytes pin f exactly
        // — and the what-if line reports at that width
        assert!(report.whatif.contains("f=16"), "whatif: {}", report.whatif);
        assert!(report.whatif.contains("i8-storage"), "whatif: {}", report.whatif);
        assert!(
            report.to_json().get("whatif").and_then(|v| v.as_str()).is_some(),
            "what-if exported"
        );

        // when a tuned plan comes back, its traffic model must match
        // its (possibly re-derived) kernel schedule
        if let Some(tuned) = tuner.maybe_tune(&reg, &plan, 4) {
            assert_eq!(
                tuned.traffic,
                TrafficModel::derive(&tuned.block, &tuned.kernels),
                "traffic model stale after tune"
            );
        }
    }

    #[test]
    fn uniform_cost_declines_with_a_report() {
        // all-dense graph, busy exactly proportional to nnz: the static
        // cut is already cost-balanced, so the tuner must decline (and
        // say so in the timeline)
        let params = PartitionParams { max_block_warps: 1, max_warp_nzs: 32 };
        let edges: Vec<(u32, u32, f32)> = (0..12u32)
            .flat_map(|r| (0..20u32).map(move |c| (r, c, 1.0)))
            .collect();
        let plan =
            Arc::new(SpmmPlan::build(Csr::from_edges(12, 20, &edges).unwrap(), params));
        let reg = Registry::new();
        let ranges = shard_ranges_for_plan(&plan, 3);
        let samples: Vec<ShardSample> = ranges
            .iter()
            .map(|r| {
                let nnz = (r.len() * 20) as u64;
                ShardSample { nnz, busy_ns: nnz * 3, dense_nnz: nnz, ..Default::default() }
            })
            .collect();
        for _ in 0..5 {
            reg.record_spmm_shards(&samples);
        }
        let tuner = PlanTuner::default();
        assert!(tuner.maybe_tune(&reg, &plan, 3).is_none(), "nothing to improve");
        let evs = reg.trace_events(usize::MAX);
        let ev = evs.iter().find(|e| e.name == "plan_tune").expect("declined is recorded");
        let applied = ev.args.as_ref().unwrap().get("applied").and_then(|v| v.as_bool());
        assert_eq!(applied, Some(false));
    }
}
