//! Zero-dependency substrates.
//!
//! The build environment is fully offline and only the `xla` crate's
//! dependency closure is vendored, so the usual ecosystem crates
//! (clap, serde, rand, criterion, tokio, proptest) are unavailable.
//! This module provides the minimal, well-tested replacements the rest
//! of the system needs:
//!
//! * [`rng`] — PCG-XSH-RR 64/32 PRNG with distributions (uniform,
//!   normal, zipf/power-law) used by graph generators and property tests.
//! * [`json`] — minimal JSON value model, parser, and writer (configs,
//!   graph specs, benchmark outputs).
//! * [`npy`] — NumPy `.npy` v1.0 reader/writer for `f32`/`i32`/`i64`
//!   C-order arrays (tensor interchange with the Python compile path).
//! * [`cli`] — declarative flag parser for the `accel-gcn` binary.
//! * [`stats`] — online moments, percentiles, histograms.
//! * [`bench`] — timing harness + table/CSV reporters (criterion stand-in).
//! * [`threadpool`] — fixed worker pool over std mpsc channels.
//! * [`proptest`] — seeded property-test driver (report failing seed).

pub mod rng;
pub mod json;
pub mod npy;
pub mod cli;
pub mod stats;
pub mod bench;
pub mod threadpool;
pub mod proptest;
