//! NumPy `.npy` v1.0 reader/writer for C-order arrays.
//!
//! Tensor interchange between the Rust preprocessing path (which emits
//! the BELL layout of a partitioned graph) and the Python compile path
//! (which consumes shapes/golden tensors in pytest and AOT lowering).
//! Supports the dtypes we exchange: `f32` (`<f4`), `i32` (`<i4`),
//! `i64` (`<i8`).

use anyhow::{anyhow, bail, Context, Result};
use std::fs;
use std::io::{Read, Write};
use std::path::Path;

/// Element types supported by the interchange format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    I64,
}

impl Dtype {
    pub fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::I32 => "<i4",
            Dtype::I64 => "<i8",
        }
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I64 => 8,
        }
    }

    fn from_descr(d: &str) -> Result<Dtype> {
        match d {
            "<f4" | "|f4" | "f4" => Ok(Dtype::F32),
            "<i4" | "|i4" | "i4" => Ok(Dtype::I32),
            "<i8" | "|i8" | "i8" => Ok(Dtype::I64),
            other => bail!("unsupported npy dtype `{other}`"),
        }
    }
}

/// An n-dimensional array in C order.
#[derive(Clone, Debug, PartialEq)]
pub struct Npy {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// Raw little-endian element bytes, C order.
    pub data: Vec<u8>,
}

impl Npy {
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Npy {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/value mismatch");
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Npy { dtype: Dtype::F32, shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Npy {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/value mismatch");
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Npy { dtype: Dtype::I32, shape: shape.to_vec(), data }
    }

    pub fn from_i64(shape: &[usize], values: &[i64]) -> Npy {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/value mismatch");
        let mut data = Vec::with_capacity(values.len() * 8);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Npy { dtype: Dtype::I64, shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("dtype is {:?}, not f32", self.dtype);
        }
        Ok(self.data.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 {
            bail!("dtype is {:?}, not i32", self.dtype);
        }
        Ok(self.data.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn to_i64(&self) -> Result<Vec<i64>> {
        if self.dtype != Dtype::I64 {
            bail!("dtype is {:?}, not i64", self.dtype);
        }
        Ok(self.data.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Serialize to `.npy` v1.0 bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let shape_str = match self.shape.len() {
            1 => format!("({},)", self.shape[0]),
            _ => format!(
                "({})",
                self.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
            self.dtype.descr(),
            shape_str
        );
        // pad so that magic(6)+ver(2)+hlen(2)+header is a multiple of 64
        let unpadded = 10 + header.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        header.extend(std::iter::repeat(' ').take(pad));
        header.push('\n');

        let mut out = Vec::with_capacity(10 + header.len() + self.data.len());
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&self.data);
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Parse `.npy` bytes (v1.0 or v2.0 headers).
    pub fn from_bytes(bytes: &[u8]) -> Result<Npy> {
        if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
            bail!("not an npy file");
        }
        let major = bytes[6];
        let (header_len, header_start) = match major {
            1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
            2 => (
                u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
                12,
            ),
            v => bail!("unsupported npy version {v}"),
        };
        let header = std::str::from_utf8(
            bytes
                .get(header_start..header_start + header_len)
                .ok_or_else(|| anyhow!("truncated npy header"))?,
        )?;
        let descr = extract_quoted(header, "descr").ok_or_else(|| anyhow!("no descr in header"))?;
        let dtype = Dtype::from_descr(&descr)?;
        if header.contains("'fortran_order': True") {
            bail!("fortran-order npy not supported");
        }
        let shape = extract_shape(header)?;
        let n: usize = shape.iter().product();
        let data_start = header_start + header_len;
        let need = n * dtype.size();
        let data = bytes
            .get(data_start..data_start + need)
            .ok_or_else(|| anyhow!("npy data truncated: need {need} bytes"))?
            .to_vec();
        Ok(Npy { dtype, shape, data })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Npy> {
        let path = path.as_ref();
        let mut bytes = Vec::new();
        fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes).with_context(|| format!("parse {path:?}"))
    }
}

fn extract_quoted(header: &str, key: &str) -> Option<String> {
    let idx = header.find(&format!("'{key}'"))?;
    let rest = &header[idx..];
    let colon = rest.find(':')?;
    let rest = rest[colon + 1..].trim_start();
    let quote = rest.chars().next()?;
    if quote != '\'' && quote != '"' {
        return None;
    }
    let end = rest[1..].find(quote)?;
    Some(rest[1..1 + end].to_string())
}

fn extract_shape(header: &str) -> Result<Vec<usize>> {
    let idx = header.find("'shape'").ok_or_else(|| anyhow!("no shape in header"))?;
    let open = header[idx..].find('(').ok_or_else(|| anyhow!("no shape tuple"))? + idx;
    let close = header[open..].find(')').ok_or_else(|| anyhow!("unclosed shape tuple"))? + open;
    let inner = &header[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(part.parse::<usize>().map_err(|e| anyhow!("bad shape dim `{part}`: {e}"))?);
    }
    Ok(shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let a = Npy::from_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
        let b = Npy::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.to_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
    }

    #[test]
    fn roundtrip_i32_1d() {
        let a = Npy::from_i32(&[4], &[-1, 0, 7, i32::MAX]);
        let b = Npy::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.shape, vec![4]);
        assert_eq!(b.to_i32().unwrap(), vec![-1, 0, 7, i32::MAX]);
    }

    #[test]
    fn roundtrip_i64_scalar_dim() {
        let a = Npy::from_i64(&[1], &[1 << 40]);
        let b = Npy::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.to_i64().unwrap(), vec![1 << 40]);
    }

    #[test]
    fn header_is_64_aligned() {
        let a = Npy::from_f32(&[3], &[0.0, 1.0, 2.0]);
        let bytes = a.to_bytes();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }

    #[test]
    fn wrong_dtype_errors() {
        let a = Npy::from_f32(&[1], &[1.0]);
        assert!(a.to_i32().is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("accel_gcn_npy_test");
        let path = dir.join("t.npy");
        let a = Npy::from_i32(&[2, 2], &[1, 2, 3, 4]);
        a.save(&path).unwrap();
        let b = Npy::load(&path).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(Npy::from_bytes(b"nope").is_err());
    }
}
