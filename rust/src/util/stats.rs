//! Online statistics, percentiles, and histograms.
//!
//! Used by the serving coordinator (latency tracking), the GPU simulator
//! (workload-balance measurements), and the bench harness.

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Coefficient of variation — the paper's workload-imbalance signal.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev() / self.mean
        }
    }
}

/// Percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Geometric mean — used for cross-graph speedup aggregation exactly as
/// speedup summaries in the paper's evaluation are (ratios compose
/// multiplicatively).
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    (samples.iter().map(|x| x.ln()).sum::<f64>() / samples.len() as f64).exp()
}

/// Fixed-bucket histogram over `[lo, hi)` with `buckets` equal bins plus
/// under/overflow. Used for the Fig. 2 degree histogram and latency
/// distributions.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram { lo, hi, counts: vec![0; buckets], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64) as usize;
            let last = self.counts.len() - 1;
            self.counts[idx.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render as an ASCII bar chart (log-scaled bars), one bucket per line.
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1) as f64;
        let bucket_w = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let lo = self.lo + i as f64 * bucket_w;
            let hi = lo + bucket_w;
            let bar_len = if c == 0 {
                0
            } else {
                (((c as f64).ln_1p() / max.ln_1p()) * width as f64).ceil() as usize
            };
            out.push_str(&format!(
                "[{:>10.1}, {:>10.1}) {:>9} |{}\n",
                lo,
                hi,
                c,
                "#".repeat(bar_len)
            ));
        }
        if self.overflow > 0 {
            out.push_str(&format!("[{:>10.1},        inf) {:>9}\n", self.hi, self.overflow));
        }
        out
    }
}

/// Logarithmically-bucketed histogram (powers of two), the natural view
/// for power-law degree distributions (paper Fig. 2 uses log-x buckets).
#[derive(Clone, Debug, Default)]
pub struct Log2Histogram {
    /// counts[i] = number of samples with floor(log2(max(x,1))) == i
    pub counts: Vec<u64>,
    pub zeros: u64,
}

impl Log2Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: u64) {
        if x == 0 {
            self.zeros += 1;
            return;
        }
        let b = 63 - x.leading_zeros() as usize;
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
    }

    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1) as f64;
        let mut out = String::new();
        if self.zeros > 0 {
            out.push_str(&format!("{:>12} {:>9}\n", "deg=0", self.zeros));
        }
        for (i, &c) in self.counts.iter().enumerate() {
            let lo = 1u64 << i;
            let hi = (1u64 << (i + 1)) - 1;
            let bar = if c == 0 {
                0
            } else {
                (((c as f64).ln_1p() / max.ln_1p()) * width as f64).ceil() as usize
            };
            out.push_str(&format!("[{:>6},{:>7}] {:>9} |{}\n", lo, hi, c, "#".repeat(bar)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 51.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 0.5]);
        assert!((g - 1.0).abs() < 1e-12);
        let g2 = geomean(&[1.17, 1.17, 1.17]);
        assert!((g2 - 1.17).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.counts, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
        assert!(h.ascii(20).lines().count() >= 10);
    }

    #[test]
    fn log2_histogram() {
        let mut h = Log2Histogram::new();
        for d in [0u64, 1, 1, 2, 3, 4, 66, 1024] {
            h.push(d);
        }
        assert_eq!(h.zeros, 1);
        assert_eq!(h.counts[0], 2); // 1,1
        assert_eq!(h.counts[1], 2); // 2,3
        assert_eq!(h.counts[2], 1); // 4
        assert_eq!(h.counts[6], 1); // 66
        assert_eq!(h.counts[10], 1); // 1024
    }

    #[test]
    fn cv_zero_for_uniform() {
        let mut s = OnlineStats::new();
        for _ in 0..10 {
            s.push(3.0);
        }
        assert!(s.cv().abs() < 1e-12);
    }
}
