//! Minimal seeded property-test driver (proptest stand-in).
//!
//! Runs a property over `cases` pseudo-random inputs derived from a base
//! seed; on failure, reports the failing case seed so the run can be
//! reproduced exactly with `check_one`. No shrinking — inputs are kept
//! small by construction instead.

use super::rng::Pcg;

/// Run `property(rng)` for `cases` seeds derived from `base_seed`.
/// The property should panic (e.g. via `assert!`) on violation.
pub fn check<F: Fn(&mut Pcg)>(name: &str, base_seed: u64, cases: usize, property: F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(case as u64);
        let mut rng = Pcg::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with util::proptest::check_one(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_one<F: Fn(&mut Pcg)>(_name: &str, seed: u64, property: F) {
    let mut rng = Pcg::seed_from(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("trivial", 1, 50, |rng| {
            let x = rng.next_below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            check("fails", 2, 50, |rng| {
                let x = rng.next_below(10);
                assert!(x < 5, "x={x}");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("fails"), "{msg}");
    }

    #[test]
    fn check_one_reproduces() {
        // find a failing seed, then confirm check_one hits the same failure
        let mut failing = None;
        for case in 0..200u64 {
            let seed = 3u64.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(case);
            let mut rng = Pcg::seed_from(seed);
            if rng.next_below(10) >= 5 {
                failing = Some(seed);
                break;
            }
        }
        let seed = failing.expect("should find a failing case");
        let r = std::panic::catch_unwind(|| {
            check_one("repro", seed, |rng| {
                assert!(rng.next_below(10) < 5);
            });
        });
        assert!(r.is_err());
    }
}
