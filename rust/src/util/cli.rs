//! Declarative command-line flag parsing (clap stand-in).
//!
//! Grammar: `accel-gcn <subcommand> [--key value]... [--flag]...`.
//! Each subcommand declares its options; unknown flags are hard errors so
//! typos never silently fall back to defaults in benchmark runs.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without program name / subcommand) against the
    /// declared option names. `value_opts` take one argument;
    /// `flag_opts` are boolean.
    pub fn parse(
        argv: &[String],
        value_opts: &[&str],
        flag_opts: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(name) = arg.strip_prefix("--") {
                // allow --key=value
                if let Some((k, v)) = name.split_once('=') {
                    if value_opts.contains(&k) {
                        out.values.insert(k.to_string(), v.to_string());
                        i += 1;
                        continue;
                    }
                    bail!("unknown option --{k}");
                }
                if value_opts.contains(&name) {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("option --{name} requires a value");
                    };
                    out.values.insert(name.to_string(), v.clone());
                    i += 2;
                } else if flag_opts.contains(&name) {
                    out.flags.push(name.to_string());
                    i += 1;
                } else {
                    bail!("unknown option --{name}");
                }
            } else {
                out.positional.push(arg.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: bad integer `{v}`: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: bad integer `{v}`: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: bad number `{v}`: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Parse a comma-separated list of integers, e.g. `--coldims 16,32,64`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("--{key}: bad entry `{p}`: {e}"))
                })
                .collect(),
        }
    }

    /// Parse a comma-separated list of strings.
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse(
            &argv(&["--graph", "collab", "--verbose", "--steps", "300"]),
            &["graph", "steps"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get("graph"), Some("collab"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 300);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&argv(&["--graph=pubmed"]), &["graph"], &[]).unwrap();
        assert_eq!(a.get("graph"), Some("pubmed"));
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Args::parse(&argv(&["--bogus"]), &["graph"], &["verbose"]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--graph"]), &["graph"], &[]).is_err());
    }

    #[test]
    fn lists() {
        let a = Args::parse(&argv(&["--coldims", "16, 32,64"]), &["coldims"], &[]).unwrap();
        assert_eq!(a.usize_list_or("coldims", &[]).unwrap(), vec![16, 32, 64]);
        let b = Args::parse(&argv(&[]), &["coldims"], &[]).unwrap();
        assert_eq!(b.usize_list_or("coldims", &[8]).unwrap(), vec![8]);
    }

    #[test]
    fn positional_collected() {
        let a = Args::parse(&argv(&["run", "--graph", "am", "fast"]), &["graph"], &[]).unwrap();
        assert_eq!(a.positional(), &["run".to_string(), "fast".to_string()]);
    }
}
