//! Fixed-size worker pool over std mpsc channels (tokio stand-in).
//!
//! The serving coordinator and the parallel simulator both run on this:
//! jobs are boxed closures; `scope`-style joining is provided by
//! [`ThreadPool::run_all`] which blocks until every submitted job in the
//! batch completes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    pending: AtomicUsize,
    done: Mutex<()>,
    cv: Condvar,
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    /// Spawn `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            pending: AtomicUsize::new(0),
            done: Mutex::new(()),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("accel-gcn-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                if shared.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                                    let _g = shared.done.lock().unwrap();
                                    shared.cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, shared }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    /// Submit an already-boxed job without re-boxing it.
    fn submit_boxed(&self, job: Job) {
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let mut g = self.shared.done.lock().unwrap();
        while self.shared.pending.load(Ordering::Acquire) != 0 {
            g = self.shared.cv.wait(g).unwrap();
        }
    }

    /// Run a batch of **borrowing** jobs to completion — the zero-copy
    /// twin of [`ThreadPool::run_all`]. Jobs may capture references to
    /// the caller's stack frame (`&[f32]` inputs, disjoint `&mut`
    /// output spans), which is what lets the SpMM hot path skip the
    /// `Arc<Vec<f32>>` input copy the `'static` job bound used to force.
    ///
    /// Blocks until every submitted job has finished, so no borrow
    /// escapes the caller's frame.
    ///
    /// # Safety (internal)
    ///
    /// The implementation erases the `'env` lifetime to satisfy the
    /// worker channel's `'static` bound. This is sound because:
    /// * every job is submitted before `wait_idle`, and `wait_idle`
    ///   returns only after the pending count — incremented at submit,
    ///   decremented after each job runs — drops to zero, so all
    ///   borrows are dead before this function returns;
    /// * if a job panics, the worker thread dies without decrementing
    ///   the count and this function blocks forever — a hang, never a
    ///   dangling borrow (same failure mode `run_all` already has).
    pub fn scoped_run<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        for job in jobs {
            // SAFETY: see above — the job cannot outlive this call.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            self.submit_boxed(job);
        }
        self.wait_idle();
    }

    /// Run a batch of independent jobs to completion, collecting results
    /// in input order.
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let results: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            self.submit(move || {
                let r = job();
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job completed"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A sensible default parallelism for this machine.
pub fn default_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn run_all_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn reusable_after_wait() {
        let pool = ThreadPool::new(2);
        let a = pool.run_all(vec![|| 1, || 2]);
        let b = pool.run_all(vec![|| 3, || 4]);
        assert_eq!((a, b), (vec![1, 2], vec![3, 4]));
    }

    #[test]
    fn scoped_run_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let input: Vec<u64> = (0..96).collect();
        let mut out = vec![0u64; 96];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(32)
                .enumerate()
                .map(|(i, chunk)| {
                    let src = &input[i * 32..(i + 1) * 32];
                    Box::new(move || {
                        for (d, s) in chunk.iter_mut().zip(src) {
                            *d = s * 3;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped_run(jobs);
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
    }

    #[test]
    fn scoped_run_empty_and_reuse() {
        let pool = ThreadPool::new(2);
        pool.scoped_run(Vec::new()); // no jobs: returns immediately
        let x = AtomicU64::new(0);
        pool.scoped_run(vec![
            Box::new(|| {
                x.fetch_add(1, Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>,
            Box::new(|| {
                x.fetch_add(2, Ordering::Relaxed);
            }),
        ]);
        assert_eq!(x.load(Ordering::Relaxed), 3);
        // pool still usable by run_all afterwards
        assert_eq!(pool.run_all(vec![|| 7]), vec![7]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }
}
