//! PCG-XSH-RR 64/32: small, fast, statistically solid PRNG.
//!
//! Deterministic across platforms — graph generation must produce the
//! same graph for the same `(dataset, seed)` on every machine so that
//! benchmark numbers are comparable and the Python mirror
//! (`python/compile/layout.py`) can cross-check golden files.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed on the default stream.
    pub fn seed_from(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire rejection-free-ish widening method).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        // widening multiply; slight modulo bias is irrelevant for our use
        // but we reject the short range to keep distribution tests honest.
        let mut m = (self.next_u32() as u64).wrapping_mul(bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                m = (self.next_u32() as u64).wrapping_mul(bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.next_below((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Sample from a (truncated) power law with exponent `alpha > 1` over
    /// `[xmin, xmax]` via inverse transform. Used to draw node degrees
    /// with the heavy tail the paper's graphs exhibit (Fig. 2).
    pub fn power_law(&mut self, alpha: f64, xmin: f64, xmax: f64) -> f64 {
        debug_assert!(alpha > 1.0 && xmax > xmin && xmin > 0.0);
        let u = self.f64();
        let a = 1.0 - alpha;
        let lo = xmin.powf(a);
        let hi = xmax.powf(a);
        (lo + u * (hi - lo)).powf(1.0 / a)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.range(0, i + 1);
            slice.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.range(0, slice.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::seed_from(42);
        let mut b = Pcg::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::seed_from(1);
        let mut b = Pcg::seed_from(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg::seed_from(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg::seed_from(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seed_from(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn power_law_bounds_and_tail() {
        let mut rng = Pcg::seed_from(5);
        let mut max = 0.0f64;
        for _ in 0..10_000 {
            let v = rng.power_law(2.1, 1.0, 1000.0);
            assert!((1.0..=1000.0).contains(&v));
            max = max.max(v);
        }
        // heavy tail: with 10k draws at alpha=2.1 we should see >100 at least once
        assert!(max > 100.0, "max={max}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seed_from(9);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
