//! Timing harness + table/CSV reporting (criterion stand-in).
//!
//! `cargo bench` runs `rust/benches/paper_benches.rs` (harness = false)
//! which uses this module to time kernels/simulations, print
//! paper-style tables, and write CSV series under `results/`.

use super::stats;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;
use std::time::Instant;

/// One timed measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// wall-clock per iteration, seconds
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// Time `f`, autoscaling iteration count to reach ~`target_secs` of total
/// measurement after `warmup` calls. Returns per-iteration seconds.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, target_secs: f64, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    // estimate single-iteration cost
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / est).ceil() as usize).clamp(1, 10_000);
    // take up to 20 batched samples
    let batches = iters.min(20);
    let per_batch = (iters / batches).max(1);
    let mut samples = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() / per_batch as f64);
    }
    Measurement { name: name.to_string(), samples }
}

/// Pretty fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String| {
            for w in &widths {
                let _ = write!(out, "+{}", "-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        line(&mut out);
        for (c, h) in self.headers.iter().enumerate() {
            let _ = write!(out, "| {:width$} ", h, width = widths[c]);
        }
        out.push_str("|\n");
        line(&mut out);
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                let _ = write!(out, "| {:width$} ", cell, width = widths[c]);
            }
            out.push_str("|\n");
        }
        line(&mut out);
        out
    }
}

/// CSV writer for figure series (one file per paper figure).
pub struct Csv {
    buf: String,
    ncol: usize,
}

impl Csv {
    pub fn new(headers: &[&str]) -> Self {
        let mut buf = String::new();
        buf.push_str(&headers.join(","));
        buf.push('\n');
        Csv { buf, ncol: headers.len() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.ncol, "csv arity mismatch");
        // quote cells containing separators
        let encoded: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        self.buf.push_str(&encoded.join(","));
        self.buf.push('\n');
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, &self.buf)?;
        Ok(())
    }

    pub fn as_str(&self) -> &str {
        &self.buf
    }
}

/// Human-readable duration.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_returns_samples() {
        let mut x = 0u64;
        let m = time_fn("noop", 1, 0.01, || {
            x = x.wrapping_add(1);
        });
        assert!(!m.samples.is_empty());
        assert!(m.mean() >= 0.0);
        assert!(m.min() <= m.mean() * 1.0001);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["graph", "speedup"]);
        t.row(vec!["collab".into(), "1.17".into()]);
        t.row(vec!["am".into(), "1.45".into()]);
        let s = t.render();
        assert!(s.contains("| graph"));
        assert!(s.contains("| collab"));
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn csv_escapes() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["x,y".to_string(), "plain".to_string()]);
        assert_eq!(c.as_str(), "a,b\n\"x,y\",plain\n");
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(3e-9).contains("ns"));
        assert!(fmt_secs(3e-6).contains("µs"));
        assert!(fmt_secs(3e-3).contains("ms"));
        assert!(fmt_secs(3.0).contains(" s"));
    }
}
