//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for configs, graph specs (consumed by `python/compile/aot.py`),
//! and benchmark result files. Covers the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII specs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministically
/// ordered (golden-file friendly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if not an object (programming error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, Some(2), 0);
        s
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_value(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline(out, indent, depth);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(item, out, indent, depth + 1);
            }
            if !map.is_empty() {
                newline(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(w * depth));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected `{}` at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number `{text}`: {e}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected , or }} got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        let inner = &v.get("a").unwrap().as_arr().unwrap()[2];
        assert_eq!(inner.get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn build_and_reparse() {
        let mut obj = Json::obj();
        obj.set("nodes", 235868usize).set("name", "Collab").set("scaled", true);
        obj.set("widths", vec![4usize, 8, 16]);
        let text = obj.to_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_usize("nodes").unwrap(), 235868);
        assert_eq!(back.req_str("name").unwrap(), "Collab");
        assert_eq!(back.req_arr("widths").unwrap().len(), 3);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""\u0041b""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Ab");
    }
}
