//! Warp-level partitioning — the GNNAdvisor-style baseline (Fig. 3(b),
//! Fig. 7's comparison target).
//!
//! Every row is chopped into fixed-size neighbour groups (NG) of
//! `group_size` nonzeros; each group is one warp's workload with its own
//! `{row, col(loc), len}` metadata record (96 bits padded to 128). The
//! fixed group size is the source of the imbalance the paper attacks:
//! a residual group of 1 nonzero occupies a whole warp, and each warp
//! loops over the dense column dimension alone (no combined-warp
//! coalescing).

use super::metadata::{MetadataFootprint, WARP_META_BYTES};
use crate::graph::csr::Csr;

/// One neighbour-group = one warp workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NzGroup {
    pub row: u32,
    /// Starting nonzero index (paper's `col` field points at the CSR
    /// position of the group's first nonzero).
    pub loc: u32,
    pub len: u32,
}

/// The warp-level partition of a graph.
#[derive(Clone, Debug)]
pub struct WarpPartition {
    pub group_size: usize,
    pub groups: Vec<NzGroup>,
    pub n_rows: usize,
    pub nnz: usize,
}

impl WarpPartition {
    /// GNNAdvisor's default neighbour-group size.
    pub const DEFAULT_GROUP_SIZE: usize = 32;

    /// Chop each row into `group_size` chunks. Works on any CSR (sorted
    /// or not); the paper's Fig. 7 baseline applies it to the original
    /// row order.
    pub fn build(csr: &Csr, group_size: usize) -> WarpPartition {
        assert!(group_size >= 1);
        let mut groups = Vec::new();
        for r in 0..csr.n_rows {
            let start = csr.row_ptr[r];
            let deg = csr.degree(r);
            let mut off = 0usize;
            while off < deg {
                let len = (deg - off).min(group_size);
                groups.push(NzGroup { row: r as u32, loc: (start + off) as u32, len: len as u32 });
                off += len;
            }
        }
        WarpPartition { group_size, groups, n_rows: csr.n_rows, nnz: csr.nnz() }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Metadata bytes: one padded 128-bit record per group (Fig. 3(b)).
    pub fn metadata_bytes(&self) -> usize {
        self.groups.len() * WARP_META_BYTES
    }

    /// Footprint comparison helper against a block partition.
    pub fn footprint_vs(&self, block_blocks: usize) -> MetadataFootprint {
        MetadataFootprint::new(block_blocks, self.groups.len())
    }

    /// Warp-load imbalance: coefficient of variation of group lengths.
    /// Fixed-size grouping leaves the tail group of every row short —
    /// on power-law graphs this is the paper's Fig. 4(d) effect.
    pub fn load_cv(&self) -> f64 {
        let mut stats = crate::util::stats::OnlineStats::new();
        for g in &self.groups {
            stats.push(g.len as f64);
        }
        stats.cv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    #[test]
    fn fig3b_example() {
        // Fig. 3(b): warps manage ≤ 2 nzs; row0 deg 2, row1 deg 4, row2 deg 2
        let csr = Csr::from_edges(
            3,
            5,
            &[
                (0, 0, 1.0),
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (1, 3, 1.0),
                (1, 4, 1.0),
                (2, 1, 1.0),
                (2, 3, 1.0),
            ],
        )
        .unwrap();
        let wp = WarpPartition::build(&csr, 2);
        // WP-1: row0 loc0 len2; WP-2/WP-3: row1; WP-4: row2
        assert_eq!(wp.groups.len(), 4);
        assert_eq!(wp.groups[0], NzGroup { row: 0, loc: 0, len: 2 });
        assert_eq!(wp.groups[1], NzGroup { row: 1, loc: 2, len: 2 });
        assert_eq!(wp.groups[2], NzGroup { row: 1, loc: 4, len: 2 });
        assert_eq!(wp.groups[3], NzGroup { row: 2, loc: 6, len: 2 });
        // cumulative metadata: 4 × 128 bits (96 + padding), Fig. 3 text
        assert_eq!(wp.metadata_bytes(), 64);
    }

    #[test]
    fn residual_groups_short() {
        let csr = Csr::from_edges(1, 5, &[(0, 0, 1.0), (0, 1, 1.0), (0, 2, 1.0)]).unwrap();
        let wp = WarpPartition::build(&csr, 2);
        assert_eq!(wp.groups.len(), 2);
        assert_eq!(wp.groups[1].len, 1); // the tail group is half idle
    }

    #[test]
    fn zero_rows_emit_nothing() {
        let csr = Csr::from_edges(3, 3, &[(1, 0, 1.0)]).unwrap();
        let wp = WarpPartition::build(&csr, 4);
        assert_eq!(wp.n_groups(), 1);
    }

    #[test]
    fn prop_groups_cover_exactly() {
        proptest::check("warp_partition_coverage", 0xAA01, 30, |rng| {
            let n = rng.range(1, 100);
            let mut edges = Vec::new();
            for r in 0..n {
                for _ in 0..rng.range(0, 12) {
                    edges.push((r as u32, rng.range(0, n) as u32, 1.0));
                }
            }
            let csr = Csr::from_edges(n, n, &edges).unwrap();
            let gs = *rng.choose(&[1usize, 2, 4, 32]);
            let wp = WarpPartition::build(&csr, gs);
            let mut covered = vec![0u8; csr.nnz()];
            for g in &wp.groups {
                assert!(g.len >= 1 && g.len as usize <= gs);
                let row = g.row as usize;
                assert!((g.loc as usize) >= csr.row_ptr[row]);
                assert!((g.loc + g.len) as usize <= csr.row_ptr[row + 1]);
                for i in g.loc..g.loc + g.len {
                    covered[i as usize] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1));
        });
    }

    #[test]
    fn imbalance_grows_with_power_law() {
        // on a power-law graph, fixed-size groups are less balanced than
        // on a regular graph — the motivation for block-level partition
        let mut rng = Pcg::seed_from(11);
        let n = 400;
        let pl_degs = crate::graph::generator::degree_sequence(
            crate::graph::generator::DegreeModel::PowerLaw { alpha: 2.0, dmax_frac: 0.3 },
            n,
            n * 6,
            &mut rng,
        );
        let pl = crate::graph::generator::from_degree_sequence(n, &pl_degs, &mut rng);
        let reg_degs = vec![6usize; n];
        let reg = crate::graph::generator::from_degree_sequence(n, &reg_degs, &mut rng);
        let wp_pl = WarpPartition::build(&pl, 32);
        let wp_reg = WarpPartition::build(&reg, 32);
        assert!(
            wp_pl.load_cv() > wp_reg.load_cv(),
            "pl cv={} reg cv={}",
            wp_pl.load_cv(),
            wp_reg.load_cv()
        );
    }
}
