//! BELL (Bucketed-ELL) export — the TPU-facing product of the paper's
//! preprocessing (DESIGN.md §Hardware-Adaptation).
//!
//! The block-level partition turns the graph into a list of warp tasks
//! with uniform per-block nonzero counts. For the Pallas kernel these
//! tasks are regrouped into **buckets of uniform padded width** (powers
//! of two up to `max_warp_nzs`, plus one bucket per split-chunk width):
//! bucket `b` holds dense `[rows_b, W_b]` column-index and value tiles
//! plus a `[rows_b]` destination-row vector. The kernel computes each
//! task's partial sum as a dense gather+multiply and the surrounding JAX
//! code scatter-adds partials by destination row — the moral equivalent
//! of the paper's shared-memory/global atomics.
//!
//! A Python mirror lives in `python/compile/layout.py`; golden-file
//! round-trip tests keep the two in sync.

use super::block_level::BlockPartition;
use crate::graph::csr::Csr;
use crate::util::json::Json;
use crate::util::npy::Npy;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Rows in every bucket are padded to a multiple of this (TPU sublane
/// tile; also keeps shapes friendly for the simulator's row tiles).
pub const ROW_TILE: usize = 8;

/// One uniform-width bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct BellBucket {
    /// Padded nonzero width (power of two).
    pub width: usize,
    /// Live task rows (before padding).
    pub rows: usize,
    /// `rows` rounded up to a multiple of [`ROW_TILE`].
    pub padded_rows: usize,
    /// `[padded_rows × width]` column indices; padding points at column 0.
    pub cols: Vec<i32>,
    /// `[padded_rows × width]` values; padding is 0.0 so it adds nothing.
    pub vals: Vec<f32>,
    /// `[padded_rows]` destination (degree-sorted) row ids; padding rows
    /// carry 0 with all-zero values.
    pub out_row: Vec<i32>,
}

/// The full layout of one partitioned graph.
#[derive(Clone, Debug, PartialEq)]
pub struct BellLayout {
    /// Output rows (degree-sorted domain).
    pub n_rows: usize,
    /// Columns of the sparse matrix = rows of the dense `X`.
    pub n_cols: usize,
    pub nnz: usize,
    /// Non-empty buckets, ascending width.
    pub buckets: Vec<BellBucket>,
}

fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

impl BellLayout {
    /// Build from a block partition over the degree-sorted CSR.
    pub fn build(sorted: &Csr, bp: &BlockPartition) -> BellLayout {
        // group tasks by pow2-rounded width
        let mut groups: BTreeMap<usize, Vec<(u32, usize, usize)>> = BTreeMap::new();
        for t in bp.warp_tasks() {
            let w = next_pow2(t.nz_len.max(1));
            groups.entry(w).or_default().push((t.sorted_row, t.nz_start, t.nz_len));
        }
        let mut buckets = Vec::with_capacity(groups.len());
        for (width, tasks) in groups {
            let rows = tasks.len();
            let padded_rows = rows.div_ceil(ROW_TILE) * ROW_TILE;
            let mut cols = vec![0i32; padded_rows * width];
            let mut vals = vec![0f32; padded_rows * width];
            let mut out_row = vec![0i32; padded_rows];
            for (i, (sorted_row, nz_start, nz_len)) in tasks.into_iter().enumerate() {
                out_row[i] = sorted_row as i32;
                for k in 0..nz_len {
                    cols[i * width + k] = sorted.col_idx[nz_start + k] as i32;
                    vals[i * width + k] = sorted.vals[nz_start + k];
                }
            }
            buckets.push(BellBucket { width, rows, padded_rows, cols, vals, out_row });
        }
        BellLayout { n_rows: sorted.n_rows, n_cols: sorted.n_cols, nnz: sorted.nnz(), buckets }
    }

    /// Merge buckets with fewer than `min_rows` live tasks into the next
    /// wider bucket (padding their tasks to the wider width). Fewer
    /// buckets = fewer Pallas kernel launches per aggregation in the AOT
    /// graph (SS Perf, L2): the widest bucket is never merged away, and
    /// numerics are unchanged since padding slots carry zero values.
    pub fn coalesce(mut self, min_rows: usize) -> BellLayout {
        let mut i = 0;
        while i + 1 < self.buckets.len() {
            if self.buckets[i].rows < min_rows {
                let src = self.buckets.remove(i);
                let dst = &mut self.buckets[i];
                let (sw, dw) = (src.width, dst.width);
                debug_assert!(sw < dw);
                // append src tasks, re-padded to dst width
                let mut cols = Vec::with_capacity((dst.rows + src.rows) * dw);
                let mut vals = Vec::with_capacity((dst.rows + src.rows) * dw);
                let mut out_row = Vec::with_capacity(dst.rows + src.rows);
                for r in 0..dst.rows {
                    cols.extend_from_slice(&dst.cols[r * dw..(r + 1) * dw]);
                    vals.extend_from_slice(&dst.vals[r * dw..(r + 1) * dw]);
                    out_row.push(dst.out_row[r]);
                }
                for r in 0..src.rows {
                    cols.extend_from_slice(&src.cols[r * sw..(r + 1) * sw]);
                    cols.extend(std::iter::repeat(0).take(dw - sw));
                    vals.extend_from_slice(&src.vals[r * sw..(r + 1) * sw]);
                    vals.extend(std::iter::repeat(0.0).take(dw - sw));
                    out_row.push(src.out_row[r]);
                }
                let rows = dst.rows + src.rows;
                let padded_rows = rows.div_ceil(ROW_TILE) * ROW_TILE;
                cols.resize(padded_rows * dw, 0);
                vals.resize(padded_rows * dw, 0.0);
                out_row.resize(padded_rows, 0);
                *dst = BellBucket { width: dw, rows, padded_rows, cols, vals, out_row };
                // stay at i: the merged bucket may still be under min_rows
            } else {
                i += 1;
            }
        }
        self
    }

    /// Total padded slots across buckets (the kernel's FLOP volume).
    pub fn padded_nnz(&self) -> usize {
        self.buckets.iter().map(|b| b.padded_rows * b.width).sum()
    }

    /// Padding overhead = padded / real nonzeros.
    pub fn padding_overhead(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        self.padded_nnz() as f64 / self.nnz as f64
    }

    /// Reference execution of the layout: gather + multiply + scatter-add,
    /// exactly what the Pallas kernel + segment-sum perform. `x` is
    /// `[n_cols × f]` row-major; the result is in the **sorted** row
    /// domain.
    pub fn execute(&self, x: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.n_cols * f, "X shape mismatch");
        let mut y = vec![0f32; self.n_rows * f];
        for b in &self.buckets {
            for i in 0..b.padded_rows {
                let dst = b.out_row[i] as usize;
                let yrow = &mut y[dst * f..(dst + 1) * f];
                for k in 0..b.width {
                    let v = b.vals[i * b.width + k];
                    if v != 0.0 {
                        let c = b.cols[i * b.width + k] as usize;
                        let xrow = &x[c * f..(c + 1) * f];
                        for j in 0..f {
                            yrow[j] += v * xrow[j];
                        }
                    }
                }
            }
        }
        y
    }

    /// JSON spec consumed by `python/compile/aot.py` (shapes only).
    pub fn spec(&self) -> Json {
        let mut spec = Json::obj();
        spec.set("n_rows", self.n_rows);
        spec.set("n_cols", self.n_cols);
        spec.set("nnz", self.nnz);
        spec.set("row_tile", ROW_TILE);
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .map(|b| {
                let mut o = Json::obj();
                o.set("width", b.width).set("rows", b.rows).set("padded_rows", b.padded_rows);
                o
            })
            .collect();
        spec.set("buckets", Json::Arr(buckets));
        spec
    }

    /// Write `spec.json` + per-bucket npy tensors into `dir`:
    /// `bell_w{width}_{cols,vals,rows}.npy`.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("bell_spec.json"), self.spec().to_pretty())
            .context("write bell_spec.json")?;
        for b in &self.buckets {
            let w = b.width;
            Npy::from_i32(&[b.padded_rows, w], &b.cols).save(dir.join(format!("bell_w{w}_cols.npy")))?;
            Npy::from_f32(&[b.padded_rows, w], &b.vals).save(dir.join(format!("bell_w{w}_vals.npy")))?;
            Npy::from_i32(&[b.padded_rows], &b.out_row).save(dir.join(format!("bell_w{w}_rows.npy")))?;
        }
        Ok(())
    }

    /// Load a layout previously written by [`BellLayout::save`].
    pub fn load(dir: impl AsRef<Path>) -> Result<BellLayout> {
        let dir = dir.as_ref();
        let spec = Json::parse(&std::fs::read_to_string(dir.join("bell_spec.json"))?)?;
        let n_rows = spec.req_usize("n_rows")?;
        let n_cols = spec.req_usize("n_cols")?;
        let nnz = spec.req_usize("nnz")?;
        let mut buckets = Vec::new();
        for b in spec.req_arr("buckets")? {
            let width = b.req_usize("width")?;
            let rows = b.req_usize("rows")?;
            let padded_rows = b.req_usize("padded_rows")?;
            let cols = Npy::load(dir.join(format!("bell_w{width}_cols.npy")))?.to_i32()?;
            let vals = Npy::load(dir.join(format!("bell_w{width}_vals.npy")))?.to_f32()?;
            let out_row = Npy::load(dir.join(format!("bell_w{width}_rows.npy")))?.to_i32()?;
            anyhow::ensure!(cols.len() == padded_rows * width, "cols shape mismatch");
            anyhow::ensure!(vals.len() == padded_rows * width, "vals shape mismatch");
            anyhow::ensure!(out_row.len() == padded_rows, "rows shape mismatch");
            buckets.push(BellBucket { width, rows, padded_rows, cols, vals, out_row });
        }
        Ok(BellLayout { n_rows, n_cols, nnz, buckets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::degree::DegreeSorted;
    use crate::partition::patterns::PartitionParams;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn random_graph(rng: &mut Pcg, n: usize, max_deg: usize) -> Csr {
        let mut edges = Vec::new();
        for r in 0..n {
            let d = if rng.f64() < 0.1 { rng.range(0, max_deg + 1) } else { rng.range(0, 6) };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    fn build_layout(csr: &Csr, params: PartitionParams) -> (Csr, BellLayout) {
        let ds = DegreeSorted::new(csr);
        let bp = BlockPartition::build(&ds.csr, params);
        let layout = BellLayout::build(&ds.csr, &bp);
        (ds.csr, layout)
    }

    #[test]
    fn widths_are_pow2_and_sorted() {
        let mut rng = Pcg::seed_from(3);
        let csr = random_graph(&mut rng, 60, 50);
        let (_, layout) = build_layout(&csr, PartitionParams { max_block_warps: 4, max_warp_nzs: 8 });
        for w in layout.buckets.windows(2) {
            assert!(w[0].width < w[1].width);
        }
        for b in &layout.buckets {
            assert!(b.width.is_power_of_two());
            assert_eq!(b.padded_rows % ROW_TILE, 0);
            assert!(b.rows <= b.padded_rows && b.padded_rows < b.rows + ROW_TILE);
        }
    }

    #[test]
    fn execute_matches_dense_reference() {
        let mut rng = Pcg::seed_from(4);
        let csr = random_graph(&mut rng, 40, 30);
        let (sorted, layout) = build_layout(&csr, PartitionParams { max_block_warps: 2, max_warp_nzs: 4 });
        let f = 5;
        let x: Vec<f32> = (0..40 * f).map(|_| rng.f32() - 0.5).collect();
        let want = sorted.spmm_dense(&x, f);
        let got = layout.execute(&x, f);
        for (a, b) in want.iter().zip(got.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn padding_rows_are_inert() {
        let mut rng = Pcg::seed_from(5);
        let csr = random_graph(&mut rng, 20, 10);
        let (_, layout) = build_layout(&csr, PartitionParams::default());
        for b in &layout.buckets {
            for i in b.rows..b.padded_rows {
                assert_eq!(b.out_row[i], 0);
                for k in 0..b.width {
                    assert_eq!(b.vals[i * b.width + k], 0.0);
                }
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Pcg::seed_from(6);
        let csr = random_graph(&mut rng, 30, 20);
        let (_, layout) = build_layout(&csr, PartitionParams { max_block_warps: 4, max_warp_nzs: 4 });
        let dir = std::env::temp_dir().join("accel_gcn_bell_test");
        layout.save(&dir).unwrap();
        let back = BellLayout::load(&dir).unwrap();
        assert_eq!(layout, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spec_shape() {
        let mut rng = Pcg::seed_from(7);
        let csr = random_graph(&mut rng, 25, 12);
        let (_, layout) = build_layout(&csr, PartitionParams::default());
        let spec = layout.spec();
        assert_eq!(spec.req_usize("n_rows").unwrap(), 25);
        assert_eq!(spec.req_arr("buckets").unwrap().len(), layout.buckets.len());
    }

    #[test]
    fn coalesce_preserves_numerics_and_reduces_buckets() {
        let mut rng = Pcg::seed_from(8);
        let csr = random_graph(&mut rng, 80, 40);
        let (sorted, layout) = build_layout(&csr, PartitionParams::default());
        let n_before = layout.buckets.len();
        let merged = layout.clone().coalesce(1_000_000); // force max merging
        assert_eq!(merged.buckets.len(), 1.min(n_before.max(1)));
        let f = 4;
        let x: Vec<f32> = (0..80 * f).map(|_| rng.f32() - 0.5).collect();
        let want = sorted.spmm_dense(&x, f);
        for l in [&layout, &merged] {
            let got = l.execute(&x, f);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
        // moderate threshold merges only sparse buckets
        let partial = layout.clone().coalesce(16);
        assert!(partial.buckets.len() <= n_before);
        for b in &partial.buckets {
            let last = partial.buckets.last().unwrap().width;
            assert!(b.rows >= 16 || b.width == last);
        }
        let got = partial.execute(&x, f);
        for (a, b) in want.iter().zip(got.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn prop_coalesce_equals_reference() {
        proptest::check("bell_coalesce", 0xC0A1, 15, |rng| {
            let n = rng.range(1, 60);
            let csr = random_graph(rng, n, 30);
            let (sorted, layout) = build_layout(&csr, PartitionParams { max_block_warps: 4, max_warp_nzs: 8 });
            let merged = layout.coalesce(rng.range(1, 40));
            let f = rng.range(1, 5);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = sorted.spmm_dense(&x, f);
            let got = merged.execute(&x, f);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn prop_execute_equals_reference() {
        proptest::check("bell_execute", 0xBE11, 20, |rng| {
            let n = rng.range(1, 60);
            let csr = random_graph(rng, n, 40);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 8, 32]),
            };
            let (sorted, layout) = build_layout(&csr, params);
            let f = rng.range(1, 7);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = sorted.spmm_dense(&x, f);
            let got = layout.execute(&x, f);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn prop_padding_overhead_bounded() {
        // structural bound: pow2 rounding wastes < 2x within a task and
        // row padding adds < ROW_TILE rows of `width` slots per bucket
        proptest::check("bell_padding", 0xBE12, 15, |rng| {
            let n = rng.range(ROW_TILE * 4, 200);
            let csr = random_graph(rng, n, 30);
            let (_, layout) = build_layout(&csr, PartitionParams::default());
            let row_pad_slots: usize =
                layout.buckets.iter().map(|b| ROW_TILE * b.width).sum();
            assert!(
                layout.padded_nnz() <= 2 * layout.nnz + row_pad_slots,
                "padded={} nnz={} row_pad={}",
                layout.padded_nnz(),
                layout.nnz,
                row_pad_slots
            );
        });
    }
}
