//! The paper's preprocessing contribution (§III-C).
//!
//! * [`patterns`] — Algorithm 1: the degree → `(block_rows, warp_nzs)`
//!   partition-pattern table.
//! * [`block_level`] — Algorithm 2: single-pass block-level partitioning
//!   over a degree-sorted CSR, emitting one int4 metadata record per
//!   block (and splitting rows with `deg > deg_bound` across blocks).
//! * [`metadata`] — the 128-bit metadata encoding and the storage-ratio
//!   accounting of Eq. 1 / Fig. 3.
//! * [`warp_level`] — the GNNAdvisor-style fixed-size neighbour-group
//!   baseline the paper compares against (Fig. 7).
//! * [`bucket`] — BELL export: the paper's warp workload list regrouped
//!   into uniform-width buckets, the layout the Pallas kernel consumes
//!   (DESIGN.md §Hardware-Adaptation).

pub mod patterns;
pub mod block_level;
pub mod metadata;
pub mod warp_level;
pub mod bucket;

pub use block_level::{BlockPartition, WarpTask};
pub use bucket::BellLayout;
pub use metadata::BlockMeta;
pub use patterns::{PartitionParams, PatternTable};
pub use warp_level::WarpPartition;
