//! Algorithm 1 — "Get partition patterns".
//!
//! For every degree `1 ≤ deg ≤ deg_bound` (`deg_bound = max_block_warps ×
//! max_warp_nzs`), pick the smallest factor `f` of `max_block_warps` such
//! that `f × max_warp_nzs ≥ deg`. A row of that degree is then processed
//! by `f` warps, each handling `warp_nzs = ceil(deg / f)` nonzeros, and a
//! block holds `block_rows = max_block_warps / f` rows — so every block is
//! fully populated with `max_block_warps` warps of (nearly) equal load,
//! which is exactly the workload-balance property Fig. 4(e) illustrates.

/// Tunable parameters of the partitioner. Paper defaults: a block has up
/// to 12 warps (`max_block_warps`, the example value given with Eq. 1)
/// and a warp handles up to 32 nonzeros.
///
/// `Hash` because the params are half of the
/// [`PlanCache`](crate::pipeline::PlanCache) key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PartitionParams {
    pub max_block_warps: usize,
    pub max_warp_nzs: usize,
}

impl Default for PartitionParams {
    fn default() -> Self {
        PartitionParams { max_block_warps: 12, max_warp_nzs: 32 }
    }
}

impl PartitionParams {
    /// Maximum nonzeros a single block can absorb; rows beyond this are
    /// split across blocks (Algorithm 2, second branch).
    pub fn deg_bound(&self) -> usize {
        self.max_block_warps * self.max_warp_nzs
    }
}

/// The pattern chosen for one degree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pattern {
    /// Rows per block (`max_block_warps / factor`).
    pub block_rows: usize,
    /// Nonzeros per warp (`ceil(deg / factor)`).
    pub warp_nzs: usize,
    /// Warps cooperating on one row (`factor`).
    pub warps_per_row: usize,
}

/// Pattern table for degrees `1..=deg_bound` (index `deg - 1`).
///
/// Note: Algorithm 1's loop reads `while deg < deg_bound`, but Fig. 3's
/// worked example partitions a row of exactly `deg_bound` nonzeros via
/// the pattern path (BP-2: deg=4=deg_bound, info=2|1), so the intended
/// range is inclusive — a full `deg_bound` row fits exactly one block.
#[derive(Clone, Debug)]
pub struct PatternTable {
    pub params: PartitionParams,
    patterns: Vec<Pattern>,
}

impl PatternTable {
    /// Algorithm 1, literally: walk `deg` upward, advancing through the
    /// sorted factors of `max_block_warps` whenever the current factor
    /// can no longer cover `deg`.
    pub fn build(params: PartitionParams) -> PatternTable {
        assert!(params.max_block_warps >= 1 && params.max_warp_nzs >= 1);
        let deg_bound = params.deg_bound();
        let factors = factors_of(params.max_block_warps);
        let mut patterns = Vec::with_capacity(deg_bound);
        let mut i = 0usize;
        let mut deg = 1usize;
        while deg <= deg_bound {
            if factors[i] * params.max_warp_nzs >= deg {
                let f = factors[i];
                patterns.push(Pattern {
                    block_rows: params.max_block_warps / f,
                    warp_nzs: deg.div_ceil(f),
                    warps_per_row: f,
                });
                deg += 1;
            } else {
                i += 1;
            }
        }
        PatternTable { params, patterns }
    }

    /// Pattern for a row of `deg` nonzeros, `1 ≤ deg ≤ deg_bound`.
    pub fn get(&self, deg: usize) -> Pattern {
        assert!(
            deg >= 1 && deg <= self.params.deg_bound(),
            "degree {deg} outside pattern range [1, {}]",
            self.params.deg_bound()
        );
        self.patterns[deg - 1]
    }

    /// All degrees covered by the table.
    pub fn degrees(&self) -> impl Iterator<Item = usize> {
        1..=self.params.deg_bound()
    }
}

/// Sorted factors of `n` (ascending), e.g. 12 → [1, 2, 3, 4, 6, 12].
pub fn factors_of(n: usize) -> Vec<usize> {
    let mut f: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
    f.sort_unstable();
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors() {
        assert_eq!(factors_of(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(factors_of(1), vec![1]);
        assert_eq!(factors_of(7), vec![1, 7]);
    }

    #[test]
    fn default_params_match_paper() {
        let p = PartitionParams::default();
        assert_eq!(p.max_block_warps, 12);
        assert_eq!(p.deg_bound(), 384);
    }

    #[test]
    fn fig3_example() {
        // Fig. 3: max_block_warps = 2, max_warp_nzs = 2 → deg_bound = 4.
        // deg 2 → factor 1: block_rows 2, warp_nzs 2 (BP-1: two rows of
        // deg 2, each warp takes a whole row).
        let t = PatternTable::build(PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        let p2 = t.get(2);
        assert_eq!(p2, Pattern { block_rows: 2, warp_nzs: 2, warps_per_row: 1 });
        // deg 3 → factor 2 (1×2 < 3): block_rows 1, warp_nzs ceil(3/2)=2
        let p3 = t.get(3);
        assert_eq!(p3, Pattern { block_rows: 1, warp_nzs: 2, warps_per_row: 2 });
    }

    #[test]
    fn covers_all_degrees_below_bound() {
        let t = PatternTable::build(PartitionParams::default());
        for deg in t.degrees() {
            let p = t.get(deg);
            // invariant 1: the pattern's warps cover the row
            assert!(
                p.warps_per_row * p.warp_nzs >= deg,
                "deg {deg}: {p:?} does not cover"
            );
            // invariant 2: warp_nzs within the cap
            assert!(p.warp_nzs <= t.params.max_warp_nzs, "deg {deg}: {p:?}");
            // invariant 3: block fully populated with warps
            assert_eq!(p.block_rows * p.warps_per_row, t.params.max_block_warps);
        }
    }

    #[test]
    fn pattern_waste_bounded() {
        // the chosen factor is minimal, so the *previous* factor cannot
        // cover the degree: warp utilization is > 50% for factor steps ≤ 2x
        let t = PatternTable::build(PartitionParams::default());
        let factors = factors_of(12);
        for deg in t.degrees() {
            let p = t.get(deg);
            let fi = factors.iter().position(|&f| f == p.warps_per_row).unwrap();
            if fi > 0 {
                assert!(
                    factors[fi - 1] * t.params.max_warp_nzs < deg,
                    "deg {deg}: factor {} not minimal",
                    p.warps_per_row
                );
            }
        }
    }

    #[test]
    fn monotone_warps_per_row() {
        let t = PatternTable::build(PartitionParams::default());
        let mut last = 0;
        for deg in t.degrees() {
            let w = t.get(deg).warps_per_row;
            assert!(w >= last, "warps_per_row not monotone at deg {deg}");
            last = w;
        }
    }

    #[test]
    #[should_panic(expected = "outside pattern range")]
    fn degree_beyond_bound_panics() {
        let t = PatternTable::build(PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        t.get(5);
    }

    #[test]
    fn degree_exactly_bound_is_one_full_block() {
        // Fig. 3 BP-2: deg = deg_bound = 4 → 2 warps × 2 nzs, 1 row
        let t = PatternTable::build(PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        assert_eq!(t.get(4), Pattern { block_rows: 1, warp_nzs: 2, warps_per_row: 2 });
    }

    #[test]
    fn single_warp_blocks() {
        // degenerate config: 1 warp per block
        let t = PatternTable::build(PartitionParams { max_block_warps: 1, max_warp_nzs: 8 });
        for deg in t.degrees() {
            assert_eq!(t.get(deg), Pattern { block_rows: 1, warp_nzs: deg, warps_per_row: 1 });
        }
    }
}
