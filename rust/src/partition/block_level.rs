//! Algorithm 2 — block-level partitioning.
//!
//! Single O(n) pass over a **degree-sorted** CSR: rows with
//! `deg ≤ deg_bound` are grouped into blocks according to the pattern
//! table (Algorithm 1); a block's metadata is one int4 record shared by
//! all of its warps. Rows with `deg > deg_bound` are split across
//! multiple blocks in `deg_bound`-sized chunks whose partial results are
//! accumulated with global atomics (paper §III-D "third cache level").

use super::metadata::{BlockMeta, MetadataFootprint};
use super::patterns::{PartitionParams, PatternTable};
use crate::graph::csr::Csr;

/// The workload of one (active) warp, derived from block metadata —
/// the unit consumed by the exact executor, the GPU simulator, and the
/// BELL export.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WarpTask {
    pub block_id: u32,
    pub warp_in_block: u32,
    /// Destination row (degree-sorted index).
    pub sorted_row: u32,
    /// Nonzero range `[nz_start, nz_start + nz_len)` in the sorted CSR.
    pub nz_start: usize,
    pub nz_len: usize,
    /// True when this task is a chunk of a split row: its partial result
    /// must be accumulated into global memory atomically.
    pub needs_global_atomic: bool,
}

/// The block-level partition of one graph.
#[derive(Clone, Debug)]
pub struct BlockPartition {
    pub params: PartitionParams,
    pub meta: Vec<BlockMeta>,
    pub n_rows: usize,
    pub nnz: usize,
    /// Number of rows whose degree reached the split path.
    pub n_split_rows: usize,
}

impl BlockPartition {
    /// Partition a degree-sorted CSR. The input **must** be sorted by
    /// ascending degree (see [`crate::graph::DegreeSorted`]); this is
    /// asserted in debug builds.
    pub fn build(sorted: &Csr, params: PartitionParams) -> BlockPartition {
        debug_assert!(
            (1..sorted.n_rows).all(|r| sorted.degree(r - 1) <= sorted.degree(r)),
            "BlockPartition::build requires an ascending degree-sorted CSR"
        );
        let table = PatternTable::build(params);
        let deg_bound = params.deg_bound();
        let mut meta = Vec::new();
        let mut n_split_rows = 0usize;

        let n = sorted.n_rows;
        let mut r = 0usize;
        while r < n {
            let deg = sorted.degree(r);
            if deg == 0 {
                // zero rows produce no work; output rows stay zero
                r += 1;
                continue;
            }
            if deg <= deg_bound {
                // pattern path: find the run of rows with this degree
                let mut end = r + 1;
                while end < n && sorted.degree(end) == deg {
                    end += 1;
                }
                let pattern = table.get(deg);
                let mut rows_remaining = end - r;
                let mut row = r;
                while rows_remaining > 0 {
                    let take = rows_remaining.min(pattern.block_rows);
                    meta.push(BlockMeta {
                        deg: deg as u32,
                        loc: sorted.row_ptr[row] as u32,
                        row: row as u32,
                        info: BlockMeta::pack_info(pattern.warp_nzs, take),
                    });
                    row += take;
                    rows_remaining -= take;
                }
                r = end;
            } else {
                // split path: chunks of deg_bound across blocks
                n_split_rows += 1;
                let start = sorted.row_ptr[r];
                let mut deg_remaining = deg;
                let mut loc = start;
                while deg_remaining > 0 {
                    let take = deg_remaining.min(deg_bound);
                    meta.push(BlockMeta {
                        deg: deg as u32,
                        loc: loc as u32,
                        row: r as u32,
                        info: take as u32,
                    });
                    loc += take;
                    deg_remaining -= take;
                }
                r += 1;
            }
        }
        BlockPartition { params, meta, n_rows: n, nnz: sorted.nnz(), n_split_rows }
    }

    /// Derive the warp workloads of block `b` from its metadata alone —
    /// the property the paper highlights: "the workload allocation for
    /// each warp within a block can be directly deduced from the
    /// block-level partition's metadata".
    pub fn block_warp_tasks(&self, b: usize) -> Vec<WarpTask> {
        let mut tasks = Vec::new();
        self.for_each_block_warp_task(b, |t| tasks.push(t));
        tasks
    }

    /// Allocation-free visitor over block `b`'s warp tasks — the hot-path
    /// twin of [`BlockPartition::block_warp_tasks`] (SS Perf: the trace
    /// generators walk every task of every block per column dimension).
    #[inline]
    pub fn for_each_block_warp_task(&self, b: usize, mut f: impl FnMut(WarpTask)) {
        let m = self.meta[b];
        let deg_bound = self.params.deg_bound();
        if m.is_split(deg_bound) {
            let nzs = m.split_nzs();
            let wn = self.params.max_warp_nzs;
            let warps = nzs.div_ceil(wn);
            for w in 0..warps {
                let s = w * wn;
                f(WarpTask {
                    block_id: b as u32,
                    warp_in_block: w as u32,
                    sorted_row: m.row,
                    nz_start: m.loc as usize + s,
                    nz_len: (nzs - s).min(wn),
                    needs_global_atomic: true,
                });
            }
        } else {
            let deg = m.deg as usize;
            let wn = m.warp_nzs();
            let rows = m.block_rows();
            let warps_per_row = deg.div_ceil(wn);
            for row_i in 0..rows {
                let row_nz_start = m.loc as usize + row_i * deg;
                for k in 0..warps_per_row {
                    let s = k * wn;
                    f(WarpTask {
                        block_id: b as u32,
                        warp_in_block: (row_i * warps_per_row + k) as u32,
                        sorted_row: m.row + row_i as u32,
                        nz_start: row_nz_start + s,
                        nz_len: (deg - s).min(wn),
                        needs_global_atomic: false,
                    });
                }
            }
        }
    }

    /// All warp tasks, block order.
    pub fn warp_tasks(&self) -> Vec<WarpTask> {
        (0..self.meta.len()).flat_map(|b| self.block_warp_tasks(b)).collect()
    }

    pub fn n_blocks(&self) -> usize {
        self.meta.len()
    }

    /// Total warp tasks (active warps across all blocks).
    pub fn n_warp_tasks(&self) -> usize {
        (0..self.meta.len()).map(|b| self.block_warp_tasks(b).len()).sum()
    }

    /// Metadata storage accounting vs a warp-level scheme with the same
    /// active warps (Eq. 1).
    pub fn footprint(&self) -> MetadataFootprint {
        MetadataFootprint::new(self.n_blocks(), self.n_warp_tasks())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::degree::DegreeSorted;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn fig3_graph() -> Csr {
        // Fig. 3(a): row0 deg 2, row1 deg 4, row2 deg 2 (cols arbitrary)
        Csr::from_edges(
            3,
            5,
            &[
                (0, 0, 1.0),
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (1, 3, 1.0),
                (1, 4, 1.0),
                (2, 1, 1.0),
                (2, 3, 1.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn fig3_metadata_exactly() {
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let ds = DegreeSorted::new(&fig3_graph());
        // sorted order: row0, row2, row1 (ascending degree, stable)
        assert_eq!(ds.perm, vec![0, 2, 1]);
        let bp = BlockPartition::build(&ds.csr, params);
        assert_eq!(bp.meta.len(), 2);
        // BP-1: deg=2, loc=0, row=0, info=2|2
        assert_eq!(bp.meta[0], BlockMeta { deg: 2, loc: 0, row: 0, info: BlockMeta::pack_info(2, 2) });
        // BP-2: deg=4, loc=4, row=2, info=2|1 (Fig. 3(c), pattern path)
        assert_eq!(bp.meta[1], BlockMeta { deg: 4, loc: 4, row: 2, info: BlockMeta::pack_info(2, 1) });
        assert_eq!(bp.n_split_rows, 0);
    }

    #[test]
    fn fig3_warp_tasks() {
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let ds = DegreeSorted::new(&fig3_graph());
        let bp = BlockPartition::build(&ds.csr, params);
        let t0 = bp.block_warp_tasks(0);
        // Warp-1 handles sorted row0 (nz 0..2), Warp-2 handles sorted row1 (nz 2..4)
        assert_eq!(t0.len(), 2);
        assert_eq!((t0[0].sorted_row, t0[0].nz_start, t0[0].nz_len), (0, 0, 2));
        assert_eq!((t0[1].sorted_row, t0[1].nz_start, t0[1].nz_len), (1, 2, 2));
        assert!(!t0[0].needs_global_atomic);
        // BP-2: Warp-3 and Warp-4 split sorted row2's 4 nzs (2 each),
        // accumulating within the block (shared-memory atomics, not global)
        let t1 = bp.block_warp_tasks(1);
        assert_eq!(t1.len(), 2);
        assert_eq!((t1[0].sorted_row, t1[0].nz_start, t1[0].nz_len), (2, 4, 2));
        assert_eq!((t1[1].sorted_row, t1[1].nz_start, t1[1].nz_len), (2, 6, 2));
        assert!(!t1[0].needs_global_atomic && !t1[1].needs_global_atomic);
    }

    #[test]
    fn residual_block_smaller_rows() {
        // 3 rows of degree 1 with block_rows=2 → blocks of 2 + 1 rows
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let csr = Csr::from_edges(3, 3, &[(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)]).unwrap();
        let bp = BlockPartition::build(&csr, params); // already uniform degree
        assert_eq!(bp.meta.len(), 2);
        assert_eq!(bp.meta[0].block_rows(), 2);
        assert_eq!(bp.meta[1].block_rows(), 1);
        assert_eq!(bp.meta[1].row, 2);
    }

    #[test]
    fn long_row_split_into_chunks() {
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 }; // bound 4
        // one row with degree 10 → chunks 4,4,2
        let edges: Vec<(u32, u32, f32)> = (0..10).map(|c| (0u32, c as u32, 1.0)).collect();
        let csr = Csr::from_edges(1, 10, &edges).unwrap();
        let bp = BlockPartition::build(&csr, params);
        assert_eq!(bp.meta.len(), 3);
        assert_eq!(
            bp.meta.iter().map(|m| m.split_nzs()).collect::<Vec<_>>(),
            vec![4, 4, 2]
        );
        assert_eq!(bp.meta.iter().map(|m| m.loc).collect::<Vec<_>>(), vec![0, 4, 8]);
        // every chunk targets the same row with global atomics
        for b in 0..3 {
            for t in bp.block_warp_tasks(b) {
                assert_eq!(t.sorted_row, 0);
                assert!(t.needs_global_atomic);
            }
        }
    }

    #[test]
    fn zero_degree_rows_skipped() {
        let params = PartitionParams::default();
        let csr = Csr::from_edges(4, 4, &[(3, 0, 1.0)]).unwrap();
        let ds = DegreeSorted::new(&csr);
        let bp = BlockPartition::build(&ds.csr, params);
        assert_eq!(bp.n_blocks(), 1);
        assert_eq!(bp.n_warp_tasks(), 1);
    }

    #[test]
    fn metadata_footprint_small() {
        // many equal-degree rows: blocks of 12 rows → ratio ≈ 1/12
        let params = PartitionParams { max_block_warps: 12, max_warp_nzs: 32 };
        let edges: Vec<(u32, u32, f32)> = (0..1200u32).map(|r| (r, 0, 1.0)).collect();
        let csr = Csr::from_edges(1200, 1, &edges).unwrap();
        let bp = BlockPartition::build(&csr, params);
        let fp = bp.footprint();
        assert_eq!(fp.n_blocks, 100);
        assert_eq!(fp.n_warp_tasks, 1200);
        assert!((fp.ratio() - 1.0 / 12.0).abs() < 1e-9);
    }

    fn random_sorted(rng: &mut Pcg, n: usize, max_deg: usize) -> Csr {
        let mut edges = Vec::new();
        for r in 0..n {
            // mixture: mostly small degrees, occasional huge row
            let d = if rng.f64() < 0.05 { rng.range(max_deg / 2, max_deg + 1) } else { rng.range(0, 8) };
            let mut used = std::collections::BTreeSet::new();
            for _ in 0..d {
                used.insert(rng.range(0, n.max(d + 1)) as u32);
            }
            for c in used {
                edges.push((r as u32, c, rng.f32() + 0.1));
            }
        }
        let csr = Csr::from_edges(n, n.max(max_deg + 1), &edges).unwrap();
        DegreeSorted::new(&csr).csr
    }

    #[test]
    fn prop_tasks_cover_all_nonzeros_exactly_once() {
        proptest::check("block_partition_coverage", 0xB10C, 30, |rng| {
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4, 6, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 4, 8]),
            };
            let n = rng.range(1, 80);
            let sorted = random_sorted(rng, n, params.deg_bound() * 2 + 3);
            let bp = BlockPartition::build(&sorted, params);
            let mut covered = vec![0u8; sorted.nnz()];
            for t in bp.warp_tasks() {
                // task range within the task's row
                let row = t.sorted_row as usize;
                assert!(t.nz_start >= sorted.row_ptr[row]);
                assert!(t.nz_start + t.nz_len <= sorted.row_ptr[row + 1]);
                assert!(t.nz_len >= 1);
                for i in t.nz_start..t.nz_start + t.nz_len {
                    covered[i] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "coverage not exactly once");
        });
    }

    #[test]
    fn prop_warp_balance_within_block() {
        // paper claim (Fig. 4e): within a block, warp loads are uniform
        // up to the ceil remainder — max-min ≤ pattern granularity
        proptest::check("block_partition_balance", 0xBA1A, 30, |rng| {
            let params = PartitionParams::default();
            let n = rng.range(1, 60);
            let sorted = random_sorted(rng, n, 40);
            let bp = BlockPartition::build(&sorted, params);
            for b in 0..bp.n_blocks() {
                let tasks = bp.block_warp_tasks(b);
                let max = tasks.iter().map(|t| t.nz_len).max().unwrap();
                let min = tasks.iter().map(|t| t.nz_len).min().unwrap();
                // every warp handles exactly warp_nzs except each row's
                // tail warp: spread strictly below one warp unit
                let unit = if bp.meta[b].is_split(params.deg_bound()) {
                    params.max_warp_nzs
                } else {
                    bp.meta[b].warp_nzs()
                };
                assert!(max - min < unit.max(1), "block {b}: spread {max}-{min}, unit {unit}");
                assert!(max <= unit);
            }
        });
    }

    #[test]
    fn prop_metadata_ratio_below_10pct_for_powerlaw() {
        // Eq. 1 claim on realistic graphs with default params
        proptest::check("metadata_ratio", 0xE41, 10, |rng| {
            let n = 400;
            let degs = crate::graph::generator::degree_sequence(
                crate::graph::generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.2 },
                n,
                n * 8,
                rng,
            );
            let csr = crate::graph::generator::from_degree_sequence(n, &degs, rng);
            let sorted = DegreeSorted::new(&csr).csr;
            let bp = BlockPartition::build(&sorted, PartitionParams::default());
            // most rows are low-degree → blocks hold many rows/warps
            assert!(bp.footprint().ratio() < 0.75, "ratio={}", bp.footprint().ratio());
        });
    }
}
