//! The int4 (128-bit) per-block metadata record and the storage
//! accounting of Eq. 1 / Fig. 3.
//!
//! One record per block, shared by every warp in the block — this is the
//! paper's metadata-compression claim: block-level partitioning needs
//! roughly `1 / avg_warps_per_block` of the warp-level metadata (≈8% at
//! `max_block_warps = 12`).

/// 128-bit block descriptor, paper §III-C:
/// * `deg` — degree of the rows this block covers,
/// * `loc` — starting nonzero address (index into `col_idx`/`vals`),
/// * `row` — starting (degree-sorted) row id,
/// * `info` — if `deg < deg_bound`: `warp_nzs` (high 16 bits) and
///   `block_rows` (low 16 bits); else: the nonzero count assigned to
///   this block of a split row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockMeta {
    pub deg: u32,
    pub loc: u32,
    pub row: u32,
    pub info: u32,
}

impl BlockMeta {
    /// Pack the pattern-path info word: `warp_nzs | block_rows`.
    pub fn pack_info(warp_nzs: usize, block_rows: usize) -> u32 {
        assert!(warp_nzs <= u16::MAX as usize && block_rows <= u16::MAX as usize);
        ((warp_nzs as u32) << 16) | block_rows as u32
    }

    /// Pattern-path accessor: nonzeros per warp.
    pub fn warp_nzs(&self) -> usize {
        (self.info >> 16) as usize
    }

    /// Pattern-path accessor: rows handled by this block.
    pub fn block_rows(&self) -> usize {
        (self.info & 0xFFFF) as usize
    }

    /// Split-path accessor: nonzeros assigned to this block.
    pub fn split_nzs(&self) -> usize {
        self.info as usize
    }

    /// Whether this block is a chunk of a row whose degree exceeds
    /// `deg_bound` (Algorithm 2, second branch). Rows of exactly
    /// `deg_bound` still fit one block via the pattern path (Fig. 3).
    pub fn is_split(&self, deg_bound: usize) -> bool {
        self.deg as usize > deg_bound
    }

    /// Serialize to the 128-bit on-device layout (4 × u32, little end.).
    pub fn to_words(&self) -> [u32; 4] {
        [self.deg, self.loc, self.row, self.info]
    }

    pub fn from_words(w: [u32; 4]) -> BlockMeta {
        BlockMeta { deg: w[0], loc: w[1], row: w[2], info: w[3] }
    }
}

/// Metadata record size in bytes — one int4 per block (128-bit memory
/// bus transaction, paper §III-C).
pub const BLOCK_META_BYTES: usize = 16;

/// Warp-level metadata record size: `{row, col, len}` = 96 bits padded
/// to 128 for bus alignment (paper Fig. 3(b)).
pub const WARP_META_BYTES: usize = 16;

/// Storage accounting comparing the two schemes (Eq. 1).
#[derive(Clone, Copy, Debug)]
pub struct MetadataFootprint {
    pub n_blocks: usize,
    pub n_warp_tasks: usize,
    pub block_level_bytes: usize,
    pub warp_level_bytes: usize,
}

impl MetadataFootprint {
    pub fn new(n_blocks: usize, n_warp_tasks: usize) -> Self {
        MetadataFootprint {
            n_blocks,
            n_warp_tasks,
            block_level_bytes: n_blocks * BLOCK_META_BYTES,
            warp_level_bytes: n_warp_tasks * WARP_META_BYTES,
        }
    }

    /// `S_B / S_W ≈ 1 / avg_warps_per_block` (Eq. 1).
    pub fn ratio(&self) -> f64 {
        if self.warp_level_bytes == 0 {
            return 0.0;
        }
        self.block_level_bytes as f64 / self.warp_level_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_packing_roundtrip() {
        let info = BlockMeta::pack_info(2, 2);
        let m = BlockMeta { deg: 2, loc: 0, row: 0, info };
        assert_eq!(m.warp_nzs(), 2);
        assert_eq!(m.block_rows(), 2);
    }

    #[test]
    fn fig3_bp1_bp2() {
        // Fig. 3(c): BP-1 = {deg=2, loc=0, row=0, info=2|2},
        //            BP-2 = {deg=4, loc=4, row=2, info=2|1}
        let bp1 = BlockMeta { deg: 2, loc: 0, row: 0, info: BlockMeta::pack_info(2, 2) };
        let bp2 = BlockMeta { deg: 4, loc: 4, row: 2, info: BlockMeta::pack_info(2, 1) };
        assert_eq!(bp1.warp_nzs(), 2);
        assert_eq!(bp1.block_rows(), 2);
        assert_eq!(bp2.warp_nzs(), 2);
        assert_eq!(bp2.block_rows(), 1);
        // deg_bound = 4 in the Fig. 3 config: deg 4 still fits one block
        assert!(!bp2.is_split(4));
        assert!(!bp1.is_split(4));
        assert!(BlockMeta { deg: 5, loc: 0, row: 0, info: 5 }.is_split(4));
    }

    #[test]
    fn words_roundtrip() {
        let m = BlockMeta { deg: 7, loc: 123, row: 5, info: BlockMeta::pack_info(3, 4) };
        assert_eq!(BlockMeta::from_words(m.to_words()), m);
    }

    #[test]
    fn eq1_ratio() {
        // avg 12 warps per block → ratio ≈ 1/12 ≈ 8.3% (paper: "a mere 8%")
        let f = MetadataFootprint::new(100, 1200);
        assert!((f.ratio() - 1.0 / 12.0).abs() < 1e-9);
        assert!(f.ratio() < 0.10);
    }

    #[test]
    fn empty_footprint() {
        let f = MetadataFootprint::new(0, 0);
        assert_eq!(f.ratio(), 0.0);
    }
}
