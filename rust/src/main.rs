//! `accel-gcn` — leader binary: preprocessing, simulation, serving,
//! training, and paper-reproduction entry points.
//!
//! ```text
//! accel-gcn prepare   --out artifacts/quickstart [--graph collab|synthetic] ...
//! accel-gcn simulate  --graph collab --coldim 64 [--kernels accel-gcn,...]
//! accel-gcn datasets                      # Table I summary
//! accel-gcn stats     --graph collab      # Fig. 2-style degree histogram
//! accel-gcn train        --artifacts artifacts/quickstart --steps 300
//! accel-gcn train-native [--steps 200] [--optimizer sgd|adam] [--quick]
//! accel-gcn serve        --artifacts artifacts/quickstart --requests 64
//! accel-gcn serve-native --requests 64 --tenants 2 [--threads T] [--ladder 32,64,128]
//!                        [--metrics-interval-ms MS] [--trace-out PATH] [--tune-every K]
//!                        [--data-dir DIR [--fsync always|never] [--snapshot-every K]]
//!                        [--rounds R] [--updates U] [--update-size K]
//!                        [--queue-capacity N] [--deadline-ms MS] [--fault SPEC]
//! accel-gcn recover-check --data-dir DIR [--verify-spmm]
//! accel-gcn update-demo  --batches 8 --batch-size 64 [--edge-list graph.txt]
//! accel-gcn bench        --out results [--experiment fig5|...|microkernel|train_native]
//! accel-gcn bench-compare OLD.json NEW.json [--max-regress PCT]
//! accel-gcn profile      [--nodes N] [--iters I] [--train-steps S] [--json PATH]
//!                        [--trace-out PATH] [--tune-every K] [--quick]
//! accel-gcn roofline     [--json PATH] [--calibration PATH] [--recalibrate]
//!                        [--coldims 16,64] [--quick]
//! accel-gcn validate-metrics FILE [FILE...]
//! ```

use accel_gcn::bench as harness;
use accel_gcn::coordinator::PreparedDataset;
use accel_gcn::graph::datasets::{self, ScalePolicy};
use accel_gcn::graph::{generator, stats, Csr};
use accel_gcn::partition::patterns::PartitionParams;
use accel_gcn::pipeline::SpmmPlan;
use accel_gcn::sim::kernels::CostModel;
use accel_gcn::sim::{simulate_kernel, GpuConfig, KernelKind, KernelOptions};
use accel_gcn::util::cli::Args;
use accel_gcn::util::rng::Pcg;
use anyhow::{bail, Context, Result};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let sub = argv[0].as_str();
    let rest = &argv[1..];
    let r = match sub {
        "prepare" => cmd_prepare(rest),
        "simulate" => cmd_simulate(rest),
        "datasets" => cmd_datasets(rest),
        "stats" => cmd_stats(rest),
        "train" => cmd_train(rest),
        "train-native" => cmd_train_native(rest),
        "serve" => cmd_serve(rest),
        "serve-native" => cmd_serve_native(rest),
        "recover-check" => cmd_recover_check(rest),
        "update-demo" => cmd_update_demo(rest),
        "bench" => cmd_bench(rest),
        "bench-compare" => cmd_bench_compare(rest),
        "profile" => cmd_profile(rest),
        "roofline" => cmd_roofline(rest),
        "validate-metrics" => cmd_validate_metrics(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(anyhow::anyhow!("unknown subcommand `{other}`"))
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "accel-gcn — Accel-GCN reproduction (see README.md)\n\
         subcommands:\n\
         \x20 prepare   --out DIR [--graph NAME|synthetic] [--nodes N] [--avg-deg D]\n\
         \x20           [--feat-dim F] [--classes K] [--seed S]\n\
         \x20           [--max-block-warps W] [--max-warp-nzs Z]\n\
         \x20 simulate  --graph NAME [--coldim C] [--kernels a,b] [--seed S]\n\
         \x20 datasets  (print Table I specs and scale factors)\n\
         \x20 stats     --graph NAME (Fig. 2 degree histogram)\n\
         \x20 train     --artifacts DIR [--steps N]\n\
         \x20 train-native [--nodes N] [--classes K] [--feat-dim F] [--hidden H]\n\
         \x20           [--layers L] [--steps N] [--lr LR] [--optimizer sgd|adam]\n\
         \x20           [--momentum M] [--homophily P] [--avg-deg D] [--threads T]\n\
         \x20           [--patience N] [--seed S] [--edge-list PATH [--one-based]]\n\
         \x20           [--require-loss-drop FRAC] [--quick]\n\
         \x20           (full GCN backprop on the native SpMM pipeline, no artifacts)\n\
         \x20 serve     --artifacts DIR [--requests N] [--coldims 16,32]\n\
         \x20 serve-native [--requests N] [--tenants K] [--nodes N] [--avg-deg D]\n\
         \x20           [--threads T] [--ladder 32,64,128] [--gcn-every K] [--seed S]\n\
         \x20           [--no-verify] [--metrics-out PATH] [--metrics-interval-ms MS]\n\
         \x20           [--trace-out PATH] [--tune-every K]\n\
         \x20           [--data-dir DIR] [--fsync always|never] [--snapshot-every K]\n\
         \x20           [--rounds R] [--updates U] [--update-size K]\n\
         \x20           [--queue-capacity N] [--deadline-ms MS] [--fault SPEC]\n\
         \x20           (multi-tenant CPU serving, no artifacts needed; --metrics-out\n\
         \x20           enables tracing and dumps the metrics snapshot JSON every\n\
         \x20           --metrics-interval-ms and at exit; --trace-out writes the\n\
         \x20           Chrome trace-event timeline; --tune-every K runs the\n\
         \x20           closed-loop plan tuner every K serve rounds; --data-dir makes\n\
         \x20           tenants durable — snapshot + WAL, recovered on restart;\n\
         \x20           --updates U streams U edge-update batches per round;\n\
         \x20           --fault arms fault injection: torn-tail, snapshot-truncate,\n\
         \x20           checksum-flip, disk-full=BYTES, comma-separated)\n\
         \x20 recover-check --data-dir DIR [--verify-spmm]\n\
         \x20           (recover every tenant from snapshot + WAL without serving;\n\
         \x20           print per-tenant epoch/generation/replay table; --verify-spmm\n\
         \x20           re-executes SpMM through the pipeline against the dense\n\
         \x20           reference; exits nonzero on corruption or divergence beyond\n\
         \x20           the documented fallbacks)\n\
         \x20 update-demo [--nodes N] [--avg-deg D] [--batches B] [--batch-size K]\n\
         \x20           [--edge-list PATH [--one-based]] [--threads T] [--seed S]\n\
         \x20           (stream edge-update batches; patch plans incrementally,\n\
         \x20           verify each patch against a from-scratch rebuild)\n\
         \x20 bench     [--out DIR] [--experiment fig2|fig3|fig5|fig6|fig7|fig8|table1|table2|\n\
         \x20           exec_scaling|microkernel|serve_native|delta_update|train_native|all]\n\
         \x20           [--quick]\n\
         \x20 bench-compare OLD.json NEW.json [--max-regress PCT]\n\
         \x20           (diff two BENCH_*.json reports: per-metric speedup table with\n\
         \x20           direction-aware regressions; exits nonzero if any metric\n\
         \x20           regresses beyond PCT percent, default 5)\n\
         \x20 profile   [--nodes N] [--avg-deg D] [--feat-dim F] [--iters I]\n\
         \x20           [--train-steps S] [--threads T] [--seed S] [--json PATH]\n\
         \x20           [--trace-out PATH] [--tune-every K] [--quick]\n\
         \x20           (run SpMM + training iterations with tracing on; print the\n\
         \x20           per-shard utilization table, imbalance ratio, and span tree;\n\
         \x20           --tune-every K re-cuts shards from measured cost every K\n\
         \x20           iters and verifies tuned output bit-for-bit)\n\
         \x20 roofline  [--json PATH] [--calibration PATH] [--recalibrate] [--quick]\n\
         \x20           [--nodes N] [--avg-deg D] [--coldims 16,64] [--threads T]\n\
         \x20           [--iters I] [--seed S]\n\
         \x20           (calibrate STREAM/FMA machine roofs — cached at --calibration,\n\
         \x20           default results/calibration.json — then run the SpMM roofline\n\
         \x20           on a power-law sweep: analytic traffic-model bytes are checked\n\
         \x20           exactly against the instrumented counting executor, achieved\n\
         \x20           GB/s and GFLOP/s are reported per degree bucket against the\n\
         \x20           calibrated peak with a bandwidth- vs compute-bound verdict;\n\
         \x20           --json writes the accel-gcn-roofline/v1 document)\n\
         \x20 validate-metrics FILE [FILE...]\n\
         \x20           (schema-check metrics snapshot JSON written by profile --json\n\
         \x20           or serve-native --metrics-out, trace-event JSON written by\n\
         \x20           --trace-out, roofline JSON written by roofline --json, and\n\
         \x20           calibration JSON; exits nonzero on violations)"
    );
}

/// Build a graph from --graph: a Table I name or `synthetic`.
fn build_graph(args: &Args) -> Result<(String, Csr)> {
    let name = args.str_or("graph", "synthetic");
    let seed = args.u64_or("seed", 42)?;
    if name != "synthetic" {
        let spec = datasets::by_name(&name)
            .with_context(|| format!("unknown dataset `{name}` (see `accel-gcn datasets`)"))?;
        let policy = ScalePolicy {
            node_cap: args.usize_or("node-cap", ScalePolicy::default().node_cap)?,
            edge_cap: args.usize_or("edge-cap", ScalePolicy::default().edge_cap)?,
        };
        Ok((name, datasets::materialize(spec, policy, seed)))
    } else {
        let n = args.usize_or("nodes", 2708)?;
        let avg = args.f64_or("avg-deg", 4.0)?;
        let mut rng = Pcg::seed_from(seed);
        let degs = generator::degree_sequence(
            generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.05 },
            n,
            (n as f64 * avg) as usize,
            &mut rng,
        );
        Ok((name, generator::from_degree_sequence(n, &degs, &mut rng)))
    }
}

fn cmd_prepare(rest: &[String]) -> Result<()> {
    let args = Args::parse(
        rest,
        &[
            "out", "graph", "nodes", "avg-deg", "feat-dim", "classes", "seed",
            "max-block-warps", "max-warp-nzs", "homophily", "node-cap", "edge-cap",
        ],
        &["no-features"],
    )?;
    let out = args.get("out").context("--out is required")?.to_string();
    let params = PartitionParams {
        max_block_warps: args.usize_or("max-block-warps", 12)?,
        max_warp_nzs: args.usize_or("max-warp-nzs", 32)?,
    };
    let seed = args.u64_or("seed", 42)?;

    let prepared = if args.str_or("graph", "synthetic") == "synthetic" && !args.flag("no-features")
    {
        // labeled community graph for the end-to-end training example
        let n = args.usize_or("nodes", 2708)?;
        let feat_dim = args.usize_or("feat-dim", 64)?;
        let classes = args.usize_or("classes", 8)?;
        let avg = args.f64_or("avg-deg", 4.0)?;
        let homophily = args.f64_or("homophily", 0.82)?;
        let mut rng = Pcg::seed_from(seed);
        let g = generator::labeled_communities(n, avg, feat_dim, classes, homophily, &mut rng);
        println!(
            "generated labeled graph: {} nodes, {} edges, {} classes, feat_dim {}",
            n,
            g.csr.nnz(),
            classes,
            feat_dim
        );
        PreparedDataset::prepare(&g.csr, params).with_node_data(feat_dim, &g.features, &g.labels)
    } else {
        let (name, csr) = build_graph(&args)?;
        println!("generated `{name}`: {} nodes, {} edges", csr.n_rows, csr.nnz());
        PreparedDataset::prepare(&csr, params)
    };

    prepared.save(&out)?;
    println!(
        "prepared: {} blocks, {} warp tasks, metadata ratio {:.1}%, padding overhead {:.2}x",
        prepared.partition.n_blocks(),
        prepared.partition.n_warp_tasks(),
        prepared.partition.footprint().ratio() * 100.0,
        prepared.layout.padding_overhead(),
    );
    println!("wrote {out}/ (bell_spec.json + tensors); next: python -m compile.aot --spec {out}/bell_spec.json --out {out}");
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["graph", "coldim", "kernels", "seed", "nodes", "avg-deg", "node-cap", "edge-cap"], &[])?;
    let (name, csr) = build_graph(&args)?;
    let coldim = args.usize_or("coldim", 64)?;
    let kernel_names =
        args.str_list_or("kernels", &["accel-gcn", "cusparse", "gnnadvisor", "graphblast"]);
    let cfg = GpuConfig::rtx3090();
    let cost = CostModel::default();
    // one-shot CLI run: build the plan directly (no point caching it —
    // long-lived consumers like the coordinator use PlanCache instead)
    let g = SpmmPlan::build(csr, PartitionParams::default());
    println!(
        "graph `{name}`: {} rows, {} nnz, coldim {coldim}",
        g.original.n_rows,
        g.original.nnz()
    );
    let mut table = accel_gcn::util::bench::Table::new(&[
        "kernel", "time (µs)", "DRAM MB", "mem-bound", "SM load CV", "blocks",
    ]);
    for kn in &kernel_names {
        let kind = match kn.as_str() {
            "accel-gcn" => KernelKind::AccelGcn,
            "cusparse" => KernelKind::CuSparse,
            "gnnadvisor" => KernelKind::GnnAdvisor,
            "graphblast" => KernelKind::GraphBlast,
            other => bail!("unknown kernel `{other}`"),
        };
        let r = simulate_kernel(&cfg, &cost, kind, KernelOptions::default(), &g, coldim);
        table.row(vec![
            r.name.clone(),
            format!("{:.1}", r.micros),
            format!("{:.2}", r.dram_bytes / 1e6),
            format!("{}", r.memory_bound),
            format!("{:.3}", r.sm_load_cv),
            format!("{}", r.n_blocks),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_datasets(_rest: &[String]) -> Result<()> {
    let policy = ScalePolicy::default();
    let mut table = accel_gcn::util::bench::Table::new(&[
        "graph", "family", "paper nodes", "paper edges", "scale", "sim nodes", "sim edges",
    ]);
    for spec in datasets::TABLE1 {
        let (n, e) = policy.scaled(spec);
        table.row(vec![
            spec.name.to_string(),
            spec.family.name().to_string(),
            spec.paper_nodes.to_string(),
            spec.paper_edges.to_string(),
            format!("{:.4}", policy.factor(spec)),
            n.to_string(),
            e.to_string(),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_stats(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["graph", "seed", "nodes", "avg-deg", "node-cap", "edge-cap"], &[])?;
    let (name, csr) = build_graph(&args)?;
    let s = stats::graph_stats(&csr);
    println!(
        "`{name}`: {} rows, {} nnz, avg deg {:.2}, max deg {} ({:.1}x avg), cv {:.2}, {} empty rows",
        s.n_rows, s.nnz, s.avg_degree, s.max_degree, s.max_over_avg, s.degree_cv, s.empty_rows
    );
    println!("row-degree histogram (log2 buckets):");
    print!("{}", stats::degree_histogram(&csr).ascii(48));
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["artifacts", "steps", "log-every"], &[])?;
    let dir = args.get("artifacts").context("--artifacts is required")?.to_string();
    let steps = args.usize_or("steps", 300)?;
    let log_every = args.usize_or("log-every", 20)?;
    harness::train::run_training(&dir, steps, log_every).map(|_| ())
}

/// Full-graph GCN training on the native pipeline — no Python, no
/// artifacts. Trains on a planted-partition labeled graph (or labels
/// planted onto a loaded edge list), verifies the backward SpMM against
/// the dense `Âᵀ` reference before training, and (with
/// `--require-loss-drop`) exits nonzero unless the final loss is at
/// most that fraction of the initial loss — the CI smoke contract.
fn cmd_train_native(rest: &[String]) -> Result<()> {
    use accel_gcn::graph::datasets::{labeled_from_topology, labeled_synthetic_with};
    use accel_gcn::graph::io::{load_edge_list, EdgeListOptions};
    use accel_gcn::model::ModelConfig;
    use accel_gcn::train::{default_lr, TrainConfig, Trainer};

    let args = Args::parse(
        rest,
        &[
            "nodes", "classes", "feat-dim", "hidden", "layers", "steps", "lr", "optimizer",
            "momentum", "homophily", "avg-deg", "threads", "patience", "seed", "edge-list",
            "require-loss-drop", "log-every",
        ],
        &["quick", "one-based"],
    )?;
    let quick = args.flag("quick");
    let seed = args.u64_or("seed", 42)?;
    let classes = args.usize_or("classes", 4)?;
    let feat_dim = args.usize_or("feat-dim", 16)?;
    let hidden = args.usize_or("hidden", 16)?;
    let layers = args.usize_or("layers", 2)?;
    let steps = args.usize_or("steps", if quick { 50 } else { 200 })?;
    let optimizer = args.str_or("optimizer", "sgd");
    let lr = args.f64_or("lr", default_lr(&optimizer))?;
    let threads = args.usize_or("threads", 4)?;
    // validate user-reachable knobs here so bad flags get clean CLI
    // errors instead of tripping library asserts
    anyhow::ensure!(lr.is_finite() && lr > 0.0, "--lr must be positive, got {lr}");
    anyhow::ensure!(classes >= 2, "--classes must be ≥ 2, got {classes}");
    anyhow::ensure!(layers >= 1, "--layers must be ≥ 1, got {layers}");
    anyhow::ensure!(
        feat_dim > 0 && hidden > 0,
        "--feat-dim and --hidden must be positive"
    );

    let data = match args.get("edge-list") {
        Some(path) => {
            let opts = EdgeListOptions { one_based: args.flag("one-based"), ..Default::default() };
            let g = load_edge_list(path, opts)?;
            anyhow::ensure!(
                g.n_rows >= 5,
                "`{path}` has {} nodes; training needs ≥ 5 for a 60/20/20 split",
                g.n_rows
            );
            println!("loaded `{path}`: {} nodes, {} edges; planting {classes} classes", g.n_rows, g.nnz());
            labeled_from_topology(&g, classes, feat_dim, seed)
        }
        None => {
            let nodes = args.usize_or("nodes", if quick { 300 } else { 1000 })?;
            let homophily = args.f64_or("homophily", 0.85)?;
            let avg_deg = args.f64_or("avg-deg", 6.0)?;
            anyhow::ensure!(nodes >= 5, "--nodes must be ≥ 5 for a 60/20/20 split, got {nodes}");
            anyhow::ensure!(
                (0.0..=1.0).contains(&homophily),
                "--homophily must be in [0, 1], got {homophily}"
            );
            let d = labeled_synthetic_with(nodes, classes, feat_dim, avg_deg, homophily, seed);
            println!(
                "generated labeled graph: {} nodes, {} edges, {} classes, feat_dim {feat_dim}, homophily {homophily}",
                nodes,
                d.csr.nnz(),
                classes
            );
            d
        }
    };
    let adj = data.csr.gcn_normalize();
    let cfg = TrainConfig {
        model: ModelConfig::gcn(feat_dim, hidden, classes, layers).with_lr(lr),
        optimizer: optimizer.clone(),
        momentum: args.f64_or("momentum", 0.9)?,
        steps,
        patience: args.usize_or("patience", 0)?,
        threads,
        seed,
        log_every: args.usize_or("log-every", 10)?,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&adj, cfg)?;
    println!(
        "training {layers}-layer GCN ({feat_dim}→{hidden}→{classes}) with {optimizer} (lr {lr}), \
         {threads} threads; transpose plan {}",
        if trainer.transpose_reused { "REUSED (Â symmetric)" } else { "built+cached" }
    );
    anyhow::ensure!(
        trainer.verify_backward_spmm(feat_dim, seed),
        "backward SpMM diverged from the dense Âᵀ reference"
    );
    println!("backward SpMM verified against dense Âᵀ reference");

    let report = trainer.train(&data)?;
    println!(
        "done: {} steps at {:.1} steps/s, loss {:.4} -> {:.4} ({:.1}% of initial){}",
        report.losses.len(),
        report.steps_per_sec,
        report.initial_loss(),
        report.final_loss(),
        100.0 * report.final_loss() / report.initial_loss(),
        if report.stopped_early { ", stopped early on val loss" } else { "" },
    );
    println!(
        "accuracy: train {:.1}%  val {:.1}%  test {:.1}%",
        report.train_accuracy * 100.0,
        report.val_accuracy * 100.0,
        report.test_accuracy * 100.0
    );
    println!("per-step phases: {}", report.phases.render_per_step(report.losses.len()));
    if let Some(frac) = args.get("require-loss-drop") {
        let frac: f64 = frac.parse().map_err(|e| anyhow::anyhow!("--require-loss-drop: {e}"))?;
        anyhow::ensure!(
            report.final_loss() <= frac * report.initial_loss(),
            "loss dropped to {:.1}% of initial, required ≤ {:.1}%",
            100.0 * report.final_loss() / report.initial_loss(),
            100.0 * frac
        );
        println!("loss-drop check passed (≤ {:.0}% of initial)", frac * 100.0);
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["artifacts", "requests", "coldims", "seed"], &[])?;
    let dir = args.get("artifacts").context("--artifacts is required")?.to_string();
    let n_requests = args.usize_or("requests", 64)?;
    let coldims = args.usize_list_or("coldims", &[16, 32, 64])?;
    harness::serve::run_serving(&dir, n_requests, &coldims, args.u64_or("seed", 1)?).map(|_| ())
}

fn cmd_serve_native(rest: &[String]) -> Result<()> {
    use accel_gcn::serve::PersistConfig;
    use accel_gcn::store::FsyncPolicy;

    let args = Args::parse(
        rest,
        &[
            "requests", "tenants", "nodes", "avg-deg", "threads", "ladder", "gcn-every", "seed",
            "metrics-out", "metrics-interval-ms", "trace-out", "tune-every", "data-dir", "fsync",
            "snapshot-every", "rounds", "updates", "update-size", "queue-capacity", "deadline-ms",
            "fault",
        ],
        &["no-verify"],
    )?;
    let persist = match args.get("data-dir") {
        Some(dir) => {
            let fsync = match args.str_or("fsync", "always").as_str() {
                "always" => FsyncPolicy::Always,
                "never" => FsyncPolicy::Never,
                other => bail!("--fsync must be always|never, got `{other}`"),
            };
            Some(PersistConfig {
                data_dir: dir.into(),
                fsync,
                snapshot_every: args.usize_or("snapshot-every", 0)?,
                fault_spec: args.get("fault").map(str::to_string),
            })
        }
        None => {
            for k in ["fsync", "snapshot-every", "fault"] {
                anyhow::ensure!(
                    args.get(k).is_none(),
                    "--{k} only makes sense together with --data-dir"
                );
            }
            None
        }
    };
    let defaults = harness::serve_native::LoadConfig::default();
    let cfg = harness::serve_native::LoadConfig {
        tenants: args.usize_or("tenants", defaults.tenants)?,
        nodes: args.usize_or("nodes", defaults.nodes)?,
        avg_deg: args.f64_or("avg-deg", defaults.avg_deg)?,
        requests: args.usize_or("requests", defaults.requests)?,
        threads: args.usize_or("threads", defaults.threads)?,
        ladder: args.usize_list_or("ladder", &defaults.ladder)?,
        gcn_every: args.usize_or("gcn-every", defaults.gcn_every)?,
        seed: args.u64_or("seed", defaults.seed)?,
        verify: !args.flag("no-verify"),
        tune_every: args.usize_or("tune-every", 0)?,
        rounds: args.usize_or("rounds", defaults.rounds)?,
        updates_per_round: args.usize_or("updates", defaults.updates_per_round)?,
        update_size: args.usize_or("update-size", defaults.update_size)?,
        queue_capacity: args.usize_or("queue-capacity", defaults.queue_capacity)?,
        deadline_ms: args.u64_or("deadline-ms", defaults.deadline_ms)?,
        persist,
    };
    let interval_ms = args.u64_or("metrics-interval-ms", 250)?;
    anyhow::ensure!(interval_ms > 0, "--metrics-interval-ms must be > 0, got {interval_ms}");
    println!(
        "serve-native: {} round(s) × {} requests, {} tenants (~{} nodes each), {} threads, \
         ladder {:?}, verify={}, tune-every={}{}",
        cfg.rounds,
        cfg.requests,
        cfg.tenants,
        cfg.nodes,
        cfg.threads,
        cfg.ladder,
        cfg.verify,
        cfg.tune_every,
        match &cfg.persist {
            Some(p) => format!(
                ", durable under {} (fsync {:?}, snapshot-every {})",
                p.data_dir.display(),
                p.fsync,
                p.snapshot_every
            ),
            None => String::new(),
        }
    );
    // --metrics-out turns tracing on and dumps the snapshot both
    // periodically (so an interrupted run still leaves a usable file)
    // and — with the serve section merged in — at exit; --trace-out and
    // --tune-every also need the registry recording
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let trace_out = args.get("trace-out").map(str::to_string);
    if metrics_out.is_some() || trace_out.is_some() || cfg.tune_every > 0 {
        accel_gcn::obs::Registry::global().set_enabled(true);
    }
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let writer = metrics_out.as_ref().map(|path| {
        let path = path.clone();
        let stop = std::sync::Arc::clone(&stop);
        std::thread::spawn(move || loop {
            let _ = write_metrics_snapshot(&path, None);
            // wait out the interval in short slices so exit isn't
            // delayed by a long --metrics-interval-ms
            let mut waited = 0u64;
            while waited < interval_ms {
                if stop.load(std::sync::atomic::Ordering::Relaxed) {
                    return;
                }
                let step = 100.min(interval_ms - waited);
                std::thread::sleep(std::time::Duration::from_millis(step));
                waited += step;
            }
        })
    });
    let run = harness::serve_native::run_once_with_metrics(&cfg);
    if let Some(h) = writer {
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = h.join();
    }
    // the final authoritative metrics/trace snapshots are written even
    // when the run failed mid-round — a faulted or interrupted run must
    // still leave usable observability artifacts behind (the server's
    // Drop has already drained the queue and flushed the WALs)
    if let Some(path) = &metrics_out {
        let serve = run.as_ref().ok().map(|(_, m)| &**m);
        write_metrics_snapshot(path, serve)?;
        println!("metrics snapshot written to {path}");
    }
    if let Some(path) = &trace_out {
        write_trace_snapshot(path)?;
        println!("trace timeline written to {path} (load in Perfetto / chrome://tracing)");
    }
    let (point, metrics) = run?;
    print!("{}", harness::serve_native::report(std::slice::from_ref(&point)));
    print!("{}", metrics.render());
    if point.recovered_tenants > 0 {
        println!(
            "recovered {} tenant(s) from {} ({} WAL batch(es) replayed)",
            point.recovered_tenants,
            cfg.persist.as_ref().map(|p| p.data_dir.display().to_string()).unwrap_or_default(),
            point.replayed_batches
        );
    }
    println!(
        "served {} requests ({} shed, {} retries) across {} resident graphs: {:.1} req/s, \
         fusion factor {:.2}, updates {}/{} applied, verified={}",
        point.requests,
        point.shed_requests,
        point.retries,
        point.tenants,
        point.requests_per_sec,
        point.fusion_factor,
        point.updates_applied,
        point.updates_applied + point.updates_shed,
        point.verified
    );
    Ok(())
}

/// Recover every tenant under `--data-dir` **without serving**: load
/// the newest readable snapshot generation, replay the WAL tail
/// through the same [`DeltaGraph`](accel_gcn::delta::DeltaGraph) path
/// live updates take, and report what recovery saw. Documented
/// fallbacks (torn final record dropped, snapshot generation fallback,
/// unsealed final epoch) are reported but pass; corruption beyond them
/// — unreadable snapshots on every generation, a mid-log checksum
/// mismatch, a sealed fingerprint that diverges — exits nonzero. The
/// post-SIGKILL CI smoke runs this against a freshly killed server's
/// directory.
fn cmd_recover_check(rest: &[String]) -> Result<()> {
    use accel_gcn::pipeline::spmm_block_level_parallel;
    use accel_gcn::spmm::verify::allclose;
    use accel_gcn::store::{recover_tenant, FsyncPolicy, Store};
    use accel_gcn::util::threadpool::ThreadPool;

    let args = Args::parse(rest, &["data-dir", "threads", "seed"], &["verify-spmm"])?;
    let dir = args.get("data-dir").context("--data-dir is required")?;
    let store = Store::open_existing(dir, FsyncPolicy::Never)?;
    let dirs = store.tenant_dirs()?;
    anyhow::ensure!(!dirs.is_empty(), "no tenants under {dir}");
    let verify_spmm = args.flag("verify-spmm");
    let seed = args.u64_or("seed", 42)?;
    let pool = ThreadPool::new(args.usize_or("threads", 4)?);
    let mut table = accel_gcn::util::bench::Table::new(&[
        "tenant", "epoch", "snap gen", "snap epoch", "replayed", "fell back", "torn tail",
        "sealed", "spmm",
    ]);
    let mut failures = Vec::new();
    for d in &dirs {
        let ts = store.tenant_by_dir(d);
        match recover_tenant(&ts) {
            Ok(rec) => {
                let spmm_cell = if verify_spmm {
                    // re-execute through the full pipeline (relabel +
                    // partition + block-level executor) against the
                    // dense reference on the recovered matrix
                    let plan = SpmmPlan::build(rec.csr.clone(), PartitionParams::default());
                    let f = 16;
                    let mut rng = Pcg::seed_from(seed);
                    let x: Vec<f32> =
                        (0..rec.csr.n_rows * f).map(|_| rng.f32() - 0.5).collect();
                    let y = spmm_block_level_parallel(&plan, &x, f, &pool);
                    if allclose(&y, &rec.csr.spmm_dense(&x, f), 1e-3, 1e-3) {
                        "ok".to_string()
                    } else {
                        failures
                            .push(format!("{}: recovered SpMM diverged from dense", rec.name));
                        "DIVERGED".to_string()
                    }
                } else {
                    "-".to_string()
                };
                table.row(vec![
                    rec.name.clone(),
                    rec.epoch.to_string(),
                    rec.snapshot_gen.to_string(),
                    rec.snapshot_epoch.to_string(),
                    rec.replayed_batches.to_string(),
                    rec.snapshot_fell_back.to_string(),
                    rec.torn_tail_dropped.to_string(),
                    rec.fingerprint_verified.to_string(),
                    spmm_cell,
                ]);
            }
            Err(e) => failures.push(format!("{d}: {e}")),
        }
    }
    print!("{}", table.render());
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("recover-check FAILED: {f}");
        }
        bail!("{} of {} tenant(s) failed recovery", failures.len(), dirs.len());
    }
    println!("recover-check: all {} tenant(s) recovered cleanly", dirs.len());
    Ok(())
}

/// Write the global registry's snapshot (plus the serve section when a
/// server's metrics are at hand) as pretty JSON at `path`.
fn write_metrics_snapshot(path: &str, serve: Option<&accel_gcn::serve::ServeMetrics>) -> Result<()> {
    let mut doc = accel_gcn::obs::Registry::global().snapshot();
    if let Some(m) = serve {
        doc.set("serve", m.snapshot_json());
    }
    let p = std::path::Path::new(path);
    if let Some(parent) = p.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(p, doc.to_pretty()).with_context(|| format!("write {path}"))
}

/// Write the global registry's Chrome trace-event timeline as pretty
/// JSON at `path` (the `{"traceEvents": [...]}` form Perfetto loads;
/// also accepted by `validate-metrics`).
fn write_trace_snapshot(path: &str) -> Result<()> {
    let doc = accel_gcn::obs::Registry::global().export_trace();
    let p = std::path::Path::new(path);
    if let Some(parent) = p.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(p, doc.to_pretty()).with_context(|| format!("write {path}"))
}

/// Stream edge-update batches against a graph, patching its plan
/// incrementally and verifying every patch against a from-scratch
/// rebuild — the delta subsystem's end-to-end demo and CI smoke
/// (exits nonzero on any divergence).
fn cmd_update_demo(rest: &[String]) -> Result<()> {
    use accel_gcn::bench::delta_update::random_batch;
    use accel_gcn::delta::{patch_plan, DeltaGraph};
    use accel_gcn::graph::io::{load_edge_list, EdgeListOptions};
    use accel_gcn::pipeline::spmm_block_level_parallel;
    use accel_gcn::spmm::verify::allclose;
    use accel_gcn::util::threadpool::ThreadPool;
    use std::sync::Arc;

    let args = Args::parse(
        rest,
        &["nodes", "avg-deg", "batches", "batch-size", "seed", "edge-list", "threads"],
        &["one-based"],
    )?;
    let seed = args.u64_or("seed", 42)?;
    let batches = args.usize_or("batches", 8)?;
    let batch_size = args.usize_or("batch-size", 64)?;
    let threads = args.usize_or("threads", 4)?;
    let csr = match args.get("edge-list") {
        Some(path) => {
            let opts = EdgeListOptions { one_based: args.flag("one-based"), ..Default::default() };
            let g = load_edge_list(path, opts)?;
            println!("loaded `{path}`: {} nodes, {} edges", g.n_rows, g.nnz());
            g
        }
        None => {
            let n = args.usize_or("nodes", 2000)?;
            let avg = args.f64_or("avg-deg", 8.0)?;
            let mut rng = Pcg::seed_from(seed);
            let degs = generator::degree_sequence(
                generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.1 },
                n,
                (n as f64 * avg) as usize,
                &mut rng,
            );
            let g = generator::from_degree_sequence(n, &degs, &mut rng);
            println!("generated power-law graph: {} nodes, {} edges", n, g.nnz());
            g
        }
    };
    anyhow::ensure!(csr.n_rows > 0, "update-demo needs a non-empty graph");
    let n = csr.n_rows;
    let params = PartitionParams::default();
    let pool = ThreadPool::new(threads);
    let mut rng = Pcg::seed_from(seed ^ 0xde17a);
    let mut delta = DeltaGraph::new(csr.clone());
    let mut plan = Arc::new(SpmmPlan::build(csr, params));
    let (mut patch_total, mut replan_total) = (0.0f64, 0.0f64);
    for b in 0..batches {
        let batch = random_batch(&delta.snapshot(), batch_size, &mut rng);
        let report = delta.apply(&batch)?;
        let new_csr = delta.snapshot();
        let t0 = std::time::Instant::now();
        let (patched, stats) = patch_plan(&plan, new_csr.clone(), &report.changes)?;
        let patch_us = t0.elapsed().as_secs_f64() * 1e6;
        let t1 = std::time::Instant::now();
        let rebuilt = SpmmPlan::build(new_csr.clone(), params);
        let replan_us = t1.elapsed().as_secs_f64() * 1e6;
        // the acceptance check: patched plan == from-scratch rebuild
        let identical = patched.sorted.perm == rebuilt.sorted.perm
            && patched.sorted.csr == rebuilt.sorted.csr
            && patched.block.meta == rebuilt.block.meta
            && patched.warp.groups == rebuilt.warp.groups;
        anyhow::ensure!(identical, "batch {b}: patched plan diverged from rebuild");
        plan = Arc::new(patched);
        let f = 16;
        let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        // the parallel executor scatters straight into original row order
        let y = spmm_block_level_parallel(&plan, &x, f, &pool);
        anyhow::ensure!(
            allclose(&y, &new_csr.spmm_dense(&x, f), 1e-3, 1e-3),
            "batch {b}: patched SpMM diverged from the dense reference"
        );
        patch_total += patch_us;
        replan_total += replan_us;
        println!(
            "batch {b}: {} ops, {} rows changed ({} moved), nnz {} -> {}, \
             meta reuse {:.1}%, patch {:.0}µs vs replan {:.0}µs ({:.2}x){}",
            report.staged_ops,
            stats.rows_changed,
            stats.rows_moved,
            stats.nnz_before,
            stats.nnz_after,
            stats.reuse_frac() * 100.0,
            patch_us,
            replan_us,
            replan_us / patch_us.max(1e-9),
            if report.compacted { ", compacted" } else { "" },
        );
    }
    println!(
        "all {batches} batches verified (plan == rebuild, SpMM == dense); \
         total patch {:.0}µs vs replan {:.0}µs ({:.2}x)",
        patch_total,
        replan_total,
        replan_total / patch_total.max(1e-9),
    );
    Ok(())
}

fn cmd_bench(rest: &[String]) -> Result<()> {
    let args = Args::parse(
        rest,
        &["out", "experiment", "seed", "node-cap", "edge-cap", "coldims", "graphs"],
        &["quick"],
    )?;
    harness::paper::run_from_args(&args)
}

/// Run SpMM and training iterations with tracing enabled, then report
/// what the observability subsystem saw: the per-shard utilization
/// table, the shard-imbalance ratio, and the flamegraph-style span
/// tree. `--json` additionally writes the full metrics snapshot
/// (validated by `validate-metrics` in CI).
fn cmd_profile(rest: &[String]) -> Result<()> {
    use accel_gcn::graph::datasets::labeled_synthetic_with;
    use accel_gcn::pipeline::spmm_block_level_parallel;
    use accel_gcn::train::{TrainConfig, Trainer};
    use accel_gcn::util::threadpool::ThreadPool;

    let args = Args::parse(
        rest,
        &[
            "nodes", "avg-deg", "feat-dim", "iters", "train-steps", "threads", "seed", "json",
            "trace-out", "tune-every",
        ],
        &["quick"],
    )?;
    let quick = args.flag("quick");
    let nodes = args.usize_or("nodes", if quick { 800 } else { 5000 })?;
    let avg_deg = args.f64_or("avg-deg", 8.0)?;
    let feat_dim = args.usize_or("feat-dim", 32)?;
    let iters = args.usize_or("iters", if quick { 10 } else { 40 })?;
    let train_steps = args.usize_or("train-steps", if quick { 5 } else { 10 })?;
    let threads = args.usize_or("threads", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let tune_every = args.usize_or("tune-every", 0)?;
    anyhow::ensure!(nodes >= 5, "--nodes must be ≥ 5, got {nodes}");
    anyhow::ensure!(iters >= 1, "--iters must be ≥ 1, got {iters}");

    let reg = accel_gcn::obs::Registry::global();
    reg.set_enabled(true);

    // skewed power-law topology — the degree shape that makes shard
    // imbalance worth measuring
    let mut rng = Pcg::seed_from(seed);
    let degs = generator::degree_sequence(
        generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.05 },
        nodes,
        (nodes as f64 * avg_deg) as usize,
        &mut rng,
    );
    let csr = generator::from_degree_sequence(nodes, &degs, &mut rng);
    println!(
        "profile: power-law graph {} nodes / {} nnz, feat dim {feat_dim}, \
         {iters} SpMM iters + {train_steps} train steps, {threads} threads",
        csr.n_rows,
        csr.nnz()
    );
    let mut plan = SpmmPlan::build(csr, PartitionParams::default());
    let pool = ThreadPool::new(threads);
    let x: Vec<f32> = (0..nodes * feat_dim).map(|_| rng.f32() - 0.5).collect();
    // untuned reference output — every tuned swap below must stay
    // bit-for-bit identical to this (the tuner's core contract)
    let baseline: Vec<u32> = if tune_every > 0 {
        spmm_block_level_parallel(&plan, &x, feat_dim, &pool).iter().map(|v| v.to_bits()).collect()
    } else {
        Vec::new()
    };
    let tuner = accel_gcn::tune::PlanTuner::default();
    let mut swaps = 0usize;
    for i in 0..iters {
        let _span = reg.span("profile/spmm");
        let y = spmm_block_level_parallel(&plan, &x, feat_dim, &pool);
        drop(y);
        if tune_every > 0 && (i + 1) % tune_every == 0 {
            if let Some(tuned) = tuner.maybe_tune(reg, &plan, threads) {
                plan = tuned;
                swaps += 1;
                reg.counter("tune.swaps").inc();
                // fresh measurement window so the next fit (and the
                // final shard table) reflects the tuned layout
                reg.reset_shards();
            }
        }
    }
    if tune_every > 0 {
        let tuned_bits: Vec<u32> = spmm_block_level_parallel(&plan, &x, feat_dim, &pool)
            .iter()
            .map(|v| v.to_bits())
            .collect();
        anyhow::ensure!(
            tuned_bits == baseline,
            "tuned plan output diverged bit-for-bit from the untuned plan"
        );
        match &plan.tuned {
            Some(t) => {
                println!(
                    "tuning: {swaps} swap(s); cost-model imbalance static {:.3} -> tuned {:.3} \
                     (crossover deg {}); output bit-identical to untuned: true",
                    t.predicted_static_imbalance, t.predicted_tuned_imbalance, t.crossover
                );
                anyhow::ensure!(
                    t.predicted_tuned_imbalance <= t.predicted_static_imbalance * (1.0 + 1e-9),
                    "tuned imbalance {:.3} exceeds static {:.3}",
                    t.predicted_tuned_imbalance,
                    t.predicted_static_imbalance
                );
            }
            None => println!(
                "tuning: tuner declined every window (already balanced within tolerance); \
                 output bit-identical to untuned: true"
            ),
        }
    }
    if train_steps > 0 {
        // no wrapper span here: the trainer opens its own `train_step`
        // guard per step, and its per-phase children are recorded under
        // explicit `train_step/...` paths — a wrapper would fork the
        // guard path away from the explicit ones
        let data = labeled_synthetic_with(nodes, 4, feat_dim, avg_deg.min(6.0), 0.85, seed);
        let adj = data.csr.gcn_normalize();
        let cfg = TrainConfig {
            model: accel_gcn::model::ModelConfig::gcn(feat_dim, 16, 4, 2).with_lr(0.1),
            steps: train_steps,
            threads,
            seed,
            tune_every,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(&adj, cfg)?;
        trainer.train(&data)?;
    }

    println!("\nper-shard utilization ({} threads):", threads);
    print!("{}", reg.render_shard_table());
    let agg = reg.shard_aggregates();
    let busy_total: u64 = agg.iter().map(|a| a.busy_ns).sum();
    println!(
        "shard busy-ns total {busy_total} across {} shards; imbalance ratio (max/mean busy) {:.3}",
        agg.len(),
        reg.imbalance_ratio()
    );
    let imb = reg.histogram("spmm.shard_imbalance").snapshot();
    println!(
        "per-dispatch imbalance: p50 {:.3}  p99 {:.3}  worst {:.3} over {} dispatches",
        imb.p50, imb.p99, imb.max, imb.count
    );
    // bytes sampled per shard by the executor (the analytic per-block
    // model applied to each dispatch) over mean shard busy time —
    // shards run concurrently, so the wall-clock denominator is the
    // mean, not the sum
    let bytes_total: u64 = agg.iter().map(|a| a.bytes_read + a.bytes_written).sum();
    if bytes_total > 0 && busy_total > 0 {
        let mean_busy_s = busy_total as f64 / agg.len().max(1) as f64 / 1e9;
        let gbps = bytes_total as f64 / mean_busy_s.max(1e-12) / 1e9;
        let peak = accel_gcn::obs::calibrate::global()
            .map(|c| format!(" ({:.1}% of the {:.2} GB/s calibrated peak)", c.pct_of_peak(gbps), c.peak_gbps))
            .unwrap_or_default();
        println!(
            "memory traffic: {:.1} MB sampled across shards, achieved {gbps:.2} GB/s{peak}",
            bytes_total as f64 / 1e6
        );
    }
    println!("\nspan tree:");
    print!("{}", accel_gcn::obs::render_span_tree(&reg.span_stats()));
    if let Some(path) = args.get("json") {
        write_metrics_snapshot(path, None)?;
        println!("\nmetrics snapshot written to {path}");
    }
    if let Some(path) = args.get("trace-out") {
        write_trace_snapshot(path)?;
        println!("trace timeline written to {path} (load in Perfetto / chrome://tracing)");
    }
    Ok(())
}

/// Roofline analysis of the SpMM stack against calibrated machine
/// roofs. Two halves:
///
/// 1. **Calibration** — [`accel_gcn::obs::calibrate`] measures the
///    achievable memory bandwidth (STREAM copy/scale/triad across
///    thread counts and working-set sizes, L1-resident through
///    DRAM-sized) and peak FLOP rate (FMA chains), cached as versioned
///    JSON at `--calibration` so repeat runs skip the ~seconds-long
///    sweep; `--recalibrate` forces a fresh one.
/// 2. **Roofline** — builds a power-law graph, runs the parallel SpMM
///    at each `--coldims` width, and reports achieved GB/s, GFLOP/s,
///    arithmetic intensity, and the bandwidth- vs compute-bound
///    verdict, per graph and per `(split, kernel, degree)` traffic
///    bucket. The plan's analytic byte count is cross-checked **byte
///    for byte** against the instrumented counting executor — any
///    drift between model and code is a hard error, and the emitted
///    JSON re-encodes both so `validate-metrics` re-checks it in CI.
fn cmd_roofline(rest: &[String]) -> Result<()> {
    use accel_gcn::obs::calibrate;
    use accel_gcn::pipeline::spmm_block_level_parallel;
    use accel_gcn::spmm::microkernel::spmm_gflops;
    use accel_gcn::spmm::verify::allclose;
    use accel_gcn::spmm::spmm_block_level_counting;
    use accel_gcn::util::json::Json;
    use accel_gcn::util::threadpool::ThreadPool;

    let args = Args::parse(
        rest,
        &["json", "calibration", "nodes", "avg-deg", "coldims", "threads", "iters", "seed"],
        &["quick", "recalibrate"],
    )?;
    let quick = args.flag("quick");
    let threads = args.usize_or("threads", 4)?;
    let nodes = args.usize_or("nodes", if quick { 2_000 } else { 20_000 })?;
    let avg_deg = args.f64_or("avg-deg", 8.0)?;
    let coldims = args.usize_list_or("coldims", &[16, 64])?;
    let iters = args.usize_or("iters", if quick { 5 } else { 20 })?;
    let seed = args.u64_or("seed", 42)?;
    anyhow::ensure!(nodes >= 5, "--nodes must be ≥ 5, got {nodes}");
    anyhow::ensure!(iters >= 1, "--iters must be ≥ 1, got {iters}");
    anyhow::ensure!(
        !coldims.is_empty() && coldims.iter().all(|&f| f > 0),
        "--coldims needs at least one positive width"
    );

    let cal_path = args.str_or("calibration", "results/calibration.json");
    let (cal, was_cached) = calibrate::load_or_run(
        std::path::Path::new(&cal_path),
        quick,
        threads,
        args.flag("recalibrate"),
    )?;
    calibrate::set_global(&cal);
    println!(
        "calibration ({} {cal_path}): {}",
        if was_cached { "cached at" } else { "measured, cached to" },
        cal.summary()
    );

    // the same skewed power-law shape `profile` uses — the degree mix
    // that exercises both kernel variants and the split path at once
    let mut rng = Pcg::seed_from(seed);
    let degs = generator::degree_sequence(
        generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.05 },
        nodes,
        (nodes as f64 * avg_deg) as usize,
        &mut rng,
    );
    let csr = generator::from_degree_sequence(nodes, &degs, &mut rng);
    let nnz = csr.nnz();
    let plan = SpmmPlan::build(csr, PartitionParams::default());
    let pool = ThreadPool::new(threads);
    println!(
        "roofline: power-law graph {nodes} nodes / {nnz} nnz, coldims {coldims:?}, \
         {threads} threads, min over {iters} iters"
    );

    let mut graphs: Vec<Json> = Vec::new();
    for &f in &coldims {
        let x: Vec<f32> = (0..nodes * f).map(|_| rng.f32() - 0.5).collect();
        // warm-up run doubles as the reference for the counting check
        let y_ref = spmm_block_level_parallel(&plan, &x, f, &pool);
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            let y = spmm_block_level_parallel(&plan, &x, f, &pool);
            best = best.min(t0.elapsed().as_secs_f64());
            drop(y);
        }
        // the instrumented scalar executor replays the exact schedule
        // and counts every byte; its total must equal the analytic
        // model's, and its output must match the parallel executor's
        let (y_counted, counts) = spmm_block_level_counting(&plan, &x, f);
        anyhow::ensure!(
            allclose(&y_counted, &y_ref, 1e-3, 1e-3),
            "counting executor diverged from the parallel executor at f={f}"
        );
        let analytic = plan.traffic.bytes_total(f);
        let instrumented = counts.bytes_read + counts.bytes_written;
        anyhow::ensure!(
            instrumented == analytic,
            "traffic model drifted from the executor at f={f}: \
             analytic {analytic} bytes != instrumented {instrumented} bytes"
        );
        let achieved_gbps = analytic as f64 / best.max(1e-12) / 1e9;
        let achieved_gflops = spmm_gflops(nnz, f, best);
        let intensity = plan.traffic.arithmetic_intensity(f);
        let verdict = cal.verdict(intensity);
        let pct = cal.pct_of_peak(achieved_gbps);
        println!(
            "\nf={f}: {:.0} µs/SpMM (best), {analytic} bytes ({:.1} B/nnz, verified against \
             the counting executor), {achieved_gbps:.2} GB/s achieved = {pct:.1}% of the \
             {:.2} GB/s peak, {achieved_gflops:.2} GFLOP/s, intensity {intensity:.4} \
             flops/byte → {verdict}",
            best * 1e6,
            plan.traffic.bytes_per_nnz(f),
            cal.peak_gbps,
        );
        println!(
            "  storage what-if: f16-storage {:.2}x, i8-storage {:.2}x fewer bytes \
             (a direct throughput multiplier while bandwidth-bound)",
            plan.traffic.quantized_speedup(f, accel_gcn::pipeline::ElemWidths::F16_STORAGE),
            plan.traffic.quantized_speedup(f, accel_gcn::pipeline::ElemWidths::I8_STORAGE),
        );

        // per-bucket table, heaviest traffic first — a power-law graph
        // can have hundreds of distinct-degree buckets, so cap the
        // human table and say what was elided (the JSON has them all)
        let mut order: Vec<&accel_gcn::pipeline::BucketTraffic> =
            plan.traffic.buckets.iter().collect();
        order.sort_by(|a, b| b.bytes_total(f).cmp(&a.bytes_total(f)));
        let shown = order.len().min(12);
        let mut table = accel_gcn::util::bench::Table::new(&[
            "deg", "kernel", "split", "blocks", "rows", "nnz", "KB", "B/nnz", "flops/B",
        ]);
        for b in &order[..shown] {
            table.row(vec![
                b.deg.to_string(),
                b.kernel.name().to_string(),
                b.split.to_string(),
                b.blocks.to_string(),
                b.rows.to_string(),
                b.nnz.to_string(),
                format!("{:.1}", b.bytes_total(f) as f64 / 1e3),
                format!("{:.1}", b.bytes_per_nnz(f)),
                format!("{:.4}", b.arithmetic_intensity(f)),
            ]);
        }
        print!("{}", table.render());
        if order.len() > shown {
            println!("  … {} more buckets (all in the JSON report)", order.len() - shown);
        }

        let buckets: Vec<Json> = plan
            .traffic
            .buckets
            .iter()
            .map(|b| {
                let mut j = Json::obj();
                j.set("deg", b.deg)
                    .set("split", b.split)
                    .set("kernel", b.kernel.name())
                    .set("blocks", b.blocks)
                    .set("rows", b.rows)
                    .set("nnz", b.nnz)
                    .set("bytes_total", b.bytes_total(f))
                    .set("bytes_per_nnz", b.bytes_per_nnz(f))
                    .set("intensity", b.arithmetic_intensity(f));
                j
            })
            .collect();
        let mut g = Json::obj();
        g.set("graph", "powerlaw")
            .set("n", nodes)
            .set("nnz", nnz)
            .set("f", f)
            .set("threads", threads)
            .set("spmm_secs", best)
            .set("analytic_bytes", analytic)
            .set("instrumented_bytes", instrumented)
            .set("bytes_per_nnz", plan.traffic.bytes_per_nnz(f))
            .set("arithmetic_intensity", intensity)
            .set("achieved_gbps", achieved_gbps)
            .set("achieved_gflops", achieved_gflops)
            .set("pct_peak", pct)
            .set("verdict", verdict)
            .set("buckets", buckets);
        graphs.push(g);
    }

    let mut doc = Json::obj();
    let mut cal_j = Json::obj();
    cal_j
        .set("peak_gbps", cal.peak_gbps)
        .set("peak_gflops", cal.peak_gflops)
        .set("machine_balance", cal.machine_balance())
        .set("threads", cal.best_threads)
        .set("simd", cal.simd.as_str());
    doc.set("schema", accel_gcn::obs::ROOFLINE_SCHEMA_VERSION)
        .set("meta", accel_gcn::obs::run_metadata())
        .set("calibration", cal_j)
        .set("graphs", graphs);
    // the emitter must pass its own validator — the same check CI
    // re-runs on the written file via `validate-metrics`
    accel_gcn::obs::validate_roofline(&doc).context("roofline self-validation")?;
    if let Some(path) = args.get("json") {
        let p = std::path::Path::new(path);
        if let Some(parent) = p.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(p, doc.to_pretty()).with_context(|| format!("write {path}"))?;
        println!("\nroofline report written to {path}");
    }
    Ok(())
}

/// Diff two `BENCH_*.json` reports ([`harness::compare`]): print the
/// per-metric speedup table and exit nonzero if any direction-aware
/// metric regresses beyond `--max-regress` percent.
fn cmd_bench_compare(rest: &[String]) -> Result<()> {
    use accel_gcn::util::json::Json;
    let args = Args::parse(rest, &["max-regress"], &[])?;
    let files = args.positional();
    anyhow::ensure!(
        files.len() == 2,
        "usage: accel-gcn bench-compare OLD.json NEW.json [--max-regress PCT]"
    );
    let max_regress = args.f64_or("max-regress", 5.0)?;
    anyhow::ensure!(
        max_regress.is_finite() && max_regress >= 0.0,
        "--max-regress must be ≥ 0, got {max_regress}"
    );
    let read = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        Json::parse(&text).with_context(|| format!("parse {path}"))
    };
    let (old, new) = (read(&files[0])?, read(&files[1])?);
    let r = harness::compare::compare(&old, &new, max_regress);
    print!("{}", r.render());
    let regressed = r.regressions().len();
    anyhow::ensure!(
        regressed == 0,
        "{regressed} metric(s) regressed beyond {max_regress}% (old {}, new {})",
        files[0],
        files[1]
    );
    println!("bench-compare: no regressions beyond {max_regress:.1}%");
    Ok(())
}

/// Schema-check observability JSON files (CI's validator for the four
/// formats the stack emits): metrics snapshots (`profile --json`,
/// `serve-native --metrics-out`), Chrome trace-event timelines
/// (`--trace-out`), roofline reports (`roofline --json`), and
/// bandwidth calibrations (the `roofline --calibration` cache).
/// Roofline and calibration files carry their own `schema` string and
/// are routed on it; the remaining two are told apart by the
/// `traceEvents` key.
fn cmd_validate_metrics(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[], &[])?;
    let files = args.positional();
    anyhow::ensure!(!files.is_empty(), "usage: accel-gcn validate-metrics FILE [FILE...]");
    for path in files {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let doc = accel_gcn::util::json::Json::parse(&text)
            .with_context(|| format!("parse {path}"))?;
        let schema = doc.get("schema").and_then(|s| s.as_str()).unwrap_or("");
        if schema == accel_gcn::obs::ROOFLINE_SCHEMA_VERSION {
            accel_gcn::obs::validate_roofline(&doc)
                .with_context(|| format!("validate {path}"))?;
            println!("{path}: OK ({schema})");
        } else if schema == accel_gcn::obs::CALIBRATION_SCHEMA_VERSION {
            accel_gcn::obs::validate_calibration(&doc)
                .with_context(|| format!("validate {path}"))?;
            println!("{path}: OK ({schema})");
        } else if doc.get("traceEvents").is_some() {
            accel_gcn::obs::validate_trace(&doc).with_context(|| format!("validate {path}"))?;
            println!("{path}: OK ({})", accel_gcn::obs::TRACE_SCHEMA_VERSION);
        } else {
            accel_gcn::obs::validate_snapshot(&doc)
                .with_context(|| format!("validate {path}"))?;
            println!("{path}: OK ({})", accel_gcn::obs::SCHEMA_VERSION);
        }
    }
    Ok(())
}
