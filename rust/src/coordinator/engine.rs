//! The engine: a dedicated device thread owning the PJRT runtime.
//!
//! `xla::PjRtClient` and friends are `Rc`-backed, so all compilation and
//! execution happens on one thread; front ends submit `Job`s over an
//! mpsc channel and receive results on per-request channels. Static
//! inputs (the BELL bucket tensors, or a frozen feature matrix) are
//! **bound** once per artifact — the device thread keeps their literals
//! alive and the hot path only ships the tensors that change
//! (vLLM-style weight residency, scaled down to one CPU device).

use crate::metrics::{Counter, LatencyRecorder};
use crate::runtime::{HostTensor, Manifest, Runtime};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub requests: Counter,
    pub errors: Counter,
    /// device-side execute latency
    pub exec_latency: LatencyRecorder,
    /// enqueue → completion
    pub total_latency: LatencyRecorder,
}

enum Job {
    /// Compile an artifact (idempotent).
    Load { name: String, reply: Sender<Result<()>> },
    /// Bind static inputs at fixed positions of an artifact.
    Bind { name: String, positions: Vec<(usize, HostTensor)>, reply: Sender<Result<()>> },
    /// Bind all `bell_*` inputs of an artifact from the artifact dir.
    BindBell { name: String, reply: Sender<Result<()>> },
    /// Execute: `dynamic` fills the unbound positions in manifest order.
    Exec {
        name: String,
        dynamic: Vec<HostTensor>,
        enqueued: Instant,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    Shutdown,
}

/// Handle to the device thread.
pub struct Engine {
    tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<EngineMetrics>,
    manifest: Manifest,
}

impl Engine {
    /// Start the device thread over an artifact directory.
    pub fn start(artifact_dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir: PathBuf = artifact_dir.into();
        let manifest = Manifest::load(&dir)?;
        let metrics = Arc::new(EngineMetrics::default());
        let (tx, rx) = channel::<Job>();
        let thread_manifest = manifest.clone();
        let thread_metrics = Arc::clone(&metrics);
        let handle = std::thread::Builder::new()
            .name("accel-gcn-device".into())
            .spawn(move || device_loop(thread_manifest, rx, thread_metrics))
            .expect("spawn device thread");
        Ok(Engine { tx, handle: Some(handle), metrics, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn rpc<T>(&self, build: impl FnOnce(Sender<Result<T>>) -> Job) -> Result<T> {
        let (reply, rx) = channel();
        self.tx.send(build(reply)).map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    /// Compile an artifact on the device thread (blocking).
    pub fn load_artifact(&self, name: &str) -> Result<()> {
        self.rpc(|reply| Job::Load { name: name.to_string(), reply })
    }

    /// Bind static tensors at explicit input positions.
    pub fn bind(&self, name: &str, positions: Vec<(usize, HostTensor)>) -> Result<()> {
        self.rpc(|reply| Job::Bind { name: name.to_string(), positions, reply })
    }

    /// Bind every `bell_*` input of an artifact from the artifact dir.
    pub fn bind_bell(&self, name: &str) -> Result<()> {
        self.rpc(|reply| Job::BindBell { name: name.to_string(), reply })
    }

    /// Submit an execution; returns the reply channel immediately.
    pub fn submit(&self, name: &str, dynamic: Vec<HostTensor>) -> Receiver<Result<Vec<HostTensor>>> {
        let (reply, rx) = channel();
        self.metrics.requests.inc();
        let job = Job::Exec {
            name: name.to_string(),
            dynamic,
            enqueued: Instant::now(),
            reply,
        };
        if self.tx.send(job).is_err() {
            // device thread gone: surface on the reply channel
            // (rx will simply yield RecvError, handled by exec_sync)
        }
        rx
    }

    /// Blocking execute.
    pub fn exec_sync(&self, name: &str, dynamic: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        self.submit(name, dynamic)
            .recv()
            .map_err(|_| anyhow!("device thread dropped request"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn device_loop(manifest: Manifest, rx: Receiver<Job>, metrics: Arc<EngineMetrics>) {
    let mut runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("device thread: failed to create PJRT client: {e:#}");
            // drain jobs with errors
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Load { reply, .. } | Job::Bind { reply, .. } | Job::BindBell { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client")));
                    }
                    Job::Exec { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client")));
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };
    // per-artifact bound (static) input literals by position
    let mut bound: HashMap<String, HashMap<usize, xla::Literal>> = HashMap::new();

    while let Ok(job) = rx.recv() {
        match job {
            Job::Load { name, reply } => {
                let _ = reply.send(runtime.load(&manifest, &name).map(|_| ()));
            }
            Job::Bind { name, positions, reply } => {
                let r = (|| -> Result<()> {
                    runtime.load(&manifest, &name)?;
                    let spec = manifest.artifact(&name)?;
                    let slot = bound.entry(name.clone()).or_default();
                    for (pos, t) in positions {
                        let ts = spec
                            .inputs
                            .get(pos)
                            .ok_or_else(|| anyhow!("{name}: no input position {pos}"))?;
                        anyhow::ensure!(
                            ts.matches(&t),
                            "{name}: bind position {pos} (`{}`) shape mismatch",
                            ts.name
                        );
                        slot.insert(pos, t.to_literal()?);
                    }
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Job::BindBell { name, reply } => {
                let r = (|| -> Result<()> {
                    runtime.load(&manifest, &name)?;
                    let spec = manifest.artifact(&name)?.clone();
                    let slot = bound.entry(name.clone()).or_default();
                    for (pos, input) in spec.inputs.iter().enumerate() {
                        if input.name.starts_with("bell_") {
                            let t = HostTensor::load_npy(
                                manifest.dir.join(format!("{}.npy", input.name)),
                            )?;
                            anyhow::ensure!(input.matches(&t), "{}: bell shape mismatch", input.name);
                            slot.insert(pos, t.to_literal()?);
                        }
                    }
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Job::Exec { name, dynamic, enqueued, reply } => {
                let r = (|| -> Result<Vec<HostTensor>> {
                    runtime.load(&manifest, &name)?;
                    let spec = manifest.artifact(&name)?;
                    let statics = bound.get(&name);
                    // assemble: bound positions from cache, the rest from
                    // `dynamic` in manifest order
                    let mut dyn_iter = dynamic.iter();
                    let mut dyn_literals: Vec<(usize, xla::Literal)> = Vec::new();
                    for (pos, input) in spec.inputs.iter().enumerate() {
                        if statics.map_or(false, |s| s.contains_key(&pos)) {
                            continue;
                        }
                        let t = dyn_iter.next().ok_or_else(|| {
                            anyhow!("{name}: missing dynamic input for `{}`", input.name)
                        })?;
                        anyhow::ensure!(
                            input.matches(t),
                            "{name}: dynamic input `{}` expects {:?} {}, got {:?} {}",
                            input.name,
                            input.shape,
                            input.dtype,
                            t.shape(),
                            t.dtype_name()
                        );
                        dyn_literals.push((pos, t.to_literal()?));
                    }
                    anyhow::ensure!(
                        dyn_iter.next().is_none(),
                        "{name}: too many dynamic inputs"
                    );
                    // merge in position order
                    let mut refs: Vec<&xla::Literal> = Vec::with_capacity(spec.inputs.len());
                    let mut d = 0usize;
                    for pos in 0..spec.inputs.len() {
                        if let Some(lit) = statics.and_then(|s| s.get(&pos)) {
                            refs.push(lit);
                        } else {
                            refs.push(&dyn_literals[d].1);
                            debug_assert_eq!(dyn_literals[d].0, pos);
                            d += 1;
                        }
                    }
                    let t0 = Instant::now();
                    let out = runtime.execute_literals(&name, &refs)?;
                    metrics.exec_latency.record(t0.elapsed().as_secs_f64());
                    Ok(out)
                })();
                if r.is_err() {
                    metrics.errors.inc();
                }
                metrics.total_latency.record(enqueued.elapsed().as_secs_f64());
                let _ = reply.send(r);
            }
            Job::Shutdown => break,
        }
    }
}
