//! Serving coordinator — the L3 request path.
//!
//! * [`state`] — `PreparedDataset`: the full preprocessing pipeline
//!   (normalize → degree-sort → relabel → block-partition → BELL) and
//!   its on-disk form (what `accel-gcn prepare` writes).
//! * [`engine`] — the device thread owning the PJRT [`crate::runtime::Runtime`]
//!   (PjRt handles are not `Send`); front ends talk to it via jobs.
//!   Static inputs (bucket tensors, features) are *bound* once per
//!   artifact so the hot path only uploads what changed.
//! * [`router`] — artifact selection: smallest compiled SpMM column
//!   width that fits a request batch.
//! * [`batcher`] — dynamic batching: requests for the same graph are
//!   coalesced along the dense column dimension (the paper's column-dim
//!   traversal) up to the widest artifact, then split back per request.
//!   The planning logic is shared with the native serve subsystem
//!   ([`crate::serve`]), which batches against a virtual width ladder
//!   instead of compiled artifacts.

pub mod state;
pub mod engine;
pub mod router;
pub mod batcher;

pub use batcher::{BatchPlan, ColumnBatcher};
pub use engine::{Engine, EngineMetrics};
pub use router::pick_artifact;
pub use state::PreparedDataset;
