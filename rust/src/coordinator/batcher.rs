//! Dynamic batching along the dense column dimension.
//!
//! SpMM requests for the same graph carry feature matrices
//! `[n, c_i]` with varying column counts (the paper evaluates
//! c ∈ [16, 128]). Because `Â·[X₁ X₂] = [Â·X₁ Â·X₂]`, requests can be
//! **concatenated column-wise**, executed through one (wider) compiled
//! artifact, and split back — amortizing the sparse traversal exactly
//! the way the combined-warp strategy amortizes it across lanes.
//!
//! The batcher plans greedily: it packs requests in arrival order while
//! the combined width fits the widest compiled artifact.

use super::router::pick_artifact;
use crate::runtime::HostTensor;
use anyhow::Result;

/// A planned batch: which requests to fuse and the artifact to run.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchPlan {
    /// Indices (into the pending queue) of fused requests.
    pub members: Vec<usize>,
    /// Total live columns.
    pub width: usize,
    /// Compiled width (≥ width; remainder zero-padded).
    pub artifact_width: usize,
    pub artifact: String,
}

/// Column batcher over a fixed artifact ladder.
#[derive(Clone, Debug)]
pub struct ColumnBatcher {
    /// Ascending (coldim, artifact) ladder.
    ladder: Vec<(usize, String)>,
    pub max_width: usize,
}

impl ColumnBatcher {
    pub fn new(ladder: Vec<(usize, String)>) -> ColumnBatcher {
        assert!(!ladder.is_empty(), "no SpMM artifacts");
        let max_width = ladder.last().unwrap().0;
        ColumnBatcher { ladder, max_width }
    }

    /// Greedily plan batches over the pending request widths, in order.
    pub fn plan(&self, widths: &[usize]) -> Result<Vec<BatchPlan>> {
        let mut plans = Vec::new();
        let mut members: Vec<usize> = Vec::new();
        let mut acc = 0usize;
        for (i, &w) in widths.iter().enumerate() {
            anyhow::ensure!(
                w <= self.max_width,
                "request width {w} exceeds widest artifact {}",
                self.max_width
            );
            anyhow::ensure!(w > 0, "request width must be positive");
            if acc + w > self.max_width && !members.is_empty() {
                plans.push(self.seal(std::mem::take(&mut members), acc)?);
                acc = 0;
            }
            members.push(i);
            acc += w;
        }
        if !members.is_empty() {
            plans.push(self.seal(members, acc)?);
        }
        Ok(plans)
    }

    fn seal(&self, members: Vec<usize>, width: usize) -> Result<BatchPlan> {
        let (artifact_width, artifact) = pick_artifact(&self.ladder, width)?;
        Ok(BatchPlan { members, width, artifact_width, artifact })
    }

    /// Fuse member feature matrices (each `[n, cᵢ]`, same `n`) into one
    /// `[n, artifact_width]` matrix, zero-padding the tail columns.
    pub fn fuse(plan: &BatchPlan, xs: &[&HostTensor]) -> Result<HostTensor> {
        anyhow::ensure!(plan.members.len() == xs.len(), "member/tensor arity mismatch");
        let n = xs[0].shape()[0];
        let mut data = vec![0f32; n * plan.artifact_width];
        let mut col = 0usize;
        for x in xs {
            anyhow::ensure!(x.shape().len() == 2 && x.shape()[0] == n, "row mismatch in batch");
            let c = x.shape()[1];
            let src = x.as_f32()?;
            for r in 0..n {
                data[r * plan.artifact_width + col..r * plan.artifact_width + col + c]
                    .copy_from_slice(&src[r * c..(r + 1) * c]);
            }
            col += c;
        }
        debug_assert_eq!(col, plan.width);
        Ok(HostTensor::f32(&[n, plan.artifact_width], data))
    }

    /// Split a fused result `[n, artifact_width]` back into per-request
    /// outputs of the original widths.
    pub fn split(plan: &BatchPlan, widths: &[usize], y: &HostTensor) -> Result<Vec<HostTensor>> {
        let n = y.shape()[0];
        let stride = y.shape()[1];
        anyhow::ensure!(stride == plan.artifact_width, "result width mismatch");
        let data = y.as_f32()?;
        let mut outs = Vec::with_capacity(plan.members.len());
        let mut col = 0usize;
        for &m in &plan.members {
            let c = widths[m];
            let mut part = vec![0f32; n * c];
            for r in 0..n {
                part[r * c..(r + 1) * c]
                    .copy_from_slice(&data[r * stride + col..r * stride + col + c]);
            }
            outs.push(HostTensor::f32(&[n, c], part));
            col += c;
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> Vec<(usize, String)> {
        vec![
            (16, "spmm_f16".into()),
            (32, "spmm_f32".into()),
            (64, "spmm_f64".into()),
            (128, "spmm_f128".into()),
        ]
    }

    #[test]
    fn packs_up_to_max() {
        let b = ColumnBatcher::new(ladder());
        let plans = b.plan(&[16, 16, 32, 64, 16]).unwrap();
        // 16+16+32+64 = 128 fits; then 16
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].members, vec![0, 1, 2, 3]);
        assert_eq!(plans[0].artifact, "spmm_f128");
        assert_eq!(plans[1].members, vec![4]);
        assert_eq!(plans[1].artifact, "spmm_f16");
    }

    #[test]
    fn rounds_up_to_ladder() {
        let b = ColumnBatcher::new(ladder());
        let plans = b.plan(&[16, 17]).unwrap();
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].width, 33);
        assert_eq!(plans[0].artifact_width, 64);
    }

    #[test]
    fn oversize_request_rejected() {
        let b = ColumnBatcher::new(ladder());
        assert!(b.plan(&[129]).is_err());
        assert!(b.plan(&[0]).is_err());
    }

    #[test]
    fn fuse_split_roundtrip() {
        let b = ColumnBatcher::new(ladder());
        let widths = [16usize, 32];
        let plans = b.plan(&widths).unwrap();
        assert_eq!(plans.len(), 1);
        let n = 4;
        let x1 = HostTensor::f32(&[n, 16], (0..n * 16).map(|i| i as f32).collect());
        let x2 = HostTensor::f32(&[n, 32], (0..n * 32).map(|i| 1000.0 + i as f32).collect());
        let fused = ColumnBatcher::fuse(&plans[0], &[&x1, &x2]).unwrap();
        assert_eq!(fused.shape(), &[n, 64]);
        // identity "execution": split the fused input back
        let outs = ColumnBatcher::split(&plans[0], &widths, &fused).unwrap();
        assert_eq!(outs[0], x1);
        assert_eq!(outs[1], x2);
        // padding columns are zero
        let f = fused.as_f32().unwrap();
        for r in 0..n {
            for c in 48..64 {
                assert_eq!(f[r * 64 + c], 0.0);
            }
        }
    }

    #[test]
    fn many_small_requests_batch_tightly() {
        let b = ColumnBatcher::new(ladder());
        let widths = vec![16usize; 9];
        let plans = b.plan(&widths).unwrap();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].members.len(), 8); // 8×16 = 128
        assert_eq!(plans[1].members.len(), 1);
    }
}
