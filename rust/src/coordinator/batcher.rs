//! Dynamic batching along the dense column dimension.
//!
//! SpMM requests for the same graph carry feature matrices
//! `[n, c_i]` with varying column counts (the paper evaluates
//! c ∈ [16, 128]). Because `Â·[X₁ X₂] = [Â·X₁ Â·X₂]`, requests can be
//! **concatenated column-wise**, executed through one (wider) compiled
//! artifact, and split back — amortizing the sparse traversal exactly
//! the way the combined-warp strategy amortizes it across lanes.
//!
//! The batcher plans greedily: it packs requests in arrival order while
//! the combined width fits the widest compiled artifact.
//!
//! Two request paths share this planning logic: the PJRT coordinator
//! (`bench::serve`), whose ladder comes from the compiled-artifact
//! manifest, and the native serve subsystem ([`crate::serve`]), which
//! has no artifacts and plans against a **virtual** ladder built from
//! config widths ([`ColumnBatcher::from_widths`]).

use super::router::pick_artifact;
use crate::runtime::HostTensor;
use anyhow::Result;

/// A planned batch: which requests to fuse and the artifact to run.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchPlan {
    /// Indices (into the pending queue) of fused requests.
    pub members: Vec<usize>,
    /// Total live columns.
    pub width: usize,
    /// Compiled width (≥ width; remainder zero-padded).
    pub artifact_width: usize,
    pub artifact: String,
}

/// Column batcher over a fixed artifact ladder.
#[derive(Clone, Debug)]
pub struct ColumnBatcher {
    /// Ascending (coldim, artifact) ladder.
    ladder: Vec<(usize, String)>,
    pub max_width: usize,
}

impl ColumnBatcher {
    /// Build a batcher over `(coldim, artifact)` pairs. The ladder is
    /// sorted here and strictly-ascending widths are enforced for real
    /// (not just `debug_assert`ed): a misordered or duplicated manifest
    /// must never silently route a batch to a too-small artifact in
    /// release builds.
    pub fn new(mut ladder: Vec<(usize, String)>) -> Result<ColumnBatcher> {
        anyhow::ensure!(!ladder.is_empty(), "no SpMM artifacts");
        ladder.sort_by_key(|(w, _)| *w);
        for pair in ladder.windows(2) {
            anyhow::ensure!(
                pair[0].0 < pair[1].0,
                "duplicate ladder width {} (artifacts `{}` and `{}`)",
                pair[0].0,
                pair[0].1,
                pair[1].1
            );
        }
        anyhow::ensure!(ladder[0].0 > 0, "ladder width must be positive");
        let max_width = ladder.last().unwrap().0;
        Ok(ColumnBatcher { ladder, max_width })
    }

    /// A batcher over a **virtual** ladder: no compiled artifacts, just
    /// the configured widths (the native serve path). Entries are named
    /// `virtual_w{width}` so `BatchPlan::artifact` stays meaningful in
    /// logs and metrics.
    pub fn from_widths(widths: &[usize]) -> Result<ColumnBatcher> {
        ColumnBatcher::new(widths.iter().map(|&w| (w, format!("virtual_w{w}"))).collect())
    }

    /// Greedily plan batches over the pending request widths, in order.
    pub fn plan(&self, widths: &[usize]) -> Result<Vec<BatchPlan>> {
        let mut plans = Vec::new();
        let mut members: Vec<usize> = Vec::new();
        let mut acc = 0usize;
        for (i, &w) in widths.iter().enumerate() {
            anyhow::ensure!(
                w <= self.max_width,
                "request width {w} exceeds widest artifact {}",
                self.max_width
            );
            anyhow::ensure!(w > 0, "request width must be positive");
            if acc + w > self.max_width && !members.is_empty() {
                plans.push(self.seal(std::mem::take(&mut members), acc)?);
                acc = 0;
            }
            members.push(i);
            acc += w;
        }
        if !members.is_empty() {
            plans.push(self.seal(members, acc)?);
        }
        Ok(plans)
    }

    fn seal(&self, members: Vec<usize>, width: usize) -> Result<BatchPlan> {
        let (artifact_width, artifact) = pick_artifact(&self.ladder, width)?;
        Ok(BatchPlan { members, width, artifact_width, artifact })
    }

    /// Fuse member feature matrices (each `[n, cᵢ]`, same `n`) into one
    /// `[n, artifact_width]` matrix, zero-padding the tail columns.
    pub fn fuse(plan: &BatchPlan, xs: &[&HostTensor]) -> Result<HostTensor> {
        anyhow::ensure!(plan.members.len() == xs.len(), "member/tensor arity mismatch");
        let n = xs[0].shape()[0];
        let mut data = vec![0f32; n * plan.artifact_width];
        let mut col = 0usize;
        for x in xs {
            anyhow::ensure!(x.shape().len() == 2 && x.shape()[0] == n, "row mismatch in batch");
            let c = x.shape()[1];
            let src = x.as_f32()?;
            for r in 0..n {
                data[r * plan.artifact_width + col..r * plan.artifact_width + col + c]
                    .copy_from_slice(&src[r * c..(r + 1) * c]);
            }
            col += c;
        }
        debug_assert_eq!(col, plan.width);
        Ok(HostTensor::f32(&[n, plan.artifact_width], data))
    }

    /// Split a fused result `[n, artifact_width]` back into per-request
    /// outputs of the original widths.
    pub fn split(plan: &BatchPlan, widths: &[usize], y: &HostTensor) -> Result<Vec<HostTensor>> {
        let n = y.shape()[0];
        let stride = y.shape()[1];
        anyhow::ensure!(stride == plan.artifact_width, "result width mismatch");
        let data = y.as_f32()?;
        let mut outs = Vec::with_capacity(plan.members.len());
        let mut col = 0usize;
        for &m in &plan.members {
            let c = widths[m];
            let mut part = vec![0f32; n * c];
            for r in 0..n {
                part[r * c..(r + 1) * c]
                    .copy_from_slice(&data[r * stride + col..r * stride + col + c]);
            }
            outs.push(HostTensor::f32(&[n, c], part));
            col += c;
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn ladder() -> Vec<(usize, String)> {
        vec![
            (16, "spmm_f16".into()),
            (32, "spmm_f32".into()),
            (64, "spmm_f64".into()),
            (128, "spmm_f128".into()),
        ]
    }

    #[test]
    fn misordered_ladder_is_sorted_duplicates_rejected() {
        // a manifest listing artifacts out of order must still route
        // correctly (sorted in `new`, not just debug_asserted)
        let shuffled = vec![
            (64, "spmm_f64".to_string()),
            (16, "spmm_f16".to_string()),
            (128, "spmm_f128".to_string()),
            (32, "spmm_f32".to_string()),
        ];
        let b = ColumnBatcher::new(shuffled).unwrap();
        assert_eq!(b.max_width, 128);
        let plans = b.plan(&[17]).unwrap();
        assert_eq!(plans[0].artifact, "spmm_f32", "must not route to a too-small artifact");

        let dup = vec![(16, "a".to_string()), (16, "b".to_string())];
        assert!(ColumnBatcher::new(dup).is_err());
        assert!(ColumnBatcher::new(Vec::new()).is_err());
        assert!(ColumnBatcher::new(vec![(0, "zero".to_string())]).is_err());
    }

    #[test]
    fn virtual_ladder_from_widths() {
        let b = ColumnBatcher::from_widths(&[64, 16, 32]).unwrap();
        assert_eq!(b.max_width, 64);
        let plans = b.plan(&[20]).unwrap();
        assert_eq!(plans[0].artifact, "virtual_w32");
        assert!(ColumnBatcher::from_widths(&[]).is_err());
        assert!(ColumnBatcher::from_widths(&[8, 8]).is_err());
    }

    #[test]
    fn packs_up_to_max() {
        let b = ColumnBatcher::new(ladder()).unwrap();
        let plans = b.plan(&[16, 16, 32, 64, 16]).unwrap();
        // 16+16+32+64 = 128 fits; then 16
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].members, vec![0, 1, 2, 3]);
        assert_eq!(plans[0].artifact, "spmm_f128");
        assert_eq!(plans[1].members, vec![4]);
        assert_eq!(plans[1].artifact, "spmm_f16");
    }

    #[test]
    fn rounds_up_to_ladder() {
        let b = ColumnBatcher::new(ladder()).unwrap();
        let plans = b.plan(&[16, 17]).unwrap();
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].width, 33);
        assert_eq!(plans[0].artifact_width, 64);
    }

    #[test]
    fn oversize_request_rejected() {
        let b = ColumnBatcher::new(ladder()).unwrap();
        assert!(b.plan(&[129]).is_err());
        assert!(b.plan(&[0]).is_err());
    }

    #[test]
    fn fuse_split_roundtrip() {
        let b = ColumnBatcher::new(ladder()).unwrap();
        let widths = [16usize, 32];
        let plans = b.plan(&widths).unwrap();
        assert_eq!(plans.len(), 1);
        let n = 4;
        let x1 = HostTensor::f32(&[n, 16], (0..n * 16).map(|i| i as f32).collect());
        let x2 = HostTensor::f32(&[n, 32], (0..n * 32).map(|i| 1000.0 + i as f32).collect());
        let fused = ColumnBatcher::fuse(&plans[0], &[&x1, &x2]).unwrap();
        assert_eq!(fused.shape(), &[n, 64]);
        // identity "execution": split the fused input back
        let outs = ColumnBatcher::split(&plans[0], &widths, &fused).unwrap();
        assert_eq!(outs[0], x1);
        assert_eq!(outs[1], x2);
        // padding columns are zero
        let f = fused.as_f32().unwrap();
        for r in 0..n {
            for c in 48..64 {
                assert_eq!(f[r * 64 + c], 0.0);
            }
        }
    }

    #[test]
    fn prop_plan_fuse_split_roundtrips_every_request() {
        // every request's columns must survive plan → fuse → split
        // exactly, for random ladders and random width mixes, and every
        // request must appear in exactly one batch
        proptest::check("batcher_roundtrip", 0xBA7C, 30, |rng| {
            // random strictly-ascending ladder
            let mut widths: Vec<usize> = Vec::new();
            let mut w = 0usize;
            for _ in 0..rng.range(1, 5) {
                w += rng.range(1, 40);
                widths.push(w);
            }
            let b = ColumnBatcher::from_widths(&widths).unwrap();
            let n = rng.range(1, 12);
            let req_widths: Vec<usize> =
                (0..rng.range(1, 14)).map(|_| rng.range(1, b.max_width + 1)).collect();
            let xs: Vec<HostTensor> = req_widths
                .iter()
                .enumerate()
                .map(|(i, &c)| {
                    HostTensor::f32(
                        &[n, c],
                        (0..n * c).map(|k| (i * 10_000 + k) as f32).collect(),
                    )
                })
                .collect();
            let plans = b.plan(&req_widths).unwrap();
            let mut seen = vec![0usize; req_widths.len()];
            for plan in &plans {
                assert!(plan.width <= plan.artifact_width);
                assert!(plan.artifact_width <= b.max_width);
                assert_eq!(
                    plan.width,
                    plan.members.iter().map(|&m| req_widths[m]).sum::<usize>()
                );
                let member_xs: Vec<&HostTensor> =
                    plan.members.iter().map(|&m| &xs[m]).collect();
                let fused = ColumnBatcher::fuse(plan, &member_xs).unwrap();
                // identity "execution": what goes in must come back out
                let outs = ColumnBatcher::split(plan, &req_widths, &fused).unwrap();
                for (slot, &m) in plan.members.iter().enumerate() {
                    assert_eq!(outs[slot], xs[m], "request {m} columns corrupted");
                    seen[m] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "each request in exactly one batch: {seen:?}");
        });
    }

    #[test]
    fn many_small_requests_batch_tightly() {
        let b = ColumnBatcher::new(ladder()).unwrap();
        let widths = vec![16usize; 9];
        let plans = b.plan(&widths).unwrap();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].members.len(), 8); // 8×16 = 128
        assert_eq!(plans[1].members.len(), 1);
    }
}
