//! `PreparedDataset`: the product of the full preprocessing pipeline,
//! and its on-disk layout (written by `accel-gcn prepare`, read by the
//! serving engine, the examples, and — for shapes — `compile/aot.py`).
//!
//! Pipeline: adjacency → GCN normalize → degree sort → symmetric
//! relabel (rows *and* columns in the sorted domain, so GCN layers
//! chain) → block-level partition → BELL export.
//!
//! Directory layout (all under one artifact dir):
//! ```text
//! graph.bin              original adjacency (pattern)
//! graph_row_ptr.npy      relabeled Â (sorted domain) — CSR arrays
//! graph_col_idx.npy
//! graph_vals.npy
//! perm.npy, inv.npy      sorted ↔ original row maps
//! bell_spec.json         bucket shapes (consumed by aot.py)
//! bell_w{W}_{cols,vals,rows}.npy
//! features.npy labels.npy   (when generated with a labeled graph)
//! dataset.json           summary + partition params
//! ```

use crate::graph::csr::Csr;
use crate::graph::io;
use crate::partition::block_level::BlockPartition;
use crate::partition::bucket::BellLayout;
use crate::partition::patterns::PartitionParams;
use crate::pipeline::PlanCache;
use crate::util::json::Json;
use crate::util::npy::Npy;
use anyhow::{Context, Result};
use std::path::Path;

/// A fully-preprocessed graph (plus optional node features/labels).
#[derive(Clone, Debug)]
pub struct PreparedDataset {
    /// Original (pattern) adjacency.
    pub original: Csr,
    /// Normalized, degree-sorted, relabeled Â — the SpMM operand.
    pub sorted: Csr,
    /// sorted row i = original row perm[i].
    pub perm: Vec<u32>,
    pub inv: Vec<u32>,
    pub partition: BlockPartition,
    pub layout: BellLayout,
    /// Row-major [n, feat_dim] in the **sorted** domain.
    pub features: Option<(usize, Vec<f32>)>,
    /// Labels in the sorted domain.
    pub labels: Option<Vec<i32>>,
}

impl PreparedDataset {
    /// Run the full pipeline on a raw adjacency matrix.
    ///
    /// The degree sort and block partition come from the process-wide
    /// [`PlanCache`], so preparing (or [`PreparedDataset::load`]-ing)
    /// the same graph twice skips preprocessing. The plan partitions the
    /// row-permuted matrix; the symmetric relabel has the identical row
    /// structure (see [`crate::pipeline::SpmmPlan::relabeled`]), so the
    /// plan's partition is used for the relabeled operand verbatim.
    ///
    /// Note the cache never evicts: each distinct (graph, params) pair
    /// stays resident (two CSR copies per plan). A serving process owns
    /// one dataset, so this is the intended trade; a process cycling
    /// through many datasets should call `PlanCache::global().clear()`
    /// between them.
    pub fn prepare(adjacency: &Csr, params: PartitionParams) -> PreparedDataset {
        let normalized = adjacency.gcn_normalize();
        let plan = PlanCache::global().plan_for(&normalized, params);
        let sorted = plan.relabeled(); // asserts row structure matches the plan
        let partition = plan.block.clone();
        // coalesce sparse buckets: fewer Pallas kernel launches in the
        // AOT graph at negligible padding cost (SS Perf, L2)
        let layout = BellLayout::build(&sorted, &partition).coalesce(64);
        PreparedDataset {
            original: adjacency.clone(),
            sorted,
            perm: plan.sorted.perm.clone(),
            inv: plan.sorted.inv.clone(),
            partition,
            layout,
            features: None,
            labels: None,
        }
    }

    /// Attach features/labels given in the **original** domain; they are
    /// stored permuted into the sorted domain.
    pub fn with_node_data(
        mut self,
        feat_dim: usize,
        features: &[f32],
        labels: &[u32],
    ) -> PreparedDataset {
        let n = self.sorted.n_rows;
        assert_eq!(features.len(), n * feat_dim);
        assert_eq!(labels.len(), n);
        let mut pf = vec![0f32; n * feat_dim];
        let mut pl = vec![0i32; n];
        for (i, &orig) in self.perm.iter().enumerate() {
            pf[i * feat_dim..(i + 1) * feat_dim]
                .copy_from_slice(&features[orig as usize * feat_dim..(orig as usize + 1) * feat_dim]);
            pl[i] = labels[orig as usize] as i32;
        }
        self.features = Some((feat_dim, pf));
        self.labels = Some(pl);
        self
    }

    /// Persist everything `aot.py` + the serving engine need.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        io::save_graph(&self.original, dir.join("graph.bin"))?;
        // relabeled Â as npy for the python cross-check
        let rp: Vec<i64> = self.sorted.row_ptr.iter().map(|&p| p as i64).collect();
        Npy::from_i64(&[rp.len()], &rp).save(dir.join("graph_row_ptr.npy"))?;
        let ci: Vec<i32> = self.sorted.col_idx.iter().map(|&c| c as i32).collect();
        Npy::from_i32(&[ci.len()], &ci).save(dir.join("graph_col_idx.npy"))?;
        Npy::from_f32(&[self.sorted.vals.len()], &self.sorted.vals)
            .save(dir.join("graph_vals.npy"))?;
        let perm: Vec<i32> = self.perm.iter().map(|&p| p as i32).collect();
        Npy::from_i32(&[perm.len()], &perm).save(dir.join("perm.npy"))?;
        let inv: Vec<i32> = self.inv.iter().map(|&p| p as i32).collect();
        Npy::from_i32(&[inv.len()], &inv).save(dir.join("inv.npy"))?;
        self.layout.save(dir)?;
        if let Some((feat_dim, feats)) = &self.features {
            Npy::from_f32(&[self.sorted.n_rows, *feat_dim], feats)
                .save(dir.join("features.npy"))?;
        }
        if let Some(labels) = &self.labels {
            Npy::from_i32(&[labels.len()], labels).save(dir.join("labels.npy"))?;
        }
        let mut summary = Json::obj();
        summary.set("n_rows", self.sorted.n_rows);
        summary.set("nnz", self.sorted.nnz());
        summary.set("n_blocks", self.partition.n_blocks());
        summary.set("n_warp_tasks", self.partition.n_warp_tasks());
        summary.set("n_split_rows", self.partition.n_split_rows);
        summary.set("metadata_ratio", self.partition.footprint().ratio());
        summary.set("padding_overhead", self.layout.padding_overhead());
        summary.set("max_block_warps", self.partition.params.max_block_warps);
        summary.set("max_warp_nzs", self.partition.params.max_warp_nzs);
        summary.set(
            "feat_dim",
            self.features.as_ref().map(|(d, _)| *d).unwrap_or(0),
        );
        std::fs::write(dir.join("dataset.json"), summary.to_pretty())
            .context("write dataset.json")?;
        Ok(())
    }

    /// Reload a prepared dataset (for serving without re-preprocessing).
    pub fn load(dir: impl AsRef<Path>) -> Result<PreparedDataset> {
        let dir = dir.as_ref();
        let original = io::load_graph(dir.join("graph.bin"))?;
        let summary = Json::parse(&std::fs::read_to_string(dir.join("dataset.json"))?)?;
        let params = PartitionParams {
            max_block_warps: summary.req_usize("max_block_warps")?,
            max_warp_nzs: summary.req_usize("max_warp_nzs")?,
        };
        let mut prepared = PreparedDataset::prepare(&original, params);
        // features/labels if present
        let feat_path = dir.join("features.npy");
        if feat_path.exists() {
            let f = Npy::load(&feat_path)?;
            let feat_dim = f.shape[1];
            prepared.features = Some((feat_dim, f.to_f32()?));
        }
        let label_path = dir.join("labels.npy");
        if label_path.exists() {
            prepared.labels = Some(Npy::load(&label_path)?.to_i32()?);
        }
        Ok(prepared)
    }

    /// The dynamic tensors for one SpMM request in the sorted domain.
    pub fn n_rows(&self) -> usize {
        self.sorted.n_rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::verify::assert_allclose;
    use crate::util::rng::Pcg;

    fn random_adj(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let edges: Vec<(u32, u32, f32)> = (0..n * 4)
            .map(|_| (rng.range(0, n) as u32, rng.range(0, n) as u32, 1.0))
            .collect();
        Csr::from_edges(n, n, &edges).unwrap().symmetrize()
    }

    #[test]
    fn pipeline_preserves_spmm() {
        let mut rng = Pcg::seed_from(5);
        let adj = random_adj(1, 30);
        let p = PreparedDataset::prepare(&adj, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        let f = 4;
        let x: Vec<f32> = (0..30 * f).map(|_| rng.f32() - 0.5).collect();
        // sorted-domain input
        let mut px = vec![0f32; 30 * f];
        for (i, &orig) in p.perm.iter().enumerate() {
            px[i * f..(i + 1) * f].copy_from_slice(&x[orig as usize * f..(orig as usize + 1) * f]);
        }
        let got = p.layout.execute(&px, f);
        let want_sorted = p.sorted.spmm_dense(&px, f);
        assert_allclose(&got, &want_sorted, 1e-4, 1e-4, "layout vs sorted csr");
        // and the sorted result matches the original-domain normalize·X
        let norm = adj.gcn_normalize();
        let want_orig = norm.spmm_dense(&x, f);
        for (i, &orig) in p.perm.iter().enumerate() {
            for k in 0..f {
                assert!(
                    (got[i * f + k] - want_orig[orig as usize * f + k]).abs() < 1e-4,
                    "row {i} col {k}"
                );
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let adj = random_adj(2, 25);
        let mut rng = Pcg::seed_from(9);
        let feats: Vec<f32> = (0..25 * 3).map(|_| rng.f32()).collect();
        let labels: Vec<u32> = (0..25).map(|_| rng.range(0, 4) as u32).collect();
        let p = PreparedDataset::prepare(&adj, PartitionParams::default())
            .with_node_data(3, &feats, &labels);
        let dir = std::env::temp_dir().join("accel_gcn_state_test");
        p.save(&dir).unwrap();
        let back = PreparedDataset::load(&dir).unwrap();
        assert_eq!(back.sorted, p.sorted);
        assert_eq!(back.perm, p.perm);
        assert_eq!(back.layout, p.layout);
        assert_eq!(back.features, p.features);
        assert_eq!(back.labels, p.labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn node_data_permuted_consistently() {
        let adj = random_adj(3, 15);
        let feats: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let labels: Vec<u32> = (0..15).collect();
        let p = PreparedDataset::prepare(&adj, PartitionParams::default())
            .with_node_data(1, &feats, &labels);
        let (_, pf) = p.features.as_ref().unwrap();
        let pl = p.labels.as_ref().unwrap();
        for i in 0..15 {
            assert_eq!(pf[i], p.perm[i] as f32);
            assert_eq!(pl[i], p.perm[i] as i32);
        }
    }
}
