//! Artifact routing: pick the smallest compiled SpMM column width that
//! fits a request (or batch), padding the remainder with zero columns.

use anyhow::{bail, Result};

/// Choose from `available` (ascending `(coldim, artifact)` pairs, as
/// returned by `Manifest::spmm_coldims`) the smallest artifact with
/// `coldim ≥ want`.
pub fn pick_artifact(available: &[(usize, String)], want: usize) -> Result<(usize, String)> {
    debug_assert!(available.windows(2).all(|w| w[0].0 < w[1].0), "must be ascending");
    for (dim, name) in available {
        if *dim >= want {
            return Ok((*dim, name.clone()));
        }
    }
    bail!(
        "no SpMM artifact fits column dim {want} (available: {:?})",
        available.iter().map(|(d, _)| *d).collect::<Vec<_>>()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn avail() -> Vec<(usize, String)> {
        vec![
            (16, "spmm_f16".into()),
            (32, "spmm_f32".into()),
            (64, "spmm_f64".into()),
            (128, "spmm_f128".into()),
        ]
    }

    #[test]
    fn exact_fit() {
        assert_eq!(pick_artifact(&avail(), 32).unwrap().0, 32);
    }

    #[test]
    fn rounds_up() {
        assert_eq!(pick_artifact(&avail(), 17).unwrap().0, 32);
        assert_eq!(pick_artifact(&avail(), 1).unwrap().0, 16);
        assert_eq!(pick_artifact(&avail(), 100).unwrap().0, 128);
    }

    #[test]
    fn too_wide_errors() {
        assert!(pick_artifact(&avail(), 129).is_err());
        assert!(pick_artifact(&[], 1).is_err());
    }
}
