//! Trace generator for the cuSPARSE-like baseline.
//!
//! cuSPARSE is closed source; we model the published CSR-adaptive /
//! merge-style algorithm family its SpMM descends from: rows are packed
//! into blocks with a fixed nonzero budget (good balance without any
//! reordering), each row is covered by vector warps of 32 nonzeros, and
//! rows longer than a block's budget are chunked with global atomic
//! accumulation. Coalescing is library-grade but generic
//! (`eff_csr_adaptive`), and there is no degree sorting, so L2 reuse
//! sees the original row order.

use super::{price_x_gather, sector_bytes, x_cache, CostModel, PreparedGraph};
use crate::sim::config::GpuConfig;
use crate::sim::machine::{BlockWork, KernelTrace};

pub fn trace(
    cfg: &GpuConfig,
    cost: &CostModel,
    graph: &PreparedGraph,
    coldim: usize,
) -> KernelTrace {
    let csr = &graph.original;
    let c_tiles = CostModel::col_tiles(coldim, cfg.warp_size) as f64;
    let row_bytes = (coldim * 4) as f64;
    let mut cache = x_cache(cfg, coldim);
    // nnz budget per block: same block capacity as the paper's kernel so
    // the comparison is about schedule quality, not resources
    let budget = (graph.params.max_block_warps * cfg.warp_size).max(cfg.warp_size);

    let mut blocks = Vec::new();
    let mut w = BlockWork::default();
    w.issue_insts = cost.block_setup_insts;
    let mut filled = 0usize;

    let flush = |w: &mut BlockWork, blocks: &mut Vec<BlockWork>, filled: &mut usize| {
        if *filled > 0 {
            blocks.push(std::mem::take(w));
            w.issue_insts = cost.block_setup_insts;
            *filled = 0;
        }
    };

    for r in 0..csr.n_rows {
        let deg = csr.degree(r);
        if deg == 0 {
            continue;
        }
        let mut off = 0usize;
        let chunked = deg > budget;
        while off < deg {
            let take = (deg - off).min(budget - filled);
            // price this row segment as vector warps of 32 nzs
            let start = csr.row_ptr[r] + off;
            let span = start..start + take;
            w.dram_bytes += sector_bytes(cfg, take * 4) * 2.0;
            let (d, l2) = price_x_gather(&mut cache, &csr.col_idx[span], row_bytes);
            w.dram_bytes += d;
            w.l2_bytes += l2;
            let mut seg = 0usize;
            while seg < take {
                let nz = (take - seg).min(cfg.warp_size) as f64;
                let per_warp = nz * cost.inst_per_nz_tile_combined * c_tiles
                    + cost.warp_setup_insts;
                w.issue_insts += per_warp;
                w.longest_warp_cycles = w.longest_warp_cycles.max(
                    nz * cost.inst_per_nz_tile_combined * c_tiles + cost.warp_setup_insts,
                );
                w.warps += 1;
                seg += cfg.warp_size;
            }
            // output: direct write for whole rows, atomic RMW for chunks
            if chunked {
                w.dram_bytes += row_bytes * cost.atomic_rmw_factor;
            } else if off + take == deg {
                w.dram_bytes += row_bytes;
            }
            filled += take;
            off += take;
            if filled >= budget {
                flush(&mut w, &mut blocks, &mut filled);
            }
        }
        // row_ptr read amortized: 8B per row
        w.dram_bytes += 8.0;
    }
    flush(&mut w, &mut blocks, &mut filled);

    KernelTrace { blocks, mem_efficiency: cost.eff_csr(coldim), name: "cusparse".into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::sim::kernels::{accel_gcn, row_split, KernelOptions};
    use crate::sim::machine::simulate;
    use crate::util::rng::Pcg;

    fn powerlaw(n: usize, seed: u64) -> PreparedGraph {
        let mut rng = Pcg::seed_from(seed);
        let degs = crate::graph::generator::degree_sequence(
            crate::graph::generator::DegreeModel::PowerLaw { alpha: 2.0, dmax_frac: 0.2 },
            n,
            n * 8,
            &mut rng,
        );
        let csr = crate::graph::generator::from_degree_sequence(n, &degs, &mut rng);
        PreparedGraph::new(csr, PartitionParams::default())
    }

    #[test]
    fn balanced_blocks_no_tail() {
        let g = powerlaw(8000, 9);
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let cu = simulate(&cfg, &trace(&cfg, &cost, &g, 64));
        let rs = simulate(&cfg, &row_split::trace(&cfg, &cost, &g, 64));
        // nnz-budget packing: no monster blocks, so better balance than
        // row splitting on the same power-law graph
        assert!(cu.sm_load_cv < rs.sm_load_cv, "cu cv={} rs cv={}", cu.sm_load_cv, rs.sm_load_cv);
    }

    #[test]
    fn between_accel_and_rowsplit_on_powerlaw() {
        // the paper's ordering: accel < cusparse < graphblast
        let g = powerlaw(1200, 10);
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let cu = simulate(&cfg, &trace(&cfg, &cost, &g, 64));
        let accel =
            simulate(&cfg, &accel_gcn::trace(&cfg, &cost, &g, 64, KernelOptions::default()));
        let rs = simulate(&cfg, &row_split::trace(&cfg, &cost, &g, 64));
        assert!(accel.micros < cu.micros, "accel {} !< cu {}", accel.micros, cu.micros);
        assert!(cu.micros < rs.micros, "cu {} !< rs {}", cu.micros, rs.micros);
    }

    #[test]
    fn long_rows_chunked_with_atomics() {
        let mut edges: Vec<(u32, u32, f32)> = (0..5000u32).map(|c| (0, c, 1.0)).collect();
        edges.push((1, 0, 1.0));
        let g = PreparedGraph::new(
            Csr::from_edges(2, 5000, &edges).unwrap(),
            PartitionParams::default(),
        );
        let cfg = GpuConfig::rtx3090();
        let t = trace(&cfg, &CostModel::default(), &g, 64);
        // deg=5000 row with budget 384 → ceil(5000/384)=14 blocks
        assert!(t.blocks.len() >= 13, "blocks={}", t.blocks.len());
    }

    #[test]
    fn empty_graph() {
        let g = PreparedGraph::new(
            Csr::from_edges(5, 5, &[]).unwrap(),
            PartitionParams::default(),
        );
        let t = trace(&GpuConfig::rtx3090(), &CostModel::default(), &g, 32);
        assert!(t.blocks.is_empty());
    }
}
