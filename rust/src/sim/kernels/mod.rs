//! Trace generators for the four SpMM kernels the paper evaluates
//! (§IV-A): Accel-GCN (ours), GNNAdvisor-like warp-level NZ groups,
//! GraphBLAST-like row splitting, and a cuSPARSE-like CSR-adaptive
//! baseline.
//!
//! Each generator walks the kernel's *schedule* (the same workloads the
//! exact executors verify numerically) and prices it into
//! [`BlockWork`](super::machine::BlockWork) descriptors using a shared
//! [`CostModel`]. All constants live in `CostModel` so the calibration
//! knobs are in one place and the ablation toggles (combined warp,
//! degree sorting / block-level partition) flip discrete schedule
//! features, not magic numbers.

pub mod accel_gcn;
pub mod warp_level;
pub mod row_split;
pub mod csr_adaptive;

use super::cache::LruCache;
use super::config::GpuConfig;
use super::machine::{simulate, KernelTrace, SimResult};

/// Which kernel to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// The paper's kernel: degree sorting + block-level partition +
    /// combined warp.
    AccelGcn,
    /// GNNAdvisor-like: fixed-size neighbour groups, per-warp column
    /// inner loop, global atomics.
    GnnAdvisor,
    /// GraphBLAST-like: row splitting (one warp per row), static
    /// scheduling.
    GraphBlast,
    /// cuSPARSE-like: CSR-adaptive row binning (nnz-budget blocks).
    CuSparse,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::AccelGcn => "accel-gcn",
            KernelKind::GnnAdvisor => "gnnadvisor",
            KernelKind::GraphBlast => "graphblast",
            KernelKind::CuSparse => "cusparse",
        }
    }

    pub fn all() -> [KernelKind; 4] {
        [KernelKind::AccelGcn, KernelKind::CuSparse, KernelKind::GnnAdvisor, KernelKind::GraphBlast]
    }
}

/// Ablation switches (paper Figs. 7–8 / Table II).
#[derive(Clone, Copy, Debug)]
pub struct KernelOptions {
    /// Combined-warp column traversal (vs per-warp inner loop).
    pub combined_warp: bool,
}

impl Default for KernelOptions {
    fn default() -> Self {
        KernelOptions { combined_warp: true }
    }
}

/// All cost constants of the model, in one calibratable place.
///
/// Instruction counts are warp-instructions per nonzero per 32-column
/// tile; efficiencies are fractions of peak DRAM bandwidth achieved by
/// the schedule's access pattern (the quantity Nsight reports as
/// memory-throughput %).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// ld.global X + FMA + address math, combined-warp path.
    pub inst_per_nz_tile_combined: f64,
    /// Same work inside a per-warp column loop: + loop branch, index
    /// recompute, predicated tail lanes (the paper's "instruction-level
    /// branching and jumps").
    pub inst_per_nz_tile_loop: f64,
    /// Fixed per-block setup instructions (metadata decode, row map).
    pub block_setup_insts: f64,
    /// Per-warp-task setup instructions.
    pub warp_setup_insts: f64,
    /// Global atomic read-modify-write multiplies write bytes.
    pub atomic_rmw_factor: f64,
    /// Shared-memory accumulate cost per element (atomicAdd_block).
    pub smem_atomic_inst: f64,
    /// DRAM efficiency: combined warp, column dim a multiple of 32.
    pub eff_combined_aligned: f64,
    /// Combined warp on a single truncated tile (coldim < 32).
    pub eff_combined_sub32: f64,
    /// Combined warp but ragged column tail (32 < coldim, % 32 ≠ 0).
    pub eff_combined_ragged: f64,
    /// Extra multiplier when the combined warp spans 3 tiles (96-byte
    /// stride misaligns the 128-byte cache line — the paper's observed
    /// (64,96] dip in Table II).
    pub eff_three_tile_penalty: f64,
    /// Block-level partition with a per-warp inner column loop
    /// (the Fig. 8 "(ii) without combined warp" variant).
    pub eff_loop: f64,
    /// GNNAdvisor's full kernel: inner loop + shared-memory caching
    /// pattern without alignment padding.
    pub eff_gnnadvisor: f64,
    /// GraphBLAST row-split column traversal.
    pub eff_row_split: f64,
    /// cuSPARSE-like library kernel (column dim a multiple of 32).
    pub eff_csr_adaptive: f64,
    /// cuSPARSE-like kernel on ragged column dims (unpadded writes).
    pub eff_csr_adaptive_ragged: f64,
    /// X-gather fragmentation of GNNAdvisor's per-warp column loop:
    /// partially-used cache lines per neighbour-group gather.
    pub x_frag_gnnadvisor: f64,
    /// X-gather fragmentation of GraphBLAST's column-dimension
    /// traversal (the inefficiency the paper calls out in §I).
    pub x_frag_row_split: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            inst_per_nz_tile_combined: 2.0,
            inst_per_nz_tile_loop: 3.4,
            block_setup_insts: 40.0,
            warp_setup_insts: 8.0,
            atomic_rmw_factor: 2.0,
            smem_atomic_inst: 1.0,
            eff_combined_aligned: 0.92,
            eff_combined_sub32: 0.88,
            eff_combined_ragged: 0.88,
            eff_three_tile_penalty: 0.82,
            eff_loop: 0.72,
            eff_gnnadvisor: 0.58,
            eff_row_split: 0.55,
            eff_csr_adaptive: 0.78,
            eff_csr_adaptive_ragged: 0.70,
            x_frag_gnnadvisor: 1.40,
            x_frag_row_split: 2.60,
        }
    }
}

impl CostModel {
    /// Column tiles a warp (or combined warp) covers for `coldim`.
    pub fn col_tiles(coldim: usize, warp_size: usize) -> usize {
        coldim.div_ceil(warp_size)
    }

    /// Memory efficiency of the combined-warp access pattern for a
    /// given column dimension.
    pub fn eff_combined(&self, coldim: usize) -> f64 {
        let base = if coldim % 32 == 0 {
            self.eff_combined_aligned
        } else if coldim < 32 {
            self.eff_combined_sub32
        } else {
            self.eff_combined_ragged
        };
        if Self::col_tiles(coldim, 32) == 3 {
            base * self.eff_three_tile_penalty
        } else {
            base
        }
    }

    /// Memory efficiency of the cuSPARSE-like kernel for a column dim.
    pub fn eff_csr(&self, coldim: usize) -> f64 {
        if coldim % 32 == 0 {
            self.eff_csr_adaptive
        } else {
            self.eff_csr_adaptive_ragged
        }
    }
}

/// A graph with both partitions prebuilt — construct once, simulate
/// every kernel × column dimension from it.
///
/// This is the pipeline's [`SpmmPlan`](crate::pipeline::SpmmPlan): the
/// trace generators consume the exact same plan the CPU executors run,
/// so simulated and executed schedules can never drift apart.
pub use crate::pipeline::SpmmPlan as PreparedGraph;

/// Shared helper: price the X-row gather of a nonzero run through the
/// L2 model. Returns (dram_bytes, l2_bytes).
pub(crate) fn price_x_gather(
    cache: &mut LruCache,
    cols: &[u32],
    row_bytes: f64,
) -> (f64, f64) {
    // batch accounting off the cache's own counters keeps the per-nz
    // loop free of float work (SS Perf: the simulator's hottest loop)
    let h0 = cache.hits;
    let m0 = cache.misses;
    for &c in cols {
        cache.access(c as u64);
    }
    (
        (cache.misses - m0) as f64 * row_bytes,
        (cache.hits - h0) as f64 * row_bytes,
    )
}

/// Build an L2 reuse model sized for X rows of `coldim` floats.
pub(crate) fn x_cache(cfg: &GpuConfig, coldim: usize) -> LruCache {
    let row_bytes = (coldim * 4).max(1);
    LruCache::new(cfg.l2_bytes / row_bytes, cfg.l2_ways)
}

/// Round bytes up to whole sectors.
pub(crate) fn sector_bytes(cfg: &GpuConfig, bytes: usize) -> f64 {
    (bytes.div_ceil(cfg.sector) * cfg.sector) as f64
}

/// Simulate one kernel on a prepared graph.
pub fn simulate_kernel(
    cfg: &GpuConfig,
    cost: &CostModel,
    kind: KernelKind,
    opts: KernelOptions,
    graph: &PreparedGraph,
    coldim: usize,
) -> SimResult {
    let trace: KernelTrace = match kind {
        KernelKind::AccelGcn => accel_gcn::trace(cfg, cost, graph, coldim, opts),
        KernelKind::GnnAdvisor => warp_level::trace(cfg, cost, graph, coldim, opts),
        KernelKind::GraphBlast => row_split::trace(cfg, cost, graph, coldim),
        KernelKind::CuSparse => csr_adaptive::trace(cfg, cost, graph, coldim),
    };
    simulate(cfg, &trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::{by_name, materialize, ScalePolicy};
    use crate::partition::patterns::PartitionParams;

    fn prepared(name: &str) -> PreparedGraph {
        let csr = materialize(by_name(name).unwrap(), ScalePolicy::tiny(), 42);
        PreparedGraph::new(csr, PartitionParams::default())
    }

    #[test]
    fn col_tiles() {
        assert_eq!(CostModel::col_tiles(16, 32), 1);
        assert_eq!(CostModel::col_tiles(32, 32), 1);
        assert_eq!(CostModel::col_tiles(33, 32), 2);
        assert_eq!(CostModel::col_tiles(96, 32), 3);
        assert_eq!(CostModel::col_tiles(128, 32), 4);
    }

    #[test]
    fn eff_combined_shape() {
        let c = CostModel::default();
        // the paper's Fig. 6 claim: minimal sensitivity to non-pow2 dims
        assert!(c.eff_combined(64) - c.eff_combined(48) < 0.05);
        assert!(c.eff_combined(96) < c.eff_combined(128)); // 3-tile dip
        assert!(c.eff_combined(96) < c.eff_combined(64));
        // baselines lose more on ragged dims
        assert!(c.eff_csr(48) < c.eff_csr(64));
    }

    #[test]
    fn paper_ordering_on_powerlaw_graph() {
        // Fig. 5's qualitative result: accel < cusparse < gnnadvisor <
        // graphblast on a power-law graph (times, so ascending).
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = prepared("collab");
        let times: Vec<f64> = KernelKind::all()
            .iter()
            .map(|&k| {
                // Fig. 5 variants: GNNAdvisor runs its own inner loop
                let opts = KernelOptions { combined_warp: k != KernelKind::GnnAdvisor };
                simulate_kernel(&cfg, &cost, k, opts, &g, 64).micros
            })
            .collect();
        // KernelKind::all() = [accel, cusparse, gnnadvisor, graphblast]
        assert!(times[0] < times[1], "accel {} !< cusparse {}", times[0], times[1]);
        assert!(times[1] < times[2], "cusparse {} !< gnnadvisor {}", times[1], times[2]);
        assert!(times[2] < times[3], "gnnadvisor {} !< graphblast {}", times[2], times[3]);
    }

    #[test]
    fn combined_warp_ablation_helps() {
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = prepared("artist");
        for coldim in [32usize, 64, 128] {
            let with = simulate_kernel(&cfg, &cost, KernelKind::AccelGcn, KernelOptions { combined_warp: true }, &g, coldim);
            let without = simulate_kernel(&cfg, &cost, KernelKind::AccelGcn, KernelOptions { combined_warp: false }, &g, coldim);
            assert!(
                without.micros > with.micros,
                "coldim {coldim}: without {} !> with {}",
                without.micros,
                with.micros
            );
        }
    }

    #[test]
    fn runtime_grows_with_coldim() {
        // Fig. 6: runtime increases gradually with the column dimension
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = prepared("pubmed");
        let t16 = simulate_kernel(&cfg, &cost, KernelKind::AccelGcn, KernelOptions::default(), &g, 16).micros;
        let t128 = simulate_kernel(&cfg, &cost, KernelKind::AccelGcn, KernelOptions::default(), &g, 128).micros;
        assert!(t128 > t16, "{t128} !> {t16}");
        assert!(t128 < t16 * 32.0, "growth should be gradual: {t128} vs {t16}");
    }
}
