//! Trace generator for the GNNAdvisor-like baseline: fixed-size
//! neighbour groups (warp-level partition), per-group metadata, global
//! atomic accumulation, and — by default — the per-warp column inner
//! loop the paper's combined warp replaces.
//!
//! The `combined_warp` option exists because Fig. 7 compares block-level
//! vs warp-level partitioning *with both sides using the combined-warp
//! strategy*; Fig. 5's GNNAdvisor bar uses the plain inner loop.

use super::{price_x_gather, sector_bytes, x_cache, CostModel, KernelOptions, PreparedGraph};
use crate::sim::config::GpuConfig;
use crate::sim::machine::{BlockWork, KernelTrace};

pub fn trace(
    cfg: &GpuConfig,
    cost: &CostModel,
    graph: &PreparedGraph,
    coldim: usize,
    opts: KernelOptions,
) -> KernelTrace {
    let csr = &graph.original;
    let wp = &graph.warp;
    let c_tiles = CostModel::col_tiles(coldim, cfg.warp_size) as f64;
    let row_bytes = (coldim * 4) as f64;
    let mut cache = x_cache(cfg, coldim);
    // groups are packed into thread blocks of max_block_warps warps, in
    // original (unsorted) order — GNNAdvisor's launch geometry
    let warps_per_block = graph.params.max_block_warps.max(1);

    let mut blocks = Vec::with_capacity(wp.groups.len() / warps_per_block + 1);
    for chunk in wp.groups.chunks(warps_per_block) {
        let mut w = BlockWork::default();
        w.issue_insts = cost.block_setup_insts;
        for g in chunk {
            // per-warp metadata record (the paper's Fig. 3(b) overhead)
            w.dram_bytes += sector_bytes(cfg, 16);
            let l = g.len as usize;
            w.dram_bytes += sector_bytes(cfg, l * 4) * 2.0;
            let span = g.loc as usize..(g.loc + g.len) as usize;
            let (d, l2) = price_x_gather(&mut cache, &csr.col_idx[span], row_bytes);
            // the per-warp column loop gathers X through partially-used
            // cache lines (no alignment padding): fragmentation factor
            let frag = if opts.combined_warp { 1.0 } else { cost.x_frag_gnnadvisor };
            w.dram_bytes += d * frag;
            w.l2_bytes += l2 * frag;

            let nz = l as f64;
            let (task_issue, task_serial) = if opts.combined_warp {
                let per_warp = nz * cost.inst_per_nz_tile_combined + cost.warp_setup_insts;
                (per_warp * c_tiles, per_warp)
            } else {
                let serial =
                    nz * cost.inst_per_nz_tile_loop * c_tiles + cost.warp_setup_insts;
                (serial, serial)
            };
            w.issue_insts += task_issue;
            w.longest_warp_cycles = w.longest_warp_cycles.max(task_serial);
            w.warps += if opts.combined_warp { c_tiles as usize } else { 1 };

            // a group covering its whole row writes directly; partial
            // groups (rows split across warps) need the global atomic RMW
            let row = g.row as usize;
            let whole_row = csr.degree(row) == l;
            w.dram_bytes += if whole_row {
                row_bytes
            } else {
                row_bytes * cost.atomic_rmw_factor
            };
        }
        blocks.push(w);
    }

    let mem_efficiency =
        if opts.combined_warp { cost.eff_combined(coldim) } else { cost.eff_gnnadvisor };
    KernelTrace {
        blocks,
        mem_efficiency,
        name: format!(
            "gnnadvisor{}",
            if opts.combined_warp { "(combined-warp)" } else { "" }
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::sim::kernels::accel_gcn;
    use crate::sim::machine::simulate;
    use crate::util::rng::Pcg;

    fn powerlaw_graph(n: usize, seed: u64) -> PreparedGraph {
        let mut rng = Pcg::seed_from(seed);
        let degs = crate::graph::generator::degree_sequence(
            crate::graph::generator::DegreeModel::PowerLaw { alpha: 2.0, dmax_frac: 0.2 },
            n,
            n * 8,
            &mut rng,
        );
        let csr = crate::graph::generator::from_degree_sequence(n, &degs, &mut rng);
        PreparedGraph::new(csr, PartitionParams::default())
    }

    #[test]
    fn more_metadata_traffic_than_block_level() {
        // the paper's Eq. 1 effect shows up as extra DRAM bytes
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = powerlaw_graph(500, 5);
        let t_warp = trace(&cfg, &cost, &g, 64, KernelOptions { combined_warp: true });
        let t_block = accel_gcn::trace(&cfg, &cost, &g, 64, KernelOptions { combined_warp: true });
        let bytes = |t: &KernelTrace| t.blocks.iter().map(|b| b.dram_bytes).sum::<f64>();
        assert!(bytes(&t_warp) > bytes(&t_block), "{} !> {}", bytes(&t_warp), bytes(&t_block));
    }

    #[test]
    fn slower_than_accel_on_powerlaw() {
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = powerlaw_graph(800, 6);
        let warp = simulate(&cfg, &trace(&cfg, &cost, &g, 64, KernelOptions { combined_warp: false }));
        let accel = simulate(
            &cfg,
            &accel_gcn::trace(&cfg, &cost, &g, 64, KernelOptions { combined_warp: true }),
        );
        assert!(warp.micros > accel.micros * 1.2, "warp {} vs accel {}", warp.micros, accel.micros);
    }

    #[test]
    fn block_geometry() {
        let mut rng = Pcg::seed_from(7);
        let mut edges = Vec::new();
        for r in 0..100u32 {
            for _ in 0..rng.range(1, 5) {
                edges.push((r, rng.range(0, 100) as u32, 1.0));
            }
        }
        let g = PreparedGraph::new(
            Csr::from_edges(100, 100, &edges).unwrap(),
            PartitionParams::default(),
        );
        let t = trace(&GpuConfig::rtx3090(), &CostModel::default(), &g, 32, KernelOptions::default());
        let expect = g.warp.n_groups().div_ceil(g.params.max_block_warps);
        assert_eq!(t.blocks.len(), expect);
    }
}
