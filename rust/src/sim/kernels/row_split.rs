//! Trace generator for the GraphBLAST-like baseline: row splitting with
//! static scheduling — one warp owns one whole row, regardless of
//! degree, and inner-loops over the column dimension.
//!
//! On power-law graphs this is the worst of both worlds the paper
//! describes: a hub row's warp serializes `deg × c_tiles` work (massive
//! makespan tail) while thousands of degree-1 warps idle, and the
//! column-dimension traversal "lacks efficiency" (fragmented
//! coalescing).

use super::{price_x_gather, sector_bytes, x_cache, CostModel, PreparedGraph};
use crate::sim::config::GpuConfig;
use crate::sim::machine::{BlockWork, KernelTrace};

pub fn trace(
    cfg: &GpuConfig,
    cost: &CostModel,
    graph: &PreparedGraph,
    coldim: usize,
) -> KernelTrace {
    let csr = &graph.original;
    let c_tiles = CostModel::col_tiles(coldim, cfg.warp_size) as f64;
    let row_bytes = (coldim * 4) as f64;
    let mut cache = x_cache(cfg, coldim);
    let warps_per_block = graph.params.max_block_warps.max(1);

    // static scheduling: rows in original order, fixed-size blocks
    let rows: Vec<usize> = (0..csr.n_rows).filter(|&r| csr.degree(r) > 0).collect();
    let mut blocks = Vec::with_capacity(rows.len() / warps_per_block + 1);
    for chunk in rows.chunks(warps_per_block) {
        let mut w = BlockWork::default();
        w.issue_insts = cost.block_setup_insts;
        // row_ptr reads for the chunk
        w.dram_bytes += sector_bytes(cfg, (chunk.len() + 1) * 8);
        for &r in chunk {
            let deg = csr.degree(r);
            let span = csr.row_ptr[r]..csr.row_ptr[r + 1];
            w.dram_bytes += sector_bytes(cfg, deg * 4) * 2.0;
            let (d, l2) = price_x_gather(&mut cache, &csr.col_idx[span], row_bytes);
            // row-split's column-dimension traversal leaves cache lines
            // partially used (the §I inefficiency): fragmentation factor
            w.dram_bytes += d * cost.x_frag_row_split;
            w.l2_bytes += l2 * cost.x_frag_row_split;

            // the whole row serialized in one warp's column loop
            let serial =
                deg as f64 * cost.inst_per_nz_tile_loop * c_tiles + cost.warp_setup_insts;
            w.issue_insts += serial;
            w.longest_warp_cycles = w.longest_warp_cycles.max(serial);
            w.warps += 1;

            // one direct (non-atomic) output write per row
            w.dram_bytes += row_bytes;
        }
        blocks.push(w);
    }

    KernelTrace { blocks, mem_efficiency: cost.eff_row_split, name: "graphblast".into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::sim::kernels::accel_gcn;
    use crate::sim::kernels::KernelOptions;
    use crate::sim::machine::simulate;

    #[test]
    fn hub_row_creates_huge_tail() {
        // star graph: one hub of degree 10k + 10k leaves of degree 1
        let n = 10_001;
        let mut edges: Vec<(u32, u32, f32)> = (1..n as u32).map(|v| (0, v, 1.0)).collect();
        edges.extend((1..n as u32).map(|v| (v, 0, 1.0)));
        let g = PreparedGraph::new(
            Csr::from_edges(n, n, &edges).unwrap(),
            PartitionParams::default(),
        );
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let rs = simulate(&cfg, &trace(&cfg, &cost, &g, 64, ));
        let accel = simulate(&cfg, &accel_gcn::trace(&cfg, &cost, &g, 64, KernelOptions::default()));
        // row-split serializes the hub: much slower than accel's split path
        assert!(rs.micros > accel.micros * 2.0, "rs {} vs accel {}", rs.micros, accel.micros);
        assert!(rs.sm_load_cv > accel.sm_load_cv);
    }

    #[test]
    fn regular_graph_is_not_pathological() {
        // on a near-regular graph row-split is a sane schedule — the gap
        // narrows (paper Fig. 5: molecular graphs show smaller spreads)
        let n = 5000;
        let mut edges = Vec::new();
        for r in 0..n as u32 {
            for k in 1..=3u32 {
                edges.push((r, (r + k) % n as u32, 1.0));
            }
        }
        let g = PreparedGraph::new(
            Csr::from_edges(n, n, &edges).unwrap(),
            PartitionParams::default(),
        );
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let rs = simulate(&cfg, &trace(&cfg, &cost, &g, 64));
        let accel = simulate(&cfg, &accel_gcn::trace(&cfg, &cost, &g, 64, KernelOptions::default()));
        assert!(rs.micros < accel.micros * 3.0, "rs {} vs accel {}", rs.micros, accel.micros);
    }

    #[test]
    fn zero_degree_rows_skipped() {
        let csr = Csr::from_edges(10, 10, &[(0, 1, 1.0)]).unwrap();
        let g = PreparedGraph::new(csr, PartitionParams::default());
        let t = trace(&GpuConfig::rtx3090(), &CostModel::default(), &g, 32);
        assert_eq!(t.blocks.len(), 1);
        assert_eq!(t.blocks[0].warps, 1);
    }
}
