//! Trace generator for the Accel-GCN kernel: degree sorting +
//! block-level partition + combined warp (the paper's §III-D mapping).
//!
//! Schedule features priced here:
//! * one int4 metadata read per block (vs per warp);
//! * per-warp col/val loads, contiguous and sector-aligned;
//! * X-row gathers in **degree-sorted** execution order through the L2
//!   model (locality from grouping similar rows);
//! * shared-memory accumulation within the block (`atomicAdd_block`),
//!   one aligned global write per block row;
//! * global atomic RMW only for split (`deg > deg_bound`) chunks;
//! * combined warp: the column dimension is covered by `c` cooperating
//!   warps with contiguous lanes — issue work spreads across warps and
//!   the serial path of each warp stays `O(nz_len)`, instead of one warp
//!   looping `c` times.

use super::{sector_bytes, price_x_gather, x_cache, CostModel, KernelOptions, PreparedGraph};
use crate::sim::config::GpuConfig;
use crate::sim::machine::{BlockWork, KernelTrace};

pub fn trace(
    cfg: &GpuConfig,
    cost: &CostModel,
    graph: &PreparedGraph,
    coldim: usize,
    opts: KernelOptions,
) -> KernelTrace {
    let sorted = &graph.sorted.csr;
    let bp = &graph.block;
    let deg_bound = bp.params.deg_bound();
    let c_tiles = CostModel::col_tiles(coldim, cfg.warp_size) as f64;
    let row_bytes = (coldim * 4) as f64;
    let mut cache = x_cache(cfg, coldim);

    let mut blocks = Vec::with_capacity(bp.meta.len());
    for (b, meta) in bp.meta.iter().enumerate() {
        let mut w = BlockWork::default();
        w.issue_insts = cost.block_setup_insts;
        // one int4 metadata record per block — the paper's compression
        w.dram_bytes += sector_bytes(cfg, 16);

        bp.for_each_block_warp_task(b, |t| {
            // contiguous col_idx + vals loads (4B each per nz)
            w.dram_bytes += sector_bytes(cfg, t.nz_len * 4) * 2.0;
            // X-row gather through L2, degree-sorted order
            let cols = &sorted.col_idx[t.nz_start..t.nz_start + t.nz_len];
            let (d, l) = price_x_gather(&mut cache, cols, row_bytes);
            w.dram_bytes += d;
            w.l2_bytes += l;

            let nz = t.nz_len as f64;
            let (task_issue, task_serial) = if opts.combined_warp {
                // c combined warps cover the column tiles in parallel
                let per_warp = nz * cost.inst_per_nz_tile_combined
                    + cost.warp_setup_insts
                    + cost.smem_atomic_inst;
                (per_warp * c_tiles, per_warp)
            } else {
                // a single warp inner-loops over the column tiles
                let serial = nz * cost.inst_per_nz_tile_loop * c_tiles
                    + cost.warp_setup_insts
                    + cost.smem_atomic_inst * c_tiles;
                (serial, serial)
            };
            w.issue_insts += task_issue;
            w.longest_warp_cycles = w.longest_warp_cycles.max(task_serial);
            w.warps += if opts.combined_warp { c_tiles as usize } else { 1 };
        });

        // output: shared → global, one aligned write per block row; split
        // chunks pay the global atomic RMW instead
        if meta.is_split(deg_bound) {
            w.dram_bytes += row_bytes * cost.atomic_rmw_factor;
        } else {
            w.dram_bytes += meta.block_rows() as f64 * row_bytes;
        }
        blocks.push(w);
    }

    let mem_efficiency =
        if opts.combined_warp { cost.eff_combined(coldim) } else { cost.eff_loop };
    KernelTrace {
        blocks,
        mem_efficiency,
        name: format!(
            "accel-gcn{}",
            if opts.combined_warp { "" } else { "(no-combined-warp)" }
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::sim::machine::simulate;
    use crate::util::rng::Pcg;

    fn graph(n: usize, seed: u64) -> PreparedGraph {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = Vec::new();
        for r in 0..n {
            for _ in 0..rng.range(1, 12) {
                edges.push((r as u32, rng.range(0, n) as u32, 1.0));
            }
        }
        PreparedGraph::new(Csr::from_edges(n, n, &edges).unwrap(), PartitionParams::default())
    }

    #[test]
    fn one_block_work_per_metadata_block() {
        let g = graph(200, 1);
        let t = trace(&GpuConfig::rtx3090(), &CostModel::default(), &g, 64, KernelOptions::default());
        assert_eq!(t.blocks.len(), g.block.n_blocks());
    }

    #[test]
    fn traffic_scales_with_coldim() {
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = graph(300, 2);
        let t32 = trace(&cfg, &cost, &g, 32, KernelOptions::default());
        let t128 = trace(&cfg, &cost, &g, 128, KernelOptions::default());
        let bytes = |t: &KernelTrace| t.blocks.iter().map(|b| b.dram_bytes + b.l2_bytes).sum::<f64>();
        // X + output traffic scale ~linearly with coldim; col/val+meta don't
        let ratio = bytes(&t128) / bytes(&t32);
        assert!(ratio > 2.5 && ratio < 4.5, "ratio={ratio}");
    }

    #[test]
    fn combined_warp_reduces_serial_path() {
        let cfg = GpuConfig::rtx3090();
        let cost = CostModel::default();
        let g = graph(300, 3);
        let with = trace(&cfg, &cost, &g, 128, KernelOptions { combined_warp: true });
        let without = trace(&cfg, &cost, &g, 128, KernelOptions { combined_warp: false });
        let longest = |t: &KernelTrace| {
            t.blocks.iter().map(|b| b.longest_warp_cycles).fold(0.0, f64::max)
        };
        assert!(longest(&with) < longest(&without));
        assert!(with.mem_efficiency > without.mem_efficiency);
    }

    #[test]
    fn split_rows_do_not_blow_up_makespan() {
        // a monster row gets chunked across blocks: the simulated tail
        // stays bounded (the whole point of the split path)
        let mut edges: Vec<(u32, u32, f32)> = (0..20_000u32).map(|c| (0, c % 2000, 1.0)).collect();
        for r in 1..2000u32 {
            edges.push((r, 0, 1.0));
        }
        let g = PreparedGraph::new(
            Csr::from_edges(2000, 2000, &edges).unwrap(),
            PartitionParams::default(),
        );
        let cfg = GpuConfig::rtx3090();
        let t = trace(&cfg, &CostModel::default(), &g, 64, KernelOptions::default());
        let r = simulate(&cfg, &t);
        // the longest block is bounded by deg_bound work, not the 18k-row
        let max_serial = t.blocks.iter().map(|b| b.longest_warp_cycles).fold(0.0, f64::max);
        let bound_work = g.params.max_warp_nzs as f64 * CostModel::default().inst_per_nz_tile_combined
            + CostModel::default().warp_setup_insts
            + CostModel::default().smem_atomic_inst;
        assert!(max_serial <= bound_work * 1.01, "max_serial={max_serial}");
        assert!(r.sm_load_cv < 1.0, "cv={}", r.sm_load_cv);
    }
}
