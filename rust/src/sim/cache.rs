//! Set-associative LRU cache model.
//!
//! Used to estimate L2 reuse of dense-matrix (`X`) rows: the key stream
//! is the sequence of X rows touched by the kernel's schedule, in
//! execution order, so orderings that group reuse (degree sorting) see
//! higher hit rates. Keys are opaque u64 (here: column index); the cache
//! is sized in *entries*, computed by the caller from capacity ÷ row
//! bytes.
//!
//! Implementation notes (this is the simulator's hottest loop — §Perf):
//! * sets are a power of two so set selection is a mask, not a modulo;
//! * each set is ordered by recency (move-to-front on hit), which is
//!   exact LRU without per-entry stamps and makes hub-row hits
//!   early-exit after one or two comparisons.

/// Set-associative LRU over u64 keys.
#[derive(Clone, Debug)]
pub struct LruCache {
    set_mask: usize,
    ways: usize,
    /// tags[set * ways + way], ordered most→least recently used;
    /// u64::MAX = invalid
    tags: Vec<u64>,
    pub hits: u64,
    pub misses: u64,
}

impl LruCache {
    /// `entries` total capacity, `ways` associativity. The set count is
    /// rounded **down** to a power of two (never exceeding the modeled
    /// capacity); minimum one set.
    pub fn new(entries: usize, ways: usize) -> LruCache {
        let ways = ways.max(1);
        let sets = (entries / ways).max(1);
        let sets = if sets.is_power_of_two() { sets } else { sets.next_power_of_two() / 2 };
        let sets = sets.max(1);
        LruCache { set_mask: sets - 1, ways, tags: vec![u64::MAX; sets * ways], hits: 0, misses: 0 }
    }

    /// Touch `key`; returns true on hit.
    #[inline]
    pub fn access(&mut self, key: u64) -> bool {
        // cheap multiplicative hash to spread keys across sets
        let set = ((key.wrapping_mul(0x9e3779b97f4a7c15) >> 32) as usize) & self.set_mask;
        let base = set * self.ways;
        let set_tags = &mut self.tags[base..base + self.ways];
        // MRU-first scan; hubs hit at position 0 and exit immediately
        if set_tags[0] == key {
            self.hits += 1;
            return true;
        }
        for w in 1..self.ways {
            if set_tags[w] == key {
                // move-to-front keeps the recency order exact
                set_tags[..=w].rotate_right(1);
                self.hits += 1;
                return true;
            }
        }
        // miss: evict LRU (last slot), insert at front
        set_tags.rotate_right(1);
        set_tags[0] = key;
        self.misses += 1;
        false
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_key_hits() {
        let mut c = LruCache::new(64, 4);
        assert!(!c.access(7));
        for _ in 0..10 {
            assert!(c.access(7));
        }
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 10);
    }

    #[test]
    fn capacity_eviction() {
        // stream far beyond capacity with no reuse: all misses
        let mut c = LruCache::new(16, 4);
        for k in 0..1000u64 {
            c.access(k);
        }
        assert_eq!(c.hits, 0);
    }

    #[test]
    fn working_set_within_capacity_hits() {
        let mut c = LruCache::new(256, 16);
        // warm
        for k in 0..100u64 {
            c.access(k);
        }
        c.hits = 0;
        c.misses = 0;
        // re-walk repeatedly: should be nearly all hits
        for _ in 0..5 {
            for k in 0..100u64 {
                c.access(k);
            }
        }
        assert!(c.hit_rate() > 0.9, "hit_rate={}", c.hit_rate());
    }

    #[test]
    fn lru_prefers_recent() {
        let mut c = LruCache::new(4, 4); // single set, 4 ways
        for k in 0..4u64 {
            c.access(k);
        }
        c.access(0); // refresh 0
        c.access(99); // evicts LRU (1)
        assert!(c.access(0), "0 was refreshed");
        assert!(!c.access(1), "1 was evicted");
    }

    #[test]
    fn move_to_front_is_exact_lru() {
        let mut c = LruCache::new(3, 3); // one set, 3 ways
        c.access(1);
        c.access(2);
        c.access(3);
        c.access(1); // order now 1,3,2
        c.access(4); // evicts 2
        assert!(c.access(1));
        assert!(c.access(3));
        assert!(!c.access(2));
    }

    #[test]
    fn degenerate_sizes() {
        let mut c = LruCache::new(0, 4); // clamps to one set
        assert!(!c.access(1));
        assert!(c.access(1));
    }

    #[test]
    fn sets_rounded_down_to_pow2() {
        // 100 entries / 4 ways = 25 sets → rounds down to 16 (≤ capacity)
        let c = LruCache::new(100, 4);
        assert_eq!(c.set_mask + 1, 16);
    }
}
