//! Block scheduling + resource model: turns a kernel trace (per-block
//! work descriptors) into cycles.
//!
//! Two coupled resources, as in a roofline with a tail term:
//! * **compute makespan** — blocks are list-scheduled onto SMs (online
//!   least-loaded, the hardware's GigaThread behaviour); each block
//!   contributes `max(issue_cycles, longest_warp_cycles)` to its SM.
//!   Power-law imbalance surfaces here: one monster block pins an SM
//!   while the rest drain.
//! * **memory cycles** — total DRAM bytes over effective bandwidth
//!   (peak × schedule-dependent coalescing efficiency), plus L2 traffic
//!   over the faster L2 bandwidth.
//!
//! Kernel time = `max(compute_makespan, mem_cycles) + launch_overhead`.

use super::config::GpuConfig;
use crate::util::stats::OnlineStats;

/// Work descriptor for one GPU thread block.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockWork {
    /// Warp-instructions issued by the whole block.
    pub issue_insts: f64,
    /// Serial cycles of the block's longest warp (latency floor).
    pub longest_warp_cycles: f64,
    /// Bytes that miss L2 and reach DRAM.
    pub dram_bytes: f64,
    /// Bytes served from L2.
    pub l2_bytes: f64,
    /// Resident warps the block occupies.
    pub warps: usize,
}

/// A kernel execution trace: its blocks plus schedule-level memory
/// efficiency (coalescing/alignment quality of the access pattern).
#[derive(Clone, Debug)]
pub struct KernelTrace {
    pub blocks: Vec<BlockWork>,
    /// Effective fraction of peak DRAM bandwidth this schedule achieves
    /// (memory coalescing + alignment quality).
    pub mem_efficiency: f64,
    /// Human-readable label for reports.
    pub name: String,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub name: String,
    pub cycles: f64,
    pub micros: f64,
    pub compute_makespan: f64,
    pub mem_cycles: f64,
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub n_blocks: usize,
    /// Coefficient of variation of per-SM compute load (imbalance).
    pub sm_load_cv: f64,
    /// Whether memory (true) or compute (false) bound.
    pub memory_bound: bool,
}

/// List-schedule the trace onto the machine and price it.
pub fn simulate(cfg: &GpuConfig, trace: &KernelTrace) -> SimResult {
    let mut sm_load = vec![0f64; cfg.sms];
    let mut dram_bytes = 0f64;
    let mut l2_bytes = 0f64;

    for b in &trace.blocks {
        // block compute: issue-throughput over the SM's schedulers,
        // floored by the longest warp's serial latency
        let issue_cycles = b.issue_insts / cfg.schedulers_per_sm as f64;
        let cost = issue_cycles.max(b.longest_warp_cycles);
        // online least-loaded assignment (GigaThread engine)
        let (idx, _) = sm_load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        sm_load[idx] += cost;
        dram_bytes += b.dram_bytes;
        l2_bytes += b.l2_bytes;
    }

    let compute_makespan = sm_load.iter().cloned().fold(0.0, f64::max);
    let mut load_stats = OnlineStats::new();
    for &l in &sm_load {
        load_stats.push(l);
    }

    // the schedule's coalescing quality applies to the whole memory
    // pipeline: fragmented transactions waste L2 bandwidth exactly as
    // they waste DRAM sectors
    let eff = trace.mem_efficiency.clamp(0.05, 1.0);
    let dram_cycles = dram_bytes / (cfg.dram_bytes_per_cycle * eff);
    let l2_cycles = l2_bytes / (cfg.dram_bytes_per_cycle * cfg.l2_bandwidth_mult * eff);
    let mem_cycles = dram_cycles + l2_cycles;

    let cycles = compute_makespan.max(mem_cycles) + cfg.launch_overhead_cycles;
    SimResult {
        name: trace.name.clone(),
        cycles,
        micros: cfg.cycles_to_us(cycles),
        compute_makespan,
        mem_cycles,
        dram_bytes,
        l2_bytes,
        n_blocks: trace.blocks.len(),
        sm_load_cv: load_stats.cv(),
        memory_bound: mem_cycles >= compute_makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(inst: f64, warp: f64, dram: f64) -> BlockWork {
        BlockWork { issue_insts: inst, longest_warp_cycles: warp, dram_bytes: dram, l2_bytes: 0.0, warps: 4 }
    }

    #[test]
    fn balanced_blocks_spread_evenly() {
        let cfg = GpuConfig::toy();
        let trace = KernelTrace {
            blocks: (0..8).map(|_| block(100.0, 10.0, 0.0)).collect(),
            mem_efficiency: 1.0,
            name: "balanced".into(),
        };
        let r = simulate(&cfg, &trace);
        // 8 equal blocks on 4 SMs → 2 per SM → makespan 200 (schedulers=1)
        assert!((r.compute_makespan - 200.0).abs() < 1e-9);
        assert!(r.sm_load_cv < 1e-9);
        assert!(!r.memory_bound);
    }

    #[test]
    fn monster_block_creates_tail() {
        let cfg = GpuConfig::toy();
        let mut blocks: Vec<BlockWork> = (0..7).map(|_| block(10.0, 1.0, 0.0)).collect();
        blocks.insert(0, block(10_000.0, 10_000.0, 0.0));
        let r = simulate(&cfg, &KernelTrace { blocks, mem_efficiency: 1.0, name: "tail".into() });
        assert!(r.compute_makespan >= 10_000.0);
        assert!(r.sm_load_cv > 1.0, "cv={}", r.sm_load_cv);
    }

    #[test]
    fn memory_bound_when_traffic_dominates() {
        let cfg = GpuConfig::toy();
        let trace = KernelTrace {
            blocks: vec![block(10.0, 1.0, 1_000_000.0)],
            mem_efficiency: 1.0,
            name: "mem".into(),
        };
        let r = simulate(&cfg, &trace);
        assert!(r.memory_bound);
        assert!((r.mem_cycles - 1_000_000.0 / 64.0).abs() < 1.0);
    }

    #[test]
    fn lower_efficiency_costs_cycles() {
        let cfg = GpuConfig::toy();
        let mk = |eff| KernelTrace {
            blocks: vec![block(1.0, 1.0, 64_000.0)],
            mem_efficiency: eff,
            name: "eff".into(),
        };
        let fast = simulate(&cfg, &mk(1.0));
        let slow = simulate(&cfg, &mk(0.5));
        assert!(slow.cycles > fast.cycles * 1.5, "{} vs {}", slow.cycles, fast.cycles);
    }

    #[test]
    fn l2_traffic_cheaper_than_dram() {
        let cfg = GpuConfig::toy();
        let dram = KernelTrace {
            blocks: vec![block(1.0, 1.0, 64_000.0)],
            mem_efficiency: 1.0,
            name: "d".into(),
        };
        let l2 = KernelTrace {
            blocks: vec![BlockWork { issue_insts: 1.0, longest_warp_cycles: 1.0, dram_bytes: 0.0, l2_bytes: 64_000.0, warps: 1 }],
            mem_efficiency: 1.0,
            name: "l".into(),
        };
        let rd = simulate(&cfg, &dram);
        let rl = simulate(&cfg, &l2);
        assert!(rl.mem_cycles < rd.mem_cycles / 2.0);
    }

    #[test]
    fn launch_overhead_floors_empty_kernel() {
        let cfg = GpuConfig::toy();
        let r = simulate(&cfg, &KernelTrace { blocks: vec![], mem_efficiency: 1.0, name: "empty".into() });
        assert_eq!(r.cycles, cfg.launch_overhead_cycles);
    }
}
