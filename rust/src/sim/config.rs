//! Machine model parameters (RTX-3090 class by default, matching the
//! paper's testbed §IV-A).

/// GPU hardware parameters used by the cost model.
#[derive(Clone, Copy, Debug)]
pub struct GpuConfig {
    /// Streaming multiprocessors (3090: 82).
    pub sms: usize,
    /// Threads per warp.
    pub warp_size: usize,
    /// Warp instructions issued per cycle per SM (4 schedulers).
    pub schedulers_per_sm: usize,
    /// Resident warp limit per SM (occupancy ceiling).
    pub max_warps_per_sm: usize,
    /// Core clock, GHz (3090 boost ≈ 1.395).
    pub clock_ghz: f64,
    /// Aggregate DRAM bytes per core cycle (936 GB/s ÷ 1.395 GHz ≈ 671).
    pub dram_bytes_per_cycle: f64,
    /// L2 capacity in bytes (3090: 6 MiB).
    pub l2_bytes: usize,
    /// L2 line size in bytes.
    pub l2_line: usize,
    /// L2 associativity used by the reuse model.
    pub l2_ways: usize,
    /// Memory transaction sector in bytes.
    pub sector: usize,
    /// Shared memory per SM in bytes (3090: 128 KiB configurable).
    pub shared_mem_per_sm: usize,
    /// Fixed kernel launch + drain overhead in cycles.
    pub launch_overhead_cycles: f64,
    /// L2-hit bandwidth multiplier relative to DRAM (L2 is ~3–4× faster).
    pub l2_bandwidth_mult: f64,
}

impl GpuConfig {
    /// The paper's testbed: GeForce RTX 3090.
    pub fn rtx3090() -> GpuConfig {
        GpuConfig {
            sms: 82,
            warp_size: 32,
            schedulers_per_sm: 4,
            max_warps_per_sm: 48,
            clock_ghz: 1.395,
            dram_bytes_per_cycle: 671.0,
            l2_bytes: 6 * 1024 * 1024,
            l2_line: 128,
            l2_ways: 16,
            sector: 32,
            shared_mem_per_sm: 128 * 1024,
            launch_overhead_cycles: 4_000.0,
            l2_bandwidth_mult: 3.5,
        }
    }

    /// A small config for fast unit tests (keeps numbers tiny and the
    /// imbalance effects visible with few blocks).
    pub fn toy() -> GpuConfig {
        GpuConfig {
            sms: 4,
            warp_size: 32,
            schedulers_per_sm: 1,
            max_warps_per_sm: 8,
            clock_ghz: 1.0,
            dram_bytes_per_cycle: 64.0,
            l2_bytes: 16 * 1024,
            l2_line: 128,
            l2_ways: 4,
            sector: 32,
            shared_mem_per_sm: 16 * 1024,
            launch_overhead_cycles: 100.0,
            l2_bandwidth_mult: 3.5,
        }
    }

    /// Convert cycles to microseconds at the configured clock.
    pub fn cycles_to_us(&self, cycles: f64) -> f64 {
        cycles / (self.clock_ghz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtx3090_matches_published_specs() {
        let c = GpuConfig::rtx3090();
        assert_eq!(c.sms, 82);
        assert_eq!(c.l2_bytes, 6 * 1024 * 1024);
        // 671 B/cycle × 1.395 GHz ≈ 936 GB/s
        let bw = c.dram_bytes_per_cycle * c.clock_ghz;
        assert!((bw - 936.0).abs() < 2.0, "bw={bw}");
    }

    #[test]
    fn cycles_to_us() {
        let c = GpuConfig::rtx3090();
        let us = c.cycles_to_us(1_395_000.0);
        assert!((us - 1000.0).abs() < 1e-6);
    }
}
