//! GPU microarchitecture simulator — the evaluation substrate standing in
//! for the paper's RTX 3090 + Nsight Compute testbed (DESIGN.md §2).
//!
//! The simulator executes each kernel's *schedule* (the same block/warp
//! workloads the exact executors in [`crate::spmm`] verify numerically)
//! as a stream of per-block work descriptors, and models the first-order
//! hardware resources the paper's techniques target:
//!
//! * **SM issue throughput and occupancy** — blocks are list-scheduled
//!   onto SMs; a block's cost is its issued instructions over the warp
//!   schedulers, floored by its longest warp → workload imbalance shows
//!   up as makespan tail exactly as in Fig. 4(d/e).
//! * **DRAM traffic at 32-byte sector granularity** with per-schedule
//!   coalescing efficiency — the combined warp's contiguous thread→
//!   address mapping vs the fragmented inner-loop traversal.
//! * **L2 reuse** via a set-associative LRU over dense-matrix rows, fed
//!   with each kernel's actual access order (degree-sorted or not).
//! * **Atomics** — global read-modify-write traffic for schemes that
//!   accumulate partial rows in global memory.
//!
//! Reported numbers are cycles/µs of the *model*, not the 3090; the
//! paper comparison is made on normalized speedups (Fig. 5/7/8 style).

pub mod config;
pub mod cache;
pub mod machine;
pub mod kernels;

pub use config::GpuConfig;
pub use kernels::{simulate_kernel, KernelKind, KernelOptions};
pub use machine::{simulate, BlockWork, KernelTrace, SimResult};
