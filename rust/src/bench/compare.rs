//! `bench-compare` — the regression gate between two benchmark reports.
//!
//! Successive PRs write `BENCH_*.json` trajectory files (see
//! [`super::report`]); this module diffs two of them. Both documents are
//! flattened to their numeric leaves (the injected `meta` section is
//! skipped — commit hashes and timestamps are not metrics), leaves
//! present in both are paired, and each pair becomes one table cell
//! with a speedup factor oriented so **> 1 is always an improvement**:
//!
//! * time-like metrics (`*_us`, `*_ns`, `*secs`, `latency`, `p50`, …)
//!   improve downward — speedup is `old / new`;
//! * throughput-like metrics (`rps`, `*_per_sec`, `gflops`, `fusion`,
//!   …) improve upward — speedup is `new / old`;
//! * everything else (counts, sizes, configuration echoes) is neutral:
//!   reported as a ratio for context but never flagged.
//!
//! A directional cell whose speedup falls below `1 - max_regress/100`
//! is a regression; the `bench-compare` subcommand exits nonzero if any
//! exist, which is the whole point — CI pins the serving/training
//! benches against their previous run without hand-curated thresholds
//! per metric.
//!
//! Array elements are labeled by their identifying fields (`threads`,
//! `ladder_max`, `graph`, `kernel`, …) rather than position, so
//! reordered sweep points still pair correctly.

use crate::util::bench::Table;
use crate::util::json::Json;

/// Which way "better" points for one metric, inferred from its leaf key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Latency-like: smaller new value is an improvement.
    LowerIsBetter,
    /// Throughput-like: larger new value is an improvement.
    HigherIsBetter,
    /// Counts / config echoes: compared for context, never a regression.
    Neutral,
}

/// Classify a flattened path by its leaf key name.
pub fn direction_of(path: &str) -> Direction {
    let leaf = path.rsplit('/').next().unwrap_or(path).to_ascii_lowercase();
    let lower_suffix = ["_us", "_ns", "_ms", "secs", "micros", "nanos"];
    let lower_sub = ["latency", "time", "imbalance", "overhead", "bytes"];
    let lower_prefix = ["p50", "p90", "p99", "p999", "max_", "worst"];
    let higher_sub = [
        "per_sec", "rps", "gflops", "gbps", "pct_peak", "throughput", "speedup", "fusion",
        "reuse", "accuracy",
    ];
    if lower_suffix.iter().any(|s| leaf.ends_with(s))
        || lower_sub.iter().any(|s| leaf.contains(s))
        || lower_prefix.iter().any(|s| leaf.starts_with(s))
    {
        Direction::LowerIsBetter
    } else if higher_sub.iter().any(|s| leaf.contains(s)) {
        Direction::HigherIsBetter
    } else {
        Direction::Neutral
    }
}

/// One paired metric.
#[derive(Clone, Debug)]
pub struct CellDelta {
    pub path: String,
    pub direction: Direction,
    pub old: f64,
    pub new: f64,
    /// Improvement factor, oriented so > 1 is better (neutral cells
    /// carry plain `new / old`).
    pub speedup: f64,
    pub regressed: bool,
}

/// The full diff between two reports.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub cells: Vec<CellDelta>,
    /// Numeric paths only the old report has (renamed / dropped metrics).
    pub only_old: Vec<String>,
    /// Numeric paths only the new report has.
    pub only_new: Vec<String>,
    pub max_regress_pct: f64,
}

impl CompareReport {
    pub fn regressions(&self) -> Vec<&CellDelta> {
        self.cells.iter().filter(|c| c.regressed).collect()
    }

    /// Paper-style stdout table plus the unmatched-path summary.
    pub fn render(&self) -> String {
        let mut table = Table::new(&["metric", "old", "new", "speedup", "dir", ""]);
        for c in &self.cells {
            table.row(vec![
                c.path.clone(),
                format!("{:.6}", c.old),
                format!("{:.6}", c.new),
                format!("{:.3}x", c.speedup),
                match c.direction {
                    Direction::LowerIsBetter => "lower".to_string(),
                    Direction::HigherIsBetter => "higher".to_string(),
                    Direction::Neutral => "·".to_string(),
                },
                if c.regressed { "REGRESSED".to_string() } else { String::new() },
            ]);
        }
        let mut out = table.render();
        if !self.only_old.is_empty() {
            out.push_str(&format!(
                "only in OLD ({}): {}\n",
                self.only_old.len(),
                self.only_old.join(", ")
            ));
        }
        if !self.only_new.is_empty() {
            out.push_str(&format!(
                "only in NEW ({}): {}\n",
                self.only_new.len(),
                self.only_new.join(", ")
            ));
        }
        let n_reg = self.regressions().len();
        out.push_str(&format!(
            "{} metrics compared, {} regression(s) beyond {:.1}%\n",
            self.cells.len(),
            n_reg,
            self.max_regress_pct
        ));
        out
    }
}

/// Fields that identify an array element (a sweep point) better than
/// its position; used to build stable labels so reordered points pair.
const ID_KEYS: &[&str] = &[
    "experiment", "graph", "kernel", "name", "optimizer", "threads", "ladder_max", "coldim",
    "width", "batch_size", "variant", "deg",
];

fn scalar_label(v: &Json) -> Option<String> {
    match v {
        Json::Num(n) => Some(format!("{n}")),
        Json::Str(s) => Some(s.clone()),
        Json::Bool(b) => Some(b.to_string()),
        _ => None,
    }
}

fn element_label(v: &Json, i: usize) -> String {
    if let Json::Obj(m) = v {
        let parts: Vec<String> = ID_KEYS
            .iter()
            .filter_map(|k| m.get(*k).and_then(scalar_label).map(|s| format!("{k}={s}")))
            .collect();
        if !parts.is_empty() {
            return format!("[{}]", parts.join(","));
        }
    }
    format!("[{i}]")
}

fn flatten_into(v: &Json, path: &str, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Num(n) => out.push((path.to_string(), *n)),
        Json::Obj(m) => {
            for (k, child) in m {
                // the report writer injects `meta` (commit, timestamp,
                // host) into every document — provenance, not metrics
                if path.is_empty() && k == "meta" {
                    continue;
                }
                let p = if path.is_empty() { k.clone() } else { format!("{path}/{k}") };
                flatten_into(child, &p, out);
            }
        }
        Json::Arr(items) => {
            for (i, it) in items.iter().enumerate() {
                flatten_into(it, &format!("{path}{}", element_label(it, i)), out);
            }
        }
        _ => {}
    }
}

/// Flatten a report to `path → value` pairs, disambiguating any
/// colliding labels with a positional suffix.
pub fn flatten_numeric(doc: &Json) -> Vec<(String, f64)> {
    let mut raw = Vec::new();
    flatten_into(doc, "", &mut raw);
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    raw.into_iter()
        .map(|(p, v)| {
            let n = seen.entry(p.clone()).or_insert(0);
            *n += 1;
            if *n == 1 { (p, v) } else { (format!("{p}#{n}"), v) }
        })
        .collect()
}

fn ratio(num: f64, den: f64) -> f64 {
    if den == 0.0 {
        if num == 0.0 { 1.0 } else { f64::INFINITY }
    } else {
        num / den
    }
}

/// Diff two benchmark reports. `max_regress_pct` is the tolerated
/// directional slowdown in percent (e.g. 5.0 ⇒ speedup ≥ 0.95 passes).
pub fn compare(old: &Json, new: &Json, max_regress_pct: f64) -> CompareReport {
    let old_flat: std::collections::BTreeMap<String, f64> =
        flatten_numeric(old).into_iter().collect();
    let new_flat: std::collections::BTreeMap<String, f64> =
        flatten_numeric(new).into_iter().collect();
    let floor = 1.0 - max_regress_pct / 100.0;
    let mut cells = Vec::new();
    for (path, &ov) in &old_flat {
        if let Some(&nv) = new_flat.get(path) {
            let direction = direction_of(path);
            let speedup = match direction {
                Direction::LowerIsBetter => ratio(ov, nv),
                Direction::HigherIsBetter | Direction::Neutral => ratio(nv, ov),
            };
            let regressed = direction != Direction::Neutral && speedup < floor;
            cells.push(CellDelta { path: path.clone(), direction, old: ov, new: nv, speedup, regressed });
        }
    }
    let only_old =
        old_flat.keys().filter(|k| !new_flat.contains_key(*k)).cloned().collect();
    let only_new =
        new_flat.keys().filter(|k| !old_flat.contains_key(*k)).cloned().collect();
    CompareReport { cells, only_old, only_new, max_regress_pct }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(rps: f64, p99: f64, batches: f64) -> Json {
        let mut point = Json::obj();
        point.set("threads", 2).set("rps", rps).set("p99_us", p99).set("batches", batches);
        let mut meta = Json::obj();
        meta.set("commit", "deadbeef").set("elapsed_secs", 9.0);
        let mut doc = Json::obj();
        doc.set("experiment", "serve_native");
        doc.set("meta", meta);
        doc.set("points", vec![point]);
        doc
    }

    #[test]
    fn direction_heuristics() {
        assert_eq!(direction_of("points[0]/p99_us"), Direction::LowerIsBetter);
        assert_eq!(direction_of("a/step_time_secs"), Direction::LowerIsBetter);
        assert_eq!(direction_of("a/imbalance_ratio"), Direction::LowerIsBetter);
        assert_eq!(direction_of("points[0]/rps"), Direction::HigherIsBetter);
        assert_eq!(direction_of("train/steps_per_sec"), Direction::HigherIsBetter);
        assert_eq!(direction_of("points[0]/fusion_factor"), Direction::HigherIsBetter);
        // bandwidth metrics improve upward: a drop in achieved GB/s or
        // % of calibrated peak is a regression, not a win
        assert_eq!(direction_of("points[0]/achieved_gbps"), Direction::HigherIsBetter);
        assert_eq!(direction_of("points[0]/pct_peak"), Direction::HigherIsBetter);
        assert_eq!(direction_of("calibration/peak_gbps"), Direction::HigherIsBetter);
        // ...while traffic volume improves downward
        assert_eq!(direction_of("points[0]/bytes_per_nnz"), Direction::LowerIsBetter);
        assert_eq!(direction_of("points[0]/batches"), Direction::Neutral);
        assert_eq!(direction_of("points[0]/threads"), Direction::Neutral);
    }

    #[test]
    fn bandwidth_points_pair_by_variant_and_regress_downward() {
        // two microkernel-style cells sharing (graph, coldim, threads)
        // but differing in `variant`: they must pair by identity, and a
        // drop in achieved_gbps must flag as a regression (it used to
        // be Neutral — silently waved through)
        let mk = |variant: &str, gbps: f64| {
            let mut p = Json::obj();
            p.set("graph", "collab").set("coldim", 16).set("threads", 1);
            p.set("variant", variant).set("achieved_gbps", gbps);
            p
        };
        let mut old = Json::obj();
        old.set("points", vec![mk("scalar+fixed", 10.0), mk("scalar+adaptive", 12.0)]);
        let mut new = Json::obj();
        // reordered AND the adaptive cell lost 25% of its bandwidth
        new.set("points", vec![mk("scalar+adaptive", 9.0), mk("scalar+fixed", 10.0)]);
        let r = compare(&old, &new, 10.0);
        let cell = r
            .cells
            .iter()
            .find(|c| c.path.contains("variant=scalar+adaptive") && c.path.contains("gbps"))
            .expect("adaptive cell pairs by variant label");
        assert_eq!(cell.direction, Direction::HigherIsBetter);
        assert!(cell.regressed, "25% bandwidth drop beyond a 10% gate must flag");
        assert_eq!(r.regressions().len(), 1, "the fixed cell is unchanged");
    }

    #[test]
    fn self_compare_is_clean() {
        let doc = report(100.0, 900.0, 7.0);
        let r = compare(&doc, &doc, 5.0);
        assert!(!r.cells.is_empty());
        assert!(r.cells.iter().all(|c| (c.speedup - 1.0).abs() < 1e-12));
        assert!(r.regressions().is_empty());
        assert!(r.only_old.is_empty() && r.only_new.is_empty());
        assert!(r.render().contains("0 regression(s)"));
    }

    #[test]
    fn regressions_flag_in_both_directions() {
        let old = report(100.0, 900.0, 7.0);
        // throughput down 20%, latency up 50%, a neutral count moves too
        let new = report(80.0, 1350.0, 9.0);
        let r = compare(&old, &new, 10.0);
        let by_path = |p: &str| r.cells.iter().find(|c| c.path.contains(p)).unwrap();
        assert!(by_path("rps").regressed, "throughput drop beyond 10% must flag");
        assert!(by_path("p99_us").regressed, "latency growth beyond 10% must flag");
        assert!(!by_path("batches").regressed, "neutral metrics never flag");
        assert_eq!(r.regressions().len(), 2);
        // a looser gate passes the same diff
        assert!(compare(&old, &new, 60.0).regressions().is_empty());
        // meta is provenance, not a metric
        assert!(r.cells.iter().all(|c| !c.path.starts_with("meta")));
        assert!(r.render().contains("REGRESSED"));
    }

    #[test]
    fn array_points_pair_by_identity_not_position() {
        let mk = |threads: usize, rps: f64| {
            let mut p = Json::obj();
            p.set("threads", threads).set("rps", rps);
            p
        };
        let mut old = Json::obj();
        old.set("points", vec![mk(1, 50.0), mk(2, 90.0)]);
        let mut new = Json::obj();
        new.set("points", vec![mk(2, 95.0), mk(1, 52.0)]); // reordered, both faster
        let r = compare(&old, &new, 5.0);
        assert_eq!(r.cells.iter().filter(|c| c.path.contains("rps")).count(), 2);
        assert!(r.regressions().is_empty(), "reordered but improved points must pair");
    }

    #[test]
    fn unmatched_paths_are_reported_not_compared() {
        let mut old = Json::obj();
        old.set("a", 1.0).set("dropped", 2.0);
        let mut new = Json::obj();
        new.set("a", 1.0).set("added", 3.0);
        let r = compare(&old, &new, 5.0);
        assert_eq!(r.cells.len(), 1);
        assert_eq!(r.only_old, vec!["dropped".to_string()]);
        assert_eq!(r.only_new, vec!["added".to_string()]);
    }
}
