//! End-to-end training driver: the Rust hot loop over the AOT train-step
//! artifact. This is the full-stack proof: Pallas kernel (L1) inside the
//! jax model (L2), lowered once, looped from Rust via PJRT (L3) — no
//! Python on the training path.

use crate::coordinator::Engine;
use crate::runtime::HostTensor;
use anyhow::{Context, Result};
use std::time::Instant;

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub final_accuracy: f64,
    pub steps_per_sec: f64,
}

/// Run `steps` SGD steps; logs every `log_every`. Requires the artifact
/// dir to contain `{arch}_train` + `{arch}_fwd` + features/labels.
pub fn run_training(dir: &str, steps: usize, log_every: usize) -> Result<TrainReport> {
    let engine = Engine::start(dir)?;
    let model = engine
        .manifest()
        .model
        .clone()
        .context("manifest has no model section (rerun aot.py without --skip-model)")?;
    let train_name = format!("{}_train", model.arch);
    let fwd_name = format!("{}_fwd", model.arch);

    let x = HostTensor::load_npy(format!("{dir}/features.npy"))
        .context("features.npy (prepare with a labeled graph)")?;
    let labels_t = HostTensor::load_npy(format!("{dir}/labels.npy")).context("labels.npy")?;
    let labels: Vec<i32> = labels_t.as_i32()?.to_vec();
    let mut params = engine.manifest().load_params()?;

    println!(
        "training {}-layer {} ({} params tensors) on {} nodes, lr {}",
        model.n_layers,
        model.arch,
        params.len(),
        x.shape()[0],
        model.lr
    );

    engine.load_artifact(&train_name)?;
    engine.bind_bell(&train_name)?;
    // bind the static x and labels by position (after params + bells)
    let spec = engine.manifest().artifact(&train_name)?.clone();
    let x_pos = spec
        .inputs
        .iter()
        .position(|t| t.name == "x")
        .context("train artifact has no `x` input")?;
    let l_pos = spec
        .inputs
        .iter()
        .position(|t| t.name == "labels")
        .context("train artifact has no `labels` input")?;
    engine.bind(&train_name, vec![(x_pos, x.clone()), (l_pos, labels_t)])?;

    let mut losses = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for step in 0..steps {
        let mut outputs = engine.exec_sync(&train_name, params)?;
        let loss = outputs.pop().context("train step returned no loss")?.scalar_f32()?;
        params = outputs;
        losses.push(loss);
        if step % log_every == 0 || step + 1 == steps {
            println!("step {step:>5}  loss {loss:.4}");
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let steps_per_sec = steps as f64 / elapsed;

    // final accuracy through the forward artifact
    engine.load_artifact(&fwd_name)?;
    engine.bind_bell(&fwd_name)?;
    let mut fwd_inputs = params.clone();
    fwd_inputs.push(x);
    let logits = engine
        .exec_sync(&fwd_name, fwd_inputs)?
        .pop()
        .context("fwd returned nothing")?;
    let final_accuracy = accuracy(&logits, &labels)?;
    println!(
        "done: {} steps in {:.1}s ({:.1} steps/s), loss {:.4} -> {:.4}, accuracy {:.1}%",
        steps,
        elapsed,
        steps_per_sec,
        losses.first().copied().unwrap_or(f32::NAN),
        losses.last().copied().unwrap_or(f32::NAN),
        final_accuracy * 100.0
    );
    println!("{}", engine.metrics.exec_latency.snapshot().render("device exec"));
    Ok(TrainReport { losses, final_accuracy, steps_per_sec })
}

/// Argmax accuracy of logits `[n, k]` against labels `[n]`.
pub fn accuracy(logits: &HostTensor, labels: &[i32]) -> Result<f64> {
    let shape = logits.shape();
    anyhow::ensure!(shape.len() == 2 && shape[0] == labels.len(), "logit shape mismatch");
    let k = shape[1];
    let data = logits.as_f32()?;
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &data[i * k..(i + 1) * k];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j as i32)
            .unwrap();
        if pred == label {
            correct += 1;
        }
    }
    Ok(correct as f64 / labels.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let logits = HostTensor::f32(&[3, 2], vec![2.0, 1.0, 0.0, 5.0, 9.0, 1.0]);
        let labels = vec![0, 1, 0];
        assert_eq!(accuracy(&logits, &labels).unwrap(), 1.0);
        let labels = vec![1, 1, 0];
        assert!((accuracy(&logits, &labels).unwrap() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_shape_mismatch() {
        let logits = HostTensor::f32(&[2, 2], vec![0.0; 4]);
        assert!(accuracy(&logits, &[0, 1, 0]).is_err());
    }
}
