//! `train_native` — end-to-end native training throughput.
//!
//! Trains the same GCN on the same planted-partition labeled graph
//! ([`labeled_synthetic_with`]) across thread counts × optimizers,
//! reporting steps/sec and the per-step phase breakdown the tentpole
//! promises: fwd-SpMM / fwd-dense / bwd-SpMM / bwd-dense / optimizer.
//! Every cell also records the loss trajectory (initial → final) and a
//! **verified** bit: before training, the backward direction's SpMM
//! (`Âᵀ·G` through the transpose plan) is checked against the dense
//! `Âᵀ` reference — bit-for-bit when the plan has no split rows, else
//! elementwise-close — so a wrong backward path fails the bench (and
//! CI) rather than silently mis-training. Written to
//! `BENCH_train_native.json` via [`bench::report`](crate::bench::report).

use crate::graph::datasets::{labeled_synthetic_with, LabeledDataset};
use crate::model::ModelConfig;
use crate::train::{default_lr, TrainConfig, Trainer};
use crate::util::bench::Table;
use crate::util::json::Json;
use anyhow::Result;

/// Default thread sweep: serial baseline, small, and the paper-relevant
/// core count.
pub const DEFAULT_THREADS: [usize; 3] = [1, 2, 8];

/// Sweep shape.
#[derive(Clone, Debug)]
pub struct TrainBenchConfig {
    pub nodes: usize,
    pub classes: usize,
    pub feat_dim: usize,
    pub hidden: usize,
    pub layers: usize,
    pub steps: usize,
    pub homophily: f64,
    pub avg_deg: f64,
    pub threads: Vec<usize>,
    pub seed: u64,
}

impl TrainBenchConfig {
    /// The full sweep the `bench` subcommand runs.
    pub fn paper(seed: u64) -> TrainBenchConfig {
        TrainBenchConfig {
            nodes: 2000,
            classes: 6,
            feat_dim: 32,
            hidden: 32,
            layers: 2,
            steps: 60,
            homophily: 0.85,
            avg_deg: 8.0,
            threads: DEFAULT_THREADS.to_vec(),
            seed,
        }
    }

    /// Reduced sweep for unit tests / `--quick`.
    pub fn quick(seed: u64) -> TrainBenchConfig {
        TrainBenchConfig {
            nodes: 250,
            classes: 4,
            feat_dim: 16,
            hidden: 16,
            layers: 2,
            steps: 50,
            homophily: 0.85,
            avg_deg: 6.0,
            threads: vec![1, 2],
            seed,
        }
    }

    fn model(&self, optimizer: &str) -> ModelConfig {
        ModelConfig::gcn(self.feat_dim, self.hidden, self.classes, self.layers)
            .with_lr(default_lr(optimizer))
    }
}

/// One (threads, optimizer) cell.
#[derive(Clone, Debug)]
pub struct TrainNativePoint {
    pub threads: usize,
    pub optimizer: String,
    pub steps: usize,
    pub steps_per_sec: f64,
    /// Per-step phase means, µs.
    pub fwd_spmm_us: f64,
    pub fwd_dense_us: f64,
    pub bwd_spmm_us: f64,
    pub bwd_dense_us: f64,
    pub opt_us: f64,
    pub loss_initial: f64,
    pub loss_final: f64,
    pub train_accuracy: f64,
    pub test_accuracy: f64,
    /// Backward SpMM matched the dense `Âᵀ` reference.
    pub verified: bool,
}

/// Run the sweep: threads × {sgd, adam}, one fresh trainer per cell
/// (same dataset, same init seed — cells differ only in the knob being
/// measured).
pub fn run(cfg: &TrainBenchConfig) -> Result<Vec<TrainNativePoint>> {
    let data = labeled_synthetic_with(
        cfg.nodes,
        cfg.classes,
        cfg.feat_dim,
        cfg.avg_deg,
        cfg.homophily,
        cfg.seed,
    );
    let adj = data.csr.gcn_normalize();
    let mut points = Vec::new();
    for &threads in &cfg.threads {
        for optimizer in ["sgd", "adam"] {
            points.push(run_cell(cfg, &data, &adj, threads, optimizer)?);
        }
    }
    Ok(points)
}

fn run_cell(
    cfg: &TrainBenchConfig,
    data: &LabeledDataset,
    adj: &crate::graph::Csr,
    threads: usize,
    optimizer: &str,
) -> Result<TrainNativePoint> {
    let tc = TrainConfig {
        model: cfg.model(optimizer),
        optimizer: optimizer.to_string(),
        steps: cfg.steps,
        threads,
        seed: cfg.seed,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(adj, tc)?;
    let verified = trainer.verify_backward_spmm(cfg.feat_dim, cfg.seed);
    let report = trainer.train(data)?;
    let steps = report.losses.len();
    let per = |s: f64| s / steps.max(1) as f64 * 1e6;
    Ok(TrainNativePoint {
        threads,
        optimizer: optimizer.to_string(),
        steps,
        steps_per_sec: report.steps_per_sec,
        fwd_spmm_us: per(report.phases.fwd_spmm),
        fwd_dense_us: per(report.phases.fwd_dense),
        bwd_spmm_us: per(report.phases.bwd_spmm),
        bwd_dense_us: per(report.phases.bwd_dense),
        opt_us: per(report.phases.opt),
        loss_initial: report.initial_loss(),
        loss_final: report.final_loss(),
        train_accuracy: report.train_accuracy,
        test_accuracy: report.test_accuracy,
        verified,
    })
}

/// Render the paper-style table.
pub fn report(points: &[TrainNativePoint]) -> String {
    let mut table = Table::new(&[
        "threads", "optim", "steps/s", "fwd-spmm µs", "fwd-dense µs", "bwd-spmm µs",
        "bwd-dense µs", "opt µs", "loss init→final", "test acc", "verified",
    ]);
    for p in points {
        table.row(vec![
            p.threads.to_string(),
            p.optimizer.clone(),
            format!("{:.1}", p.steps_per_sec),
            format!("{:.0}", p.fwd_spmm_us),
            format!("{:.0}", p.fwd_dense_us),
            format!("{:.0}", p.bwd_spmm_us),
            format!("{:.0}", p.bwd_dense_us),
            format!("{:.0}", p.opt_us),
            format!("{:.3}→{:.3}", p.loss_initial, p.loss_final),
            format!("{:.2}", p.test_accuracy),
            p.verified.to_string(),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[TrainNativePoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("threads", p.threads);
            o.set("optimizer", p.optimizer.as_str());
            o.set("steps", p.steps);
            o.set("steps_per_sec", p.steps_per_sec);
            o.set("fwd_spmm_us", p.fwd_spmm_us);
            o.set("fwd_dense_us", p.fwd_dense_us);
            o.set("bwd_spmm_us", p.bwd_spmm_us);
            o.set("bwd_dense_us", p.bwd_dense_us);
            o.set("opt_us", p.opt_us);
            o.set("loss_initial", p.loss_initial);
            o.set("loss_final", p.loss_final);
            o.set("train_accuracy", p.train_accuracy);
            o.set("test_accuracy", p.test_accuracy);
            o.set("verified", p.verified);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "train_native");
    doc.set("executor", "train/block-level-parallel");
    doc.set("unit", "us");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_trains_verifies_and_reports() {
        let mut cfg = TrainBenchConfig::quick(7);
        cfg.threads = vec![2];
        cfg.steps = 50;
        let pts = run(&cfg).unwrap();
        assert_eq!(pts.len(), 2, "one cell per optimizer");
        for p in &pts {
            assert!(p.verified, "{p:?}: backward SpMM must match dense Âᵀ");
            assert!(p.steps_per_sec > 0.0, "{p:?}");
            assert!(
                p.loss_final <= 0.5 * p.loss_initial,
                "{}: loss {:.4} -> {:.4} must halve in {} steps",
                p.optimizer,
                p.loss_initial,
                p.loss_final,
                p.steps
            );
            assert!(p.fwd_spmm_us >= 0.0 && p.bwd_dense_us >= 0.0);
        }
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("train_native"));
        assert!(json.contains("bwd_spmm_us"));
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), 2);
        assert!(report(&pts).contains("steps/s"));
    }
}
