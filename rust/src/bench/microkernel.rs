//! `microkernel` — the SIMD × dispatch matrix over a degree-skew sweep.
//!
//! Every cell runs the same block-level schedule over the same
//! [`SpmmPlan`] and shard layout; the matrix axes are everything the
//! SIMD tentpole made selectable:
//!
//! * **lane strategy** — `scalar` (the PR 4 autovectorized tile,
//!   kept as the measured floor), `portable-simd` (explicit 8-wide
//!   unrolled lanes), and the arch path (`avx2` / `neon`) when the host
//!   supports it;
//! * **dispatch mode** — `fixed` forces the dense tiled kernel on every
//!   block (PR 4 behavior); `adaptive` honors the plan's per-bucket
//!   [`KernelSchedule`](crate::pipeline::KernelSchedule), routing
//!   short-row blocks through the sparse gather kernel.
//!
//! The graph list is a **degree-skew sweep**: the Collab stand-in (the
//! paper's headline power-law graph), a near-regular low-degree graph
//! (`uniform-d2`, almost entirely gather-territory rows — where
//! adaptive dispatch must win) and a synthetic power-law mix
//! (`powerlaw-2.1`, both kernel shapes live in one plan — where the
//! dense/sparse crossover shows). Each point records its plan's
//! `sparse_frac` so the crossover is readable straight from
//! `BENCH_microkernel.json`.
//!
//! Speedups are relative to the `scalar+fixed` cell — exactly the PR 4
//! tiled path — and **every cell is verified against the dense CSR
//! reference** before it is timed. The legacy pre-tiling path
//! ([`spmm_block_level_parallel_scalar`]) is also timed per cell as
//! `legacy-scalar` for cross-PR continuity.

use crate::graph::csr::Csr;
use crate::graph::datasets::{by_name, materialize, ScalePolicy};
use crate::graph::generator::{degree_sequence, from_degree_sequence, DegreeModel};
use crate::partition::patterns::PartitionParams;
use crate::pipeline::{
    spmm_block_level_parallel_scalar, spmm_block_level_parallel_with, KernelSchedule, SpmmPlan,
    TrafficModel,
};
use crate::spmm::verify::allclose;
use crate::spmm::{spmm_gflops, SimdLevel, SPARSE_DEG_MAX};
use crate::util::bench::{time_fn, Table};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Arc;

/// Default thread sweep: serial baseline, small, and the paper-relevant
/// core count.
pub const DEFAULT_THREADS: [usize; 3] = [1, 2, 8];

/// Default column dimensions: the paper's 16..128 range plus ragged
/// widths (17) and a non-power-of-two multiple of the tile (96).
pub const DEFAULT_COLDIMS: [usize; 5] = [16, 17, 64, 96, 128];

/// The degree-skew sweep (see the module docs).
pub const DEFAULT_GRAPHS: [&str; 3] = ["collab", "uniform-d2", "powerlaw-2.1"];

/// Reduced axes for the `--quick` CI smoke: one ragged and one exact
/// width, both dispatch modes, both skew extremes — small enough to run
/// with verification on in seconds.
pub const QUICK_THREADS: [usize; 2] = [1, 2];
pub const QUICK_COLDIMS: [usize; 2] = [16, 17];
pub const QUICK_GRAPHS: [&str; 2] = ["collab", "uniform-d2"];

/// One timed (graph, coldim, threads, variant) cell.
#[derive(Clone, Debug)]
pub struct MicroPoint {
    pub graph: String,
    pub coldim: usize,
    pub threads: usize,
    /// `"<level>+<dispatch>"`, e.g. `"portable-simd+adaptive"`, or
    /// `"legacy-scalar"` for the pre-tiling path.
    pub variant: String,
    pub us: f64,
    pub gflops: f64,
    /// This cell's time relative to the `scalar+fixed` (PR 4 tiled)
    /// cell at the same (graph, coldim, threads).
    pub speedup_vs_baseline: f64,
    /// Fraction of the plan's blocks the schedule routed to the sparse
    /// gather kernel (a property of the graph+params, constant across
    /// the cell's variants).
    pub sparse_frac: f64,
    /// Analytic traffic-model bytes this variant moves per nonzero at
    /// this coldim (fixed dispatch is priced under an all-dense
    /// schedule, adaptive under the plan's).
    pub bytes_per_nnz: f64,
    /// Analytic bytes over measured wall time, GB/s.
    pub achieved_gbps: f64,
    /// `achieved_gbps` as % of the calibrated peak — 0 when no
    /// calibration has been published this process
    /// ([`crate::obs::calibrate::global`]).
    pub pct_peak: f64,
    /// This variant matched the dense CSR reference on this input.
    pub verified: bool,
}

/// Resolve a sweep graph name: Table I stand-ins via the dataset layer,
/// synthetic skew points via the degree-sequence generator (scaled by
/// the same policy so `--quick` stays small).
fn build_graph(name: &str, policy: ScalePolicy, seed: u64) -> Result<Csr> {
    if let Some(spec) = by_name(name) {
        return Ok(materialize(spec, policy, seed));
    }
    let n = policy.node_cap.clamp(64, 20_000);
    let mut rng = Pcg::seed_from(seed ^ 0x5_4e57);
    let (model, target_edges) = match name {
        // nearly every row lands at deg ≤ SPARSE_DEG_MAX: the
        // gather-dominant end of the skew sweep
        "uniform-d2" => (DegreeModel::NearRegular { jitter: 0.3 }, 2 * n),
        // heavy-tailed mix: sparse rows and dense buckets in one plan
        "powerlaw-2.1" => {
            (DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.05 }, (8 * n).min(policy.edge_cap))
        }
        _ => anyhow::bail!("unknown graph `{name}` (see `accel-gcn datasets`)"),
    };
    let degs = degree_sequence(model, n, target_edges.min(policy.edge_cap), &mut rng);
    Ok(from_degree_sequence(n, &degs, &mut rng))
}

/// The lane×dispatch variant list for this host: `arch` rows appear
/// only when the features are actually available (an unavailable arch
/// request would silently degrade to portable and time the same code
/// twice).
fn variants() -> Vec<(SimdLevel, bool)> {
    let mut levels = vec![SimdLevel::Scalar, SimdLevel::Portable];
    if SimdLevel::Arch.available() {
        levels.push(SimdLevel::Arch);
    }
    let mut out = Vec::with_capacity(levels.len() * 2);
    for level in levels {
        for adaptive in [false, true] {
            out.push((level, adaptive));
        }
    }
    out
}

fn variant_name(level: SimdLevel, adaptive: bool) -> String {
    format!("{}+{}", level.name(), if adaptive { "adaptive" } else { "fixed" })
}

/// Run the matrix over one named graph.
pub fn run(
    graph: &str,
    coldims: &[usize],
    threads: &[usize],
    policy: ScalePolicy,
    seed: u64,
) -> Result<Vec<MicroPoint>> {
    let csr = build_graph(graph, policy, seed)?;
    let n_cols = csr.n_cols;
    let nnz = csr.nnz();
    let plan = Arc::new(SpmmPlan::build(csr, PartitionParams::default()));
    let sparse_frac = plan.kernels.sparse_frac();
    // fixed dispatch (and the legacy path) run every block dense: price
    // their traffic under an all-dense schedule (crossover 0), adaptive
    // cells under the plan's own model
    let fixed_traffic =
        TrafficModel::derive(&plan.block, &KernelSchedule::derive_with(&plan.block, 0));
    let mut rng = Pcg::seed_from(seed ^ 0x71c7_0e);
    let vs = variants();

    let mut points = Vec::with_capacity(coldims.len() * threads.len() * (vs.len() + 1));
    for &coldim in coldims {
        let x: Vec<f32> = (0..n_cols * coldim).map(|_| rng.f32() - 0.5).collect();
        let want = plan.original.spmm_dense(&x, coldim);
        for &t in threads {
            let pool = ThreadPool::new(t);
            // verify first: a fast wrong kernel is worse than no kernel
            // (variant, verified, secs, traffic-model bytes)
            let mut cells: Vec<(String, bool, f64, u64)> = Vec::new();
            let mut baseline_s = f64::NAN;
            for &(level, adaptive) in &vs {
                let y = spmm_block_level_parallel_with(&plan, &x, coldim, &pool, level, adaptive);
                let verified = allclose(&y, &want, 1e-3, 1e-3);
                drop(y);
                let name = variant_name(level, adaptive);
                let m = time_fn(&format!("microkernel_{name}"), 1, 0.2, || {
                    std::hint::black_box(spmm_block_level_parallel_with(
                        &plan, &x, coldim, &pool, level, adaptive,
                    ));
                });
                let secs = m.p50();
                if level == SimdLevel::Scalar && !adaptive {
                    baseline_s = secs; // the PR 4 tiled path
                }
                let bytes = if adaptive {
                    plan.traffic.bytes_total(coldim)
                } else {
                    fixed_traffic.bytes_total(coldim)
                };
                cells.push((name, verified, secs, bytes));
            }
            // the pre-tiling legacy path, for cross-PR continuity
            {
                let y = spmm_block_level_parallel_scalar(&plan, &x, coldim, &pool);
                let verified = allclose(&y, &want, 1e-3, 1e-3);
                drop(y);
                let m = time_fn("microkernel_legacy_scalar", 1, 0.2, || {
                    std::hint::black_box(spmm_block_level_parallel_scalar(
                        &plan, &x, coldim, &pool,
                    ));
                });
                cells.push((
                    "legacy-scalar".to_string(),
                    verified,
                    m.p50(),
                    fixed_traffic.bytes_total(coldim),
                ));
            }
            let cal = crate::obs::calibrate::global();
            for (variant, verified, secs, bytes) in cells {
                let achieved_gbps = bytes as f64 / secs.max(1e-12) / 1e9;
                points.push(MicroPoint {
                    graph: graph.to_string(),
                    coldim,
                    threads: t,
                    variant,
                    us: secs * 1e6,
                    gflops: spmm_gflops(nnz, coldim, secs),
                    speedup_vs_baseline: baseline_s / secs.max(1e-12),
                    sparse_frac,
                    bytes_per_nnz: bytes as f64 / nnz.max(1) as f64,
                    achieved_gbps,
                    pct_peak: cal.map_or(0.0, |c| c.pct_of_peak(achieved_gbps)),
                    verified,
                });
            }
        }
    }
    Ok(points)
}

/// Run the matrix over a list of graphs (the skew sweep).
pub fn run_graphs(
    graphs: &[&str],
    coldims: &[usize],
    threads: &[usize],
    policy: ScalePolicy,
    seed: u64,
) -> Result<Vec<MicroPoint>> {
    let mut all = Vec::new();
    for g in graphs {
        all.extend(run(g, coldims, threads, policy, seed)?);
    }
    Ok(all)
}

/// Render the paper-style table.
pub fn report(points: &[MicroPoint]) -> String {
    let mut table = Table::new(&[
        "graph", "coldim", "threads", "variant", "µs", "GF/s", "GB/s", "B/nnz",
        "vs scalar+fixed", "sparse frac", "verified",
    ]);
    for p in points {
        table.row(vec![
            p.graph.clone(),
            p.coldim.to_string(),
            p.threads.to_string(),
            p.variant.clone(),
            format!("{:.1}", p.us),
            format!("{:.2}", p.gflops),
            format!("{:.2}", p.achieved_gbps),
            format!("{:.1}", p.bytes_per_nnz),
            format!("{:.2}x", p.speedup_vs_baseline),
            format!("{:.2}", p.sparse_frac),
            p.verified.to_string(),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[MicroPoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("graph", p.graph.as_str());
            o.set("coldim", p.coldim);
            o.set("threads", p.threads);
            o.set("variant", p.variant.as_str());
            o.set("us", p.us);
            o.set("gflops", p.gflops);
            o.set("speedup_vs_baseline", p.speedup_vs_baseline);
            o.set("sparse_frac", p.sparse_frac);
            o.set("bytes_per_nnz", p.bytes_per_nnz);
            o.set("achieved_gbps", p.achieved_gbps);
            o.set("pct_peak", p.pct_peak);
            o.set("verified", p.verified);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "microkernel");
    doc.set("baseline", "scalar+fixed (the PR 4 tiled path)");
    doc.set("simd_detected", SimdLevel::detect().name());
    doc.set("sparse_deg_max", SPARSE_DEG_MAX);
    doc.set("unit", "us");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shape_verification_and_json() {
        let pts = run("collab", &[16, 17], &[1], ScalePolicy::tiny(), 7).unwrap();
        // variants() cells + legacy-scalar, per (coldim, thread) pair
        let per_cell = variants().len() + 1;
        assert_eq!(pts.len(), 2 * per_cell);
        for p in &pts {
            assert!(p.verified, "{p:?}: every variant must match the dense reference");
            assert!(p.us > 0.0 && p.gflops.is_finite(), "{p:?}");
            assert!(p.speedup_vs_baseline > 0.0, "{p:?}");
            assert!((0.0..=1.0).contains(&p.sparse_frac), "{p:?}");
            // the traffic model always charges ≥ 8 B/nnz (col idx +
            // value) and the cell ran for nonzero wall time
            assert!(p.bytes_per_nnz >= 8.0, "{p:?}");
            assert!(p.achieved_gbps > 0.0 && p.achieved_gbps.is_finite(), "{p:?}");
            assert!((0.0..=100.0).contains(&p.pct_peak), "{p:?}");
        }
        // the gather kernel pays one dst RMW per *nonzero* where dense
        // pays one per *row*: an adaptive schedule can only add traffic
        // relative to all-dense (it wins on time, not bytes)
        let by = |v: &str| pts.iter().find(|p| p.variant == v).unwrap().bytes_per_nnz;
        assert!(by("scalar+adaptive") >= by("scalar+fixed") - 1e-9);
        // the baseline cell's speedup is exactly 1 by definition
        let base = pts.iter().find(|p| p.variant == "scalar+fixed").unwrap();
        assert!((base.speedup_vs_baseline - 1.0).abs() < 1e-9);
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("microkernel"));
        assert!(json.contains("sparse_deg_max"));
        assert!(json.contains("simd_detected"));
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), pts.len());
        let rendered = report(&pts);
        assert!(rendered.contains("vs scalar+fixed"));
    }

    #[test]
    fn skew_sweep_covers_both_kernel_regimes() {
        let pts =
            run_graphs(&["uniform-d2", "powerlaw-2.1"], &[16], &[1], ScalePolicy::tiny(), 3)
                .unwrap();
        let frac = |g: &str| {
            pts.iter().find(|p| p.graph == g).map(|p| p.sparse_frac).unwrap()
        };
        // the near-regular deg-2 graph is gather-dominant; the
        // power-law mix keeps a meaningful dense share — the crossover
        // the bench exists to show
        assert!(frac("uniform-d2") > 0.5, "uniform-d2 sparse_frac {}", frac("uniform-d2"));
        assert!(frac("powerlaw-2.1") < 1.0, "powerlaw sparse_frac {}", frac("powerlaw-2.1"));
        for p in &pts {
            assert!(p.verified, "{p:?}");
        }
    }

    #[test]
    fn unknown_graph_rejected() {
        assert!(run("nope", &[16], &[1], ScalePolicy::tiny(), 1).is_err());
    }
}
