//! `microkernel` — old scalar execution path vs the column-tiled
//! zero-copy path, head to head.
//!
//! Both paths run the same block-level schedule over the same
//! [`SpmmPlan`] and the same shard layout; what differs is everything
//! this PR's tentpole changed:
//!
//! * **scalar** ([`spmm_block_level_parallel_scalar`]) — `Arc` input
//!   copy, bounds-checked scalar inner loop, per-block `vec!` staging,
//!   post-join copy pass, separate full unpermute;
//! * **tiled** ([`spmm_block_level_parallel`]) — borrowed inputs,
//!   register-tiled autovectorized inner loop, direct-write sharding,
//!   fused unpermute-scatter.
//!
//! The sweep runs on the Collab stand-in (the paper's headline
//! power-law graph) across threads × column dimensions — including
//! ragged widths (17) that exercise the tail path — and **every cell is
//! verified against the dense CSR reference** before it is timed.
//! Results (GFLOP/s per path + speedup) go to `BENCH_microkernel.json`
//! so successive PRs can track the hot path.

use crate::graph::datasets::{by_name, materialize, ScalePolicy};
use crate::partition::patterns::PartitionParams;
use crate::pipeline::{spmm_block_level_parallel, spmm_block_level_parallel_scalar, SpmmPlan};
use crate::spmm::spmm_flops;
use crate::spmm::verify::allclose;
use crate::util::bench::{time_fn, Table};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Arc;

/// Default thread sweep: serial baseline, small, and the paper-relevant
/// core count.
pub const DEFAULT_THREADS: [usize; 3] = [1, 2, 8];

/// Default column dimensions: the paper's 16..128 range plus ragged
/// widths (17) and a non-power-of-two multiple of the tile (96).
pub const DEFAULT_COLDIMS: [usize; 5] = [16, 17, 64, 96, 128];

/// One timed (coldim, threads) cell: both paths, same plan and input.
#[derive(Clone, Debug)]
pub struct MicroPoint {
    pub graph: String,
    pub coldim: usize,
    pub threads: usize,
    pub scalar_us: f64,
    pub tiled_us: f64,
    pub scalar_gflops: f64,
    pub tiled_gflops: f64,
    /// `scalar_us / tiled_us`.
    pub speedup: f64,
    /// Both paths matched the dense CSR reference on this cell's input.
    pub verified: bool,
}

/// Run the head-to-head sweep on one named dataset.
pub fn run(
    graph: &str,
    coldims: &[usize],
    threads: &[usize],
    policy: ScalePolicy,
    seed: u64,
) -> Result<Vec<MicroPoint>> {
    let spec = by_name(graph)
        .ok_or_else(|| anyhow::anyhow!("unknown graph `{graph}` (see `accel-gcn datasets`)"))?;
    let csr = materialize(spec, policy, seed);
    let n_cols = csr.n_cols;
    let nnz = csr.nnz();
    let plan = Arc::new(SpmmPlan::build(csr, PartitionParams::default()));
    let mut rng = Pcg::seed_from(seed ^ 0x71c7_0e);

    let mut points = Vec::with_capacity(coldims.len() * threads.len());
    for &coldim in coldims {
        let x: Vec<f32> = (0..n_cols * coldim).map(|_| rng.f32() - 0.5).collect();
        let want = plan.original.spmm_dense(&x, coldim);
        for &t in threads {
            let pool = ThreadPool::new(t);
            // verify first: a fast wrong kernel is worse than no kernel
            let tiled_y = spmm_block_level_parallel(&plan, &x, coldim, &pool);
            let scalar_y = spmm_block_level_parallel_scalar(&plan, &x, coldim, &pool);
            let verified = allclose(&tiled_y, &want, 1e-3, 1e-3)
                && allclose(&scalar_y, &want, 1e-3, 1e-3);
            drop((tiled_y, scalar_y));
            let m_scalar = time_fn("microkernel_scalar", 1, 0.2, || {
                std::hint::black_box(spmm_block_level_parallel_scalar(&plan, &x, coldim, &pool));
            });
            let m_tiled = time_fn("microkernel_tiled", 1, 0.2, || {
                std::hint::black_box(spmm_block_level_parallel(&plan, &x, coldim, &pool));
            });
            let (scalar_s, tiled_s) = (m_scalar.p50(), m_tiled.p50());
            let flops = spmm_flops(nnz, coldim);
            points.push(MicroPoint {
                graph: graph.to_string(),
                coldim,
                threads: t,
                scalar_us: scalar_s * 1e6,
                tiled_us: tiled_s * 1e6,
                scalar_gflops: flops / scalar_s.max(1e-12) / 1e9,
                tiled_gflops: flops / tiled_s.max(1e-12) / 1e9,
                speedup: scalar_s / tiled_s.max(1e-12),
                verified,
            });
        }
    }
    Ok(points)
}

/// Render the paper-style table.
pub fn report(points: &[MicroPoint]) -> String {
    let mut table = Table::new(&[
        "graph", "coldim", "threads", "scalar µs", "tiled µs", "scalar GF/s", "tiled GF/s",
        "speedup", "verified",
    ]);
    for p in points {
        table.row(vec![
            p.graph.clone(),
            p.coldim.to_string(),
            p.threads.to_string(),
            format!("{:.1}", p.scalar_us),
            format!("{:.1}", p.tiled_us),
            format!("{:.2}", p.scalar_gflops),
            format!("{:.2}", p.tiled_gflops),
            format!("{:.2}x", p.speedup),
            p.verified.to_string(),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[MicroPoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("graph", p.graph.as_str());
            o.set("coldim", p.coldim);
            o.set("threads", p.threads);
            o.set("scalar_us", p.scalar_us);
            o.set("tiled_us", p.tiled_us);
            o.set("scalar_gflops", p.scalar_gflops);
            o.set("tiled_gflops", p.tiled_gflops);
            o.set("speedup", p.speedup);
            o.set("verified", p.verified);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "microkernel");
    doc.set("baseline", "block-level-parallel-scalar");
    doc.set("candidate", "block-level-parallel-tiled");
    doc.set("unit", "us");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shape_verification_and_json() {
        let pts = run("collab", &[16, 17], &[1, 2], ScalePolicy::tiny(), 7).unwrap();
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.verified, "{p:?}: both paths must match the dense reference");
            assert!(p.scalar_us > 0.0 && p.tiled_us > 0.0, "{p:?}");
            assert!(p.scalar_gflops.is_finite() && p.tiled_gflops.is_finite(), "{p:?}");
            assert!(p.speedup > 0.0, "{p:?}");
        }
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("microkernel"));
        assert!(json.contains("tiled_gflops"));
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), 4);
        let rendered = report(&pts);
        assert!(rendered.contains("speedup"));
    }

    #[test]
    fn unknown_graph_rejected() {
        assert!(run("nope", &[16], &[1], ScalePolicy::tiny(), 1).is_err());
    }
}
