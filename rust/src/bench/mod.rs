//! Reproduction harnesses.
//!
//! * [`paper`] — regenerates every table and figure of the paper's
//!   evaluation (Fig. 2/3/5/6/7/8, Table I/II) on the GPU simulator,
//!   writing CSVs under `results/` and printing paper-style tables.
//! * [`train`] — the end-to-end training driver (EXPERIMENTS.md §E2E):
//!   loops the AOT train-step artifact from Rust, logging the loss
//!   curve.
//! * [`serve`] — the serving driver: dynamic column batching over the
//!   compiled SpMM ladder with latency/throughput metrics.
//! * [`exec_scaling`] — thread-scaling sweep of the parallel block-level
//!   executor (writes `BENCH_exec_scaling.json`).
//! * [`serve_native`] — open-loop load generation against the native
//!   serving subsystem ([`crate::serve`]): fusion factor, throughput,
//!   and tail latency across thread counts and ladder widths (writes
//!   `BENCH_serve_native.json`).
//! * [`delta_update`] — incremental plan maintenance vs full replanning
//!   across update-batch sizes × degree-skew regimes, with every batch
//!   verified bit-for-bit (writes `BENCH_delta_update.json`).
//! * [`microkernel`] — the SIMD × dispatch matrix: lane strategies
//!   {scalar, portable-simd, arch} × {fixed, adaptive} kernel dispatch
//!   over a degree-skew graph sweep, threads × column widths (ragged
//!   tails included), every cell verified against the dense reference
//!   (writes `BENCH_microkernel.json`).
//! * [`train_native`] — end-to-end native training ([`crate::train`]):
//!   steps/sec + per-phase breakdown (fwd-SpMM / fwd-dense / bwd-SpMM /
//!   bwd-dense / opt) across threads × optimizers, backward SpMM
//!   verified against the dense `Âᵀ` reference (writes
//!   `BENCH_train_native.json`).
//! * [`report`] — the one writer for every `BENCH_*.json` trajectory
//!   file (out-dir + repo-root duplicate conventions live here, not in
//!   each experiment).
//! * [`compare`] — the regression gate: diffs two `BENCH_*.json`
//!   reports cell-by-cell with direction-aware speedups
//!   (`accel-gcn bench-compare OLD NEW --max-regress PCT`).

pub mod paper;
pub mod ablation;
pub mod compare;
pub mod delta_update;
pub mod exec_scaling;
pub mod microkernel;
pub mod report;
pub mod train;
pub mod train_native;
pub mod serve;
pub mod serve_native;
