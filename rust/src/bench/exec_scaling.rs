//! `exec_scaling` — CPU-executor thread-scaling experiment.
//!
//! Sweeps the parallel block-level executor
//! ([`crate::pipeline::spmm_block_level_parallel`]) over thread counts
//! on the Collab stand-in (the paper's headline power-law graph) and a
//! set of column dimensions, and writes a machine-readable
//! `BENCH_exec_scaling.json` so successive PRs can track the hot path's
//! parallel efficiency over time.
//!
//! Timing methodology: one [`SpmmPlan`] is built per graph (plan build
//! is *not* timed — that is the point of the pipeline), the input
//! matrix is borrowed directly by the scoped shard jobs (zero-copy),
//! and each (coldim, threads) cell times the full tiled executor —
//! including its fused unpermute-scatter — with a persistent pool, p50
//! over [`time_fn`]'s batched samples.

use crate::graph::datasets::{by_name, materialize, ScalePolicy};
use crate::partition::patterns::PartitionParams;
use crate::pipeline::{spmm_block_level_parallel, SpmmPlan};
use crate::util::bench::{time_fn, Table};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Arc;

/// Default thread sweep: serial baseline through the paper-relevant
/// core counts.
pub const DEFAULT_THREADS: [usize; 4] = [1, 2, 4, 8];

/// Default column dimensions (ends + middle of the paper's 16..128).
pub const DEFAULT_COLDIMS: [usize; 3] = [16, 64, 128];

/// One timed (graph, coldim, threads) cell.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub graph: String,
    pub coldim: usize,
    pub threads: usize,
    pub micros: f64,
    /// `t(1 thread) / t(this)` at the same (graph, coldim).
    pub speedup_vs_1t: f64,
}

/// Run the sweep on one graph. `threads` should include 1 (the baseline
/// for `speedup_vs_1t`; otherwise speedups are reported as 0).
pub fn exec_scaling(
    graph: &str,
    coldims: &[usize],
    threads: &[usize],
    policy: ScalePolicy,
    seed: u64,
) -> Result<Vec<ScalingPoint>> {
    let spec = by_name(graph)
        .ok_or_else(|| anyhow::anyhow!("unknown graph `{graph}` (see `accel-gcn datasets`)"))?;
    let csr = materialize(spec, policy, seed);
    let n_cols = csr.n_cols;
    let plan = Arc::new(SpmmPlan::build(csr, PartitionParams::default()));
    let mut rng = Pcg::seed_from(seed ^ 0x5ca1ab1e);

    let mut points = Vec::with_capacity(coldims.len() * threads.len());
    for &coldim in coldims {
        let x: Vec<f32> = (0..n_cols * coldim).map(|_| rng.f32() - 0.5).collect();
        // time every thread count first, then derive speedups from the
        // 1-thread entry so the `threads` ordering doesn't matter
        let timed: Vec<(usize, f64)> = threads
            .iter()
            .map(|&t| {
                let pool = ThreadPool::new(t);
                let m = time_fn("exec_scaling", 1, 0.25, || {
                    std::hint::black_box(spmm_block_level_parallel(&plan, &x, coldim, &pool));
                });
                (t, m.p50() * 1e6)
            })
            .collect();
        let base_us = timed.iter().find(|(t, _)| *t == 1).map(|(_, us)| *us);
        for (t, micros) in timed {
            points.push(ScalingPoint {
                graph: graph.to_string(),
                coldim,
                threads: t,
                micros,
                speedup_vs_1t: base_us.map_or(0.0, |b| b / micros),
            });
        }
    }
    Ok(points)
}

/// Render the paper-style table.
pub fn report(points: &[ScalingPoint]) -> String {
    let mut table = Table::new(&["graph", "coldim", "threads", "µs (p50)", "speedup vs 1t"]);
    for p in points {
        table.row(vec![
            p.graph.clone(),
            p.coldim.to_string(),
            p.threads.to_string(),
            format!("{:.1}", p.micros),
            format!("{:.2}x", p.speedup_vs_1t),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[ScalingPoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("graph", p.graph.as_str());
            o.set("coldim", p.coldim);
            o.set("threads", p.threads);
            o.set("us", p.micros);
            o.set("speedup_vs_1t", p.speedup_vs_1t);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "exec_scaling");
    doc.set("executor", "block-level-parallel");
    doc.set("unit", "us");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shape_and_json() {
        let pts = exec_scaling("collab", &[16], &[1, 2], ScalePolicy::tiny(), 7).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.micros.is_finite() && p.micros > 0.0, "{p:?}");
            assert!(p.speedup_vs_1t > 0.0, "{p:?}");
        }
        assert!((pts[0].speedup_vs_1t - 1.0).abs() < 1e-9, "1-thread baseline");
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("exec_scaling"));
        assert!(json.contains("speedup_vs_1t"));
        // round-trips through our own parser
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), 2);
    }

    #[test]
    fn unknown_graph_rejected() {
        assert!(exec_scaling("nope", &[16], &[1], ScalePolicy::tiny(), 1).is_err());
    }
}
