//! `serve_native` — open-loop load generation against the native
//! serving subsystem ([`crate::serve`]).
//!
//! The generator registers several synthetic power-law tenants, builds
//! one GCN model per tenant, then fires a burst of mixed-width SpMM and
//! GCN requests **without waiting for completions** (open loop: the
//! arrival process is independent of service). The server drains the
//! backlog in fused rounds; the report captures requests/sec, the
//! batch-fusion factor (requests amortized per sparse traversal), and
//! p50/p99 end-to-end latency — swept across thread counts and ladder
//! widths, written to `BENCH_serve_native.json` so successive PRs can
//! track the serving path.
//!
//! Every response is (optionally but by default) verified against the
//! exact CPU executor — the bench doubles as the serving path's
//! end-to-end correctness check in CI.

use crate::graph::generator::{self, DegreeModel};
use crate::graph::Csr;
use crate::model::ModelConfig;
use crate::runtime::HostTensor;
use crate::serve::{reference_forward, GcnModel, ServeConfig, ServeMetrics, Server};
use crate::spmm::verify::allclose;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::rng::Pcg;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// One load-generation run's shape.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Resident graphs (tenants); sizes are staggered around `nodes`.
    pub tenants: usize,
    pub nodes: usize,
    pub avg_deg: f64,
    pub requests: usize,
    pub threads: usize,
    /// Virtual width ladder for the server's column batcher.
    pub ladder: Vec<usize>,
    /// Every k-th request is a full GCN forward pass (0 = SpMM only).
    pub gcn_every: usize,
    pub seed: u64,
    /// Check every response against the exact CPU executor.
    pub verify: bool,
    /// Forwarded to [`ServeConfig::tune_every`]: run the closed-loop
    /// plan tuner every this many serve rounds (0 = off; effective
    /// only while the global registry is enabled).
    pub tune_every: usize,
}

impl Default for LoadConfig {
    fn default() -> LoadConfig {
        LoadConfig {
            tenants: 2,
            nodes: 300,
            avg_deg: 6.0,
            requests: 64,
            threads: 4,
            ladder: vec![32, 64, 128],
            gcn_every: 3,
            seed: 42,
            verify: true,
            tune_every: 0,
        }
    }
}

/// One measured (threads, ladder) cell.
#[derive(Clone, Debug)]
pub struct ServeNativePoint {
    pub threads: usize,
    pub ladder_max: usize,
    pub tenants: usize,
    pub requests: usize,
    pub batches: u64,
    /// Mean requests fused per executed batch (> 1 ⇒ traversals amortized).
    pub fusion_factor: f64,
    pub requests_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub verified: bool,
}

/// Synthetic power-law tenant graphs, sizes staggered so the tenants
/// are genuinely distinct.
fn tenant_graphs(cfg: &LoadConfig) -> Vec<Csr> {
    (0..cfg.tenants)
        .map(|t| {
            let n = cfg.nodes + t * cfg.nodes / 4;
            let mut rng = Pcg::seed_from(cfg.seed.wrapping_add(t as u64 * 7919));
            let degs = generator::degree_sequence(
                DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.1 },
                n,
                (n as f64 * cfg.avg_deg) as usize,
                &mut rng,
            );
            generator::from_degree_sequence(n, &degs, &mut rng)
        })
        .collect()
}

/// Run one open-loop burst and measure it.
pub fn run_once(cfg: &LoadConfig) -> Result<ServeNativePoint> {
    run_once_with_metrics(cfg).map(|(p, _)| p)
}

/// [`run_once`], additionally handing back the server's metrics (the
/// `serve-native` subcommand prints the per-stage breakdown from them).
pub fn run_once_with_metrics(cfg: &LoadConfig) -> Result<(ServeNativePoint, Arc<ServeMetrics>)> {
    anyhow::ensure!(cfg.tenants >= 1, "need at least one tenant");
    anyhow::ensure!(cfg.requests >= 1, "need at least one request");
    let graphs = tenant_graphs(cfg);
    let server = Server::start(ServeConfig {
        threads: cfg.threads,
        queue_capacity: cfg.requests + 8,
        ladder: cfg.ladder.clone(),
        tune_every: cfg.tune_every,
        ..ServeConfig::default()
    })?;
    let handles: Vec<_> = graphs
        .iter()
        .enumerate()
        .map(|(t, g)| server.register_graph(&format!("tenant-{t}"), g))
        .collect::<Result<_>>()?;
    let max_w = server.max_width();
    let narrowest = *cfg.ladder.iter().min().expect("ladder validated non-empty");
    let in_dim = narrowest.min(32);
    let models: Vec<Arc<GcnModel>> = (0..cfg.tenants)
        .map(|t| {
            Arc::new(GcnModel::random(
                ModelConfig::gcn(in_dim, in_dim, 8, 2),
                cfg.seed.wrapping_add(t as u64),
            ))
        })
        .collect();

    // generate the request stream up front (generation is not timed)
    let mut rng = Pcg::seed_from(cfg.seed ^ 0x0bea_7e55);
    enum Gen {
        Spmm { t: usize, x: HostTensor },
        Gcn { t: usize, x: HostTensor },
    }
    let stream: Vec<Gen> = (0..cfg.requests)
        .map(|i| {
            let t = rng.range(0, cfg.tenants);
            let n = graphs[t].n_rows;
            if cfg.gcn_every > 0 && i % cfg.gcn_every == 0 {
                let x = HostTensor::f32(
                    &[n, in_dim],
                    (0..n * in_dim).map(|_| rng.f32() - 0.5).collect(),
                );
                Gen::Gcn { t, x }
            } else {
                let lo = (max_w / 8).max(1);
                let hi = (max_w / 2 + 1).max(lo + 1);
                let w = rng.range(lo, hi);
                let x =
                    HostTensor::f32(&[n, w], (0..n * w).map(|_| rng.f32() - 0.5).collect());
                Gen::Spmm { t, x }
            }
        })
        .collect();
    let expected: Vec<Option<Vec<f32>>> = if cfg.verify {
        stream
            .iter()
            .map(|g| match g {
                Gen::Spmm { t, x } => Some(
                    graphs[*t].spmm_dense(x.as_f32().expect("f32 stream"), x.shape()[1]),
                ),
                Gen::Gcn { t, x } => Some(reference_forward(
                    &graphs[*t],
                    &models[*t],
                    x.as_f32().expect("f32 stream"),
                )),
            })
            .collect()
    } else {
        stream.iter().map(|_| None).collect()
    };

    // open loop: the whole burst arrives before any completion is
    // observed (pause holds the worker so the arrival process is
    // independent of service even for the first requests)
    server.pause();
    let t0 = Instant::now();
    let rxs: Vec<_> = stream
        .iter()
        .map(|g| match g {
            Gen::Spmm { t, x } => server.submit_spmm(handles[*t], x.clone()),
            Gen::Gcn { t, x } => server.submit_gcn(handles[*t], Arc::clone(&models[*t]), x.clone()),
        })
        .collect::<Result<_>>()?;
    server.resume();
    let mut responses = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        responses.push(
            rxs[i].recv().map_err(|_| anyhow::anyhow!("server dropped request {i}"))??,
        );
    }
    // stop the clock before verification: the sequential exact-executor
    // comparison must not flatten the measured thread-scaling signal
    let elapsed = t0.elapsed().as_secs_f64();
    let mut verified = true;
    for (i, resp) in responses.iter().enumerate() {
        if let Some(want) = &expected[i] {
            if !allclose(resp.y.as_f32()?, want, 1e-3, 1e-3) {
                verified = false;
                eprintln!("VERIFICATION FAILED for request {i}");
            }
        }
    }
    anyhow::ensure!(!cfg.verify || verified, "serve_native responses failed verification");

    let m = Arc::clone(server.metrics());
    // bridge the plan cache's lifetime counters into the global
    // registry, so `--metrics-out` snapshots carry plan-cache events
    // alongside the shard timeline (set, not add: these are totals)
    let reg = crate::obs::Registry::global();
    if reg.enabled() {
        let cache = server.plan_cache();
        reg.gauge("serve.plan_cache.hits").set(cache.hits() as i64);
        reg.gauge("serve.plan_cache.misses").set(cache.misses() as i64);
        reg.gauge("serve.plan_cache.evictions").set(cache.evictions() as i64);
        reg.gauge("serve.plan_cache.invalidations").set(cache.invalidations() as i64);
    }
    let total = m.total.snapshot();
    let point = ServeNativePoint {
        threads: cfg.threads,
        ladder_max: max_w,
        tenants: cfg.tenants,
        requests: cfg.requests,
        batches: m.batches.get(),
        fusion_factor: m.fusion_factor(),
        requests_per_sec: cfg.requests as f64 / elapsed,
        p50_us: total.p50 * 1e6,
        p99_us: total.p99 * 1e6,
        verified: cfg.verify,
    };
    Ok((point, m))
}

/// Sweep thread counts × ladder prefixes (wider ladders admit wider
/// fused batches, so the fusion factor should grow along that axis).
pub fn run_sweep(cfg: &LoadConfig, threads: &[usize]) -> Result<Vec<ServeNativePoint>> {
    let mut points = Vec::new();
    for cut in 1..=cfg.ladder.len() {
        for &t in threads {
            let cell = LoadConfig {
                threads: t,
                ladder: cfg.ladder[..cut].to_vec(),
                ..cfg.clone()
            };
            points.push(run_once(&cell)?);
        }
    }
    Ok(points)
}

/// Paper-style stdout table.
pub fn report(points: &[ServeNativePoint]) -> String {
    let mut table = Table::new(&[
        "threads", "ladder max", "tenants", "requests", "batches", "fusion", "req/s",
        "p50 µs", "p99 µs", "verified",
    ]);
    for p in points {
        table.row(vec![
            p.threads.to_string(),
            p.ladder_max.to_string(),
            p.tenants.to_string(),
            p.requests.to_string(),
            p.batches.to_string(),
            format!("{:.2}", p.fusion_factor),
            format!("{:.1}", p.requests_per_sec),
            format!("{:.0}", p.p50_us),
            format!("{:.0}", p.p99_us),
            p.verified.to_string(),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[ServeNativePoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("threads", p.threads);
            o.set("ladder_max", p.ladder_max);
            o.set("tenants", p.tenants);
            o.set("requests", p.requests);
            o.set("batches", p.batches as usize);
            o.set("fusion_factor", p.fusion_factor);
            o.set("rps", p.requests_per_sec);
            o.set("p50_us", p.p50_us);
            o.set("p99_us", p.p99_us);
            o.set("verified", p.verified);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "serve_native");
    doc.set("executor", "serve/block-level-parallel");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> LoadConfig {
        LoadConfig {
            tenants: 2,
            nodes: 40,
            requests: 16,
            threads: 2,
            ladder: vec![16, 32, 64],
            ..LoadConfig::default()
        }
    }

    #[test]
    fn burst_run_verifies_and_fuses() {
        let p = run_once(&tiny()).unwrap();
        assert!(p.verified);
        assert_eq!(p.requests, 16);
        assert!(p.batches >= 1);
        assert!(
            p.fusion_factor > 1.0,
            "a paused burst against a 64-wide ladder must fuse (factor {:.2})",
            p.fusion_factor
        );
        assert!(p.requests_per_sec > 0.0);
        assert!(p.p50_us >= 0.0 && p.p99_us >= p.p50_us);
    }

    #[test]
    fn sweep_and_json_roundtrip() {
        let cfg = LoadConfig { ladder: vec![16, 64], ..tiny() };
        let pts = run_sweep(&cfg, &[1, 2]).unwrap();
        assert_eq!(pts.len(), 4, "2 ladder prefixes × 2 thread counts");
        assert!(pts.iter().all(|p| p.verified));
        // a burst round can never need more batches than requests
        assert!(pts.iter().all(|p| p.fusion_factor >= 1.0));
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("serve_native"));
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), 4);
        assert!(report(&pts).contains("fusion"));
    }

    #[test]
    fn spmm_only_stream() {
        let p = run_once(&LoadConfig { gcn_every: 0, ..tiny() }).unwrap();
        assert!(p.verified);
    }
}
