//! `serve_native` — open-loop load generation against the native
//! serving subsystem ([`crate::serve`]).
//!
//! The generator registers several synthetic power-law tenants, builds
//! one GCN model per tenant, then fires rounds of mixed-width SpMM and
//! GCN requests **without waiting for completions** (open loop: the
//! arrival process is independent of service). The server drains the
//! backlog in fused rounds; the report captures requests/sec, the
//! batch-fusion factor (requests amortized per sparse traversal), and
//! p50/p99 end-to-end latency — swept across thread counts and ladder
//! widths, written to `BENCH_serve_native.json` so successive PRs can
//! track the serving path.
//!
//! Robustness knobs (DESIGN §11):
//!
//! * **Bounded retry-with-backoff** — submissions go through
//!   [`Server::try_submit`]; a typed
//!   [`SubmitError::Backpressure`] is retried with exponential backoff
//!   up to a small cap, then the request is **shed and counted**
//!   instead of aborting the run. Deadline rejections shed immediately
//!   (retrying doomed work only deepens the overload).
//! * **Update stream** — between compute rounds the generator submits
//!   `UpdateGraph` batches (via [`delta_update::random_batch`]) and
//!   mirrors every *applied* batch into its CPU-side oracle, so later
//!   rounds verify against the evolved adjacency. Shed updates (disk
//!   full under fault injection, overload) are counted, not fatal.
//! * **Durable resume** — with [`LoadConfig::persist`] set and a data
//!   directory that already holds tenant state, the run **recovers**
//!   the tenants (snapshot + WAL replay) instead of registering fresh
//!   ones, and verifies against [`Server::graph_snapshot`] — the
//!   recovered adjacency — rather than a seed-regenerated graph.
//!
//! Every response is (optionally but by default) verified against the
//! exact CPU executor — the bench doubles as the serving path's
//! end-to-end correctness check in CI.

use super::delta_update;
use crate::delta::DeltaGraph;
use crate::graph::generator::{self, DegreeModel};
use crate::graph::Csr;
use crate::model::ModelConfig;
use crate::runtime::HostTensor;
use crate::serve::{
    reference_forward, GcnModel, Payload, PersistConfig, Request, Response, ServeConfig,
    ServeMetrics, Server, SubmitError,
};
use crate::spmm::verify::allclose;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::rng::Pcg;
use anyhow::Result;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Retries granted to one backpressured submission before it is shed.
const MAX_RETRIES: u32 = 8;
/// First backoff step; doubles per retry (≈ 25 ms total at the cap).
const BACKOFF_BASE: Duration = Duration::from_micros(100);

/// One load-generation run's shape.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Resident graphs (tenants); sizes are staggered around `nodes`.
    pub tenants: usize,
    pub nodes: usize,
    pub avg_deg: f64,
    /// Compute requests **per round**.
    pub requests: usize,
    pub threads: usize,
    /// Virtual width ladder for the server's column batcher.
    pub ladder: Vec<usize>,
    /// Every k-th request is a full GCN forward pass (0 = SpMM only).
    pub gcn_every: usize,
    pub seed: u64,
    /// Check every response against the exact CPU executor.
    pub verify: bool,
    /// Forwarded to [`ServeConfig::tune_every`]: run the closed-loop
    /// plan tuner every this many serve rounds (0 = off; effective
    /// only while the global registry is enabled).
    pub tune_every: usize,
    /// Compute rounds; `UpdateGraph` batches interleave between rounds.
    pub rounds: usize,
    /// Update batches submitted after each round (round-robin tenants).
    pub updates_per_round: usize,
    /// Edge updates per batch.
    pub update_size: usize,
    /// Bounded queue capacity (0 = auto: one round + headroom; the
    /// burst then fits, so the open-loop pause is preserved).
    pub queue_capacity: usize,
    /// Per-request deadline budget in ms (0 = none).
    pub deadline_ms: u64,
    /// Durability config; `Some` = snapshot + WAL under `data_dir`,
    /// resuming (recovering) when the directory already holds tenants.
    pub persist: Option<PersistConfig>,
}

impl Default for LoadConfig {
    fn default() -> LoadConfig {
        LoadConfig {
            tenants: 2,
            nodes: 300,
            avg_deg: 6.0,
            requests: 64,
            threads: 4,
            ladder: vec![32, 64, 128],
            gcn_every: 3,
            seed: 42,
            verify: true,
            tune_every: 0,
            rounds: 1,
            updates_per_round: 0,
            update_size: 8,
            queue_capacity: 0,
            deadline_ms: 0,
            persist: None,
        }
    }
}

/// One measured (threads, ladder) cell.
#[derive(Clone, Debug)]
pub struct ServeNativePoint {
    pub threads: usize,
    pub ladder_max: usize,
    pub tenants: usize,
    /// Compute requests **served** (submitted minus shed).
    pub requests: usize,
    pub rounds: usize,
    pub batches: u64,
    /// Mean requests fused per executed batch (> 1 ⇒ traversals amortized).
    pub fusion_factor: f64,
    pub requests_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub verified: bool,
    /// Compute requests dropped after exhausting retries (or expired
    /// under their deadline) — shed, not fatal.
    pub shed_requests: u64,
    /// Backpressure retries across all submissions.
    pub retries: u64,
    /// `UpdateGraph` batches applied / shed.
    pub updates_applied: u64,
    pub updates_shed: u64,
    /// Tenants restored from snapshot + WAL instead of registered
    /// fresh (0 on a cold start).
    pub recovered_tenants: usize,
    /// WAL batches replayed across all recovered tenants.
    pub replayed_batches: u64,
    /// Mean achieved SpMM bandwidth across served requests, GB/s —
    /// the plan's analytic traffic-model bytes over batch wall time,
    /// as recorded by [`ServeMetrics::spmm_gbps`].
    pub achieved_gbps: f64,
    /// `achieved_gbps` as % of the calibrated peak (0 when no
    /// calibration has been published this process).
    pub pct_peak: f64,
}

/// Synthetic power-law tenant graphs, sizes staggered so the tenants
/// are genuinely distinct.
fn tenant_graphs(cfg: &LoadConfig) -> Vec<Csr> {
    (0..cfg.tenants)
        .map(|t| {
            let n = cfg.nodes + t * cfg.nodes / 4;
            let mut rng = Pcg::seed_from(cfg.seed.wrapping_add(t as u64 * 7919));
            let degs = generator::degree_sequence(
                DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.1 },
                n,
                (n as f64 * cfg.avg_deg) as usize,
                &mut rng,
            );
            generator::from_degree_sequence(n, &degs, &mut rng)
        })
        .collect()
}

/// Submit with bounded retry-with-backoff. `Ok(Some(rx))` = accepted,
/// `Ok(None)` = shed (backpressure retries exhausted, or rejected by
/// deadline admission), `Err` = a non-transient refusal (bad request,
/// dead worker) the run cannot absorb.
fn submit_with_retry(
    server: &Server,
    req: &Request,
    retries: &mut u64,
    shed: &mut u64,
) -> Result<Option<Receiver<Result<Response>>>> {
    let mut backoff = BACKOFF_BASE;
    for _attempt in 0..=MAX_RETRIES {
        match server.try_submit(req.clone()) {
            Ok(rx) => return Ok(Some(rx)),
            Err(e) if e.is_retryable() => {
                *retries += 1;
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
            }
            Err(SubmitError::Deadline { .. }) => {
                *shed += 1;
                return Ok(None);
            }
            Err(e) => return Err(anyhow::Error::new(e)),
        }
    }
    *shed += 1;
    Ok(None)
}

/// Run one open-loop burst and measure it.
pub fn run_once(cfg: &LoadConfig) -> Result<ServeNativePoint> {
    run_once_with_metrics(cfg).map(|(p, _)| p)
}

/// [`run_once`], additionally handing back the server's metrics (the
/// `serve-native` subcommand prints the per-stage breakdown from them).
pub fn run_once_with_metrics(cfg: &LoadConfig) -> Result<(ServeNativePoint, Arc<ServeMetrics>)> {
    anyhow::ensure!(cfg.tenants >= 1, "need at least one tenant");
    anyhow::ensure!(cfg.requests >= 1, "need at least one request");
    anyhow::ensure!(cfg.rounds >= 1, "need at least one round");
    let queue_capacity =
        if cfg.queue_capacity == 0 { cfg.requests + 8 } else { cfg.queue_capacity };
    let server = Server::start(ServeConfig {
        threads: cfg.threads,
        queue_capacity,
        ladder: cfg.ladder.clone(),
        tune_every: cfg.tune_every,
        deadline: (cfg.deadline_ms > 0).then(|| Duration::from_millis(cfg.deadline_ms)),
        persist: cfg.persist.clone(),
        ..ServeConfig::default()
    })?;

    // tenants: recover from the data directory when it already holds
    // state (the oracle is then the *recovered* adjacency), otherwise
    // generate + register fresh
    let mut recovered_tenants = 0usize;
    let mut replayed_batches = 0u64;
    let (mut graphs, handles): (Vec<Csr>, Vec<_>) = {
        let resumable = match server.persist() {
            Some(p) => p.has_tenants()?,
            None => false,
        };
        if resumable {
            let mut sums = server.recover_tenants()?;
            sums.sort_by(|a, b| a.name.cmp(&b.name));
            recovered_tenants = sums.len();
            replayed_batches = sums.iter().map(|s| s.replayed_batches as u64).sum();
            for s in &sums {
                eprintln!(
                    "[store] recovered '{}' at epoch {} (snapshot gen {} @ epoch {}, \
                     {} batch(es) replayed{}{}{})",
                    s.name,
                    s.epoch,
                    s.snapshot_gen,
                    s.snapshot_epoch,
                    s.replayed_batches,
                    if s.snapshot_fell_back { ", fell back a generation" } else { "" },
                    if s.torn_tail_dropped { ", torn tail dropped" } else { "" },
                    if s.fingerprint_verified { "" } else { ", final epoch unsealed" },
                );
            }
            let graphs = sums
                .iter()
                .map(|s| server.graph_snapshot(s.handle))
                .collect::<Result<Vec<_>>>()?;
            (graphs, sums.into_iter().map(|s| s.handle).collect())
        } else {
            let graphs = tenant_graphs(cfg);
            let handles = graphs
                .iter()
                .enumerate()
                .map(|(t, g)| server.register_graph(&format!("tenant-{t}"), g))
                .collect::<Result<_>>()?;
            (graphs, handles)
        }
    };
    let tenants = graphs.len();
    let max_w = server.max_width();
    let narrowest = *cfg.ladder.iter().min().expect("ladder validated non-empty");
    let in_dim = narrowest.min(32);
    let models: Vec<Arc<GcnModel>> = (0..tenants)
        .map(|t| {
            Arc::new(GcnModel::random(
                ModelConfig::gcn(in_dim, in_dim, 8, 2),
                cfg.seed.wrapping_add(t as u64),
            ))
        })
        .collect();

    let mut rng = Pcg::seed_from(cfg.seed ^ 0x0bea_7e55);
    let mut served = 0usize;
    let mut shed_requests = 0u64;
    let mut retries = 0u64;
    let mut updates_applied = 0u64;
    let mut updates_shed = 0u64;
    let mut compute_secs = 0.0f64;
    let mut all_verified = true;
    // the open-loop pause (whole burst arrives before any completion)
    // only composes with a queue that can hold the burst; a smaller
    // explicit capacity means closed-loop backpressure is the point —
    // pausing there would deadlock the retry loop against a worker
    // that can never drain
    let open_loop = queue_capacity >= cfg.requests;

    for _round in 0..cfg.rounds {
        // generate the round's request stream up front (not timed),
        // with expectations taken against the *current* oracle graphs
        enum Gen {
            Spmm { t: usize, x: HostTensor },
            Gcn { t: usize, x: HostTensor },
        }
        let stream: Vec<Gen> = (0..cfg.requests)
            .map(|i| {
                let t = rng.range(0, tenants);
                let n = graphs[t].n_rows;
                if cfg.gcn_every > 0 && i % cfg.gcn_every == 0 {
                    let x = HostTensor::f32(
                        &[n, in_dim],
                        (0..n * in_dim).map(|_| rng.f32() - 0.5).collect(),
                    );
                    Gen::Gcn { t, x }
                } else {
                    let lo = (max_w / 8).max(1);
                    let hi = (max_w / 2 + 1).max(lo + 1);
                    let w = rng.range(lo, hi);
                    let x =
                        HostTensor::f32(&[n, w], (0..n * w).map(|_| rng.f32() - 0.5).collect());
                    Gen::Spmm { t, x }
                }
            })
            .collect();
        let expected: Vec<Option<Vec<f32>>> = if cfg.verify {
            stream
                .iter()
                .map(|g| match g {
                    Gen::Spmm { t, x } => Some(
                        graphs[*t].spmm_dense(x.as_f32().expect("f32 stream"), x.shape()[1]),
                    ),
                    Gen::Gcn { t, x } => Some(reference_forward(
                        &graphs[*t],
                        &models[*t],
                        x.as_f32().expect("f32 stream"),
                    )),
                })
                .collect()
        } else {
            stream.iter().map(|_| None).collect()
        };

        if open_loop {
            server.pause();
        }
        let t0 = Instant::now();
        let mut rxs: Vec<Option<Receiver<Result<Response>>>> = Vec::with_capacity(cfg.requests);
        for g in &stream {
            let req = match g {
                Gen::Spmm { t, x } => {
                    Request { graph: handles[*t], payload: Payload::Spmm { x: x.clone() } }
                }
                Gen::Gcn { t, x } => Request {
                    graph: handles[*t],
                    payload: Payload::Gcn { model: Arc::clone(&models[*t]), x: x.clone() },
                },
            };
            rxs.push(submit_with_retry(&server, &req, &mut retries, &mut shed_requests)?);
        }
        if open_loop {
            server.resume();
        }
        let mut responses: Vec<Option<Response>> = Vec::with_capacity(cfg.requests);
        for (i, rx) in rxs.iter().enumerate() {
            match rx {
                None => responses.push(None),
                Some(rx) => {
                    let reply =
                        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request {i}"))?;
                    match reply {
                        Ok(resp) => {
                            served += 1;
                            responses.push(Some(resp));
                        }
                        // an admitted request can still expire at
                        // pickup under a deadline — a shed, not a bug
                        Err(e) if e.downcast_ref::<SubmitError>().is_some() => {
                            shed_requests += 1;
                            responses.push(None);
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
        // stop the clock before verification: the sequential
        // exact-executor comparison must not flatten the measured
        // thread-scaling signal
        compute_secs += t0.elapsed().as_secs_f64();
        for (i, resp) in responses.iter().enumerate() {
            if let (Some(resp), Some(want)) = (resp, &expected[i]) {
                if !allclose(resp.y.as_f32()?, want, 1e-3, 1e-3) {
                    all_verified = false;
                    eprintln!("VERIFICATION FAILED for request {i}");
                }
            }
        }

        // inter-round update stream: WAL-logged (under persistence),
        // applied server-side, then mirrored into the oracle so the
        // next round verifies against the evolved adjacency
        for u in 0..cfg.updates_per_round {
            let t = u % tenants;
            let batch = delta_update::random_batch(&graphs[t], cfg.update_size, &mut rng);
            if batch.is_empty() {
                continue;
            }
            match server.update_graph(handles[t], batch.clone()) {
                Ok(_) => {
                    updates_applied += 1;
                    let mut dg = DeltaGraph::new(graphs[t].clone());
                    dg.apply(&batch)?;
                    graphs[t] = dg.snapshot();
                }
                Err(e) => {
                    updates_shed += 1;
                    eprintln!("[bench] update shed: {e:#}");
                }
            }
        }
    }
    anyhow::ensure!(!cfg.verify || all_verified, "serve_native responses failed verification");

    let m = Arc::clone(server.metrics());
    // bridge the plan cache's lifetime counters into the global
    // registry, so `--metrics-out` snapshots carry plan-cache events
    // alongside the shard timeline (set, not add: these are totals)
    let reg = crate::obs::Registry::global();
    if reg.enabled() {
        let cache = server.plan_cache();
        reg.gauge("serve.plan_cache.hits").set(cache.hits() as i64);
        reg.gauge("serve.plan_cache.misses").set(cache.misses() as i64);
        reg.gauge("serve.plan_cache.evictions").set(cache.evictions() as i64);
        reg.gauge("serve.plan_cache.invalidations").set(cache.invalidations() as i64);
    }
    let total = m.total.snapshot();
    let achieved_gbps = m.spmm_gbps.snapshot().mean;
    let point = ServeNativePoint {
        threads: cfg.threads,
        ladder_max: max_w,
        tenants,
        requests: served,
        rounds: cfg.rounds,
        batches: m.batches.get(),
        fusion_factor: m.fusion_factor(),
        requests_per_sec: if compute_secs > 0.0 { served as f64 / compute_secs } else { 0.0 },
        p50_us: total.p50 * 1e6,
        p99_us: total.p99 * 1e6,
        verified: cfg.verify,
        shed_requests,
        retries,
        updates_applied,
        updates_shed,
        recovered_tenants,
        replayed_batches,
        achieved_gbps,
        pct_peak: crate::obs::calibrate::global()
            .map_or(0.0, |c| c.pct_of_peak(achieved_gbps)),
    };
    Ok((point, m))
}

/// Sweep thread counts × ladder prefixes (wider ladders admit wider
/// fused batches, so the fusion factor should grow along that axis).
pub fn run_sweep(cfg: &LoadConfig, threads: &[usize]) -> Result<Vec<ServeNativePoint>> {
    let mut points = Vec::new();
    for cut in 1..=cfg.ladder.len() {
        for &t in threads {
            let cell = LoadConfig {
                threads: t,
                ladder: cfg.ladder[..cut].to_vec(),
                ..cfg.clone()
            };
            points.push(run_once(&cell)?);
        }
    }
    Ok(points)
}

/// Paper-style stdout table.
pub fn report(points: &[ServeNativePoint]) -> String {
    let mut table = Table::new(&[
        "threads", "ladder max", "tenants", "served", "shed", "retried", "updates", "batches",
        "fusion", "req/s", "GB/s", "p50 µs", "p99 µs", "verified",
    ]);
    for p in points {
        table.row(vec![
            p.threads.to_string(),
            p.ladder_max.to_string(),
            p.tenants.to_string(),
            p.requests.to_string(),
            p.shed_requests.to_string(),
            p.retries.to_string(),
            format!("{}/{}", p.updates_applied, p.updates_applied + p.updates_shed),
            p.batches.to_string(),
            format!("{:.2}", p.fusion_factor),
            format!("{:.1}", p.requests_per_sec),
            format!("{:.2}", p.achieved_gbps),
            format!("{:.0}", p.p50_us),
            format!("{:.0}", p.p99_us),
            p.verified.to_string(),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[ServeNativePoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("threads", p.threads);
            o.set("ladder_max", p.ladder_max);
            o.set("tenants", p.tenants);
            o.set("requests", p.requests);
            o.set("rounds", p.rounds);
            o.set("batches", p.batches as usize);
            o.set("fusion_factor", p.fusion_factor);
            o.set("rps", p.requests_per_sec);
            o.set("p50_us", p.p50_us);
            o.set("p99_us", p.p99_us);
            o.set("verified", p.verified);
            o.set("shed_requests", p.shed_requests as usize);
            o.set("retries", p.retries as usize);
            o.set("updates_applied", p.updates_applied as usize);
            o.set("updates_shed", p.updates_shed as usize);
            o.set("recovered_tenants", p.recovered_tenants);
            o.set("replayed_batches", p.replayed_batches as usize);
            o.set("achieved_gbps", p.achieved_gbps);
            o.set("pct_peak", p.pct_peak);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "serve_native");
    doc.set("executor", "serve/block-level-parallel");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> LoadConfig {
        LoadConfig {
            tenants: 2,
            nodes: 40,
            requests: 16,
            threads: 2,
            ladder: vec![16, 32, 64],
            ..LoadConfig::default()
        }
    }

    #[test]
    fn burst_run_verifies_and_fuses() {
        let p = run_once(&tiny()).unwrap();
        assert!(p.verified);
        assert_eq!(p.requests, 16);
        assert_eq!(p.shed_requests, 0);
        assert!(p.batches >= 1);
        assert!(
            p.fusion_factor > 1.0,
            "a paused burst against a 64-wide ladder must fuse (factor {:.2})",
            p.fusion_factor
        );
        assert!(p.requests_per_sec > 0.0);
        assert!(p.p50_us >= 0.0 && p.p99_us >= p.p50_us);
        assert!(
            p.achieved_gbps > 0.0 && p.achieved_gbps.is_finite(),
            "served batches must record traffic-model bandwidth ({})",
            p.achieved_gbps
        );
        assert!((0.0..=100.0).contains(&p.pct_peak));
    }

    #[test]
    fn sweep_and_json_roundtrip() {
        let cfg = LoadConfig { ladder: vec![16, 64], ..tiny() };
        let pts = run_sweep(&cfg, &[1, 2]).unwrap();
        assert_eq!(pts.len(), 4, "2 ladder prefixes × 2 thread counts");
        assert!(pts.iter().all(|p| p.verified));
        // a burst round can never need more batches than requests
        assert!(pts.iter().all(|p| p.fusion_factor >= 1.0));
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("serve_native"));
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), 4);
        assert!(report(&pts).contains("fusion"));
    }

    #[test]
    fn spmm_only_stream() {
        let p = run_once(&LoadConfig { gcn_every: 0, ..tiny() }).unwrap();
        assert!(p.verified);
    }

    #[test]
    fn rounds_with_updates_keep_verifying() {
        // three rounds with update batches between them: each round's
        // responses must verify against the *evolved* oracle
        let p = run_once(&LoadConfig {
            rounds: 3,
            updates_per_round: 2,
            update_size: 4,
            ..tiny()
        })
        .unwrap();
        assert!(p.verified);
        assert_eq!(p.requests, 48, "3 rounds × 16 requests, none shed");
        assert!(p.updates_applied >= 4, "applied {} update batches", p.updates_applied);
        assert_eq!(p.updates_shed, 0);
    }

    #[test]
    fn tiny_queue_sheds_or_retries_without_aborting() {
        // capacity 2 with a live worker: submissions hit backpressure,
        // retry with backoff, and in the worst case shed — the run
        // completes either way and served + shed == submitted
        let p = run_once(&LoadConfig { queue_capacity: 2, requests: 24, ..tiny() }).unwrap();
        assert_eq!(p.requests as u64 + p.shed_requests, 24);
        assert!(p.verified, "served responses must still verify");
    }

    #[test]
    fn persisted_run_resumes_from_data_dir() {
        let dir = crate::store::test_dir("bench-resume");
        let persisted = LoadConfig {
            rounds: 2,
            updates_per_round: 2,
            update_size: 4,
            persist: Some(PersistConfig {
                fsync: crate::store::FsyncPolicy::Never,
                ..PersistConfig::new(&dir)
            }),
            ..tiny()
        };
        let p1 = run_once(&persisted).unwrap();
        assert_eq!(p1.recovered_tenants, 0, "cold start registers fresh tenants");
        assert!(p1.updates_applied >= 1);
        // second run over the same directory: tenants recover (snapshot
        // + WAL replay) and the verification oracle is the recovered
        // adjacency — every response must still match it
        let p2 = run_once(&persisted).unwrap();
        assert_eq!(p2.recovered_tenants, 2);
        assert!(p2.verified);
        assert_eq!(p2.requests, 32);
        std::fs::remove_dir_all(&dir).ok();
    }
}
