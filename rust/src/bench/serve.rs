//! Serving driver: synthetic inference load through the coordinator —
//! column-batched SpMM requests against the compiled artifact ladder,
//! with end-to-end latency/throughput reporting and response
//! verification against the exact CPU executor.

use crate::coordinator::{ColumnBatcher, Engine};
use crate::partition::bucket::BellLayout;
use crate::runtime::HostTensor;
use crate::spmm::verify::allclose;
use crate::util::rng::Pcg;
use anyhow::{Context, Result};
use std::time::Instant;

/// Serving run statistics.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub requests_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub verified: bool,
}

/// Serve `n_requests` random-width SpMM requests against an artifact dir.
pub fn run_serving(dir: &str, n_requests: usize, coldims: &[usize], seed: u64) -> Result<ServeReport> {
    let engine = Engine::start(dir)?;
    let ladder = engine.manifest().spmm_coldims();
    anyhow::ensure!(!ladder.is_empty(), "no spmm_f* artifacts in {dir}");
    let n_cols = engine.manifest().n_cols;
    println!(
        "serving over artifact ladder {:?} (graph: {} nodes)",
        ladder.iter().map(|(d, _)| *d).collect::<Vec<_>>(),
        n_cols
    );
    for (_, name) in &ladder {
        engine.load_artifact(name)?;
        engine.bind_bell(name)?;
    }
    // reference layout for verification
    let layout = BellLayout::load(dir).context("load BELL layout for verification")?;

    let batcher = ColumnBatcher::new(ladder)?;
    let mut rng = Pcg::seed_from(seed);
    // generate the request stream
    let widths: Vec<usize> = (0..n_requests).map(|_| *rng.choose(coldims)).collect();
    let xs: Vec<HostTensor> = widths
        .iter()
        .map(|&w| {
            HostTensor::f32(&[n_cols, w], (0..n_cols * w).map(|_| rng.f32() - 0.5).collect())
        })
        .collect();

    let plans = batcher.plan(&widths)?;
    println!("{} requests → {} fused batches", n_requests, plans.len());

    let mut latencies: Vec<f64> = Vec::with_capacity(plans.len());
    let mut responses: Vec<Option<HostTensor>> = vec![None; n_requests];
    let t0 = Instant::now();
    for plan in &plans {
        let member_xs: Vec<&HostTensor> = plan.members.iter().map(|&m| &xs[m]).collect();
        let fused = ColumnBatcher::fuse(plan, &member_xs)?;
        let tb = Instant::now();
        let y = engine
            .exec_sync(&plan.artifact, vec![fused])?
            .pop()
            .context("spmm returned nothing")?;
        latencies.push(tb.elapsed().as_secs_f64());
        for (i, out) in ColumnBatcher::split(plan, &widths, &y)?.into_iter().enumerate() {
            responses[plan.members[i]] = Some(out);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // verify a sample of responses against the exact executor
    let mut verified = true;
    for &i in &[0usize, n_requests / 2, n_requests - 1] {
        let x = xs[i].as_f32()?;
        let want = layout.execute(x, widths[i]);
        let got = responses[i].as_ref().context("missing response")?.as_f32()?;
        if !allclose(got, &want, 1e-3, 1e-3) {
            verified = false;
            eprintln!("VERIFICATION FAILED for request {i}");
        }
    }

    let report = ServeReport {
        requests: n_requests,
        batches: plans.len(),
        requests_per_sec: n_requests as f64 / elapsed,
        p50_us: crate::util::stats::percentile(&latencies, 50.0) * 1e6,
        p99_us: crate::util::stats::percentile(&latencies, 99.0) * 1e6,
        verified,
    };
    println!(
        "served {} requests in {:.2}s: {:.1} req/s, batch p50 {:.0} µs, p99 {:.0} µs, verified={}",
        report.requests, elapsed, report.requests_per_sec, report.p50_us, report.p99_us, report.verified
    );
    println!("{}", engine.metrics.exec_latency.snapshot().render("device exec"));
    println!("{}", engine.metrics.total_latency.snapshot().render("queue+exec"));
    anyhow::ensure!(report.verified, "served responses failed verification");
    Ok(report)
}
