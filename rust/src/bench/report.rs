//! One writer for every perf-trajectory `BENCH_*.json` file.
//!
//! Each experiment builds its own `Json` document (`to_json`) and hands
//! it here; this module owns the on-disk conventions that used to be
//! copy-pasted across five experiments: parent directories are created,
//! output is pretty-printed, and — when the process runs from the repo
//! root (the usual `cargo run` case) — a duplicate lands next to
//! `ROADMAP.md` so successive PRs can diff trajectories without digging
//! through results dirs. Nothing is written outside `out_dir` when the
//! working directory is not the checkout, and the duplicate is skipped
//! when `out_dir` *is* the working directory.
//!
//! Every report is stamped with a `meta` object
//! ([`crate::obs::run_metadata`]): git commit (when in a checkout),
//! ISO-8601 UTC timestamp, host thread count, detected SIMD level, and
//! the metrics schema version — so a trajectory row is attributable to
//! the exact commit and host conditions that produced it.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Write `doc` as `out_dir/filename` (+ the repo-root duplicate when
/// applicable), with run metadata injected under `meta`. `filename`
/// should be a bare `BENCH_<experiment>.json` name.
pub fn write_report(out_dir: &Path, filename: &str, doc: &Json) -> Result<()> {
    let mut doc = doc.clone();
    let mut meta = crate::obs::run_metadata();
    // when a bandwidth calibration has been published this process,
    // stamp it too: a trajectory row quoting achieved GB/s is only
    // comparable against the peak it was measured under
    if let Some(cal) = crate::obs::calibrate::global() {
        meta.set("peak_gbps", cal.peak_gbps);
        meta.set("calibration_threads", cal.best_threads);
        meta.set("calibration_simd", cal.simd.as_str());
    }
    doc.set("meta", meta);
    let doc = &doc;
    write_one(&out_dir.join(filename), doc)?;
    let cwd_is_repo_root = Path::new("ROADMAP.md").exists() || Path::new(".git").exists();
    let same_dir = std::fs::canonicalize(out_dir)
        .and_then(|o| std::fs::canonicalize(".").map(|c| o == c))
        .unwrap_or(false);
    if cwd_is_repo_root && !same_dir {
        write_one(Path::new(filename), doc)?;
    }
    Ok(())
}

fn write_one(path: &Path, doc: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.to_pretty()).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_into_fresh_nested_dir() {
        let dir = std::env::temp_dir().join(format!("accel-report-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut doc = Json::obj();
        doc.set("experiment", "unit-test").set("points", Vec::<Json>::new());
        // temp dir has no ROADMAP.md/.git relative to cwd semantics —
        // only the out_dir copy must appear under `dir`
        write_report(&dir.join("deep"), "BENCH_unit.json", &doc).unwrap();
        let text = std::fs::read_to_string(dir.join("deep/BENCH_unit.json")).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req_str("experiment").unwrap(), "unit-test");
        // run metadata is stamped on the way out
        let meta = back.get("meta").expect("meta injected");
        assert_eq!(meta.req_str("schema").unwrap(), crate::obs::SCHEMA_VERSION);
        assert!(meta.req_usize("threads").unwrap() >= 1);
        assert!(!meta.req_str("simd").unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
        // if the test ever runs from a repo root, clean the duplicate
        let _ = std::fs::remove_file("BENCH_unit.json");
    }

    #[test]
    fn calibration_meta_is_stamped_when_published() {
        // publish *a* calibration (first-write-wins; any valid one has
        // peak > 0) and check the stamp rides the meta block
        let cal = crate::obs::calibrate::calibrate_with(&[1], &[64], 1, 1, true);
        crate::obs::calibrate::set_global(&cal);
        let dir = std::env::temp_dir().join(format!("accel-report-cal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut doc = Json::obj();
        doc.set("experiment", "unit-test-cal").set("points", Vec::<Json>::new());
        write_report(&dir, "BENCH_unit_cal.json", &doc).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_unit_cal.json")).unwrap();
        let meta = Json::parse(&text).unwrap().get("meta").cloned().expect("meta");
        assert!(meta.req_f64("peak_gbps").unwrap() > 0.0);
        assert!(meta.req_usize("calibration_threads").unwrap() >= 1);
        assert!(!meta.req_str("calibration_simd").unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_file("BENCH_unit_cal.json");
    }
}
