//! Paper-evaluation harness: one regenerator per table/figure.
//!
//! Experiment index (DESIGN.md §1):
//! * `table1` — dataset specs + measured properties of the synthesized
//!   stand-ins (scale factors reported).
//! * `fig2`   — Collab row-degree histogram.
//! * `fig3`   — metadata storage: block-level vs warp-level (Eq. 1).
//! * `fig5`   — overall speedup vs cuSPARSE (geomean over column dims).
//! * `fig6`   — raw kernel time vs column dimension, per graph.
//! * `fig7`   — block-level vs warp-level partition (both + combined warp).
//! * `fig8`   — combined warp vs plain inner loop (both block-level).
//! * `table2` — Fig. 7/8 ratios aggregated over column-dim ranges.

use crate::graph::datasets::{self, ScalePolicy};
use crate::graph::stats;
use crate::partition::patterns::PartitionParams;
use crate::pipeline::SpmmPlan;
use crate::sim::kernels::{CostModel, KernelKind, KernelOptions};
use crate::sim::{simulate_kernel, GpuConfig};
use crate::util::bench::{Csv, Table};
use crate::util::cli::Args;
use crate::util::stats::geomean;
use crate::util::threadpool::{default_parallelism, ThreadPool};
use anyhow::Result;
use std::path::Path;

/// The paper's column-dimension sweep (§IV-A: 16 to 128).
pub const PAPER_COLDIMS: [usize; 8] = [16, 32, 48, 64, 80, 96, 112, 128];

/// One (graph, coldim) measurement across all kernels and ablations.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub graph: String,
    pub coldim: usize,
    /// µs per kernel
    pub accel: f64,
    pub cusparse: f64,
    pub gnnadvisor: f64,
    pub graphblast: f64,
    /// ablations
    pub accel_no_cw: f64,
    /// warp-level partition *with* combined warp (Fig. 7's (ii))
    pub warp_cw: f64,
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub graphs: Vec<String>,
    pub coldims: Vec<usize>,
    pub policy: ScalePolicy,
    pub seed: u64,
}

impl SweepConfig {
    pub fn paper(policy: ScalePolicy, seed: u64) -> SweepConfig {
        SweepConfig {
            graphs: datasets::all_names().iter().map(|s| s.to_string()).collect(),
            coldims: PAPER_COLDIMS.to_vec(),
            policy,
            seed,
        }
    }

    /// Reduced sweep for unit tests / --quick.
    pub fn quick(seed: u64) -> SweepConfig {
        SweepConfig {
            graphs: vec!["pubmed".into(), "collab".into(), "yeast".into()],
            coldims: vec![16, 64, 128],
            policy: ScalePolicy::tiny(),
            seed,
        }
    }
}

/// Run the full sweep, parallel across graphs.
pub fn full_sweep(cfg: &SweepConfig) -> Vec<SweepPoint> {
    let pool = ThreadPool::new(default_parallelism().min(cfg.graphs.len().max(1)));
    let gpu = GpuConfig::rtx3090();
    let cost = CostModel::default();
    let jobs: Vec<_> = cfg
        .graphs
        .iter()
        .map(|name| {
            let name = name.clone();
            let policy = cfg.policy;
            let seed = cfg.seed;
            let coldims = cfg.coldims.clone();
            move || -> Vec<SweepPoint> {
                let spec = datasets::by_name(&name).expect("dataset name validated");
                let csr = datasets::materialize(spec, policy, seed);
                let g = SpmmPlan::build(csr, PartitionParams::default());
                coldims
                    .iter()
                    .map(|&coldim| sweep_point(&gpu, &cost, &g, &name, coldim))
                    .collect()
            }
        })
        .collect();
    pool.run_all(jobs).into_iter().flatten().collect()
}

fn sweep_point(
    gpu: &GpuConfig,
    cost: &CostModel,
    g: &SpmmPlan,
    name: &str,
    coldim: usize,
) -> SweepPoint {
    let with_cw = KernelOptions { combined_warp: true };
    let no_cw = KernelOptions { combined_warp: false };
    SweepPoint {
        graph: name.to_string(),
        coldim,
        accel: simulate_kernel(gpu, cost, KernelKind::AccelGcn, with_cw, g, coldim).micros,
        cusparse: simulate_kernel(gpu, cost, KernelKind::CuSparse, with_cw, g, coldim).micros,
        gnnadvisor: simulate_kernel(gpu, cost, KernelKind::GnnAdvisor, no_cw, g, coldim).micros,
        graphblast: simulate_kernel(gpu, cost, KernelKind::GraphBlast, with_cw, g, coldim).micros,
        accel_no_cw: simulate_kernel(gpu, cost, KernelKind::AccelGcn, no_cw, g, coldim).micros,
        warp_cw: simulate_kernel(gpu, cost, KernelKind::GnnAdvisor, with_cw, g, coldim).micros,
    }
}

/// Fig. 5 — overall speedup normalized to cuSPARSE (plus the paper's
/// headline averages vs all three baselines).
pub fn fig5(points: &[SweepPoint], out: Option<&Path>) -> Result<String> {
    let mut csv = Csv::new(&["graph", "speedup_vs_cusparse", "speedup_vs_gnnadvisor", "speedup_vs_graphblast"]);
    let mut table = Table::new(&["graph", "vs cuSPARSE", "vs GNNAdvisor", "vs GraphBLAST"]);
    let graphs = unique_graphs(points);
    let (mut all_cu, mut all_gnn, mut all_gb) = (Vec::new(), Vec::new(), Vec::new());
    for g in &graphs {
        let pts: Vec<&SweepPoint> = points.iter().filter(|p| &p.graph == g).collect();
        let cu = geomean(&pts.iter().map(|p| p.cusparse / p.accel).collect::<Vec<_>>());
        let gnn = geomean(&pts.iter().map(|p| p.gnnadvisor / p.accel).collect::<Vec<_>>());
        let gb = geomean(&pts.iter().map(|p| p.graphblast / p.accel).collect::<Vec<_>>());
        all_cu.push(cu);
        all_gnn.push(gnn);
        all_gb.push(gb);
        csv.row(&[g.clone(), format!("{cu:.3}"), format!("{gnn:.3}"), format!("{gb:.3}")]);
        table.row(vec![g.clone(), format!("{cu:.2}x"), format!("{gnn:.2}x"), format!("{gb:.2}x")]);
    }
    let summary = format!(
        "fig5 averages (paper: 1.17x / 1.86x / 2.94x): vs cuSPARSE {:.2}x, vs GNNAdvisor {:.2}x, vs GraphBLAST {:.2}x\n\
         fig5 maxima   (paper: 1.45x / 3.41x / 5.02x): {:.2}x / {:.2}x / {:.2}x",
        geomean(&all_cu),
        geomean(&all_gnn),
        geomean(&all_gb),
        all_cu.iter().cloned().fold(0.0, f64::max),
        all_gnn.iter().cloned().fold(0.0, f64::max),
        all_gb.iter().cloned().fold(0.0, f64::max),
    );
    if let Some(dir) = out {
        csv.save(dir.join("fig5.csv"))?;
    }
    Ok(format!("{}{}\n", table.render(), summary))
}

/// Fig. 6 — raw kernel µs per (graph, coldim, kernel).
pub fn fig6(points: &[SweepPoint], out: Option<&Path>) -> Result<String> {
    let mut csv = Csv::new(&["graph", "coldim", "accel_us", "cusparse_us", "gnnadvisor_us", "graphblast_us"]);
    for p in points {
        csv.row(&[
            p.graph.clone(),
            p.coldim.to_string(),
            format!("{:.2}", p.accel),
            format!("{:.2}", p.cusparse),
            format!("{:.2}", p.gnnadvisor),
            format!("{:.2}", p.graphblast),
        ]);
    }
    if let Some(dir) = out {
        csv.save(dir.join("fig6.csv"))?;
    }
    // compact per-graph view: time ratio t(128)/t(16) for the paper's
    // "gradual increase" claim
    let mut table = Table::new(&["graph", "accel t(min) µs", "accel t(max) µs", "growth"]);
    for g in unique_graphs(points) {
        let pts: Vec<&SweepPoint> = points.iter().filter(|p| p.graph == g).collect();
        let lo = pts.iter().map(|p| p.coldim).min().unwrap();
        let hi = pts.iter().map(|p| p.coldim).max().unwrap();
        let t_lo = pts.iter().find(|p| p.coldim == lo).unwrap().accel;
        let t_hi = pts.iter().find(|p| p.coldim == hi).unwrap().accel;
        table.row(vec![g, format!("{t_lo:.1}"), format!("{t_hi:.1}"), format!("{:.2}x", t_hi / t_lo)]);
    }
    Ok(table.render())
}

/// Fig. 7 — degree sorting & block-level partition vs warp-level
/// partition (both with combined warp). Values are speedups (i)/(ii).
pub fn fig7(points: &[SweepPoint], out: Option<&Path>) -> Result<String> {
    let mut csv = Csv::new(&["graph", "coldim", "speedup_block_over_warp"]);
    for p in points {
        csv.row(&[p.graph.clone(), p.coldim.to_string(), format!("{:.4}", p.warp_cw / p.accel)]);
    }
    if let Some(dir) = out {
        csv.save(dir.join("fig7.csv"))?;
    }
    let mut table = Table::new(&["graph", "block-level speedup (geomean over coldims)"]);
    for g in unique_graphs(points) {
        let r: Vec<f64> = points
            .iter()
            .filter(|p| p.graph == g)
            .map(|p| p.warp_cw / p.accel)
            .collect();
        table.row(vec![g, format!("{:.3}x", geomean(&r))]);
    }
    Ok(table.render())
}

/// Fig. 8 — block-level partition with vs without combined warp.
pub fn fig8(points: &[SweepPoint], out: Option<&Path>) -> Result<String> {
    let mut csv = Csv::new(&["graph", "coldim", "speedup_combined_warp"]);
    for p in points {
        csv.row(&[p.graph.clone(), p.coldim.to_string(), format!("{:.4}", p.accel_no_cw / p.accel)]);
    }
    if let Some(dir) = out {
        csv.save(dir.join("fig8.csv"))?;
    }
    let mut table = Table::new(&["graph", "combined-warp speedup (geomean over coldims)"]);
    for g in unique_graphs(points) {
        let r: Vec<f64> = points
            .iter()
            .filter(|p| p.graph == g)
            .map(|p| p.accel_no_cw / p.accel)
            .collect();
        table.row(vec![g, format!("{:.3}x", geomean(&r))]);
    }
    Ok(table.render())
}

/// Table II — ablation speed ratios (%) over column-dimension ranges.
pub fn table2(points: &[SweepPoint], out: Option<&Path>) -> Result<String> {
    let ranges: [(usize, usize, &str); 4] =
        [(16, 32, "[16, 32]"), (33, 64, "(32, 64]"), (65, 96, "(64, 96]"), (97, 128, "(96, 128]")];
    let mut table = Table::new(&[
        "column dim range",
        "block avg%", "block max%", "block min%",
        "cw avg%", "cw max%", "cw min%",
    ]);
    let mut csv = Csv::new(&["range", "block_avg", "block_max", "block_min", "cw_avg", "cw_max", "cw_min"]);
    for (lo, hi, label) in ranges {
        let block: Vec<f64> = points
            .iter()
            .filter(|p| p.coldim >= lo && p.coldim <= hi)
            .map(|p| 100.0 * p.warp_cw / p.accel)
            .collect();
        let cw: Vec<f64> = points
            .iter()
            .filter(|p| p.coldim >= lo && p.coldim <= hi)
            .map(|p| 100.0 * p.accel_no_cw / p.accel)
            .collect();
        if block.is_empty() {
            continue;
        }
        let f = |v: &[f64]| {
            (
                v.iter().sum::<f64>() / v.len() as f64,
                v.iter().cloned().fold(f64::MIN, f64::max),
                v.iter().cloned().fold(f64::MAX, f64::min),
            )
        };
        let (ba, bx, bn) = f(&block);
        let (ca, cx, cn) = f(&cw);
        table.row(vec![
            label.to_string(),
            format!("{ba:.1}"), format!("{bx:.1}"), format!("{bn:.1}"),
            format!("{ca:.1}"), format!("{cx:.1}"), format!("{cn:.1}"),
        ]);
        csv.row(&[
            label.to_string(),
            format!("{ba:.2}"), format!("{bx:.2}"), format!("{bn:.2}"),
            format!("{ca:.2}"), format!("{cx:.2}"), format!("{cn:.2}"),
        ]);
    }
    if let Some(dir) = out {
        csv.save(dir.join("table2.csv"))?;
    }
    Ok(format!(
        "{}(paper Table II: block-level avg 105.2-107.2%, max 130.7, min 92.4; combined warp avg 105.5-133.4%, max 194.5, min 81.3)\n",
        table.render()
    ))
}

/// Table I — dataset specs + measured synthetic stand-ins.
pub fn table1(policy: ScalePolicy, seed: u64, out: Option<&Path>) -> Result<String> {
    let mut table = Table::new(&[
        "graph", "paper nodes", "paper edges", "scale", "sim nodes", "sim nnz", "avg deg", "max/avg",
    ]);
    let mut csv = Csv::new(&["graph", "paper_nodes", "paper_edges", "scale", "sim_nodes", "sim_nnz", "avg_deg", "max_over_avg"]);
    for spec in datasets::TABLE1 {
        let csr = datasets::materialize(spec, policy, seed);
        let s = stats::graph_stats(&csr);
        let scale = policy.factor(spec);
        table.row(vec![
            spec.name.to_string(),
            spec.paper_nodes.to_string(),
            spec.paper_edges.to_string(),
            format!("{scale:.4}"),
            s.n_rows.to_string(),
            s.nnz.to_string(),
            format!("{:.1}", s.avg_degree),
            format!("{:.1}", s.max_over_avg),
        ]);
        csv.row(&[
            spec.name.to_string(),
            spec.paper_nodes.to_string(),
            spec.paper_edges.to_string(),
            format!("{scale:.5}"),
            s.n_rows.to_string(),
            s.nnz.to_string(),
            format!("{:.2}", s.avg_degree),
            format!("{:.2}", s.max_over_avg),
        ]);
    }
    if let Some(dir) = out {
        csv.save(dir.join("table1.csv"))?;
    }
    Ok(table.render())
}

/// Fig. 2 — Collab row-degree histogram.
pub fn fig2(policy: ScalePolicy, seed: u64, out: Option<&Path>) -> Result<String> {
    let spec = datasets::by_name("collab").expect("collab in Table I");
    let csr = datasets::materialize(spec, policy, seed);
    let s = stats::graph_stats(&csr);
    let h = stats::degree_histogram(&csr);
    if let Some(dir) = out {
        let mut csv = Csv::new(&["bucket_lo", "bucket_hi", "count"]);
        if h.zeros > 0 {
            csv.row(&["0".into(), "0".into(), h.zeros.to_string()]);
        }
        for (i, &c) in h.counts.iter().enumerate() {
            csv.row(&[(1u64 << i).to_string(), ((1u64 << (i + 1)) - 1).to_string(), c.to_string()]);
        }
        csv.save(dir.join("fig2.csv"))?;
    }
    Ok(format!(
        "collab degree distribution (paper Fig. 2: max degree ≈ 66× the average)\n\
         measured: avg {:.1}, max {} ({:.1}× avg), cv {:.2}\n{}",
        s.avg_degree,
        s.max_degree,
        s.max_over_avg,
        s.degree_cv,
        h.ascii(48)
    ))
}

/// Fig. 3 / Eq. 1 — metadata storage comparison per graph.
pub fn fig3(cfg: &SweepConfig, out: Option<&Path>) -> Result<String> {
    let mut table = Table::new(&["graph", "blocks", "warp groups", "block meta KB", "warp meta KB", "ratio"]);
    let mut csv = Csv::new(&["graph", "blocks", "warp_groups", "block_bytes", "warp_bytes", "ratio"]);
    let mut ratios = Vec::new();
    for name in &cfg.graphs {
        let spec = datasets::by_name(name).expect("valid name");
        let csr = datasets::materialize(spec, cfg.policy, cfg.seed);
        let g = SpmmPlan::build(csr, PartitionParams::default());
        let wp = &g.warp; // same group size: the plan's warp-level baseline
        let fp = g.block.footprint();
        let warp_bytes = wp.metadata_bytes();
        let ratio = fp.block_level_bytes as f64 / warp_bytes.max(1) as f64;
        ratios.push(ratio);
        table.row(vec![
            name.clone(),
            g.block.n_blocks().to_string(),
            wp.n_groups().to_string(),
            format!("{:.1}", fp.block_level_bytes as f64 / 1024.0),
            format!("{:.1}", warp_bytes as f64 / 1024.0),
            format!("{:.1}%", ratio * 100.0),
        ]);
        csv.row(&[
            name.clone(),
            g.block.n_blocks().to_string(),
            wp.n_groups().to_string(),
            fp.block_level_bytes.to_string(),
            warp_bytes.to_string(),
            format!("{ratio:.4}"),
        ]);
    }
    if let Some(dir) = out {
        csv.save(dir.join("fig3_metadata.csv"))?;
    }
    Ok(format!(
        "{}avg metadata ratio {:.1}% (paper Eq. 1: <10%, ≈8% at max_block_warps=12)\n",
        table.render(),
        100.0 * ratios.iter().sum::<f64>() / ratios.len().max(1) as f64
    ))
}

/// Preprocessing-throughput microbench backing the O(n) claim (§III-C).
/// Times the full plan build: fingerprint + degree sort + block-level
/// partition + warp-level baseline (includes one CSR clone per
/// iteration, since a plan owns its matrix).
pub fn preprocessing_scaling(seed: u64) -> String {
    use crate::util::bench::time_fn;
    let mut table = Table::new(&["nodes", "nnz", "plan build", "ns/edge"]);
    for scale in [10_000usize, 40_000, 160_000] {
        let mut rng = crate::util::rng::Pcg::seed_from(seed);
        let degs = crate::graph::generator::degree_sequence(
            crate::graph::generator::DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.01 },
            scale,
            scale * 10,
            &mut rng,
        );
        let csr = crate::graph::generator::from_degree_sequence(scale, &degs, &mut rng);
        let m = time_fn("prep", 1, 0.3, || {
            let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
            std::hint::black_box(plan.block.n_blocks());
        });
        table.row(vec![
            scale.to_string(),
            csr.nnz().to_string(),
            crate::util::bench::fmt_secs(m.p50()),
            format!("{:.1}", m.p50() * 1e9 / csr.nnz() as f64),
        ]);
    }
    table.render()
}

fn unique_graphs(points: &[SweepPoint]) -> Vec<String> {
    let mut v: Vec<String> = Vec::new();
    for p in points {
        if !v.contains(&p.graph) {
            v.push(p.graph.clone());
        }
    }
    v
}

pub fn run_from_args(args: &Args) -> Result<()> {
    let out_dir = args.str_or("out", "results");
    let out = Path::new(&out_dir);
    std::fs::create_dir_all(out)?;
    let seed = args.u64_or("seed", 42)?;
    let mut cfg = if args.flag("quick") {
        SweepConfig::quick(seed)
    } else {
        let policy = ScalePolicy {
            node_cap: args.usize_or("node-cap", ScalePolicy::default().node_cap)?,
            edge_cap: args.usize_or("edge-cap", ScalePolicy::default().edge_cap)?,
        };
        SweepConfig::paper(policy, seed)
    };
    if let Some(graphs) = args.get("graphs") {
        cfg.graphs = graphs.split(',').map(|s| s.trim().to_string()).collect();
    }
    cfg.coldims = args.usize_list_or("coldims", &cfg.coldims.clone())?;

    let experiment = args.str_or("experiment", "all");
    let needs_sweep = matches!(experiment.as_str(), "all" | "fig5" | "fig6" | "fig7" | "fig8" | "table2");
    let points = if needs_sweep {
        eprintln!(
            "sweeping {} graphs × {} coldims × 6 kernel variants ...",
            cfg.graphs.len(),
            cfg.coldims.len()
        );
        full_sweep(&cfg)
    } else {
        Vec::new()
    };

    let mut report = String::new();
    let arm = |name: &str| experiment == "all" || experiment == name;
    if arm("table1") {
        report += &format!("=== Table I ===\n{}\n", table1(cfg.policy, seed, Some(out))?);
    }
    if arm("fig2") {
        report += &format!("=== Fig. 2 ===\n{}\n", fig2(cfg.policy, seed, Some(out))?);
    }
    if arm("fig3") {
        report += &format!("=== Fig. 3 / Eq. 1 (metadata) ===\n{}\n", fig3(&cfg, Some(out))?);
    }
    if arm("fig5") {
        report += &format!("=== Fig. 5 ===\n{}\n", fig5(&points, Some(out))?);
    }
    if arm("fig6") {
        report += &format!("=== Fig. 6 ===\n{}\n", fig6(&points, Some(out))?);
    }
    if arm("fig7") {
        report += &format!("=== Fig. 7 ===\n{}\n", fig7(&points, Some(out))?);
    }
    if arm("fig8") {
        report += &format!("=== Fig. 8 ===\n{}\n", fig8(&points, Some(out))?);
    }
    if arm("table2") {
        report += &format!("=== Table II ===\n{}\n", table2(&points, Some(out))?);
    }
    if arm("prep") {
        report += &format!("=== Preprocessing O(n) scaling ===\n{}\n", preprocessing_scaling(seed));
    }
    if arm("exec_scaling") {
        use crate::bench::exec_scaling as es;
        let pts = es::exec_scaling(
            "collab",
            &es::DEFAULT_COLDIMS,
            &es::DEFAULT_THREADS,
            cfg.policy,
            seed,
        )?;
        crate::bench::report::write_report(out, "BENCH_exec_scaling.json", &es::to_json(&pts))?;
        report += &format!(
            "=== Exec scaling (parallel block-level, collab) ===\n{}(written to BENCH_exec_scaling.json)\n\n",
            es::report(&pts)
        );
    }
    if arm("microkernel") {
        use crate::bench::microkernel as mk;
        // --quick shrinks every axis but keeps both dispatch modes and
        // both skew extremes, with verification on — the CI smoke
        let (graphs, coldims, threads): (&[&str], &[usize], &[usize]) = if args.flag("quick") {
            (&mk::QUICK_GRAPHS, &mk::QUICK_COLDIMS, &mk::QUICK_THREADS)
        } else {
            (&mk::DEFAULT_GRAPHS, &mk::DEFAULT_COLDIMS, &mk::DEFAULT_THREADS)
        };
        let pts = mk::run_graphs(graphs, coldims, threads, cfg.policy, seed)?;
        anyhow::ensure!(
            pts.iter().all(|p| p.verified),
            "microkernel: a variant diverged from the dense reference"
        );
        crate::bench::report::write_report(out, "BENCH_microkernel.json", &mk::to_json(&pts))?;
        report += &format!(
            "=== Microkernel (SIMD × dispatch matrix, degree-skew sweep) ===\n{}(written to BENCH_microkernel.json)\n\n",
            mk::report(&pts)
        );
    }
    if arm("serve_native") {
        use crate::bench::serve_native as sn;
        let load = sn::LoadConfig {
            nodes: if args.flag("quick") { 60 } else { 300 },
            seed,
            ..sn::LoadConfig::default()
        };
        let pts = sn::run_sweep(&load, &[1, 2, 4])?;
        crate::bench::report::write_report(out, "BENCH_serve_native.json", &sn::to_json(&pts))?;
        report += &format!(
            "=== Serve native (multi-tenant, column-fused) ===\n{}(written to BENCH_serve_native.json)\n\n",
            sn::report(&pts)
        );
    }
    if arm("delta_update") {
        use crate::bench::delta_update as du;
        let cfg = if args.flag("quick") {
            du::DeltaConfig::quick(seed)
        } else {
            du::DeltaConfig::paper(seed)
        };
        let pts = du::run(&cfg)?;
        anyhow::ensure!(
            pts.iter().all(|p| p.verified),
            "delta_update: a patched plan diverged from the from-scratch rebuild"
        );
        crate::bench::report::write_report(out, "BENCH_delta_update.json", &du::to_json(&pts))?;
        report += &format!(
            "=== Delta update (patch vs full replan) ===\n{}(written to BENCH_delta_update.json)\n\n",
            du::report(&pts)
        );
    }
    if arm("train_native") {
        use crate::bench::train_native as tn;
        let cfg = if args.flag("quick") {
            tn::TrainBenchConfig::quick(seed)
        } else {
            tn::TrainBenchConfig::paper(seed)
        };
        let pts = tn::run(&cfg)?;
        anyhow::ensure!(
            pts.iter().all(|p| p.verified),
            "train_native: backward SpMM diverged from the dense Âᵀ reference"
        );
        crate::bench::report::write_report(out, "BENCH_train_native.json", &tn::to_json(&pts))?;
        report += &format!(
            "=== Train native (full GCN backprop, threads × optimizers) ===\n{}(written to BENCH_train_native.json)\n\n",
            tn::report(&pts)
        );
    }
    if arm("ablation-params") || experiment == "all" {
        let pts = crate::bench::ablation::partition_param_sweep(
            "collab",
            64,
            cfg.policy,
            seed,
        )?;
        report += &format!(
            "=== Ablation: partition parameters (collab, coldim 64) ===\n{}\n",
            crate::bench::ablation::report("collab", &pts, Some(out))?
        );
    }
    print!("{report}");
    std::fs::write(out.join("report.txt"), &report)?;
    eprintln!("CSVs + report written to {out_dir}/");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_points() -> Vec<SweepPoint> {
        full_sweep(&SweepConfig::quick(7))
    }

    #[test]
    fn quick_sweep_shape_and_ordering() {
        let points = quick_points();
        assert_eq!(points.len(), 3 * 3);
        // Fig. 5's qualitative claim on the power-law graphs: accel beats
        // the two open baselines on every point; cuSPARSE on average.
        for p in &points {
            assert!(p.gnnadvisor > p.accel, "{p:?}");
            assert!(p.graphblast > p.accel, "{p:?}");
            assert!(p.accel > 0.0 && p.accel.is_finite());
        }
        let cu: Vec<f64> = points.iter().map(|p| p.cusparse / p.accel).collect();
        assert!(geomean(&cu) > 1.0, "avg vs cusparse {:.3}", geomean(&cu));
    }

    #[test]
    fn reports_render() {
        let points = quick_points();
        let f5 = fig5(&points, None).unwrap();
        assert!(f5.contains("vs cuSPARSE"));
        let f6 = fig6(&points, None).unwrap();
        assert!(f6.contains("growth"));
        let f7 = fig7(&points, None).unwrap();
        let f8 = fig8(&points, None).unwrap();
        assert!(f7.contains("block-level"));
        assert!(f8.contains("combined-warp"));
        let t2 = table2(&points, None).unwrap();
        assert!(t2.contains("[16, 32]"));
    }

    #[test]
    fn table1_and_fig2_render() {
        let t1 = table1(ScalePolicy::tiny(), 7, None).unwrap();
        assert!(t1.contains("collab"));
        assert!(t1.contains("123718280")); // paper edge count preserved
        let f2 = fig2(ScalePolicy::tiny(), 7, None).unwrap();
        assert!(f2.contains("degree distribution"));
    }

    #[test]
    fn fig3_metadata_under_10pct_on_powerlaw() {
        let cfg = SweepConfig {
            graphs: vec!["collab".into(), "artist".into()],
            coldims: vec![],
            policy: ScalePolicy::tiny(),
            seed: 7,
        };
        let report = fig3(&cfg, None).unwrap();
        assert!(report.contains("ratio"));
    }
}
