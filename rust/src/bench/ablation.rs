//! Design-space ablation beyond the paper's two ablations: sweep the
//! partitioner's tunables (`max_block_warps`, `max_warp_nzs` — together
//! `deg_bound`) and report their effect on simulated kernel time,
//! metadata footprint, balance, and BELL padding. DESIGN.md lists this
//! as the design-choice ablation backing the defaults (12, 32).

use crate::graph::datasets::{by_name, materialize, ScalePolicy};
use crate::partition::patterns::PartitionParams;
use crate::pipeline::SpmmPlan;
use crate::sim::kernels::{CostModel, KernelKind, KernelOptions};
use crate::sim::{simulate_kernel, GpuConfig};
use crate::util::bench::{Csv, Table};
use anyhow::Result;
use std::path::Path;

/// One configuration's measurements.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub max_block_warps: usize,
    pub max_warp_nzs: usize,
    pub sim_us: f64,
    pub sm_load_cv: f64,
    pub metadata_ratio: f64,
    pub padding_overhead: f64,
    pub n_blocks: usize,
    pub n_split_rows: usize,
}

/// Sweep partition parameters on one graph at one column dim.
pub fn partition_param_sweep(
    graph: &str,
    coldim: usize,
    policy: ScalePolicy,
    seed: u64,
) -> Result<Vec<AblationPoint>> {
    let spec = by_name(graph)
        .ok_or_else(|| anyhow::anyhow!("unknown graph `{graph}`"))?;
    let csr = materialize(spec, policy, seed);
    let gpu = GpuConfig::rtx3090();
    let cost = CostModel::default();
    let mut out = Vec::new();
    for &mbw in &[1usize, 2, 4, 6, 12, 24] {
        for &mwn in &[8usize, 16, 32, 64] {
            let params = PartitionParams { max_block_warps: mbw, max_warp_nzs: mwn };
            let g = SpmmPlan::build(csr.clone(), params);
            let r = simulate_kernel(&gpu, &cost, KernelKind::AccelGcn, KernelOptions::default(), &g, coldim);
            let layout = crate::partition::bucket::BellLayout::build(&g.sorted.csr, &g.block);
            out.push(AblationPoint {
                max_block_warps: mbw,
                max_warp_nzs: mwn,
                sim_us: r.micros,
                sm_load_cv: r.sm_load_cv,
                metadata_ratio: g.block.footprint().ratio(),
                padding_overhead: layout.padding_overhead(),
                n_blocks: g.block.n_blocks(),
                n_split_rows: g.block.n_split_rows,
            });
        }
    }
    Ok(out)
}

/// Render + optionally persist the sweep.
pub fn report(graph: &str, points: &[AblationPoint], out: Option<&Path>) -> Result<String> {
    let mut table = Table::new(&[
        "block warps", "warp nzs", "deg_bound", "sim µs", "SM cv", "meta ratio", "padding", "blocks", "split rows",
    ]);
    let mut csv = Csv::new(&[
        "max_block_warps", "max_warp_nzs", "deg_bound", "sim_us", "sm_cv", "meta_ratio", "padding", "blocks", "split_rows",
    ]);
    for p in points {
        let bound = p.max_block_warps * p.max_warp_nzs;
        table.row(vec![
            p.max_block_warps.to_string(),
            p.max_warp_nzs.to_string(),
            bound.to_string(),
            format!("{:.1}", p.sim_us),
            format!("{:.3}", p.sm_load_cv),
            format!("{:.1}%", p.metadata_ratio * 100.0),
            format!("{:.2}x", p.padding_overhead),
            p.n_blocks.to_string(),
            p.n_split_rows.to_string(),
        ]);
        csv.row(&[
            p.max_block_warps.to_string(),
            p.max_warp_nzs.to_string(),
            bound.to_string(),
            format!("{:.2}", p.sim_us),
            format!("{:.4}", p.sm_load_cv),
            format!("{:.4}", p.metadata_ratio),
            format!("{:.3}", p.padding_overhead),
            p.n_blocks.to_string(),
            p.n_split_rows.to_string(),
        ]);
    }
    if let Some(dir) = out {
        csv.save(dir.join(format!("ablation_params_{graph}.csv")))?;
    }
    let best = points
        .iter()
        .min_by(|a, b| a.sim_us.partial_cmp(&b.sim_us).unwrap())
        .unwrap();
    Ok(format!(
        "{}best config on `{graph}`: max_block_warps={}, max_warp_nzs={} ({:.1} µs); paper default (12, 32) trades ≤ a few % of time for the smallest metadata.\n",
        table.render(),
        best.max_block_warps,
        best.max_warp_nzs,
        best.sim_us
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_and_orders_sanely() {
        let pts = partition_param_sweep("pubmed", 64, ScalePolicy::tiny(), 7).unwrap();
        assert_eq!(pts.len(), 24);
        // metadata ratio shrinks as blocks hold more warps
        let r1 = pts.iter().find(|p| p.max_block_warps == 1 && p.max_warp_nzs == 32).unwrap();
        let r12 = pts.iter().find(|p| p.max_block_warps == 12 && p.max_warp_nzs == 32).unwrap();
        assert!(r12.metadata_ratio < r1.metadata_ratio);
        // 1-warp blocks: every block is one warp → ratio ≈ 1
        assert!(r1.metadata_ratio > 0.9);
        // all configs simulate to finite positive time
        assert!(pts.iter().all(|p| p.sim_us.is_finite() && p.sim_us > 0.0));
    }

    #[test]
    fn report_renders() {
        let pts = partition_param_sweep("pubmed", 32, ScalePolicy::tiny(), 7).unwrap();
        let r = report("pubmed", &pts, None).unwrap();
        assert!(r.contains("best config"));
        assert!(r.contains("deg_bound"));
    }
}
