//! `delta_update` — incremental plan maintenance vs full replanning.
//!
//! For each (degree-skew α, update-batch size) cell: generate a
//! power-law graph, build its [`SpmmPlan`], then stream update batches
//! through a [`DeltaGraph`] and measure, per batch,
//!
//! * **patch** — [`patch_plan`]: incremental permutation merge +
//!   dirty-bucket metadata rebuild,
//! * **replan** — `SpmmPlan::build` on the updated matrix from scratch,
//! * **post-update SpMM** — parallel block-level execution on the
//!   patched plan (the serving hot path after a swap).
//!
//! Every batch is verified: the patched plan must equal the from-scratch
//! rebuild field-for-field *and* its SpMM output must match the dense
//! reference — the bench doubles as the delta path's end-to-end check
//! in CI. Written to `BENCH_delta_update.json` so successive PRs can
//! track the update path.

use crate::delta::{patch_plan, DeltaGraph, EdgeUpdate};
use crate::graph::generator::{self, DegreeModel};
use crate::graph::Csr;
use crate::partition::patterns::PartitionParams;
use crate::pipeline::{spmm_block_level_parallel, SpmmPlan};
use crate::spmm::verify::allclose;
use crate::util::bench::{time_fn, Table};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Arc;

/// Sweep shape.
#[derive(Clone, Debug)]
pub struct DeltaConfig {
    pub nodes: usize,
    pub avg_deg: f64,
    /// Power-law exponents, one graph regime per value (smaller α =
    /// heavier skew).
    pub skews: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    /// Batches streamed (and timed) per cell; times are p50 over these.
    pub batches_per_cell: usize,
    /// Column dimension of the post-update SpMM measurement.
    pub coldim: usize,
    pub threads: usize,
    pub seed: u64,
}

impl DeltaConfig {
    /// The full sweep the `bench` subcommand runs.
    pub fn paper(seed: u64) -> DeltaConfig {
        DeltaConfig {
            nodes: 3000,
            avg_deg: 8.0,
            skews: vec![1.8, 2.2, 2.7],
            batch_sizes: vec![8, 64, 512],
            batches_per_cell: 5,
            coldim: 64,
            threads: 4,
            seed,
        }
    }

    /// Reduced sweep for CI check mode / unit tests.
    pub fn quick(seed: u64) -> DeltaConfig {
        DeltaConfig {
            nodes: 1200,
            avg_deg: 8.0,
            skews: vec![2.0],
            batch_sizes: vec![4, 64],
            batches_per_cell: 3,
            coldim: 32,
            threads: 2,
            seed,
        }
    }
}

/// One measured (skew, batch size) cell.
#[derive(Clone, Debug)]
pub struct DeltaPoint {
    pub alpha: f64,
    pub batch_size: usize,
    pub nodes: usize,
    pub nnz: usize,
    /// p50 over the cell's batches, µs.
    pub patch_us: f64,
    pub replan_us: f64,
    /// `replan / patch` (> 1 ⇒ patching wins).
    pub speedup: f64,
    /// Post-update SpMM p50 on the patched plan, µs.
    pub spmm_us: f64,
    /// Mean fraction of block-metadata records reused per patch.
    pub meta_reuse_frac: f64,
    /// Mean rows whose degree changed per batch.
    pub rows_moved_mean: f64,
    /// Every batch's patched plan equaled the rebuild and matched the
    /// dense SpMM reference.
    pub verified: bool,
}

/// A mixed insert/delete batch against the current matrix: ~half
/// deletions of existing edges, the rest random insertions. Shared
/// with the `update-demo` subcommand.
pub fn random_batch(cur: &Csr, k: usize, rng: &mut Pcg) -> Vec<EdgeUpdate> {
    (0..k)
        .map(|_| {
            let n = cur.n_rows;
            if rng.f64() < 0.5 && cur.nnz() > 0 {
                let r = rng.range(0, n);
                if cur.degree(r) > 0 {
                    let i = cur.row_ptr[r] + rng.range(0, cur.degree(r));
                    return EdgeUpdate::Delete { row: r as u32, col: cur.col_idx[i] };
                }
            }
            EdgeUpdate::Insert {
                row: rng.range(0, n) as u32,
                col: rng.range(0, n) as u32,
                val: rng.f32() + 0.1,
            }
        })
        .collect()
}

fn p50(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Structural equality of the patched plan against the from-scratch
/// rebuild (the acceptance criterion, checked on every batch).
fn plans_equal(patched: &SpmmPlan, rebuilt: &SpmmPlan) -> bool {
    patched.sorted.perm == rebuilt.sorted.perm
        && patched.sorted.csr == rebuilt.sorted.csr
        && patched.block.meta == rebuilt.block.meta
        && patched.block.n_split_rows == rebuilt.block.n_split_rows
        && patched.warp.groups == rebuilt.warp.groups
}

/// Run the sweep.
pub fn run(cfg: &DeltaConfig) -> Result<Vec<DeltaPoint>> {
    anyhow::ensure!(cfg.batches_per_cell >= 1, "need at least one batch per cell");
    let params = PartitionParams::default();
    let pool = ThreadPool::new(cfg.threads);
    let mut points = Vec::with_capacity(cfg.skews.len() * cfg.batch_sizes.len());
    for &alpha in &cfg.skews {
        for &batch_size in &cfg.batch_sizes {
            let mut rng = Pcg::seed_from(
                cfg.seed ^ (alpha.to_bits().rotate_left(17)) ^ batch_size as u64,
            );
            let degs = generator::degree_sequence(
                DegreeModel::PowerLaw { alpha, dmax_frac: 0.1 },
                cfg.nodes,
                (cfg.nodes as f64 * cfg.avg_deg) as usize,
                &mut rng,
            );
            let base = generator::from_degree_sequence(cfg.nodes, &degs, &mut rng);
            let nnz0 = base.nnz();
            let mut delta = DeltaGraph::new(base.clone());
            let mut plan = Arc::new(SpmmPlan::build(base, params));
            let (mut patch_times, mut replan_times) = (Vec::new(), Vec::new());
            let (mut reuse_sum, mut moved_sum) = (0.0f64, 0.0f64);
            let mut verified = true;
            for _ in 0..cfg.batches_per_cell {
                let batch = random_batch(&delta.snapshot(), batch_size, &mut rng);
                let report = delta.apply(&batch)?;
                let new_csr = delta.snapshot();

                let t0 = std::time::Instant::now();
                let (patched, stats) = patch_plan(&plan, new_csr.clone(), &report.changes)?;
                patch_times.push(t0.elapsed().as_secs_f64() * 1e6);

                let t1 = std::time::Instant::now();
                let rebuilt = SpmmPlan::build(new_csr.clone(), params);
                replan_times.push(t1.elapsed().as_secs_f64() * 1e6);

                reuse_sum += stats.reuse_frac();
                moved_sum += stats.rows_moved as f64;
                verified &= plans_equal(&patched, &rebuilt);
                plan = Arc::new(patched);
                // numeric check against the dense reference
                let f = cfg.coldim.min(8); // keep the verify pass cheap
                let x: Vec<f32> = (0..cfg.nodes * f).map(|_| rng.f32() - 0.5).collect();
                // fused unpermute-scatter: already in original row order
                let y = spmm_block_level_parallel(&plan, &x, f, &pool);
                verified &= allclose(&y, &new_csr.spmm_dense(&x, f), 1e-3, 1e-3);
            }
            // post-update SpMM throughput on the final patched plan
            let x: Vec<f32> =
                (0..cfg.nodes * cfg.coldim).map(|_| rng.f32() - 0.5).collect();
            let m = time_fn("delta_spmm", 1, 0.05, || {
                std::hint::black_box(spmm_block_level_parallel(&plan, &x, cfg.coldim, &pool));
            });
            let (patch_us, replan_us) = (p50(patch_times), p50(replan_times));
            points.push(DeltaPoint {
                alpha,
                batch_size,
                nodes: cfg.nodes,
                nnz: nnz0,
                patch_us,
                replan_us,
                speedup: replan_us / patch_us.max(1e-9),
                spmm_us: m.p50() * 1e6,
                meta_reuse_frac: reuse_sum / cfg.batches_per_cell as f64,
                rows_moved_mean: moved_sum / cfg.batches_per_cell as f64,
                verified,
            });
        }
    }
    Ok(points)
}

/// Paper-style stdout table.
pub fn report(points: &[DeltaPoint]) -> String {
    let mut table = Table::new(&[
        "alpha", "batch", "nnz", "patch µs", "replan µs", "speedup", "spmm µs", "meta reuse",
        "rows moved", "verified",
    ]);
    for p in points {
        table.row(vec![
            format!("{:.1}", p.alpha),
            p.batch_size.to_string(),
            p.nnz.to_string(),
            format!("{:.1}", p.patch_us),
            format!("{:.1}", p.replan_us),
            format!("{:.2}x", p.speedup),
            format!("{:.1}", p.spmm_us),
            format!("{:.1}%", p.meta_reuse_frac * 100.0),
            format!("{:.1}", p.rows_moved_mean),
            p.verified.to_string(),
        ]);
    }
    table.render()
}

/// The machine-readable form consumed by the perf-trajectory tooling.
pub fn to_json(points: &[DeltaPoint]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("alpha", p.alpha);
            o.set("batch_size", p.batch_size);
            o.set("nodes", p.nodes);
            o.set("nnz", p.nnz);
            o.set("patch_us", p.patch_us);
            o.set("replan_us", p.replan_us);
            o.set("speedup", p.speedup);
            o.set("spmm_us", p.spmm_us);
            o.set("meta_reuse_frac", p.meta_reuse_frac);
            o.set("rows_moved_mean", p.rows_moved_mean);
            o.set("verified", p.verified);
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("experiment", "delta_update");
    doc.set("executor", "delta/patch-vs-replan");
    doc.set("unit", "us");
    doc.set("points", rows);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_verifies_and_reports() {
        let pts = run(&DeltaConfig::quick(7)).unwrap();
        assert_eq!(pts.len(), 2, "1 skew × 2 batch sizes");
        for p in &pts {
            assert!(p.verified, "{p:?}");
            assert!(p.patch_us > 0.0 && p.replan_us > 0.0 && p.spmm_us > 0.0, "{p:?}");
            assert!(p.meta_reuse_frac >= 0.0 && p.meta_reuse_frac <= 1.0);
        }
        // The patch-beats-replan claim is asserted structurally here and
        // only sanity-bounded on wall clock: this test runs in debug
        // mode on shared CI runners, where a strict `speedup > 1`
        // p50-of-3 comparison of microsecond-scale work would be flaky.
        // The release-mode bench run reports the real speedup in
        // BENCH_delta_update.json.
        let small = pts.iter().find(|p| p.batch_size == 4).unwrap();
        let large = pts.iter().find(|p| p.batch_size == 64).unwrap();
        assert!(
            small.speedup > 0.5,
            "patch ({:.1}µs) grossly slower than replan ({:.1}µs)",
            small.patch_us,
            small.replan_us
        );
        // structural evidence the patch does less work: a 4-op batch
        // dirties at most 8 degree buckets, so some metadata survives,
        // and it can never move more rows than it has ops
        assert!(small.meta_reuse_frac > 0.0, "reuse {:.2}", small.meta_reuse_frac);
        assert!(small.rows_moved_mean <= 4.0, "moved {:.1}", small.rows_moved_mean);
        assert!(
            small.rows_moved_mean < large.rows_moved_mean,
            "larger batches must move more rows ({:.1} vs {:.1})",
            small.rows_moved_mean,
            large.rows_moved_mean
        );
        let json = to_json(&pts).to_pretty();
        assert!(json.contains("delta_update"));
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("points").unwrap().len(), 2);
        assert!(report(&pts).contains("speedup"));
    }

    #[test]
    fn invalid_config_rejected() {
        let cfg = DeltaConfig { batches_per_cell: 0, ..DeltaConfig::quick(1) };
        assert!(run(&cfg).is_err());
    }
}
