//! Serving metrics: counters and latency recorders with percentile
//! snapshots. Thread-safe; shared via `Arc` between the coordinator's
//! front end and its device thread.

use crate::util::stats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency recorder: stores samples (seconds), reports percentiles.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples: Mutex<Vec<f64>>,
}

/// Snapshot of a latency distribution.
#[derive(Clone, Copy, Debug)]
pub struct LatencySnapshot {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, seconds: f64) {
        self.samples.lock().unwrap().push(seconds);
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let samples = self.samples.lock().unwrap();
        if samples.is_empty() {
            return LatencySnapshot { count: 0, mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        LatencySnapshot {
            count: samples.len(),
            mean: stats::mean(&samples),
            p50: stats::percentile(&samples, 50.0),
            p95: stats::percentile(&samples, 95.0),
            p99: stats::percentile(&samples, 99.0),
            max: samples.iter().cloned().fold(0.0, f64::max),
        }
    }
}

impl LatencySnapshot {
    pub fn render(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={} p50={} p95={} p99={} max={}",
            self.count,
            crate::util::bench::fmt_secs(self.mean),
            crate::util::bench::fmt_secs(self.p50),
            crate::util::bench::fmt_secs(self.p95),
            crate::util::bench::fmt_secs(self.p99),
            crate::util::bench::fmt_secs(self.max),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        use std::sync::Arc;
        let c = Arc::new(Counter::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn latency_percentiles() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        let s = r.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.p50 - 0.051).abs() < 0.002);
        assert!(s.p99 >= 0.099 - 1e-9);
        assert_eq!(s.max, 0.1);
        assert!(s.render("test").contains("n=100"));
    }

    #[test]
    fn empty_snapshot() {
        let s = LatencyRecorder::new().snapshot();
        assert_eq!(s.count, 0);
    }
}
