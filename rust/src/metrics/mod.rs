//! Serving metrics: counters, gauges, and latency recorders with
//! percentile snapshots. Thread-safe; shared via `Arc` between the
//! coordinator's front end and its device thread, and between the native
//! serve subsystem's submitters and worker loop.
//!
//! Since the unified observability subsystem landed, this module is a
//! thin facade over [`crate::obs`]: `Counter`/`Gauge` are re-exports,
//! and [`LatencyRecorder`] wraps the fixed log-bucket
//! [`Histogram`](crate::obs::Histogram) — `count`/`mean`/`max` are
//! exact over **every** sample and `p50`/`p95`/`p99` carry the
//! histogram's documented ≤ 2.2% one-sided relative error (well inside
//! the ≤ 5% bound this module promises), in constant memory with no
//! sampling. The prior reservoir sampler (Vitter's Algorithm R) is
//! gone: it gave exact quantiles only below capacity and *sampled*
//! estimates forever after, where the histogram's bound holds at any
//! count.

pub use crate::obs::{Counter, Gauge};
use crate::obs::{HistSnapshot, Histogram};

/// Latency recorder: fixed log-bucket histogram of samples (seconds),
/// reports percentiles.
///
/// Constant memory regardless of how long a server runs; `count`,
/// `mean`, and `max` are exact, quantiles are within the bucket bound
/// ([`crate::obs::QUANTILE_REL_ERROR`], ≈ 2.2%, documented ≤ 5%).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    hist: Histogram,
}

/// Snapshot of a latency distribution.
#[derive(Clone, Copy, Debug)]
pub struct LatencySnapshot {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, seconds: f64) {
        self.hist.record(seconds);
    }

    /// The underlying histogram's summary (same numbers as
    /// [`snapshot`](Self::snapshot), histogram-native type) — used when
    /// merging serve latencies into a registry snapshot document.
    pub fn hist_snapshot(&self) -> HistSnapshot {
        self.hist.snapshot()
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let s = self.hist.snapshot();
        LatencySnapshot {
            count: s.count,
            mean: s.mean,
            p50: s.p50,
            p95: s.p95,
            p99: s.p99,
            max: s.max,
        }
    }
}

impl LatencySnapshot {
    pub fn render(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={} p50={} p95={} p99={} max={}",
            self.count,
            crate::util::bench::fmt_secs(self.mean),
            crate::util::bench::fmt_secs(self.p50),
            crate::util::bench::fmt_secs(self.p95),
            crate::util::bench::fmt_secs(self.p99),
            crate::util::bench::fmt_secs(self.max),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Counter/Gauge behaviour is covered where they now live
    // (`obs::tests`); these tests pin the facade's latency semantics.

    #[test]
    fn latency_percentiles() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        let s = r.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.p50 - 0.051).abs() < 0.002);
        assert!(s.p99 >= 0.099 - 1e-9);
        assert_eq!(s.max, 0.1);
        assert!(s.render("test").contains("n=100"));
    }

    #[test]
    fn empty_snapshot() {
        let s = LatencyRecorder::new().snapshot();
        assert_eq!(s.count, 0);
    }

    #[test]
    fn histogram_stays_bounded_and_exact() {
        // 50k samples through one recorder: the histogram's memory is
        // fixed at construction (no per-sample allocation at all), and
        // — unlike the reservoir this replaced — count/mean/max stay
        // exact while quantiles keep their error bound at any count.
        let r = LatencyRecorder::new();
        let n = 50_000u64;
        for i in 0..n {
            r.record(i as f64 * 1e-3); // 0 .. 50 s ramp
        }
        let s = r.snapshot();
        assert_eq!(s.count, n as usize);
        assert_eq!(s.max, (n - 1) as f64 * 1e-3);
        assert!((s.mean - (n - 1) as f64 * 1e-3 / 2.0).abs() < 1e-6);
        let bound = 1.0 + crate::obs::QUANTILE_REL_ERROR;
        let (p50_true, p99_true) = (0.5 * n as f64 * 1e-3, 0.99 * n as f64 * 1e-3);
        assert!(s.p50 >= p50_true * 0.999 && s.p50 <= p50_true * bound, "p50={}", s.p50);
        assert!(s.p99 >= p99_true * 0.999 && s.p99 <= p99_true * bound, "p99={}", s.p99);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99, "percentiles ordered");
    }

    #[test]
    fn quantile_error_within_documented_bound() {
        // The ≤ 5% promise in the serve docs: reported quantiles are
        // upper bucket edges, so error is one-sided and ≤ 2^(1/32)−1.
        let r = LatencyRecorder::new();
        for i in 1..=1000 {
            r.record(i as f64 * 1e-4); // 0.1 ms .. 100 ms
        }
        let s = r.snapshot();
        for (got, want) in [(s.p50, 0.05), (s.p95, 0.095), (s.p99, 0.099)] {
            let rel = (got - want) / want;
            assert!((-1e-9..=0.05).contains(&rel), "rel err {rel} for {want}");
        }
    }
}
