//! Serving metrics: counters, gauges, and latency recorders with
//! percentile snapshots. Thread-safe; shared via `Arc` between the
//! coordinator's front end and its device thread, and between the native
//! serve subsystem's submitters and worker loop.

use crate::util::rng::Pcg;
use crate::util::stats;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (e.g. queue depth): settable, signed so transient
/// dips below zero under racing inc/dec never wrap.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    /// Ratchet the gauge up to `v` (no-op if already higher) — for
    /// high-water levels like "highest tenant epoch" where plain `set`
    /// would regress under interleaved writers.
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Default reservoir size: large enough that percentiles over a bench run
/// are exact, small enough that a server recording forever stays flat.
pub const DEFAULT_RESERVOIR_CAPACITY: usize = 4096;

#[derive(Debug)]
struct ReservoirInner {
    /// Uniform sample of everything seen (Vitter's Algorithm R); exact
    /// while `seen <= capacity`.
    samples: Vec<f64>,
    seen: u64,
    sum: f64,
    max: f64,
    rng: Pcg,
}

/// Latency recorder: bounded-memory reservoir of samples (seconds),
/// reports percentiles.
///
/// `count`, `mean`, and `max` are exact over every recorded sample;
/// `p50`/`p95`/`p99` are exact until `capacity` samples have been seen
/// and computed over a uniform reservoir sample thereafter — so a
/// long-running server's recorder neither grows nor goes stale.
#[derive(Debug)]
pub struct LatencyRecorder {
    capacity: usize,
    inner: Mutex<ReservoirInner>,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_RESERVOIR_CAPACITY)
    }
}

/// Snapshot of a latency distribution.
#[derive(Clone, Copy, Debug)]
pub struct LatencySnapshot {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// A recorder keeping at most `capacity` samples (≥ 1).
    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        LatencyRecorder {
            capacity,
            inner: Mutex::new(ReservoirInner {
                samples: Vec::new(),
                seen: 0,
                sum: 0.0,
                max: 0.0,
                rng: Pcg::seed_from(0x1a7e_4ec0),
            }),
        }
    }

    pub fn record(&self, seconds: f64) {
        let mut g = self.inner.lock().unwrap();
        g.seen += 1;
        g.sum += seconds;
        if seconds > g.max {
            g.max = seconds;
        }
        if g.samples.len() < self.capacity {
            g.samples.push(seconds);
        } else {
            // Algorithm R: keep with probability capacity / seen
            let j = (g.rng.next_u64() % g.seen) as usize;
            if j < self.capacity {
                g.samples[j] = seconds;
            }
        }
    }

    /// Samples currently held (≤ capacity); exposed for memory tests.
    pub fn reservoir_len(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let g = self.inner.lock().unwrap();
        if g.seen == 0 {
            return LatencySnapshot { count: 0, mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        LatencySnapshot {
            count: g.seen as usize,
            mean: g.sum / g.seen as f64,
            p50: stats::percentile(&g.samples, 50.0),
            p95: stats::percentile(&g.samples, 95.0),
            p99: stats::percentile(&g.samples, 99.0),
            max: g.max,
        }
    }
}

impl LatencySnapshot {
    pub fn render(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={} p50={} p95={} p99={} max={}",
            self.count,
            crate::util::bench::fmt_secs(self.mean),
            crate::util::bench::fmt_secs(self.p50),
            crate::util::bench::fmt_secs(self.p95),
            crate::util::bench::fmt_secs(self.p99),
            crate::util::bench::fmt_secs(self.max),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        use std::sync::Arc;
        let c = Arc::new(Counter::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn gauge_levels() {
        let g = Gauge::new();
        g.set(5);
        g.inc();
        g.dec();
        g.dec();
        assert_eq!(g.get(), 4);
        g.set(0);
        g.dec();
        assert_eq!(g.get(), -1, "signed: no wraparound under racing dec");
        g.set_max(5);
        g.set_max(3);
        assert_eq!(g.get(), 5, "set_max never regresses");
    }

    #[test]
    fn latency_percentiles() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        let s = r.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.p50 - 0.051).abs() < 0.002);
        assert!(s.p99 >= 0.099 - 1e-9);
        assert_eq!(s.max, 0.1);
        assert!(s.render("test").contains("n=100"));
    }

    #[test]
    fn empty_snapshot() {
        let s = LatencyRecorder::new().snapshot();
        assert_eq!(s.count, 0);
    }

    #[test]
    fn reservoir_stays_bounded() {
        let cap = 64;
        let r = LatencyRecorder::with_capacity(cap);
        let n = 50_000u64;
        for i in 0..n {
            r.record(i as f64);
        }
        assert_eq!(r.reservoir_len(), cap, "memory must not grow past capacity");
        let s = r.snapshot();
        // exact statistics survive sampling
        assert_eq!(s.count, n as usize);
        assert_eq!(s.max, (n - 1) as f64);
        assert!((s.mean - (n - 1) as f64 / 2.0).abs() < 1e-6);
        // percentile estimates come from a uniform sample of the ramp
        // (deterministic seed, so these bounds are stable, not flaky)
        assert!(s.p50 > 0.2 * n as f64 && s.p50 < 0.8 * n as f64, "p50={}", s.p50);
        assert!(s.p99 > 0.8 * n as f64, "p99={}", s.p99);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99, "percentiles ordered");
    }

    #[test]
    fn reservoir_exact_below_capacity() {
        let r = LatencyRecorder::with_capacity(1000);
        for i in 1..=100 {
            r.record(i as f64);
        }
        let s = r.snapshot();
        assert!((s.p50 - 51.0).abs() < 1.5, "exact nearest-rank while under capacity");
        assert_eq!(s.max, 100.0);
        assert_eq!(s.count, 100);
    }
}
