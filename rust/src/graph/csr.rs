//! Compressed Sparse Row adjacency matrix.
//!
//! The left operand of the paper's SpMM (`A' · Y`): `row_ptr` has
//! `n_rows + 1` entries; row `r`'s nonzeros live at
//! `col_idx[row_ptr[r]..row_ptr[r+1]]` with weights `vals[...]`.
//! GCN uses the symmetrically-normalized adjacency
//! `Â = D^{-1/2}(A+I)D^{-1/2}`, built by [`Csr::gcn_normalize`].

use anyhow::{bail, Result};

/// CSR sparse matrix with f32 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Build from an edge list (row, col, val). Duplicate edges are
    /// summed; rows/cols outside bounds are an error.
    pub fn from_edges(n_rows: usize, n_cols: usize, edges: &[(u32, u32, f32)]) -> Result<Csr> {
        // counting pass
        let mut counts = vec![0usize; n_rows + 1];
        for &(r, c, _) in edges {
            if r as usize >= n_rows || c as usize >= n_cols {
                bail!("edge ({r},{c}) out of bounds {n_rows}x{n_cols}");
            }
            counts[r as usize + 1] += 1;
        }
        for i in 0..n_rows {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts;
        let mut col_idx = vec![0u32; edges.len()];
        let mut vals = vec![0f32; edges.len()];
        let mut cursor = row_ptr.clone();
        for &(r, c, v) in edges {
            let p = cursor[r as usize];
            col_idx[p] = c;
            vals[p] = v;
            cursor[r as usize] += 1;
        }
        let mut m = Csr { n_rows, n_cols, row_ptr, col_idx, vals };
        m.sort_rows_and_merge_dups();
        Ok(m)
    }

    /// Build directly from raw CSR arrays (validated).
    pub fn from_raw(
        n_rows: usize,
        n_cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        vals: Vec<f32>,
    ) -> Result<Csr> {
        if row_ptr.len() != n_rows + 1 {
            bail!("row_ptr length {} != n_rows+1 {}", row_ptr.len(), n_rows + 1);
        }
        if row_ptr[0] != 0 || *row_ptr.last().unwrap() != col_idx.len() {
            bail!("row_ptr endpoints invalid");
        }
        if col_idx.len() != vals.len() {
            bail!("col_idx/vals length mismatch");
        }
        if row_ptr.windows(2).any(|w| w[0] > w[1]) {
            bail!("row_ptr not monotone");
        }
        if col_idx.iter().any(|&c| c as usize >= n_cols) {
            bail!("column index out of bounds");
        }
        Ok(Csr { n_rows, n_cols, row_ptr, col_idx, vals })
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Degree (stored nonzeros) of row `r`.
    #[inline]
    pub fn degree(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Iterator over `(col, val)` of row `r`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let span = self.row_ptr[r]..self.row_ptr[r + 1];
        self.col_idx[span.clone()].iter().copied().zip(self.vals[span].iter().copied())
    }

    /// Degrees of all rows.
    pub fn degrees(&self) -> Vec<usize> {
        (0..self.n_rows).map(|r| self.degree(r)).collect()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n_rows).map(|r| self.degree(r)).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n_rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows as f64
        }
    }

    /// Sort each row's entries by column and merge duplicates (summing
    /// values). Canonical form for comparisons and deterministic layout.
    pub fn sort_rows_and_merge_dups(&mut self) {
        let mut new_ptr = Vec::with_capacity(self.n_rows + 1);
        let mut new_cols = Vec::with_capacity(self.col_idx.len());
        let mut new_vals = Vec::with_capacity(self.vals.len());
        new_ptr.push(0);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for r in 0..self.n_rows {
            scratch.clear();
            scratch.extend(self.row(r));
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                new_cols.push(c);
                new_vals.push(v);
                i = j;
            }
            new_ptr.push(new_cols.len());
        }
        self.row_ptr = new_ptr;
        self.col_idx = new_cols;
        self.vals = new_vals;
    }

    /// Make the matrix pattern-symmetric: for every stored (r,c) ensure
    /// (c,r) is stored (values averaged on collision). Requires square.
    pub fn symmetrize(&self) -> Csr {
        assert_eq!(self.n_rows, self.n_cols, "symmetrize requires square");
        let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(self.nnz() * 2);
        for r in 0..self.n_rows {
            for (c, v) in self.row(r) {
                edges.push((r as u32, c, v * 0.5));
                edges.push((c, r as u32, v * 0.5));
            }
        }
        Csr::from_edges(self.n_rows, self.n_cols, &edges).expect("valid edges")
    }

    /// GCN normalization: `Â = D^{-1/2} (A + I) D^{-1/2}` where `D` is
    /// the degree matrix of `A + I` (Kipf & Welling). Pattern values are
    /// replaced (the input values are treated as edge indicators).
    pub fn gcn_normalize(&self) -> Csr {
        assert_eq!(self.n_rows, self.n_cols, "gcn_normalize requires square");
        let n = self.n_rows;
        // A + I pattern
        let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(self.nnz() + n);
        for r in 0..n {
            let mut has_self = false;
            for (c, _) in self.row(r) {
                if c as usize == r {
                    has_self = true;
                }
                edges.push((r as u32, c, 1.0));
            }
            if !has_self {
                edges.push((r as u32, r as u32, 1.0));
            }
        }
        let with_self = Csr::from_edges(n, n, &edges).expect("valid edges");
        let deg: Vec<f64> = (0..n).map(|r| with_self.degree(r) as f64).collect();
        let inv_sqrt: Vec<f64> =
            deg.iter().map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 }).collect();
        let mut out = with_self.clone();
        for r in 0..n {
            let span = out.row_ptr[r]..out.row_ptr[r + 1];
            for i in span {
                let c = out.col_idx[i] as usize;
                out.vals[i] = (inv_sqrt[r] * inv_sqrt[c]) as f32;
            }
        }
        out
    }

    /// Dense SpMM reference: `Y = A · X` where `X` is `n_cols × f`
    /// row-major. The numeric ground truth everything else is checked
    /// against.
    pub fn spmm_dense(&self, x: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.n_cols * f, "X shape mismatch");
        let mut y = vec![0f32; self.n_rows * f];
        for r in 0..self.n_rows {
            let yrow = &mut y[r * f..(r + 1) * f];
            for (c, v) in self.row(r) {
                let xrow = &x[c as usize * f..(c as usize + 1) * f];
                for k in 0..f {
                    yrow[k] += v * xrow[k];
                }
            }
        }
        y
    }

    /// Transpose: `out[(c, r)] = self[(r, c)]`, via one counting pass
    /// over the column ids — O(n + nnz), no sort. Because rows are
    /// scanned in ascending order, every output row comes out with its
    /// columns already ascending (and duplicate-free whenever `self` is
    /// canonical), so the result is in canonical form without a
    /// `sort_rows_and_merge_dups` pass.
    ///
    /// This is the backward pass's left operand: `dL/dH = Âᵀ · G`
    /// (see [`crate::train`]).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.col_idx {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts;
        let mut cursor = row_ptr.clone();
        let mut col_idx = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        for r in 0..self.n_rows {
            for (c, v) in self.row(r) {
                let p = cursor[c as usize];
                col_idx[p] = r as u32;
                vals[p] = v;
                cursor[c as usize] += 1;
            }
        }
        Csr { n_rows: self.n_cols, n_cols: self.n_rows, row_ptr, col_idx, vals }
    }

    /// Whether the matrix equals its transpose **bit-for-bit** (same
    /// pattern, same f32 values). Requires canonical form (rows sorted,
    /// duplicates merged — the invariant every constructor maintains).
    /// `Â = D^{-1/2}(A+I)D^{-1/2}` of an undirected graph is symmetric,
    /// which is what lets the training path reuse the forward plan for
    /// the backward SpMM instead of building a transposed one.
    pub fn is_symmetric(&self) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        let t = self.transpose();
        t.row_ptr == self.row_ptr && t.col_idx == self.col_idx && t.vals == self.vals
    }

    /// Apply a row permutation: `out.row[i] = self.row[perm[i]]`.
    pub fn permute_rows(&self, perm: &[u32]) -> Csr {
        assert_eq!(perm.len(), self.n_rows);
        let mut row_ptr = Vec::with_capacity(self.n_rows + 1);
        let mut col_idx = Vec::with_capacity(self.nnz());
        let mut vals = Vec::with_capacity(self.nnz());
        row_ptr.push(0);
        for &src in perm {
            let span = self.row_ptr[src as usize]..self.row_ptr[src as usize + 1];
            col_idx.extend_from_slice(&self.col_idx[span.clone()]);
            vals.extend_from_slice(&self.vals[span]);
            row_ptr.push(col_idx.len());
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, row_ptr, col_idx, vals }
    }

    /// Symmetric relabeling: node `perm[i]` becomes node `i` — rows are
    /// permuted by `perm` and column ids are mapped through `inv`
    /// (`inv[perm[i]] == i`). For a degree-sorted permutation this puts
    /// both the row and column space of `P·A·Pᵀ` in the sorted domain,
    /// so GCN layers can chain without per-layer unpermutes.
    pub fn relabel(&self, perm: &[u32], inv: &[u32]) -> Csr {
        assert_eq!(self.n_rows, self.n_cols, "relabel requires square");
        assert_eq!(perm.len(), self.n_rows);
        let mut out = self.permute_rows(perm);
        for c in out.col_idx.iter_mut() {
            *c = inv[*c as usize];
        }
        out.sort_rows_and_merge_dups();
        out
    }

    /// Density (nnz / (rows*cols)).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // 3x3: row0 = {0:1, 2:2}, row1 = {}, row2 = {1:3}
        Csr::from_edges(3, 3, &[(0, 2, 2.0), (0, 0, 1.0), (2, 1, 3.0)]).unwrap()
    }

    #[test]
    fn from_edges_sorts_columns() {
        let m = small();
        assert_eq!(m.row_ptr, vec![0, 2, 2, 3]);
        assert_eq!(m.col_idx, vec![0, 2, 1]);
        assert_eq!(m.vals, vec![1.0, 2.0, 3.0]);
        assert_eq!(m.degree(0), 2);
        assert_eq!(m.degree(1), 0);
    }

    #[test]
    fn duplicate_edges_merge() {
        let m = Csr::from_edges(2, 2, &[(0, 1, 1.0), (0, 1, 2.5)]).unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.vals, vec![3.5]);
    }

    #[test]
    fn out_of_bounds_edge_rejected() {
        assert!(Csr::from_edges(2, 2, &[(0, 5, 1.0)]).is_err());
        assert!(Csr::from_edges(2, 2, &[(7, 0, 1.0)]).is_err());
    }

    #[test]
    fn from_raw_validates() {
        assert!(Csr::from_raw(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).is_ok());
        assert!(Csr::from_raw(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err());
        assert!(Csr::from_raw(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(Csr::from_raw(1, 1, vec![0, 1], vec![3], vec![1.0]).is_err());
    }

    #[test]
    fn spmm_dense_reference() {
        let m = small();
        // X = I3 scaled by row: X[c] = e_c * (c+1)
        let f = 3;
        let mut x = vec![0f32; 9];
        for c in 0..3 {
            x[c * f + c] = (c + 1) as f32;
        }
        let y = m.spmm_dense(&x, f);
        // row0 = 1*X[0] + 2*X[2] = [1,0,0] + [0,0,6]
        assert_eq!(&y[0..3], &[1.0, 0.0, 6.0]);
        assert_eq!(&y[3..6], &[0.0, 0.0, 0.0]);
        // row2 = 3*X[1] = [0,6,0]
        assert_eq!(&y[6..9], &[0.0, 6.0, 0.0]);
    }

    #[test]
    fn symmetrize_makes_symmetric_pattern() {
        let m = Csr::from_edges(3, 3, &[(0, 1, 1.0), (2, 0, 1.0)]).unwrap();
        let s = m.symmetrize();
        let has = |r: usize, c: u32| s.row(r).any(|(cc, _)| cc == c);
        assert!(has(0, 1) && has(1, 0) && has(2, 0) && has(0, 2));
    }

    #[test]
    fn gcn_normalize_rows_and_selfloops() {
        let m = Csr::from_edges(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let a = m.gcn_normalize();
        // every node: degree 2 after self-loop; all entries 1/2
        assert_eq!(a.nnz(), 4);
        for r in 0..2 {
            for (_, v) in a.row(r) {
                assert!((v - 0.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gcn_normalize_isolated_node() {
        // node 2 is isolated
        let m = Csr::from_edges(3, 3, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let a = m.gcn_normalize();
        // isolated node gets a self loop with weight 1/1
        let row2: Vec<_> = a.row(2).collect();
        assert_eq!(row2, vec![(2, 1.0)]);
    }

    #[test]
    fn permute_rows_moves_data() {
        let m = small();
        let p = m.permute_rows(&[2, 0, 1]);
        assert_eq!(p.degree(0), 1);
        assert_eq!(p.row(0).collect::<Vec<_>>(), vec![(1, 3.0)]);
        assert_eq!(p.degree(1), 2);
        assert_eq!(p.degree(2), 0);
    }

    #[test]
    fn relabel_preserves_spmm_semantics() {
        // (P·A·Pᵀ)·(P·X) == P·(A·X)
        use crate::util::rng::Pcg;
        let mut rng = Pcg::seed_from(41);
        let n = 20;
        let edges: Vec<(u32, u32, f32)> = (0..80)
            .map(|_| (rng.range(0, n) as u32, rng.range(0, n) as u32, rng.f32()))
            .collect();
        let a = Csr::from_edges(n, n, &edges).unwrap();
        let ds = crate::graph::degree::DegreeSorted::new(&a);
        let rel = a.relabel(&ds.perm, &ds.inv);
        let f = 3;
        let x: Vec<f32> = (0..n * f).map(|_| rng.f32()).collect();
        // P·X
        let mut px = vec![0f32; n * f];
        for i in 0..n {
            let src = ds.perm[i] as usize;
            px[i * f..(i + 1) * f].copy_from_slice(&x[src * f..(src + 1) * f]);
        }
        let got = rel.spmm_dense(&px, f);
        let want_full = a.spmm_dense(&x, f);
        for i in 0..n {
            let src = ds.perm[i] as usize;
            for k in 0..f {
                assert!((got[i * f + k] - want_full[src * f + k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn transpose_moves_entries() {
        let m = small();
        let t = m.transpose();
        assert_eq!((t.n_rows, t.n_cols), (3, 3));
        // (0,0,1) -> (0,0,1); (0,2,2) -> (2,0,2); (2,1,3) -> (1,2,3)
        assert_eq!(t.row(0).collect::<Vec<_>>(), vec![(0, 1.0)]);
        assert_eq!(t.row(1).collect::<Vec<_>>(), vec![(2, 3.0)]);
        assert_eq!(t.row(2).collect::<Vec<_>>(), vec![(0, 2.0)]);
    }

    #[test]
    fn transpose_roundtrip_and_canonical() {
        use crate::util::rng::Pcg;
        let mut rng = Pcg::seed_from(91);
        let (n_rows, n_cols) = (17, 23);
        let edges: Vec<(u32, u32, f32)> = (0..120)
            .map(|_| (rng.range(0, n_rows) as u32, rng.range(0, n_cols) as u32, rng.f32() + 0.1))
            .collect();
        let m = Csr::from_edges(n_rows, n_cols, &edges).unwrap();
        let t = m.transpose();
        // canonical: rows sorted, no duplicates
        for r in 0..t.n_rows {
            let cols: Vec<u32> = t.row(r).map(|(c, _)| c).collect();
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {r} not canonical");
        }
        assert_eq!(t.transpose(), m, "double transpose is identity");
    }

    #[test]
    fn transpose_spmm_is_dense_at() {
        use crate::util::rng::Pcg;
        let mut rng = Pcg::seed_from(92);
        let n = 20;
        let edges: Vec<(u32, u32, f32)> = (0..90)
            .map(|_| (rng.range(0, n) as u32, rng.range(0, n) as u32, rng.f32() - 0.5))
            .collect();
        let m = Csr::from_edges(n, n, &edges).unwrap();
        let t = m.transpose();
        let f = 3;
        let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        // Aᵀ·X via the transpose == column-wise accumulation over A
        let got = t.spmm_dense(&x, f);
        let mut want = vec![0f32; n * f];
        for r in 0..n {
            for (c, v) in m.row(r) {
                for k in 0..f {
                    want[c as usize * f + k] += v * x[r * f + k];
                }
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }

    #[test]
    fn is_symmetric_detects() {
        let asym = Csr::from_edges(3, 3, &[(0, 1, 1.0), (2, 0, 1.0)]).unwrap();
        assert!(!asym.is_symmetric());
        assert!(asym.symmetrize().is_symmetric());
        // GCN normalization of a symmetric pattern stays symmetric
        assert!(asym.symmetrize().gcn_normalize().is_symmetric());
        // value asymmetry on a symmetric pattern is caught
        let vals = Csr::from_edges(2, 2, &[(0, 1, 1.0), (1, 0, 2.0)]).unwrap();
        assert!(!vals.is_symmetric());
        // non-square is never symmetric
        let rect = Csr::from_edges(2, 3, &[(0, 2, 1.0)]).unwrap();
        assert!(!rect.is_symmetric());
    }

    #[test]
    fn stats_helpers() {
        let m = small();
        assert_eq!(m.max_degree(), 2);
        assert!((m.avg_degree() - 1.0).abs() < 1e-12);
        assert!((m.density() - 3.0 / 9.0).abs() < 1e-12);
    }
}
