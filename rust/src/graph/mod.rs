//! Graph substrate: containers, generators, datasets, IO, degree sorting.
//!
//! The paper evaluates on 18 benchmark graphs (Table I) whose raw data we
//! cannot download in this environment; [`generator`] synthesizes graphs
//! matched to each dataset's published node/edge counts and family-typical
//! degree distribution, and [`datasets`] carries the Table I specs plus
//! the scaling rule (see DESIGN.md §2).

pub mod csr;
pub mod degree;
pub mod generator;
pub mod datasets;
pub mod io;
pub mod stats;

pub use csr::Csr;
pub use datasets::{DatasetSpec, GraphFamily};
pub use degree::DegreeSorted;
