//! O(n) degree sorting — the paper's first preprocessing stage (§III-C).
//!
//! Steps exactly as the paper describes: (1) compute each row's degree
//! from `row_ptr` (O(n)); (2) **stable** sort rows by degree using count
//! sort (O(n + max_deg)); (3) rebuild the row pointer array for the new
//! order (O(n)). Stability matters: rows of equal degree keep their
//! original relative order, which preserves whatever locality the input
//! ordering had and makes the transform deterministic.

use super::csr::Csr;

/// A degree-sorted view of a CSR matrix: the permuted matrix plus the
/// permutation metadata needed to map results back to original row ids.
#[derive(Clone, Debug)]
pub struct DegreeSorted {
    /// The permuted matrix: row `i` of `csr` is row `perm[i]` of the
    /// original. Rows are in **ascending** degree order, matching the
    /// paper's Fig. 3 (row0, row2, then row1) so that equal-degree rows
    /// are contiguous and long (block-splitting) rows come last.
    pub csr: Csr,
    /// `perm[i]` = original row id of sorted row `i`.
    pub perm: Vec<u32>,
    /// `inv[orig]` = position of original row `orig` in the sorted order.
    pub inv: Vec<u32>,
}

impl DegreeSorted {
    /// Stable count-sort of rows by degree, ascending. O(n + max_deg).
    pub fn new(csr: &Csr) -> DegreeSorted {
        let n = csr.n_rows;
        let max_deg = csr.max_degree();
        // counting pass over degrees
        let mut counts = vec![0usize; max_deg + 2];
        for r in 0..n {
            counts[csr.degree(r)] += 1;
        }
        // prefix sums for ASCENDING degree buckets:
        // start[d] = number of rows with degree < d
        let mut start = vec![0usize; max_deg + 2];
        for d in 1..=max_deg + 1 {
            start[d] = start[d - 1] + counts[d - 1];
        }
        // stable scatter
        let mut perm = vec![0u32; n];
        let mut cursor = start;
        for r in 0..n {
            let d = csr.degree(r);
            perm[cursor[d]] = r as u32;
            cursor[d] += 1;
        }
        let mut inv = vec![0u32; n];
        for (i, &p) in perm.iter().enumerate() {
            inv[p as usize] = i as u32;
        }
        let sorted = csr.permute_rows(&perm);
        DegreeSorted { csr: sorted, perm, inv }
    }

    /// Undo the permutation on a row-major dense result:
    /// `out[perm[i]] = y[i]`.
    pub fn unpermute_rows(&self, y: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(y.len(), self.csr.n_rows * f);
        let mut out = vec![0f32; y.len()];
        for (i, &orig) in self.perm.iter().enumerate() {
            out[orig as usize * f..(orig as usize + 1) * f]
                .copy_from_slice(&y[i * f..(i + 1) * f]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, n: usize, max_deg: usize) -> Csr {
        let mut edges = Vec::new();
        for r in 0..n {
            let d = rng.range(0, max_deg + 1);
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn sorts_ascending() {
        let csr = Csr::from_edges(
            4,
            4,
            &[(1, 0, 1.0), (1, 2, 1.0), (1, 3, 1.0), (3, 0, 1.0), (2, 1, 1.0), (2, 2, 1.0)],
        )
        .unwrap();
        let ds = DegreeSorted::new(&csr);
        let degs: Vec<usize> = (0..4).map(|r| ds.csr.degree(r)).collect();
        assert_eq!(degs, vec![0, 1, 2, 3]);
        assert_eq!(ds.perm, vec![0, 3, 2, 1]);
    }

    #[test]
    fn stable_for_equal_degrees() {
        // rows 0,1,2 all have degree 1 — order must be preserved
        let csr =
            Csr::from_edges(3, 3, &[(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0)]).unwrap();
        let ds = DegreeSorted::new(&csr);
        assert_eq!(ds.perm, vec![0, 1, 2]);
        assert_eq!(ds.csr.vals, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn inv_is_inverse_of_perm() {
        let mut rng = Pcg::seed_from(13);
        let csr = random_csr(&mut rng, 50, 8);
        let ds = DegreeSorted::new(&csr);
        for i in 0..50 {
            assert_eq!(ds.inv[ds.perm[i] as usize] as usize, i);
        }
    }

    #[test]
    fn unpermute_roundtrip() {
        let mut rng = Pcg::seed_from(29);
        let csr = random_csr(&mut rng, 30, 5);
        let ds = DegreeSorted::new(&csr);
        let f = 4;
        // y[i] = constant row = perm[i] so unpermuted out[orig] == orig
        let mut y = vec![0f32; 30 * f];
        for i in 0..30 {
            for k in 0..f {
                y[i * f + k] = ds.perm[i] as f32;
            }
        }
        let out = ds.unpermute_rows(&y, f);
        for orig in 0..30 {
            assert_eq!(out[orig * f], orig as f32);
        }
    }

    #[test]
    fn prop_permutation_valid_and_sorted() {
        proptest::check("degree_sort_valid", 0xD56, 40, |rng| {
            let n = rng.range(1, 120);
            let csr = random_csr(rng, n, 12);
            let ds = DegreeSorted::new(&csr);
            // perm is a permutation
            let mut seen = vec![false; n];
            for &p in &ds.perm {
                assert!(!seen[p as usize], "dup in perm");
                seen[p as usize] = true;
            }
            // ascending degrees
            for i in 1..n {
                assert!(ds.csr.degree(i - 1) <= ds.csr.degree(i));
            }
            // nnz preserved
            assert_eq!(ds.csr.nnz(), csr.nnz());
            // row content preserved
            for i in 0..n {
                let orig = ds.perm[i] as usize;
                assert_eq!(
                    ds.csr.row(i).collect::<Vec<_>>(),
                    csr.row(orig).collect::<Vec<_>>()
                );
            }
        });
    }

    #[test]
    fn prop_spmm_invariant_under_sort() {
        proptest::check("degree_sort_spmm", 0xD57, 20, |rng| {
            let n = rng.range(1, 60);
            let f = rng.range(1, 9);
            let csr = random_csr(rng, n, 6);
            let ds = DegreeSorted::new(&csr);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let y_orig = csr.spmm_dense(&x, f);
            let y_sorted = ds.csr.spmm_dense(&x, f);
            let y_back = ds.unpermute_rows(&y_sorted, f);
            for (a, b) in y_orig.iter().zip(y_back.iter()) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn empty_and_single_row() {
        let empty = Csr::from_edges(0, 0, &[]).unwrap();
        let ds = DegreeSorted::new(&empty);
        assert_eq!(ds.perm.len(), 0);
        let single = Csr::from_edges(1, 1, &[(0, 0, 1.0)]).unwrap();
        let ds = DegreeSorted::new(&single);
        assert_eq!(ds.perm, vec![0]);
    }
}
