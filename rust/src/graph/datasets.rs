//! The paper's 18 benchmark graphs (Table I) as synthetic dataset specs.
//!
//! Each spec records the published node/edge counts and the
//! degree-distribution family used to synthesize a stand-in graph
//! (DESIGN.md §2 documents the substitution). Because the largest graphs
//! (PRODUCTS: 123.7M edges, Reddit: 114.6M) are far beyond what the
//! cycle-level simulator should chew per bench iteration, specs are
//! **scaled** by [`ScalePolicy`]: node and edge counts shrink by a common
//! factor so the average degree — the property the paper's partitioning
//! effects depend on — is preserved. The applied factor is reported next
//! to every measurement in EXPERIMENTS.md.

use super::csr::Csr;
use super::generator::{self, DegreeModel};
use crate::util::rng::Pcg;

/// Qualitative family of a benchmark graph, selecting the degree model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphFamily {
    /// Citation / social / web: power-law tail (Fig. 2 shape).
    PowerLaw,
    /// Dense social aggregation (Reddit, PRODUCTS, PPA): power-law with a
    /// fatter tail and much higher average degree.
    DenseSocial,
    /// Union of small molecules: near-regular degree ≈ 2.
    Molecular,
    /// Co-purchase / RDF: lognormal moderate tail.
    CoPurchase,
}

impl GraphFamily {
    pub fn degree_model(self) -> DegreeModel {
        match self {
            GraphFamily::PowerLaw => DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.02 },
            GraphFamily::DenseSocial => DegreeModel::PowerLaw { alpha: 1.8, dmax_frac: 0.05 },
            GraphFamily::Molecular => DegreeModel::NearRegular { jitter: 0.25 },
            GraphFamily::CoPurchase => DegreeModel::LogNormal { sigma: 0.9 },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GraphFamily::PowerLaw => "power-law",
            GraphFamily::DenseSocial => "dense-social",
            GraphFamily::Molecular => "molecular",
            GraphFamily::CoPurchase => "co-purchase",
        }
    }
}

/// One row of the paper's Table I.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Published node count (Table I).
    pub paper_nodes: usize,
    /// Published edge count (Table I).
    pub paper_edges: usize,
    pub family: GraphFamily,
}

/// Scaling policy: shrink graphs so `nodes ≤ node_cap` and
/// `edges ≤ edge_cap`, preserving average degree.
#[derive(Clone, Copy, Debug)]
pub struct ScalePolicy {
    pub node_cap: usize,
    pub edge_cap: usize,
}

impl Default for ScalePolicy {
    fn default() -> Self {
        // keeps the full fig5/fig6 sweep (18 graphs × 8 coldims × 4
        // kernels) within minutes of simulation on this machine
        ScalePolicy { node_cap: 100_000, edge_cap: 1_500_000 }
    }
}

impl ScalePolicy {
    /// Tiny policy for unit tests.
    pub fn tiny() -> Self {
        ScalePolicy { node_cap: 2_000, edge_cap: 20_000 }
    }

    /// Common scale factor (≤ 1) for a spec.
    pub fn factor(&self, spec: &DatasetSpec) -> f64 {
        let fn_ = self.node_cap as f64 / spec.paper_nodes as f64;
        let fe = self.edge_cap as f64 / spec.paper_edges as f64;
        fn_.min(fe).min(1.0)
    }

    /// Scaled (nodes, edges) for a spec.
    pub fn scaled(&self, spec: &DatasetSpec) -> (usize, usize) {
        let f = self.factor(spec);
        let n = ((spec.paper_nodes as f64 * f) as usize).max(16);
        let e = ((spec.paper_edges as f64 * f) as usize).max(n);
        (n, e)
    }
}

/// Table I, verbatim counts.
pub const TABLE1: &[DatasetSpec] = &[
    DatasetSpec { name: "am", paper_nodes: 881_680, paper_edges: 5_668_682, family: GraphFamily::CoPurchase },
    DatasetSpec { name: "amazon0601", paper_nodes: 403_394, paper_edges: 5_478_357, family: GraphFamily::CoPurchase },
    DatasetSpec { name: "artist", paper_nodes: 50_515, paper_edges: 1_638_396, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "arxiv", paper_nodes: 169_343, paper_edges: 1_166_243, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "citation", paper_nodes: 2_927_963, paper_edges: 30_387_995, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "collab", paper_nodes: 235_868, paper_edges: 2_358_104, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "com-amazon", paper_nodes: 334_863, paper_edges: 1_851_744, family: GraphFamily::CoPurchase },
    DatasetSpec { name: "ovcar-8h", paper_nodes: 1_889_542, paper_edges: 3_946_402, family: GraphFamily::Molecular },
    DatasetSpec { name: "products", paper_nodes: 2_449_029, paper_edges: 123_718_280, family: GraphFamily::DenseSocial },
    DatasetSpec { name: "pubmed", paper_nodes: 19_717, paper_edges: 99_203, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "ppa", paper_nodes: 576_289, paper_edges: 42_463_862, family: GraphFamily::DenseSocial },
    DatasetSpec { name: "reddit", paper_nodes: 232_965, paper_edges: 114_615_891, family: GraphFamily::DenseSocial },
    DatasetSpec { name: "sw-620h", paper_nodes: 1_888_584, paper_edges: 3_944_206, family: GraphFamily::Molecular },
    DatasetSpec { name: "twitter-partial", paper_nodes: 580_768, paper_edges: 1_435_116, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "wikikg2", paper_nodes: 2_500_604, paper_edges: 16_109_182, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "yelp", paper_nodes: 716_847, paper_edges: 13_954_819, family: GraphFamily::PowerLaw },
    DatasetSpec { name: "yeast", paper_nodes: 1_710_902, paper_edges: 3_636_546, family: GraphFamily::Molecular },
    DatasetSpec { name: "youtube", paper_nodes: 1_138_499, paper_edges: 5_980_886, family: GraphFamily::PowerLaw },
];

/// Look up a Table I spec by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
    let lower = name.to_ascii_lowercase();
    TABLE1.iter().find(|s| s.name == lower)
}

/// Names of all 18 graphs, Table I order.
pub fn all_names() -> Vec<&'static str> {
    TABLE1.iter().map(|s| s.name).collect()
}

/// Materialize a dataset: synthesize the scaled graph deterministically
/// from `(spec.name, seed)`.
pub fn materialize(spec: &DatasetSpec, policy: ScalePolicy, seed: u64) -> Csr {
    let (n, e) = policy.scaled(spec);
    // fold the name into the stream so each dataset gets its own sequence
    let stream = spec.name.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
    let mut rng = Pcg::new(seed, stream);
    let degs = generator::degree_sequence(spec.family.degree_model(), n, e, &mut rng);
    generator::from_degree_sequence(n, &degs, &mut rng)
}

/// A labeled graph ready for native training ([`crate::train`]):
/// topology + node features + class labels + disjoint 60/20/20
/// train/val/test masks. Everything is deterministic in the seed.
#[derive(Clone, Debug)]
pub struct LabeledDataset {
    pub csr: Csr,
    /// Row-major `n × feat_dim`.
    pub features: Vec<f32>,
    pub feat_dim: usize,
    /// One class id per node, `< n_classes`.
    pub labels: Vec<u32>,
    pub n_classes: usize,
    /// Disjoint boolean masks covering every node: ~60% / 20% / 20%.
    pub train_mask: Vec<bool>,
    pub val_mask: Vec<bool>,
    pub test_mask: Vec<bool>,
}

impl LabeledDataset {
    pub fn n_nodes(&self) -> usize {
        self.labels.len()
    }

    /// Nodes selected by a mask.
    pub fn mask_count(mask: &[bool]) -> usize {
        mask.iter().filter(|&&m| m).count()
    }
}

/// Split `n` nodes 60/20/20 by a seeded shuffle. Train gets the
/// rounding slack; val and test each get `n/5` (so all three are
/// non-empty for `n ≥ 5`, asserted).
fn split_masks(n: usize, rng: &mut Pcg) -> (Vec<bool>, Vec<bool>, Vec<bool>) {
    assert!(n >= 5, "need ≥ 5 nodes for a 60/20/20 split, got {n}");
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let n_val = n / 5;
    let n_test = n / 5;
    let (mut train, mut val, mut test) = (vec![false; n], vec![false; n], vec![false; n]);
    for (i, &v) in order.iter().enumerate() {
        if i < n_val {
            val[v] = true;
        } else if i < n_val + n_test {
            test[v] = true;
        } else {
            train[v] = true;
        }
    }
    (train, val, test)
}

/// Planted-partition labeled graph for native training: a homophilous
/// community graph (`generator::labeled_communities`) with 60/20/20
/// masks. `homophily` is the probability an edge endpoint is drawn from
/// the same class; `feat_dim` defaults to `max(8, 2·classes)` — use
/// [`labeled_synthetic_with`] to control it and the average degree.
pub fn labeled_synthetic(n: usize, classes: usize, homophily: f64, seed: u64) -> LabeledDataset {
    labeled_synthetic_with(n, classes, (2 * classes).max(8), 6.0, homophily, seed)
}

/// [`labeled_synthetic`] with explicit feature dimension and average
/// degree.
pub fn labeled_synthetic_with(
    n: usize,
    classes: usize,
    feat_dim: usize,
    avg_deg: f64,
    homophily: f64,
    seed: u64,
) -> LabeledDataset {
    assert!(classes >= 2, "need ≥ 2 classes");
    assert!((0.0..=1.0).contains(&homophily), "homophily must be in [0,1]");
    let mut rng = Pcg::new(seed, 0x1abe1);
    let g = generator::labeled_communities(n, avg_deg, feat_dim, classes, homophily, &mut rng);
    let (train_mask, val_mask, test_mask) = split_masks(n, &mut rng);
    LabeledDataset {
        csr: g.csr,
        features: g.features,
        feat_dim: g.feat_dim,
        labels: g.labels,
        n_classes: g.n_classes,
        train_mask,
        val_mask,
        test_mask,
    }
}

/// Plant labels *onto an existing topology* (e.g. a loaded edge list,
/// which carries no labels): random seed labels smoothed by a few
/// rounds of deterministic majority-vote propagation so labels are
/// locally consistent — learnable by a GCN — then centroid features and
/// 60/20/20 masks as in [`labeled_synthetic`].
pub fn labeled_from_topology(csr: &Csr, classes: usize, feat_dim: usize, seed: u64) -> LabeledDataset {
    assert_eq!(csr.n_rows, csr.n_cols, "labeling needs a square adjacency");
    assert!(classes >= 2, "need ≥ 2 classes");
    let n = csr.n_rows;
    let mut rng = Pcg::new(seed, 0x70b0);
    let mut labels: Vec<u32> = (0..n).map(|_| rng.range(0, classes) as u32).collect();
    // majority-vote label propagation; ties keep the current label
    // (deterministic), isolated nodes keep their seed label
    for _round in 0..3 {
        let mut next = labels.clone();
        let mut votes = vec![0usize; classes];
        for v in 0..n {
            votes.iter_mut().for_each(|c| *c = 0);
            for (u, _) in csr.row(v) {
                votes[labels[u as usize] as usize] += 1;
            }
            let cur = labels[v] as usize;
            let best = (0..classes).max_by_key(|&c| (votes[c], usize::from(c == cur))).unwrap();
            if votes[best] > votes[cur] {
                next[v] = best as u32;
            }
        }
        labels = next;
    }
    let features = generator::centroid_features(&labels, classes, feat_dim, &mut rng);
    let (train_mask, val_mask, test_mask) = split_masks(n, &mut rng);
    LabeledDataset {
        csr: csr.clone(),
        features,
        feat_dim,
        labels,
        n_classes: classes,
        train_mask,
        val_mask,
        test_mask,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eighteen_graphs() {
        assert_eq!(TABLE1.len(), 18);
        // paper ranges: nodes 19,717..=2,927,963, edges 99,203..=123,718,280
        let min_nodes = TABLE1.iter().map(|s| s.paper_nodes).min().unwrap();
        let max_nodes = TABLE1.iter().map(|s| s.paper_nodes).max().unwrap();
        let max_edges = TABLE1.iter().map(|s| s.paper_edges).max().unwrap();
        assert_eq!(min_nodes, 19_717);
        assert_eq!(max_nodes, 2_927_963);
        assert_eq!(max_edges, 123_718_280);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("Collab").unwrap().paper_nodes, 235_868);
        assert_eq!(by_name("REDDIT").unwrap().paper_edges, 114_615_891);
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn scaling_preserves_avg_degree() {
        let policy = ScalePolicy::default();
        for spec in TABLE1 {
            let (n, e) = policy.scaled(spec);
            assert!(n <= policy.node_cap + 16);
            assert!(e <= policy.edge_cap.max(n) + 16);
            let paper_avg = spec.paper_edges as f64 / spec.paper_nodes as f64;
            let scaled_avg = e as f64 / n as f64;
            let rel = (scaled_avg - paper_avg).abs() / paper_avg;
            assert!(rel < 0.05, "{}: paper_avg={paper_avg:.1} scaled_avg={scaled_avg:.1}", spec.name);
        }
    }

    #[test]
    fn pubmed_not_scaled() {
        // pubmed fits under the caps: factor must be 1
        let policy = ScalePolicy::default();
        let spec = by_name("pubmed").unwrap();
        assert_eq!(policy.factor(spec), 1.0);
        let (n, e) = policy.scaled(spec);
        assert_eq!(n, 19_717);
        assert_eq!(e, 99_203);
    }

    #[test]
    fn materialize_deterministic_and_sized() {
        let policy = ScalePolicy::tiny();
        let spec = by_name("collab").unwrap();
        let a = materialize(spec, policy, 42);
        let b = materialize(spec, policy, 42);
        assert_eq!(a, b);
        let c = materialize(spec, policy, 43);
        assert_ne!(a, c);
        let (n, _) = policy.scaled(spec);
        assert_eq!(a.n_rows, n);
    }

    fn assert_split_invariants(d: &LabeledDataset) {
        let n = d.n_nodes();
        // masks are disjoint and cover every node exactly once
        for v in 0..n {
            let picks =
                usize::from(d.train_mask[v]) + usize::from(d.val_mask[v]) + usize::from(d.test_mask[v]);
            assert_eq!(picks, 1, "node {v} must be in exactly one split");
        }
        // 60/20/20 within integer rounding
        let (tr, va, te) = (
            LabeledDataset::mask_count(&d.train_mask),
            LabeledDataset::mask_count(&d.val_mask),
            LabeledDataset::mask_count(&d.test_mask),
        );
        assert_eq!(tr + va + te, n);
        assert_eq!(va, n / 5);
        assert_eq!(te, n / 5);
        assert!(tr >= va && tr >= te, "train must be the largest split");
        // labels in range, features shaped
        assert!(d.labels.iter().all(|&l| (l as usize) < d.n_classes));
        assert_eq!(d.features.len(), n * d.feat_dim);
        assert_eq!(d.csr.n_rows, n);
    }

    #[test]
    fn labeled_synthetic_invariants() {
        let d = labeled_synthetic(200, 4, 0.85, 7);
        assert_split_invariants(&d);
        assert_eq!(d.n_classes, 4);
        assert_eq!(d.feat_dim, 8);
        // every class present at this size
        for c in 0..4u32 {
            assert!(d.labels.contains(&c), "class {c} missing");
        }
        // homophily carried through: most edges intra-class
        let (mut intra, mut total) = (0usize, 0usize);
        for r in 0..d.n_nodes() {
            for (c, _) in d.csr.row(r) {
                total += 1;
                intra += usize::from(d.labels[r] == d.labels[c as usize]);
            }
        }
        assert!(intra as f64 > 0.6 * total as f64, "intra={intra}/{total}");
        // deterministic in the seed
        let d2 = labeled_synthetic(200, 4, 0.85, 7);
        assert_eq!(d.labels, d2.labels);
        assert_eq!(d.train_mask, d2.train_mask);
        assert_ne!(labeled_synthetic(200, 4, 0.85, 8).labels, d.labels);
    }

    #[test]
    fn labeled_from_topology_invariants() {
        use crate::graph::generator::{degree_sequence, from_degree_sequence, DegreeModel};
        let mut rng = Pcg::seed_from(11);
        let n = 150;
        let degs =
            degree_sequence(DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.1 }, n, n * 6, &mut rng);
        let csr = from_degree_sequence(n, &degs, &mut rng);
        let d = labeled_from_topology(&csr, 3, 12, 5);
        assert_split_invariants(&d);
        assert_eq!(d.feat_dim, 12);
        // propagation makes labels locally consistent: strictly more
        // intra-class edges than a uniform assignment would give
        let (mut intra, mut total) = (0usize, 0usize);
        for r in 0..n {
            for (c, _) in d.csr.row(r) {
                total += 1;
                intra += usize::from(d.labels[r] == d.labels[c as usize]);
            }
        }
        assert!(
            intra as f64 > 1.1 * total as f64 / 3.0,
            "propagated labels not locally consistent: {intra}/{total}"
        );
    }

    #[test]
    fn families_produce_expected_shapes() {
        let policy = ScalePolicy::tiny();
        let collab = materialize(by_name("collab").unwrap(), policy, 1);
        let yeast = materialize(by_name("yeast").unwrap(), policy, 1);
        // power-law: max degree many times average (Fig. 2: 66x for Collab)
        assert!(
            collab.max_degree() as f64 > 8.0 * collab.avg_degree(),
            "collab max={} avg={}",
            collab.max_degree(),
            collab.avg_degree()
        );
        // molecular: max degree close to average
        assert!(
            (yeast.max_degree() as f64) < 6.0 * yeast.avg_degree().max(1.0),
            "yeast max={} avg={}",
            yeast.max_degree(),
            yeast.avg_degree()
        );
    }
}
