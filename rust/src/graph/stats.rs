//! Graph statistics: degree histograms (paper Fig. 2) and the imbalance
//! metrics the paper's motivation section cites.

use super::csr::Csr;
use crate::util::stats::{Log2Histogram, OnlineStats};

/// Summary statistics for one graph.
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub n_rows: usize,
    pub nnz: usize,
    pub avg_degree: f64,
    pub max_degree: usize,
    /// max/avg — Fig. 2 notes "up to 66 times greater than the average"
    /// for Collab.
    pub max_over_avg: f64,
    /// coefficient of variation of the degree distribution — the
    /// first-order driver of warp-level workload imbalance.
    pub degree_cv: f64,
    pub density: f64,
    pub empty_rows: usize,
}

pub fn graph_stats(csr: &Csr) -> GraphStats {
    let mut stats = OnlineStats::new();
    let mut empty = 0usize;
    for r in 0..csr.n_rows {
        let d = csr.degree(r);
        if d == 0 {
            empty += 1;
        }
        stats.push(d as f64);
    }
    let avg = csr.avg_degree();
    GraphStats {
        n_rows: csr.n_rows,
        nnz: csr.nnz(),
        avg_degree: avg,
        max_degree: csr.max_degree(),
        max_over_avg: if avg > 0.0 { csr.max_degree() as f64 / avg } else { 0.0 },
        degree_cv: stats.cv(),
        density: csr.density(),
        empty_rows: empty,
    }
}

/// Row-degree histogram with power-of-two buckets (Fig. 2).
pub fn degree_histogram(csr: &Csr) -> Log2Histogram {
    let mut h = Log2Histogram::new();
    for r in 0..csr.n_rows {
        h.push(csr.degree(r) as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::{by_name, materialize, ScalePolicy};

    #[test]
    fn stats_basic() {
        let csr = Csr::from_edges(
            4,
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0), (1, 0, 1.0)],
        )
        .unwrap();
        let s = graph_stats(&csr);
        assert_eq!(s.nnz, 4);
        assert_eq!(s.max_degree, 3);
        assert_eq!(s.empty_rows, 2);
        assert!((s.avg_degree - 1.0).abs() < 1e-12);
        assert!((s.max_over_avg - 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_rows() {
        let csr = Csr::from_edges(3, 3, &[(0, 0, 1.0), (1, 0, 1.0), (1, 1, 1.0)]).unwrap();
        let h = degree_histogram(&csr);
        assert_eq!(h.zeros, 1);
        assert_eq!(h.counts[0], 1); // deg 1
        assert_eq!(h.counts[1], 1); // deg 2
    }

    #[test]
    fn collab_shows_fig2_imbalance() {
        // Fig. 2 motivation: Collab max degree many times the average.
        let spec = by_name("collab").unwrap();
        let g = materialize(spec, ScalePolicy::tiny(), 7);
        let s = graph_stats(&g);
        assert!(s.max_over_avg > 8.0, "max_over_avg={}", s.max_over_avg);
        assert!(s.degree_cv > 0.5, "cv={}", s.degree_cv);
    }
}
