//! Binary graph serialization + npy tensor export.
//!
//! `graph.bin` format (little endian):
//! ```text
//! magic  "AGCN"            4 bytes
//! version u32              (1)
//! n_rows  u64
//! n_cols  u64
//! nnz     u64
//! row_ptr u64 × (n_rows+1)
//! col_idx u32 × nnz
//! vals    f32 × nnz
//! ```
//! Written by `accel-gcn prepare`, consumed by examples and the serving
//! coordinator so graph generation cost is paid once.
//!
//! Also provides a plain-text edge-list loader ([`load_edge_list`],
//! SNAP style) so real-world graph dumps can feed the delta benchmarks
//! and `update-demo` without converting to the binary format first.

use super::csr::Csr;
use anyhow::{bail, Context, Result};
use std::fs;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"AGCN";
const VERSION: u32 = 1;

pub fn save_graph(csr: &Csr, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let f = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(csr.n_rows as u64).to_le_bytes())?;
    w.write_all(&(csr.n_cols as u64).to_le_bytes())?;
    w.write_all(&(csr.nnz() as u64).to_le_bytes())?;
    for &p in &csr.row_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &c in &csr.col_idx {
        w.write_all(&c.to_le_bytes())?;
    }
    for &v in &csr.vals {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

pub fn load_graph(path: impl AsRef<Path>) -> Result<Csr> {
    let path = path.as_ref();
    let f = fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not an AGCN graph file");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{path:?}: unsupported version {version}");
    }
    let n_rows = read_u64(&mut r)? as usize;
    let n_cols = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;

    let mut row_ptr = Vec::with_capacity(n_rows + 1);
    for _ in 0..=n_rows {
        row_ptr.push(read_u64(&mut r)? as usize);
    }
    let mut col_idx = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        col_idx.push(read_u32(&mut r)?);
    }
    let mut vals = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        vals.push(f32::from_le_bytes(b));
    }
    Csr::from_raw(n_rows, n_cols, row_ptr, col_idx, vals)
        .with_context(|| format!("{path:?}: invalid CSR payload"))
}

/// Options for the plain-text edge-list loader.
#[derive(Clone, Copy, Debug)]
pub struct EdgeListOptions {
    /// Treat node ids as 1-based (many published edge lists are);
    /// every id is shifted down by one and id 0 is rejected.
    pub one_based: bool,
    /// Weight assigned to 2-column lines.
    pub default_weight: f32,
    /// Node count override. `None` infers `max id + 1` — pass a value
    /// when trailing isolated nodes matter.
    pub n_nodes: Option<usize>,
}

impl Default for EdgeListOptions {
    fn default() -> EdgeListOptions {
        EdgeListOptions { one_based: false, default_weight: 1.0, n_nodes: None }
    }
}

/// Parse a SNAP-style edge list: one `src dst [weight]` per line,
/// whitespace-separated, `#` comment lines and blank lines ignored.
/// Duplicate edges sum their weights (the [`Csr::from_edges`]
/// convention). The result is a square `n × n` matrix.
pub fn parse_edge_list(text: &str, opts: EdgeListOptions) -> Result<Csr> {
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    let mut max_id = 0u64;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (src, dst) = match (it.next(), it.next()) {
            (Some(s), Some(d)) => (s, d),
            _ => bail!("line {}: expected `src dst [weight]`, got {raw:?}", lineno + 1),
        };
        let weight = match it.next() {
            Some(w) => w
                .parse::<f32>()
                .with_context(|| format!("line {}: bad weight {w:?}", lineno + 1))?,
            None => opts.default_weight,
        };
        if let Some(extra) = it.next() {
            bail!("line {}: trailing token {extra:?}", lineno + 1);
        }
        let parse_id = |tok: &str| -> Result<u64> {
            let id = tok
                .parse::<u64>()
                .with_context(|| format!("line {}: bad node id {tok:?}", lineno + 1))?;
            if opts.one_based {
                if id == 0 {
                    bail!("line {}: id 0 in a 1-based edge list", lineno + 1);
                }
                Ok(id - 1)
            } else {
                Ok(id)
            }
        };
        let (s, d) = (parse_id(src)?, parse_id(dst)?);
        if s > u32::MAX as u64 || d > u32::MAX as u64 {
            bail!("line {}: node id exceeds u32 range", lineno + 1);
        }
        max_id = max_id.max(s).max(d);
        edges.push((s as u32, d as u32, weight));
    }
    let inferred = if edges.is_empty() { 0 } else { max_id as usize + 1 };
    let n = match opts.n_nodes {
        Some(n) => {
            if n < inferred {
                bail!("--n-nodes {n} smaller than max node id + 1 ({inferred})");
            }
            n
        }
        None => inferred,
    };
    Csr::from_edges(n, n, &edges)
}

/// [`parse_edge_list`] from a file.
pub fn load_edge_list(path: impl AsRef<Path>, opts: EdgeListOptions) -> Result<Csr> {
    let path = path.as_ref();
    let text = fs::read_to_string(path).with_context(|| format!("open {path:?}"))?;
    parse_edge_list(&text, opts).with_context(|| format!("parse edge list {path:?}"))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("accel_gcn_io_test");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg::seed_from(77);
        let edges: Vec<(u32, u32, f32)> =
            (0..500).map(|_| (rng.range(0, 64) as u32, rng.range(0, 64) as u32, rng.f32())).collect();
        let csr = Csr::from_edges(64, 64, &edges).unwrap();
        let path = tmpfile("roundtrip.bin");
        save_graph(&csr, &path).unwrap();
        let back = load_graph(&path).unwrap();
        assert_eq!(csr, back);
    }

    #[test]
    fn empty_graph_roundtrip() {
        let csr = Csr::from_edges(5, 5, &[]).unwrap();
        let path = tmpfile("empty.bin");
        save_graph(&csr, &path).unwrap();
        assert_eq!(load_graph(&path).unwrap(), csr);
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmpfile("bad.bin");
        fs::write(&path, b"NOPExxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(load_graph(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let csr = Csr::from_edges(4, 4, &[(0, 1, 1.0), (2, 3, 2.0)]).unwrap();
        let path = tmpfile("trunc.bin");
        save_graph(&csr, &path).unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_graph(&path).is_err());
    }

    #[test]
    fn edge_list_basic_with_comments_and_weights() {
        let text = "\
# SNAP-style comment
# src dst
0 1
1 2 0.5

2 0 2.0
";
        let csr = parse_edge_list(text, EdgeListOptions::default()).unwrap();
        assert_eq!(csr.n_rows, 3);
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.row(0).collect::<Vec<_>>(), vec![(1, 1.0)]);
        assert_eq!(csr.row(1).collect::<Vec<_>>(), vec![(2, 0.5)]);
        assert_eq!(csr.row(2).collect::<Vec<_>>(), vec![(0, 2.0)]);
    }

    #[test]
    fn edge_list_one_based_ids() {
        let opts = EdgeListOptions { one_based: true, ..EdgeListOptions::default() };
        let csr = parse_edge_list("1 2\n3 1\n", opts).unwrap();
        assert_eq!(csr.n_rows, 3);
        assert_eq!(csr.row(0).collect::<Vec<_>>(), vec![(1, 1.0)]);
        assert_eq!(csr.row(2).collect::<Vec<_>>(), vec![(0, 1.0)]);
        // id 0 is illegal in 1-based mode
        assert!(parse_edge_list("0 1\n", opts).is_err());
    }

    #[test]
    fn edge_list_duplicates_sum() {
        let csr = parse_edge_list("0 1 1.0\n0 1 2.5\n", EdgeListOptions::default()).unwrap();
        assert_eq!(csr.nnz(), 1);
        assert_eq!(csr.row(0).collect::<Vec<_>>(), vec![(1, 3.5)]);
    }

    #[test]
    fn edge_list_node_count_override() {
        let opts = EdgeListOptions { n_nodes: Some(10), ..EdgeListOptions::default() };
        let csr = parse_edge_list("0 1\n", opts).unwrap();
        assert_eq!(csr.n_rows, 10, "trailing isolated nodes preserved");
        // override below max id + 1 is an error
        let tight = EdgeListOptions { n_nodes: Some(1), ..EdgeListOptions::default() };
        assert!(parse_edge_list("0 1\n", tight).is_err());
    }

    #[test]
    fn edge_list_malformed_lines_error_with_lineno() {
        let e = parse_edge_list("0 1\nnot-a-line\n", EdgeListOptions::default()).unwrap_err();
        assert!(format!("{e:#}").contains("line 2"), "{e:#}");
        let e = parse_edge_list("0 1 2.0 extra\n", EdgeListOptions::default()).unwrap_err();
        assert!(format!("{e:#}").contains("trailing"), "{e:#}");
        let e = parse_edge_list("0 x\n", EdgeListOptions::default()).unwrap_err();
        assert!(format!("{e:#}").contains("bad node id"), "{e:#}");
        let e = parse_edge_list("0 1 nope\n", EdgeListOptions::default()).unwrap_err();
        assert!(format!("{e:#}").contains("bad weight"), "{e:#}");
    }

    #[test]
    fn edge_list_empty_and_file_roundtrip() {
        let empty = parse_edge_list("# nothing\n", EdgeListOptions::default()).unwrap();
        assert_eq!(empty.n_rows, 0);
        let path = tmpfile("edges.txt");
        fs::write(&path, "0 1\n1 0\n").unwrap();
        let csr = load_edge_list(&path, EdgeListOptions::default()).unwrap();
        assert_eq!(csr.nnz(), 2);
        assert!(load_edge_list(tmpfile("missing.txt"), EdgeListOptions::default()).is_err());
    }
}
