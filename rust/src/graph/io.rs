//! Binary graph serialization + npy tensor export.
//!
//! `graph.bin` format (little endian):
//! ```text
//! magic  "AGCN"            4 bytes
//! version u32              (1)
//! n_rows  u64
//! n_cols  u64
//! nnz     u64
//! row_ptr u64 × (n_rows+1)
//! col_idx u32 × nnz
//! vals    f32 × nnz
//! ```
//! Written by `accel-gcn prepare`, consumed by examples and the serving
//! coordinator so graph generation cost is paid once.

use super::csr::Csr;
use anyhow::{bail, Context, Result};
use std::fs;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"AGCN";
const VERSION: u32 = 1;

pub fn save_graph(csr: &Csr, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let f = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(csr.n_rows as u64).to_le_bytes())?;
    w.write_all(&(csr.n_cols as u64).to_le_bytes())?;
    w.write_all(&(csr.nnz() as u64).to_le_bytes())?;
    for &p in &csr.row_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &c in &csr.col_idx {
        w.write_all(&c.to_le_bytes())?;
    }
    for &v in &csr.vals {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

pub fn load_graph(path: impl AsRef<Path>) -> Result<Csr> {
    let path = path.as_ref();
    let f = fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not an AGCN graph file");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{path:?}: unsupported version {version}");
    }
    let n_rows = read_u64(&mut r)? as usize;
    let n_cols = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;

    let mut row_ptr = Vec::with_capacity(n_rows + 1);
    for _ in 0..=n_rows {
        row_ptr.push(read_u64(&mut r)? as usize);
    }
    let mut col_idx = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        col_idx.push(read_u32(&mut r)?);
    }
    let mut vals = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        vals.push(f32::from_le_bytes(b));
    }
    Csr::from_raw(n_rows, n_cols, row_ptr, col_idx, vals)
        .with_context(|| format!("{path:?}: invalid CSR payload"))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("accel_gcn_io_test");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg::seed_from(77);
        let edges: Vec<(u32, u32, f32)> =
            (0..500).map(|_| (rng.range(0, 64) as u32, rng.range(0, 64) as u32, rng.f32())).collect();
        let csr = Csr::from_edges(64, 64, &edges).unwrap();
        let path = tmpfile("roundtrip.bin");
        save_graph(&csr, &path).unwrap();
        let back = load_graph(&path).unwrap();
        assert_eq!(csr, back);
    }

    #[test]
    fn empty_graph_roundtrip() {
        let csr = Csr::from_edges(5, 5, &[]).unwrap();
        let path = tmpfile("empty.bin");
        save_graph(&csr, &path).unwrap();
        assert_eq!(load_graph(&path).unwrap(), csr);
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmpfile("bad.bin");
        fs::write(&path, b"NOPExxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(load_graph(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let csr = Csr::from_edges(4, 4, &[(0, 1, 1.0), (2, 3, 2.0)]).unwrap();
        let path = tmpfile("trunc.bin");
        save_graph(&csr, &path).unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_graph(&path).is_err());
    }
}
