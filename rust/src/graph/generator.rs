//! Synthetic graph generators matched to the paper's dataset families.
//!
//! The paper's 18 graphs (Table I) come from OGB / SNAP / Network
//! Repository / TU molecular collections. Raw downloads are unavailable
//! here, so each dataset is synthesized to match its published node
//! count, edge count, and the degree-distribution *family* that drives
//! the paper's effects (power-law imbalance for social/citation/web
//! graphs; near-regular low degree for molecular graph unions; very dense
//! heavy tails for Reddit/PRODUCTS). See DESIGN.md §2 for why this
//! substitution preserves the relevant behaviour.
//!
//! All generators are deterministic in `(spec, seed)` and O(edges).

use super::csr::Csr;
use crate::util::rng::Pcg;

/// Degree-distribution family for a synthetic graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DegreeModel {
    /// Power-law with exponent `alpha` and max degree `dmax_frac * n`:
    /// the social/citation/web shape of Fig. 2 (Collab's max degree is
    /// ~66× its average).
    PowerLaw { alpha: f64, dmax_frac: f64 },
    /// Near-regular: degree = avg ± jitter, clipped at 1 — the shape of
    /// molecular dataset unions (OVCAR-8H, SW-620H, Yeast) where each
    /// component is a small molecule with degree ~2.
    NearRegular { jitter: f64 },
    /// Lognormal degrees (σ in log space) — moderate-tail e-commerce /
    /// co-purchase shape (amazon0601, com-amazon, am).
    LogNormal { sigma: f64 },
}

/// Draw a degree sequence with the given model, scaled so the sum is
/// (approximately, then exactly) `target_edges`.
pub fn degree_sequence(
    model: DegreeModel,
    n: usize,
    target_edges: usize,
    rng: &mut Pcg,
) -> Vec<usize> {
    assert!(n > 0);
    let avg = target_edges as f64 / n as f64;
    let mut degs: Vec<f64> = match model {
        DegreeModel::PowerLaw { alpha, dmax_frac } => {
            let dmax = (dmax_frac * n as f64).max(8.0);
            (0..n).map(|_| rng.power_law(alpha, 1.0, dmax)).collect()
        }
        DegreeModel::NearRegular { jitter } => {
            (0..n).map(|_| (avg + rng.normal() * jitter * avg).max(1.0)).collect()
        }
        DegreeModel::LogNormal { sigma } => {
            (0..n).map(|_| (rng.normal() * sigma).exp()).collect()
        }
    };
    // rescale to hit the edge target, then integerize with stochastic
    // rounding and exact repair.
    let sum: f64 = degs.iter().sum();
    let scale = target_edges as f64 / sum;
    let mut idegs: Vec<usize> = degs
        .iter_mut()
        .map(|d| {
            let x = *d * scale;
            let base = x.floor();
            let frac = x - base;
            (base as usize) + usize::from(rng.f64() < frac)
        })
        .collect();
    // exact repair: adjust random rows until the sum matches
    let mut total: isize = idegs.iter().sum::<usize>() as isize;
    let target = target_edges as isize;
    while total < target {
        let i = rng.range(0, n);
        idegs[i] += 1;
        total += 1;
    }
    while total > target {
        let i = rng.range(0, n);
        if idegs[i] > 0 {
            idegs[i] -= 1;
            total -= 1;
        }
    }
    idegs
}

/// Build a graph from a degree sequence using a Chung-Lu-style stub
/// pairing: endpoints are drawn proportional to degree, giving the
/// degree sequence in expectation on the column side while the row side
/// is exact. Self-loops are allowed (they are what GCN adds anyway);
/// duplicate edges merge in CSR construction, so realized nnz can be
/// slightly below target on dense graphs — `pad_to_target` tops the
/// count back up.
pub fn from_degree_sequence(n: usize, degs: &[usize], rng: &mut Pcg) -> Csr {
    assert_eq!(degs.len(), n);
    let nnz: usize = degs.iter().sum();
    // alias-free endpoint sampling: cumulative stub table
    // (sampling a uniform stub = sampling endpoint ∝ degree)
    let mut stubs: Vec<u32> = Vec::with_capacity(nnz);
    for (v, &d) in degs.iter().enumerate() {
        stubs.extend(std::iter::repeat(v as u32).take(d));
    }
    let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(nnz);
    for (r, &d) in degs.iter().enumerate() {
        for _ in 0..d {
            let c = if stubs.is_empty() {
                rng.range(0, n) as u32
            } else {
                *rng.choose(&stubs)
            };
            edges.push((r as u32, c, 1.0));
        }
    }
    let mut csr = Csr::from_edges(n, n, &edges).expect("valid generated edges");
    pad_to_target(&mut csr, nnz, rng);
    csr
}

/// Top up nnz to `target` by inserting random non-duplicate edges
/// (biased toward high-degree rows to preserve shape).
fn pad_to_target(csr: &mut Csr, target: usize, rng: &mut Pcg) {
    let n = csr.n_rows;
    if n == 0 {
        return;
    }
    let mut extra: Vec<(u32, u32, f32)> = Vec::new();
    let mut have = csr.nnz();
    let mut attempts = 0usize;
    let max_attempts = (target - have) * 20 + 100;
    while have < target && attempts < max_attempts {
        attempts += 1;
        let r = rng.range(0, n);
        let c = rng.range(0, n) as u32;
        if csr.row(r).any(|(cc, _)| cc == c) {
            continue;
        }
        extra.push((r as u32, c, 1.0));
        have += 1;
    }
    if !extra.is_empty() {
        let mut edges: Vec<(u32, u32, f32)> = extra;
        for r in 0..n {
            for (c, v) in csr.row(r) {
                edges.push((r as u32, c, v));
            }
        }
        *csr = Csr::from_edges(n, n, &edges).expect("valid edges");
    }
}

/// RMAT (Kronecker) generator — alternative heavy-tail model with
/// community structure; used by the `--generator rmat` CLI option and by
/// tests as a structurally different source of power-law graphs.
pub fn rmat(
    scale: u32,
    edges: usize,
    (a, b, c): (f64, f64, f64),
    rng: &mut Pcg,
) -> Csr {
    let n = 1usize << scale;
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0, "rmat probabilities sum > 1");
    let mut list = Vec::with_capacity(edges);
    for _ in 0..edges {
        let (mut r, mut cc) = (0usize, 0usize);
        for level in (0..scale).rev() {
            let p = rng.f64();
            let (dr, dc) = if p < a {
                (0, 0)
            } else if p < a + b {
                (0, 1)
            } else if p < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            r |= dr << level;
            cc |= dc << level;
        }
        list.push((r as u32, cc as u32, 1.0));
    }
    Csr::from_edges(n, n, &list).expect("valid rmat edges")
}

/// A small synthetic "citation network" with features and labels, used by
/// the end-to-end GCN training example: power-law graph + planted
/// community structure so a GCN can actually learn (features correlate
/// with the label of a node's community).
pub struct LabeledGraph {
    pub csr: Csr,
    /// row-major `n × feat_dim`
    pub features: Vec<f32>,
    pub feat_dim: usize,
    pub labels: Vec<u32>,
    pub n_classes: usize,
}

pub fn labeled_communities(
    n: usize,
    avg_degree: f64,
    feat_dim: usize,
    n_classes: usize,
    homophily: f64,
    rng: &mut Pcg,
) -> LabeledGraph {
    let labels: Vec<u32> = (0..n).map(|_| rng.range(0, n_classes) as u32).collect();
    let target_edges = (n as f64 * avg_degree) as usize;
    let mut edges = Vec::with_capacity(target_edges);
    // class-conditional wiring: with prob `homophily`, endpoints share a class
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); n_classes];
    for (v, &l) in labels.iter().enumerate() {
        by_class[l as usize].push(v as u32);
    }
    for _ in 0..target_edges {
        let r = rng.range(0, n);
        let c = if rng.f64() < homophily {
            let peers = &by_class[labels[r] as usize];
            *rng.choose(peers)
        } else {
            rng.range(0, n) as u32
        };
        edges.push((r as u32, c, 1.0));
    }
    let csr = Csr::from_edges(n, n, &edges).unwrap().symmetrize();
    let features = centroid_features(&labels, n_classes, feat_dim, rng);
    LabeledGraph { csr, features, feat_dim, labels, n_classes }
}

/// Class-centroid features with Gaussian noise: per-class N(0,1)
/// centroids plus 0.8·N(0,1) per-node noise — what makes planted labels
/// learnable from features alone. Shared by [`labeled_communities`] and
/// the training datasets' label-planting paths
/// ([`crate::graph::datasets`]).
pub fn centroid_features(
    labels: &[u32],
    n_classes: usize,
    feat_dim: usize,
    rng: &mut Pcg,
) -> Vec<f32> {
    let mut centroids = vec![0f32; n_classes * feat_dim];
    for v in centroids.iter_mut() {
        *v = rng.normal() as f32;
    }
    let mut features = vec![0f32; labels.len() * feat_dim];
    for (v, &l) in labels.iter().enumerate() {
        for k in 0..feat_dim {
            features[v * feat_dim + k] =
                centroids[l as usize * feat_dim + k] + 0.8 * rng.normal() as f32;
        }
    }
    features
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn degree_sequence_sums_exactly() {
        let mut rng = Pcg::seed_from(1);
        for model in [
            DegreeModel::PowerLaw { alpha: 2.1, dmax_frac: 0.1 },
            DegreeModel::NearRegular { jitter: 0.2 },
            DegreeModel::LogNormal { sigma: 1.0 },
        ] {
            let degs = degree_sequence(model, 500, 3000, &mut rng);
            assert_eq!(degs.iter().sum::<usize>(), 3000, "{model:?}");
        }
    }

    #[test]
    fn power_law_has_heavy_tail() {
        let mut rng = Pcg::seed_from(2);
        let degs = degree_sequence(
            DegreeModel::PowerLaw { alpha: 2.0, dmax_frac: 0.25 },
            2000,
            20_000,
            &mut rng,
        );
        let avg = 10.0;
        let max = *degs.iter().max().unwrap() as f64;
        // paper Fig. 2: max degree tens of times the average
        assert!(max > 10.0 * avg, "max={max} avg={avg}");
    }

    #[test]
    fn near_regular_is_tight() {
        let mut rng = Pcg::seed_from(3);
        let degs = degree_sequence(DegreeModel::NearRegular { jitter: 0.1 }, 1000, 2080, &mut rng);
        let max = *degs.iter().max().unwrap();
        assert!(max <= 8, "molecular-style degrees should be tiny, max={max}");
    }

    #[test]
    fn from_degree_sequence_row_degrees_close() {
        let mut rng = Pcg::seed_from(4);
        let degs = vec![5usize; 100];
        let csr = from_degree_sequence(100, &degs, &mut rng);
        // duplicates merge then get padded back: total preserved
        assert_eq!(csr.nnz(), 500);
        assert_eq!(csr.n_rows, 100);
    }

    #[test]
    fn rmat_shape_and_determinism() {
        let mut r1 = Pcg::seed_from(5);
        let mut r2 = Pcg::seed_from(5);
        let a = rmat(8, 2000, (0.57, 0.19, 0.19), &mut r1);
        let b = rmat(8, 2000, (0.57, 0.19, 0.19), &mut r2);
        assert_eq!(a, b);
        assert_eq!(a.n_rows, 256);
        assert!(a.nnz() <= 2000 && a.nnz() > 1000); // duplicates merge
    }

    #[test]
    fn labeled_graph_learnable_structure() {
        let mut rng = Pcg::seed_from(6);
        let g = labeled_communities(300, 8.0, 16, 4, 0.8, &mut rng);
        assert_eq!(g.labels.len(), 300);
        assert_eq!(g.features.len(), 300 * 16);
        assert!(g.csr.nnz() > 0);
        // homophily: most edges intra-class
        let mut intra = 0usize;
        let mut total = 0usize;
        for r in 0..300 {
            for (c, _) in g.csr.row(r) {
                total += 1;
                if g.labels[r] == g.labels[c as usize] {
                    intra += 1;
                }
            }
        }
        assert!(intra as f64 > 0.6 * total as f64, "intra={intra}/{total}");
    }

    #[test]
    fn prop_generator_valid_csr() {
        proptest::check("generator_valid", 0x6E4, 15, |rng| {
            let n = rng.range(10, 300);
            let e = rng.range(n, 6 * n);
            let degs = degree_sequence(
                DegreeModel::PowerLaw { alpha: 2.2, dmax_frac: 0.3 },
                n,
                e,
                rng,
            );
            let csr = from_degree_sequence(n, &degs, rng);
            // structural validity
            assert_eq!(csr.row_ptr.len(), n + 1);
            assert!(csr.col_idx.iter().all(|&c| (c as usize) < n));
            // rows sorted & deduped
            for r in 0..n {
                let cols: Vec<u32> = csr.row(r).map(|(c, _)| c).collect();
                assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {r} not sorted/dedup");
            }
        });
    }
}
